#!/usr/bin/env python3
"""CI perf-regression gate over the BENCH_*.json records.

Diffs the current bench output (repo root, written by `cargo bench`) against
committed baselines in `baselines/`:

* `BENCH_hotpath.json` — **gating**: any per-row `median_ns` more than
  `--threshold` percent slower than baseline fails the build (exit 1).
  Rows are matched by name; rows present on only one side are reported
  but never gate (bench evolution must not need a baseline dance in the
  same PR).
* `BENCH_serving.json` — **informational**: the closed-loop router cells
  are too noisy on shared CI runners to gate, so the diff is printed
  (images_per_s and p99_ms per cell, plus pool notes) without failing.
* `BENCH_video.json` — **gating on medians**: any open-loop cell whose
  `p50_ms` is more than `--threshold` percent slower than baseline fails
  the build. Medians are robust to scheduler noise in a way the p99 tail
  is not, so p99 and deadline_miss deltas are printed report-only.

Missing files degrade to a skip-with-notice (exit 0): a fresh checkout has
no baselines until a toolchain host seeds them (see baselines/README.md),
and that must not block CI. A budget mismatch (baseline recorded under a
different BENCH_BUDGET_MS) downgrades the hotpath gate to report-only —
iteration counts differ too much for a fair comparison.

Stdlib only; no third-party imports.

Usage:
    python3 scripts/perf_gate.py [--threshold 15] [--current DIR] [--baseline DIR]
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load(path: str):
    """Parse one BENCH json, or None (with a notice) when absent/invalid."""
    if not os.path.exists(path):
        print(f"perf-gate: {os.path.relpath(path, REPO_ROOT)} not found — skipping")
        return None
    try:
        with open(path, encoding="utf-8") as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"perf-gate: cannot read {path}: {e} — skipping")
        return None


def rows_by_name(doc) -> dict:
    return {
        e["name"]: e
        for e in doc.get("entries", [])
        if isinstance(e, dict) and "name" in e
    }


def diff_hotpath(base, cur, threshold_pct: float, gate: bool) -> int:
    """Compare per-row median_ns; return the number of gating regressions."""
    base_rows, cur_rows = rows_by_name(base), rows_by_name(cur)
    regressions = 0
    print(f"\n== hotpath ({'gating' if gate else 'report-only'}, "
          f"threshold {threshold_pct:.0f}%) ==")
    for name, cur_row in cur_rows.items():
        base_row = base_rows.get(name)
        if base_row is None:
            print(f"  NEW      {name} (no baseline row)")
            continue
        b, c = base_row.get("median_ns"), cur_row.get("median_ns")
        if not b or not c:
            continue
        delta_pct = (c - b) / b * 100.0
        verdict = "ok"
        if delta_pct > threshold_pct:
            verdict = "REGRESSION" if gate else "regression (not gating)"
            if gate:
                regressions += 1
        print(f"  {verdict:<24} {name}: {b:.0f} ns -> {c:.0f} ns ({delta_pct:+.1f}%)")
    for name in base_rows.keys() - cur_rows.keys():
        print(f"  GONE     {name} (baseline row has no current counterpart)")
    return regressions


def diff_serving(base, cur) -> None:
    """Report-only diff of the closed-loop cells and pool notes."""
    base_rows, cur_rows = rows_by_name(base), rows_by_name(cur)
    print("\n== serving (informational) ==")
    for name, cur_row in sorted(cur_rows.items()):
        base_row = base_rows.get(name)
        for key in ("images_per_s", "p99_ms"):
            b = (base_row or {}).get(key)
            c = cur_row.get(key)
            if b and c:
                print(f"  {name}.{key}: {b:.2f} -> {c:.2f} ({(c - b) / b * 100.0:+.1f}%)")
    for key in ("pool_workers", "pool_pinned", "pool_lanes", "pool_steals"):
        b = base.get("derived", {}).get(key)
        c = cur.get("derived", {}).get(key)
        if c is not None:
            print(f"  derived.{key}: {b} -> {c}")


def diff_video(base, cur, threshold_pct: float, gate: bool) -> int:
    """Gate the open-loop video cells on p50_ms; report the tail columns."""
    base_rows, cur_rows = rows_by_name(base), rows_by_name(cur)
    regressions = 0
    print(f"\n== video ({'gating on p50_ms' if gate else 'report-only'}, "
          f"threshold {threshold_pct:.0f}%) ==")
    for name, cur_row in sorted(cur_rows.items()):
        base_row = base_rows.get(name)
        if base_row is None:
            print(f"  NEW      {name} (no baseline row)")
            continue
        b, c = base_row.get("p50_ms"), cur_row.get("p50_ms")
        if b and c:
            delta_pct = (c - b) / b * 100.0
            verdict = "ok"
            if delta_pct > threshold_pct:
                verdict = "REGRESSION" if gate else "regression (not gating)"
                if gate:
                    regressions += 1
            print(f"  {verdict:<24} {name}.p50_ms: {b:.2f} -> {c:.2f} ({delta_pct:+.1f}%)")
        for key in ("p99_ms", "deadline_miss"):
            b_t, c_t = base_row.get(key), cur_row.get(key)
            if b_t is not None and c_t is not None:
                print(f"  info                     {name}.{key}: {b_t:.2f} -> {c_t:.2f}")
    for name in base_rows.keys() - cur_rows.keys():
        print(f"  GONE     {name} (baseline row has no current counterpart)")
    return regressions


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--threshold", type=float,
                    default=float(os.environ.get("PERF_GATE_PCT", "15")),
                    help="max tolerated hot-path slowdown, percent (default 15)")
    ap.add_argument("--current", default=REPO_ROOT,
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--baseline", default=os.path.join(REPO_ROOT, "baselines"),
                    help="directory holding the committed baselines")
    args = ap.parse_args()

    failures = 0
    compared_any = False

    base = load(os.path.join(args.baseline, "BENCH_hotpath.json"))
    cur = load(os.path.join(args.current, "BENCH_hotpath.json"))
    if base is not None and cur is not None:
        compared_any = True
        gate = base.get("budget_ms") == cur.get("budget_ms")
        if not gate:
            print(f"perf-gate: budget mismatch (baseline {base.get('budget_ms')} ms, "
                  f"current {cur.get('budget_ms')} ms) — hotpath gate downgraded "
                  f"to report-only")
        failures += diff_hotpath(base, cur, args.threshold, gate)

    base_s = load(os.path.join(args.baseline, "BENCH_serving.json"))
    cur_s = load(os.path.join(args.current, "BENCH_serving.json"))
    if base_s is not None and cur_s is not None:
        compared_any = True
        diff_serving(base_s, cur_s)

    base_v = load(os.path.join(args.baseline, "BENCH_video.json"))
    cur_v = load(os.path.join(args.current, "BENCH_video.json"))
    if base_v is not None and cur_v is not None:
        compared_any = True
        gate_v = base_v.get("budget_ms") == cur_v.get("budget_ms")
        if not gate_v:
            print(f"perf-gate: budget mismatch (baseline {base_v.get('budget_ms')} ms, "
                  f"current {cur_v.get('budget_ms')} ms) — video gate downgraded "
                  f"to report-only")
        failures += diff_video(base_v, cur_v, args.threshold, gate_v)

    if not compared_any:
        print("perf-gate: nothing to compare (no baselines committed yet) — pass")
        return 0
    if failures:
        print(f"\nperf-gate: FAIL — {failures} gating row(s) (hotpath p50 / "
              f"video p50_ms) regressed beyond {args.threshold:.0f}%")
        return 1
    print("\nperf-gate: pass")
    return 0


if __name__ == "__main__":
    sys.exit(main())
