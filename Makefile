# Convenience targets (see README.md for the full quickstart).

.PHONY: artifacts test serve-bench detect-bench chaos-bench video-bench perf-gate clean

# Lower the per-scale JAX/Pallas graphs to HLO text in artifacts/ — the
# `make artifacts` step referenced throughout the docs. Requires JAX;
# aot.py's --out-dir defaults to ../artifacts (the repo root).
artifacts:
	cd python && python3 -m compile.aot

# Tier-1 verify plus the Python kernel-parity suite.
test:
	cargo build --release
	cargo test -q
	cd python && python3 -m pytest tests -q

# Closed-loop serving benchmark over every (policy x shard-count) cell;
# writes BENCH_serving.json at the repo root (EXPERIMENTS.md §Serving).
serve-bench:
	cargo bench --bench serve_bench

# Quality bench: Fig.5 curves + served-cascade recall-at-k; writes
# BENCH_detect.json at the repo root (EXPERIMENTS.md §Detections).
detect-bench:
	cargo bench --bench fig5_quality

# Robustness bench: fault rate x retry policy sweep plus quarantine,
# brownout, and SDC cells (corruption containment, corrupt-shard
# quarantine, hang containment, golden-probe audit); writes
# BENCH_chaos.json (EXPERIMENTS.md §Robustness and §Integrity).
chaos-bench:
	cargo bench --bench chaos_bench

# Open-loop video serving benchmark: trace-paced Poisson/bursty arrivals,
# full recompute vs the dirty-tile incremental path; writes
# BENCH_video.json at the repo root (EXPERIMENTS.md §Video).
video-bench:
	cargo bench --bench video_bench

# Diff fresh BENCH_hotpath/serving.json against baselines/ — fails on a
# >15% hot-path median regression (skips when baselines are absent).
perf-gate:
	python3 scripts/perf_gate.py

clean:
	cargo clean
	rm -rf artifacts
