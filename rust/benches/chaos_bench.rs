//! Robustness benchmark: closed-loop serving over a fault-injecting
//! [`ChaosBackend`], sweeping fault rate × retry policy, plus one
//! quarantine cell (a permanently poisoned shard that must trip the
//! breaker) and one brownout cell (pressure thresholds forced low so the
//! shedding path fires). Reports per-cell success/failure counts, retry
//! and injection tallies, and p50/p99 latency of the survivors — the cost
//! of resilience measured at the serving layer.
//!
//! Bit-parity is asserted inside the cells themselves: every successful
//! chaos-cell response is compared against the fault-free
//! `SoftwareBing::propose` oracle, so the bench doubles as an end-to-end
//! robustness check (CI smoke-runs it under `BENCH_BUDGET_MS`).
//!
//! Emits `BENCH_chaos.json` at the repo root (field dictionary in
//! EXPERIMENTS.md §Robustness).
//!
//! ```bash
//! cargo bench --bench chaos_bench            # or: make chaos-bench
//! ```

#[path = "harness.rs"]
mod harness;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Proposal, Pyramid};
use bingflow::config::{IntegrityConfig, ResilienceConfig, RoutePolicyKind, ServingConfig};
use bingflow::coordinator::ProposalRequest;
use bingflow::data::SyntheticDataset;
use bingflow::fault::{ChaosBackend, FaultPlan};
use bingflow::image::ImageRgb;
use bingflow::serving::ServerRuntime;
use bingflow::simd::KernelChoice;
use bingflow::svm::Stage2Calibration;

const TOP_K: usize = 100;
const CLIENTS: usize = 4;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32)]
}

fn software() -> Arc<SoftwareBing> {
    Arc::new(SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    ))
}

fn plan(seed: u64, fault_p: f64) -> FaultPlan {
    // split the budget 40/60 between panics (worker loss) and transients
    FaultPlan {
        panic_p: fault_p * 0.4,
        transient_p: fault_p * 0.6,
        ..FaultPlan::zero(seed)
    }
}

/// Latency percentile from a sorted sample (conservative upper pick).
fn pct(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() as f64 * q).ceil() as usize)
        .clamp(1, sorted_ms.len())
        - 1;
    sorted_ms[idx]
}

struct CellResult {
    ok: u64,
    failed: u64,
    retries: u64,
    injected: u64,
    p50_ms: f64,
    p99_ms: f64,
    images_per_s: f64,
}

/// Closed-loop client fleet over a prepared runtime; successes must be
/// bit-identical to `expected` for their image.
fn drive(
    runtime: &ServerRuntime<ChaosBackend<SoftwareBing>>,
    images: &[ImageRgb],
    expected: &[Vec<Proposal>],
    check_parity: bool,
) -> (u64, u64, Vec<f64>, f64) {
    let next = AtomicUsize::new(0);
    let ok = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(images.len()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..CLIENTS {
            let next = &next;
            let ok = &ok;
            let failed = &failed;
            let latencies = &latencies;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= images.len() {
                    break;
                }
                let t = Instant::now();
                match runtime.serve(ProposalRequest::new(images[i].clone())) {
                    Ok(resp) => {
                        if check_parity {
                            assert_eq!(
                                resp.items, expected[i],
                                "chaos survivor diverged from the fault-free oracle"
                            );
                        }
                        ok.fetch_add(1, Ordering::Relaxed);
                        latencies
                            .lock()
                            .unwrap()
                            .push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    Err(_) => {
                        failed.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let mut lat = latencies.into_inner().unwrap();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (
        ok.load(Ordering::Relaxed) as u64,
        failed.load(Ordering::Relaxed) as u64,
        lat,
        wall_s,
    )
}

/// One (fault rate × retry budget) sweep cell.
fn run_cell(
    fault_p: f64,
    retries_budget: u32,
    images: &[ImageRgb],
    expected: &[Vec<Proposal>],
) -> CellResult {
    let chaos = Arc::new(ChaosBackend::new(software(), plan(42, fault_p)));
    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::new(
        chaos.clone(),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards: 2,
            workers: 2,
            top_k: TOP_K,
            resilience: ResilienceConfig {
                retry_max_attempts: retries_budget + 1,
                retry_backoff_ms: 0,
                // the sweep isolates the retry axis: both shards share one
                // chaos backend, so keep the breaker out of the picture
                quarantine_failures: usize::MAX,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let (ok, failed, lat, wall_s) = drive(&runtime, images, expected, true);
    let result = CellResult {
        ok,
        failed,
        retries: runtime.metrics.retries.get(),
        injected: chaos.injected_total(),
        p50_ms: pct(&lat, 0.50),
        p99_ms: pct(&lat, 0.99),
        images_per_s: ok as f64 / wall_s.max(1e-9),
    };
    runtime.shutdown();
    result
}

/// One corruption sweep cell: scale outputs are corrupted at `corrupt_p`,
/// structural validation (on by default) must catch every injection, and
/// the retry budget turns containment back into successful responses.
/// `drive`'s bit-parity assertion *is* the zero-escape check — a corrupted
/// payload reaching a client aborts the bench.
fn run_corrupt_cell(
    corrupt_p: f64,
    retries_budget: u32,
    images: &[ImageRgb],
    expected: &[Vec<Proposal>],
) -> (CellResult, u64) {
    let chaos = Arc::new(ChaosBackend::new(
        software(),
        FaultPlan { corrupt_p, ..FaultPlan::zero(42) },
    ));
    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::new(
        chaos.clone(),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards: 2,
            workers: 2,
            top_k: TOP_K,
            resilience: ResilienceConfig {
                retry_max_attempts: retries_budget + 1,
                retry_backoff_ms: 0,
                quarantine_failures: usize::MAX,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let (ok, failed, lat, wall_s) = drive(&runtime, images, expected, true);
    let violations = runtime.metrics.integrity_violations.get();
    let injected = chaos.injected_corrupts.get();
    assert!(
        violations >= injected,
        "validation missed injected corruption ({injected} injected, {violations} caught)"
    );
    let result = CellResult {
        ok,
        failed,
        retries: runtime.metrics.retries.get(),
        injected: chaos.injected_total(),
        p50_ms: pct(&lat, 0.50),
        p99_ms: pct(&lat, 0.99),
        images_per_s: ok as f64 / wall_s.max(1e-9),
    };
    runtime.shutdown();
    (result, violations)
}

fn main() {
    let budget_ms = harness::budget().as_millis() as usize;
    let n_images = (budget_ms / 4).clamp(8, 256);
    let ds = SyntheticDataset::voc_like_val(4);
    let images: Vec<ImageRgb> = (0..n_images).map(|i| ds.sample(i % 4).image).collect();
    let reference = software();
    let expected: Vec<Vec<Proposal>> =
        images.iter().map(|img| reference.propose(img, TOP_K)).collect();

    let mut json = harness::JsonReport::new("chaos");
    json.note("images_per_cell", n_images as f64);
    json.note("clients", CLIENTS as f64);

    println!("\n=== chaos_bench — fault rate x retry policy ===");
    println!(
        "{:<22} {:>6} {:>6} {:>8} {:>9} {:>10} {:>10}",
        "cell", "ok", "fail", "retries", "injected", "p50", "p99"
    );
    let mut total_retries = 0u64;
    for &fault_p in &[0.0f64, 0.05, 0.15] {
        for &retries_budget in &[0u32, 1, 2] {
            let cell = run_cell(fault_p, retries_budget, &images, &expected);
            let label = format!("fault{:.2}_retry{}", fault_p, retries_budget);
            println!(
                "{label:<22} {:>6} {:>6} {:>8} {:>9} {:>7.2} ms {:>7.2} ms",
                cell.ok, cell.failed, cell.retries, cell.injected, cell.p50_ms, cell.p99_ms
            );
            total_retries += cell.retries;
            json.record_fields(
                &label,
                &[
                    ("fault_p", fault_p),
                    ("retry_budget", retries_budget as f64),
                    ("images", n_images as f64),
                    ("ok", cell.ok as f64),
                    ("failed", cell.failed as f64),
                    ("retries", cell.retries as f64),
                    ("injected_faults", cell.injected as f64),
                    ("p50_ms", cell.p50_ms),
                    ("p99_ms", cell.p99_ms),
                    ("images_per_s", cell.images_per_s),
                ],
            );
            // fault-free cells are the control: nothing may fail or retry
            if fault_p == 0.0 {
                assert_eq!(cell.failed, 0, "control cell failed requests");
                assert_eq!(cell.retries, 0, "control cell retried");
                assert_eq!(cell.injected, 0, "control cell injected faults");
            }
        }
    }

    // corruption sweep: silent-data-corruption injections must be caught by
    // structural validation (zero escapes — parity-asserted in drive) and
    // recovered by retries
    println!("\n=== chaos_bench — corruption containment ===");
    for &corrupt_p in &[0.05f64, 0.25] {
        let (cell, violations) = run_corrupt_cell(corrupt_p, 3, &images, &expected);
        let label = format!("corrupt{corrupt_p:.2}_retry3");
        println!(
            "{label:<22} {:>6} {:>6} {:>8} {:>9} {:>7.2} ms {:>7.2} ms  (violations {})",
            cell.ok, cell.failed, cell.retries, cell.injected, cell.p50_ms, cell.p99_ms, violations
        );
        total_retries += cell.retries;
        json.record_fields(
            &label,
            &[
                ("corrupt_p", corrupt_p),
                ("images", n_images as f64),
                ("ok", cell.ok as f64),
                ("failed", cell.failed as f64),
                ("retries", cell.retries as f64),
                ("injected_faults", cell.injected as f64),
                ("integrity_violations", violations as f64),
                // asserted by drive(): every surviving response was
                // bit-identical to the fault-free oracle
                ("corrupt_escapes", 0.0),
                ("p50_ms", cell.p50_ms),
                ("p99_ms", cell.p99_ms),
                ("images_per_s", cell.images_per_s),
            ],
        );
    }

    // quarantine cell: shard 1 panics on every call; the breaker must trip
    // while failover keeps every request succeeding bit-identically
    let clean = Arc::new(ChaosBackend::new(software(), plan(7, 0.0)));
    let poisoned = Arc::new(ChaosBackend::new(
        software(),
        FaultPlan { panic_p: 1.0, ..plan(8, 0.0) },
    ));
    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::from_backends(
        vec![clean, poisoned],
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            workers: 2,
            top_k: TOP_K,
            policy: RoutePolicyKind::RoundRobin,
            resilience: ResilienceConfig {
                retry_max_attempts: 4,
                retry_backoff_ms: 0,
                supervisor_window: 8,
                degrade_failures: 2,
                quarantine_failures: 3,
                quarantine_cooldown_ms: 60_000,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let (ok, failed, lat, _) = drive(&runtime, &images, &expected, true);
    let quarantined = runtime.metrics.shards_quarantined.get();
    assert!(quarantined >= 1, "poisoned shard never tripped the breaker");
    assert_eq!(failed, 0, "failover must absorb a single poisoned shard");
    println!(
        "{:<22} {:>6} {:>6} {:>8} {:>9} {:>7.2} ms {:>7.2} ms  (quarantined {})",
        "poisoned_shard",
        ok,
        failed,
        runtime.metrics.retries.get(),
        "-",
        pct(&lat, 0.50),
        pct(&lat, 0.99),
        quarantined
    );
    json.record_fields(
        "poisoned_shard",
        &[
            ("images", n_images as f64),
            ("ok", ok as f64),
            ("failed", failed as f64),
            ("retries", runtime.metrics.retries.get() as f64),
            ("shards_quarantined", quarantined as f64),
            ("p50_ms", pct(&lat, 0.50)),
            ("p99_ms", pct(&lat, 0.99)),
        ],
    );
    total_retries += runtime.metrics.retries.get();
    runtime.shutdown();

    // corrupt-shard cell: shard 1 corrupts every output; with corruption
    // outcomes weighted CORRUPT_WEIGHT× against the breaker, one window's
    // worth of garbage quarantines it while failover keeps every request
    // succeeding bit-identically
    let clean = Arc::new(ChaosBackend::new(software(), plan(17, 0.0)));
    let corrupting = Arc::new(ChaosBackend::new(
        software(),
        FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(18) },
    ));
    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::from_backends(
        vec![clean, corrupting],
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            workers: 2,
            top_k: TOP_K,
            policy: RoutePolicyKind::RoundRobin,
            resilience: ResilienceConfig {
                retry_max_attempts: 4,
                retry_backoff_ms: 0,
                supervisor_window: 8,
                degrade_failures: 2,
                quarantine_failures: 4,
                quarantine_cooldown_ms: 60_000,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let (ok, failed, lat, _) = drive(&runtime, &images, &expected, true);
    let quarantined = runtime.metrics.shards_quarantined.get();
    let violations = runtime.metrics.integrity_violations.get();
    assert!(quarantined >= 1, "corrupting shard never tripped the breaker");
    assert_eq!(failed, 0, "failover must absorb a single corrupting shard");
    println!(
        "{:<22} {:>6} {:>6} {:>8} {:>9} {:>7.2} ms {:>7.2} ms  (quarantined {})",
        "corrupt_shard",
        ok,
        failed,
        runtime.metrics.retries.get(),
        violations,
        pct(&lat, 0.50),
        pct(&lat, 0.99),
        quarantined
    );
    json.record_fields(
        "corrupt_shard",
        &[
            ("images", n_images as f64),
            ("ok", ok as f64),
            ("failed", failed as f64),
            ("retries", runtime.metrics.retries.get() as f64),
            ("integrity_violations", violations as f64),
            ("corrupt_escapes", 0.0),
            ("shards_quarantined", quarantined as f64),
            ("p50_ms", pct(&lat, 0.50)),
            ("p99_ms", pct(&lat, 0.99)),
        ],
    );
    total_retries += runtime.metrics.retries.get();
    runtime.shutdown();

    // hang cell: injected hangs wedge workers for far longer than the
    // request budget; the serving layer must contain each hit near the
    // deadline, reap the wedged worker, and keep serving on replacements
    let hang_images: Vec<ImageRgb> = images.iter().take(10).cloned().collect();
    let deadline_ms = 100u64;
    let chaos = Arc::new(ChaosBackend::new(
        software(),
        FaultPlan {
            hang_p: 0.5,
            hang: Duration::from_millis(400),
            ..FaultPlan::zero(23)
        },
    ));
    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::new(
        chaos.clone(),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards: 1,
            workers: 2,
            top_k: TOP_K,
            deadline_ms: Some(deadline_ms),
            ..Default::default()
        },
    );
    let (mut h_ok, mut h_failed) = (0u64, 0u64);
    let mut max_request_ms = 0f64;
    for (i, img) in hang_images.iter().enumerate() {
        let t = Instant::now();
        let result = runtime.serve(ProposalRequest::new(img.clone()));
        let ms = t.elapsed().as_secs_f64() * 1e3;
        max_request_ms = max_request_ms.max(ms);
        assert!(
            ms < (deadline_ms * 4) as f64,
            "request {i} escaped deadline containment: {ms:.1} ms against a {deadline_ms} ms budget"
        );
        match result {
            Ok(resp) => {
                assert_eq!(resp.items, expected[i], "hang-cell survivor diverged");
                h_ok += 1;
            }
            Err(_) => h_failed += 1,
        }
    }
    let wedged = runtime.metrics.workers_wedged.get();
    if h_failed > 0 {
        assert!(wedged >= 1, "deadline misses without a single reaped worker");
    }
    println!(
        "{:<22} {:>6} {:>6} {:>8} {:>9} max {:>7.2} ms  (wedged {}, hangs {})",
        "hang0.50",
        h_ok,
        h_failed,
        "-",
        chaos.injected_hangs.get(),
        max_request_ms,
        wedged,
        chaos.injected_hangs.get()
    );
    json.record_fields(
        "hang0.50",
        &[
            ("hang_p", 0.5),
            ("deadline_ms", deadline_ms as f64),
            ("images", hang_images.len() as f64),
            ("ok", h_ok as f64),
            ("failed", h_failed as f64),
            ("injected_hangs", chaos.injected_hangs.get() as f64),
            ("workers_wedged", wedged as f64),
            ("max_request_ms", max_request_ms),
        ],
    );
    runtime.shutdown();

    // audit cell: golden probes over a clean fleet — every sampled request
    // re-executes through the scalar oracle and must match bitwise, so
    // mismatches and demotions both stay at zero
    let chaos = Arc::new(ChaosBackend::new(software(), plan(29, 0.0)));
    let mut runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::new(
        chaos,
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards: 2,
            workers: 2,
            top_k: TOP_K,
            integrity: IntegrityConfig { audit_rate: 2, ..Default::default() },
            ..Default::default()
        },
    );
    runtime.install_auditor(software(), KernelChoice::Auto.resolve());
    let (ok, failed, _, _) = drive(&runtime, &images, &expected, true);
    let audits = runtime.metrics.audits_run.get();
    let mismatches = runtime.metrics.audit_mismatches.get();
    let demotions = runtime.metrics.kernel_demotions.get();
    assert!(audits >= 1, "audit cell sampled nothing at rate 2");
    assert_eq!(mismatches, 0, "clean fleet must never mismatch its golden probe");
    assert_eq!(demotions, 0, "clean fleet must never demote its kernel");
    println!(
        "{:<22} {:>6} {:>6} audits {} mismatches {} demotions {}",
        "audited_clean", ok, failed, audits, mismatches, demotions
    );
    json.record_fields(
        "audited_clean",
        &[
            ("audit_rate", 2.0),
            ("images", n_images as f64),
            ("ok", ok as f64),
            ("failed", failed as f64),
            ("audits_run", audits as f64),
            ("audit_mismatches", mismatches as f64),
            ("kernel_demotions", demotions as f64),
        ],
    );
    runtime.shutdown();

    // brownout cell: thresholds forced to the floor so concurrent load
    // trips the shedding path (downgraded, not rejected)
    let chaos = Arc::new(ChaosBackend::new(software(), plan(9, 0.0)));
    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::new(
        chaos,
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards: 1,
            workers: 2,
            top_k: TOP_K,
            resilience: ResilienceConfig {
                brownout: true,
                brownout_queue_depth: 1,
                brownout_top_k: 20,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    // downgraded responses are intentionally not bit-identical to the
    // full-fidelity oracle — parity checking is off for this cell
    let (ok, failed, _, _) = drive(&runtime, &images, &expected, false);
    let downgrades = runtime.metrics.brownout_downgrades.get();
    println!(
        "{:<22} {:>6} {:>6} {:>8} downgrades {}",
        "brownout", ok, failed, "-", downgrades
    );
    json.record_fields(
        "brownout",
        &[
            ("images", n_images as f64),
            ("ok", ok as f64),
            ("failed", failed as f64),
            ("brownout_downgrades", downgrades as f64),
        ],
    );
    runtime.shutdown();

    json.note("total_retries", total_retries as f64);
    json.write_and_announce();
}
