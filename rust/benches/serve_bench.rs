//! Closed-loop serving benchmark: a client fleet drives the sharded
//! `ServerRuntime` over every (policy × shard-count) cell and reports p50 /
//! p99 request latency plus images/s — the scalability claim measured at
//! the serving layer, the way the paper measures pipeline replication.
//!
//! Closed loop: each client submits one request, waits for the response,
//! then immediately submits the next — offered load tracks capacity, so
//! the numbers compare *policies and shard counts*, not queue explosions.
//! The workload mixes small (96×96) and large (192×192) frames so the
//! `affinity` policy actually splits traffic across its shard groups.
//!
//! Methodology caveat: every cell shares the process-wide worker pool,
//! which starts at the machine's default parallelism and never shrinks —
//! so the shard axis varies *routing and per-shard admission* (queue
//! boundaries, policy placement, drain surface), not raw execution
//! parallelism. The pool size is recorded as `pool_threads` in the JSON
//! so readers can interpret the cells.
//!
//! Emits `BENCH_serving.json` at the repo root (field dictionary in
//! EXPERIMENTS.md §Serving). Budget honours `BENCH_BUDGET_MS` — CI smoke
//! runs it with a few milliseconds so bench bitrot fails the build.
//!
//! ```bash
//! cargo bench --bench serve_bench            # or: make serve-bench
//! ```

#[path = "harness.rs"]
mod harness;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::{RoutePolicyKind, ServingConfig};
use bingflow::data::{SceneConfig, SyntheticDataset};
use bingflow::image::ImageRgb;
use bingflow::serving::ServerRuntime;
use bingflow::svm::Stage2Calibration;

const TOP_K: usize = 100;
const CLIENTS: usize = 4;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32)]
}

fn software() -> Arc<SoftwareBing> {
    Arc::new(SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    ))
}

/// Alternating small/large frames (affinity-relevant size mix).
fn workload(n: usize) -> Vec<ImageRgb> {
    let small = SyntheticDataset::new(
        SceneConfig { width: 96, height: 96, ..Default::default() },
        2007,
        4,
    );
    let large = SyntheticDataset::voc_like_val(4);
    (0..n)
        .map(|i| {
            // (i / 2) % 4 walks all four samples of each split; i % 4 would
            // pin evens to {0, 2} and odds to {1, 3}
            if i % 2 == 0 {
                small.sample((i / 2) % 4).image
            } else {
                large.sample((i / 2) % 4).image
            }
        })
        .collect()
}

/// Latency percentile from a sorted sample (conservative upper pick).
fn pct(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() as f64 * q).ceil() as usize)
        .clamp(1, sorted_ms.len())
        - 1;
    sorted_ms[idx]
}

struct CellResult {
    wall_s: f64,
    images_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
}

/// Drive one (policy, shards) cell with a closed-loop client fleet.
fn run_cell(policy: RoutePolicyKind, shards: usize, images: &[ImageRgb]) -> CellResult {
    let runtime: ServerRuntime<SoftwareBing> = ServerRuntime::new(
        software(),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards,
            policy,
            workers: 2,
            top_k: TOP_K,
            ..Default::default()
        },
    );

    let next = AtomicUsize::new(0);
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(images.len()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..CLIENTS {
            let runtime = &runtime;
            let next = &next;
            let latencies = &latencies;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= images.len() {
                    break;
                }
                let resp = runtime
                    .submit(images[i].clone())
                    .expect("bench runtime admits every request")
                    .wait()
                    .expect("bench request resolves");
                latencies
                    .lock()
                    .unwrap()
                    .push(resp.latency.as_secs_f64() * 1e3);
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    runtime.shutdown();

    let mut lat = latencies.into_inner().unwrap();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    CellResult {
        wall_s,
        images_per_s: images.len() as f64 / wall_s.max(1e-9),
        p50_ms: pct(&lat, 0.50),
        p99_ms: pct(&lat, 0.99),
    }
}

fn main() {
    // scale the per-cell workload with the budget: the 15 ms CI smoke run
    // serves a handful of images per cell, a full run a few hundred
    let budget_ms = harness::budget().as_millis() as usize;
    let n_images = (budget_ms / 4).clamp(8, 256);
    let images = workload(n_images);

    // bit-identity: the routed runtime must reproduce the serial baseline
    // (cheap spot check on every bench run, mirroring the hotpath bench;
    // workers/shards kept at the sweep's own floor so the never-shrinking
    // global pool is not pre-grown past what the cells request)
    {
        let rt: ServerRuntime<SoftwareBing> = ServerRuntime::new(
            software(),
            Stage2Calibration::identity(sizes()),
            ServingConfig { shards: 1, workers: 2, top_k: TOP_K, ..Default::default() },
        );
        let want = software().propose(&images[0], TOP_K);
        let got = rt.submit(images[0].clone()).unwrap().wait().unwrap();
        assert_eq!(got.items, want, "sharded serving diverged from the baseline");
        rt.shutdown();
    }

    let policies = [
        RoutePolicyKind::RoundRobin,
        RoutePolicyKind::LeastLoaded,
        RoutePolicyKind::ScaleAffinity,
    ];
    let shard_counts = [1usize, 2, 4];

    let mut json = harness::JsonReport::new("serving");
    json.note("images_per_cell", n_images as f64);
    json.note("clients", CLIENTS as f64);
    json.note(
        "pool_threads",
        bingflow::util::pool::global().threads() as f64,
    );
    println!("\n=== serve_bench — closed-loop router benchmark ===");
    println!(
        "{:<18} {:>7} {:>12} {:>12} {:>12}",
        "policy x shards", "images", "p50", "p99", "rate"
    );

    let mut best_rate = 0.0f64;
    for &shards in &shard_counts {
        for &policy in &policies {
            let cell = run_cell(policy, shards, &images);
            let label = format!("{}_s{}", policy.name(), shards);
            println!(
                "{label:<18} {:>7} {:>9.2} ms {:>9.2} ms {:>9.1}/s",
                n_images, cell.p50_ms, cell.p99_ms, cell.images_per_s
            );
            json.record_fields(
                &label,
                &[
                    ("shards", shards as f64),
                    ("images", n_images as f64),
                    ("wall_s", cell.wall_s),
                    ("images_per_s", cell.images_per_s),
                    ("p50_ms", cell.p50_ms),
                    ("p99_ms", cell.p99_ms),
                ],
            );
            best_rate = best_rate.max(cell.images_per_s);
        }
    }
    json.note("best_images_per_s", best_rate);
    // pool scheduling telemetry (PR 8): how many workers got a core pin,
    // how many shard lanes were installed, and how often idle workers stole
    // from hot shards across the whole sweep
    let pool = bingflow::util::pool::global().stats();
    json.note("pool_workers", pool.workers as f64);
    json.note("pool_pinned", pool.pinned as f64);
    json.note("pool_lanes", pool.lanes as f64);
    json.note("pool_steals", pool.steals as f64);
    println!(
        "pool: workers={} pinned={} lanes={} steals={}",
        pool.workers, pool.pinned, pool.lanes, pool.steals
    );
    json.write_and_announce();
}
