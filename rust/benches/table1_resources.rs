//! E1 — regenerate **Table 1**: FPGA resource utilization on Artix-7 LV and
//! Kintex UltraScale+, from the parametric resource model (DESIGN.md §S9).
//!
//! Run: `cargo bench --bench table1_resources`

#[path = "harness.rs"]
mod harness;

use bingflow::config::{AcceleratorConfig, Device};
use bingflow::dataflow::{resource_estimate, Resources, WorkloadGeometry};

/// Paper Table 1, "Utilized" columns, for the delta report.
const PAPER: [(&str, [u64; 5]); 2] = [
    ("Artix-7 Low Volt. @ 3.3MHz", [54_453, 4_166, 48_611, 135, 25]),
    ("Kintex UltraScale+ @ 100MHz", [56_504, 3_157, 50_079, 146, 25]),
];

fn main() {
    println!("Table 1: FPGA resource utilization (model vs paper)");
    println!(
        "{:<30} {:>9} {:>9} {:>9} {:>9} {:>6} {:>6}",
        "target", "LUT", "LUT-RAM", "FF", "BRAM", "DSP", "BUF-G"
    );
    let wl = WorkloadGeometry::paper();
    for (device, paper_row) in [
        (Device::Artix7LowVolt, PAPER[0]),
        (Device::KintexUltraScalePlus, PAPER[1]),
    ] {
        let cfg = AcceleratorConfig {
            pipelines: 4,
            heap_capacity: 1000,
            nms_fifo_depth: 64,
            ping_pong: true,
            device,
            ..Default::default()
        };
        let est = resource_estimate(&cfg, &wl);
        let avail = Resources::available(device);
        println!(
            "{:<30} {:>9} {:>9} {:>9} {:>9} {:>6} {:>6}   <- model",
            device.name(),
            est.lut,
            est.lutram,
            est.ff,
            est.bram36,
            est.dsp,
            est.bufg
        );
        let [lut, lutram, ff, bram, dsp] = paper_row.1;
        let paper_bufg = if device == Device::KintexUltraScalePlus { 8 } else { 0 };
        println!(
            "{:<30} {:>9} {:>9} {:>9} {:>9} {:>6} {:>6}   <- paper",
            "", lut, lutram, ff, bram, dsp, paper_bufg
        );
        println!(
            "{:<30} {:>9} {:>9} {:>9} {:>9} {:>6}        <- available",
            "", avail.lut, avail.lutram, avail.ff, avail.bram36, avail.dsp
        );
        for (name, pct) in est.percent_of(device) {
            print!("  {name} {pct:.1}%");
        }
        println!("\n");
    }

    // model evaluation speed (it runs inside config sweeps)
    harness::header("resource model throughput");
    let cfg = AcceleratorConfig::default();
    let stats = harness::bench(|| {
        harness::black_box(resource_estimate(&cfg, &wl));
    });
    harness::report("resource_estimate(paper workload)", &stats);
}
