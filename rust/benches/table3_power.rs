//! E3 — regenerate **Table 3**: total/dynamic power and proposal speed on
//! both FPGA targets, from the cycle simulator + calibrated power model.
//!
//! Run: `cargo bench --bench table3_power`

#[path = "harness.rs"]
mod harness;

use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::{AcceleratorConfig, Device};
use bingflow::data::{SceneConfig, SyntheticDataset};
use bingflow::dataflow::{power_estimate, Accelerator};

fn main() {
    let ladder = [10usize, 20, 40, 80, 160, 320];
    let pyramid = Pyramid::new(
        ladder
            .iter()
            .flat_map(|&h| ladder.iter().map(move |&w| (h, w)))
            .collect(),
    );
    let ds = SyntheticDataset::new(
        SceneConfig { width: 500, height: 375, ..Default::default() },
        2007,
        1,
    );
    let img = ds.sample(0).image;

    let accel = Accelerator::new(
        AcceleratorConfig { pipelines: 4, heap_capacity: 1000, ..Default::default() },
        pyramid,
        default_stage1(),
    );

    // simulate once (deterministic); also time the simulator itself
    let report = accel.run_image(&img);
    harness::header("cycle simulator throughput");
    let stats = harness::bench(|| {
        harness::black_box(accel.run_image(&img));
    });
    harness::report("simulate full paper pyramid (36 scales)", &stats);
    println!(
        "sim speed: {:.1} Mcycles/s",
        report.total_cycles as f64 / stats.median.as_secs_f64() / 1e6
    );

    println!("\nTable 3: power and speed ({} cycles/image, activity {:.3})",
        report.total_cycles, report.activity);
    println!(
        "{:<30} {:>10} {:>10} {:>10}",
        "target", "P_tot", "P_dyn", "Speed"
    );
    let paper = [
        (Device::Artix7LowVolt, "97mW", "15mW", "35fps"),
        (Device::KintexUltraScalePlus, "821mW", "350mW", "1100fps"),
    ];
    for (device, p_tot, p_dyn, speed) in paper {
        let power = power_estimate(device, report.activity);
        let fps = report.fps(device.clock_hz()).expect("simulation ran cycles");
        println!(
            "{:<30} {:>8.0}mW {:>8.0}mW {:>7.1}fps   <- model",
            device.name(),
            power.total_mw(),
            power.dynamic_mw,
            fps
        );
        println!(
            "{:<30} {:>10} {:>10} {:>10}   <- paper",
            "", p_tot, p_dyn, speed
        );
    }
}
