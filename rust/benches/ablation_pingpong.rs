//! E5 — ablation of the **Ping-Pong cache** (paper §3.2, Fig. 3): with two
//! cache lanes the kernel pipelines receive a continuous batch stream; with
//! a single lane the stream stalls during every refill.
//!
//! Also sweeps the NMS FIFO depth (paper §3.3: the FIFO smooths the bursty
//! NMS output "to make sure the pipelines run smoothly").
//!
//! Run: `cargo bench --bench ablation_pingpong`

#[path = "harness.rs"]
mod harness;

use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::AcceleratorConfig;
use bingflow::data::SyntheticDataset;
use bingflow::dataflow::Accelerator;

fn main() {
    let pyramid = Pyramid::new(bingflow::config::default_sizes());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;

    println!("Ping-Pong cache ablation (default pyramid, 16 scales)");
    println!(
        "{:<22} {:>12} {:>14} {:>12} {:>10}",
        "config", "cycles", "cache starves", "fps@100MHz", "slowdown"
    );
    let mut base_cycles = 0u64;
    for (name, ping_pong) in [("ping-pong (paper)", true), ("single lane", false)] {
        let cfg = AcceleratorConfig { ping_pong, ..Default::default() };
        let accel = Accelerator::new(cfg, pyramid.clone(), default_stage1());
        let report = accel.run_image(&img);
        let starves: u64 = report.per_scale.iter().map(|s| s.cache_starves).sum();
        if ping_pong {
            base_cycles = report.total_cycles;
        }
        println!(
            "{:<22} {:>12} {:>14} {:>12.1} {:>9.2}x",
            name,
            report.total_cycles,
            starves,
            report.fps(100.0e6),
            report.total_cycles as f64 / base_cycles as f64
        );
    }

    println!("\nNMS FIFO depth sweep (backpressure smoothing)");
    println!(
        "{:<22} {:>12} {:>16} {:>16}",
        "depth", "cycles", "fifo full stalls", "max occupancy"
    );
    for depth in [1usize, 2, 4, 8, 16, 64, 256] {
        let cfg = AcceleratorConfig { nms_fifo_depth: depth, ..Default::default() };
        let accel = Accelerator::new(cfg, pyramid.clone(), default_stage1());
        let report = accel.run_image(&img);
        let stalls: u64 = report.per_scale.iter().map(|s| s.fifo_full_stalls).sum();
        let occ = report
            .per_scale
            .iter()
            .map(|s| s.fifo_max_occupancy)
            .max()
            .unwrap_or(0);
        println!("{depth:<22} {:>12} {stalls:>16} {occ:>16}", report.total_cycles);
    }
}
