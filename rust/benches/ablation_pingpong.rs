//! E5 — ablation of the **Ping-Pong cache** (paper §3.2, Fig. 3): with two
//! cache lanes the kernel pipelines receive a continuous batch stream; with
//! a single lane the stream stalls during every refill.
//!
//! Also sweeps the NMS FIFO depth (paper §3.3: the FIFO smooths the bursty
//! NMS output "to make sure the pipelines run smoothly").
//!
//! Run: `cargo bench --bench ablation_pingpong`
//!
//! Emits `BENCH_dataflow.json` at the repo root — the machine-readable
//! record of the driver-based cycle model (cycle totals, derived
//! swap/flush overheads, FIFO sweep) plus timed rows for the simulator's
//! own wall-clock speed (EXPERIMENTS.md §Perf / §Backends).

#[path = "harness.rs"]
mod harness;

use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::AcceleratorConfig;
use bingflow::data::SyntheticDataset;
use bingflow::dataflow::Accelerator;

fn main() {
    let mut rep = harness::JsonReport::new("dataflow");
    let pyramid = Pyramid::new(bingflow::config::default_sizes());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;

    println!("Ping-Pong cache ablation (default pyramid, 16 scales)");
    println!(
        "{:<22} {:>12} {:>14} {:>12} {:>10}",
        "config", "cycles", "cache starves", "fps@100MHz", "slowdown"
    );
    // the ping-pong config IS the default config — its run doubles as the
    // reference for the derived-overhead and wall-clock sections below
    let default_accel =
        Accelerator::new(AcceleratorConfig::default(), pyramid.clone(), default_stage1());
    let default_report = default_accel.run_image(&img);
    let base_cycles = default_report.total_cycles;
    for (name, key, ping_pong) in [
        ("ping-pong (paper)", "pingpong_on", true),
        ("single lane", "pingpong_off", false),
    ] {
        let single_lane;
        let report = if ping_pong {
            &default_report
        } else {
            let cfg = AcceleratorConfig { ping_pong, ..Default::default() };
            single_lane = Accelerator::new(cfg, pyramid.clone(), default_stage1()).run_image(&img);
            &single_lane
        };
        let starves: u64 = report.per_scale.iter().map(|s| s.cache_starves).sum();
        println!(
            "{:<22} {:>12} {:>14} {:>12.1} {:>9.2}x",
            name,
            report.total_cycles,
            starves,
            report.fps(100.0e6).expect("simulation ran cycles"),
            report.total_cycles as f64 / base_cycles as f64
        );
        rep.note(&format!("cycles_{key}"), report.total_cycles as f64);
        rep.note(&format!("cache_starves_{key}"), starves as f64);
    }

    // derived scale-boundary overheads (formerly fixed constants; now
    // properties of the stage graph's drain schedule)
    let s0 = &default_report.per_scale[0];
    println!(
        "\nderived scale-boundary overheads: swap {} cycles, flush {} cycles",
        s0.swap_cycles, s0.flush_cycles
    );
    rep.note("derived_swap_cycles", s0.swap_cycles as f64);
    rep.note("derived_flush_cycles", s0.flush_cycles as f64);

    // the simulator's own wall-clock speed (driver overhead watchdog)
    harness::header("simulator wall-clock (stage-graph driver)");
    let stats = harness::bench(|| {
        harness::black_box(default_accel.run_image(&img));
    });
    rep.row("sim run_image, default pyramid (16 scales)", &stats);
    rep.note(
        "sim_mcycles_per_sec",
        default_report.total_cycles as f64 / stats.median.as_secs_f64() / 1e6,
    );

    println!("\nNMS FIFO depth sweep (backpressure smoothing)");
    println!(
        "{:<22} {:>12} {:>16} {:>16}",
        "depth", "cycles", "fifo full stalls", "max occupancy"
    );
    for depth in [1usize, 2, 4, 8, 16, 64, 256] {
        let cfg = AcceleratorConfig { nms_fifo_depth: depth, ..Default::default() };
        let accel = Accelerator::new(cfg, pyramid.clone(), default_stage1());
        let report = accel.run_image(&img);
        let stalls: u64 = report.per_scale.iter().map(|s| s.fifo_full_stalls).sum();
        let occ = report
            .per_scale
            .iter()
            .map(|s| s.fifo_max_occupancy)
            .max()
            .unwrap_or(0);
        println!("{depth:<22} {:>12} {stalls:>16} {occ:>16}", report.total_cycles);
        rep.note(&format!("fifo_depth_{depth}_cycles"), report.total_cycles as f64);
        rep.note(&format!("fifo_depth_{depth}_full_stalls"), stalls as f64);
    }
    rep.write_and_announce();
}
