//! E4 — regenerate **Fig. 5**: DR vs #WIN and MABO vs #WIN on the
//! (synthetic) VOC-like validation split, comparing:
//!
//!   * `BING`      — the float software pipeline (float-trained stage-I
//!                   weights at full precision), 5000-window budget — the
//!                   paper's software reference;
//!   * `FPGA`      — the accelerator path: the same weights quantized to the
//!                   i8 deployment template, 1000-window budget (the paper's
//!                   hardware configuration);
//!   * `BIN`       — BING's binarized bitwise fast path, for context.
//!
//! The paper reports FPGA-DR ≈ 94.72% vs BING ≈ 97.63% at 1000 proposals —
//! a small quality gap from quantization + the reduced window budget. The
//! reproduction target is that *shape*: FPGA within a few points of BING,
//! both curves saturating with #WIN.
//!
//! Run: `cargo bench --bench fig5_quality`

#[path = "harness.rs"]
mod harness;

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{BBox, Pyramid, Stage1Weights};
use bingflow::config::default_sizes;
use bingflow::data::{GtBox, SyntheticDataset};
use bingflow::metrics::{dr_curve, mabo_curve, ImageEval};
use bingflow::svm::{train_stage1, Stage2Calibration, SvmTrainConfig};

const N_IMAGES: usize = 48;
const IOU_THRESH: f32 = 0.4; // paper §4.2 default

fn collect(
    sw: &SoftwareBing,
    ds: &SyntheticDataset,
    top_k: usize,
) -> (Vec<Vec<BBox>>, Vec<Vec<GtBox>>) {
    let mut proposals = Vec::new();
    let mut gts = Vec::new();
    for sample in ds.iter() {
        proposals.push(
            sw.propose(&sample.image, top_k)
                .into_iter()
                .map(|p| p.bbox)
                .collect(),
        );
        gts.push(sample.boxes);
    }
    (proposals, gts)
}

fn main() {
    let sizes = default_sizes();
    let pyramid = Pyramid::new(sizes.clone());
    let stage2 = Stage2Calibration::identity(sizes.clone());

    // train stage-I on the disjoint train split (float model), then derive
    // the two deployment variants the figure compares
    eprintln!("[fig5] training stage-I SVM on the synthetic train split...");
    let train_ds = SyntheticDataset::voc_like_train(24);
    let model = train_stage1(&train_ds, &SvmTrainConfig::default());
    let float_mode = ScoringMode::hi_precision(&model.w);
    let quant_weights = Stage1Weights::quantize(&model.w);

    let ds = SyntheticDataset::voc_like_val(N_IMAGES);

    // BING software reference: float weights, 5000-window budget
    let bing = SoftwareBing::new(
        pyramid.clone(),
        quant_weights.clone(), // unused by HiPrecision scoring
        stage2.clone(),
        float_mode,
    );
    let (bing_props, gts) = collect(&bing, &ds, 5000);

    // FPGA path: quantized i8 weights, 1000-window budget
    let fpga = SoftwareBing::new(
        pyramid.clone(),
        quant_weights.clone(),
        stage2.clone(),
        ScoringMode::Exact,
    );
    let (fpga_props, _) = collect(&fpga, &ds, 1000);

    // binarized CPU fast path
    let bin = SoftwareBing::new(
        pyramid,
        quant_weights,
        stage2,
        ScoringMode::Binarized { nw: 3, ng: 6 },
    );
    let (bin_props, _) = collect(&bin, &ds, 1000);

    let n_wins = [1, 5, 10, 25, 50, 100, 250, 500, 1000];
    println!(
        "Fig. 5: proposal quality on synthetic VOC-like val ({N_IMAGES} images, IoU {IOU_THRESH})"
    );
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>11} {:>11} {:>11}",
        "#WIN", "DR BING", "DR FPGA", "DR BIN", "MABO BING", "MABO FPGA", "MABO BIN"
    );
    fn eval<'a>(props: &'a [Vec<BBox>], gts: &'a [Vec<GtBox>]) -> Vec<ImageEval<'a>> {
        props
            .iter()
            .zip(gts)
            .map(|(p, g)| ImageEval { proposals: p, gt: g })
            .collect()
    }
    let e_bing = eval(&bing_props, &gts);
    let e_fpga = eval(&fpga_props, &gts);
    let e_bin = eval(&bin_props, &gts);
    let dr_b = dr_curve(&e_bing, &n_wins, IOU_THRESH);
    let dr_f = dr_curve(&e_fpga, &n_wins, IOU_THRESH);
    let dr_n = dr_curve(&e_bin, &n_wins, IOU_THRESH);
    let mb_b = mabo_curve(&e_bing, &n_wins);
    let mb_f = mabo_curve(&e_fpga, &n_wins);
    let mb_n = mabo_curve(&e_bin, &n_wins);
    for i in 0..n_wins.len() {
        println!(
            "{:>6} {:>10.4} {:>10.4} {:>10.4} {:>11.4} {:>11.4} {:>11.4}",
            n_wins[i],
            dr_b.value[i],
            dr_f.value[i],
            dr_n.value[i],
            mb_b.value[i],
            mb_f.value[i],
            mb_n.value[i]
        );
    }
    let last = n_wins.len() - 1;
    println!(
        "\nheadline: DR@1000 — BING(float) {:.2}% vs FPGA(quantized) {:.2}% \
         (paper: 97.63% vs 94.72%)",
        dr_b.value[last] * 100.0,
        dr_f.value[last] * 100.0
    );
}
