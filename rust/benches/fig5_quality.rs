//! E4 — regenerate **Fig. 5**: DR vs #WIN and MABO vs #WIN on the
//! (synthetic) VOC-like validation split, comparing:
//!
//!   * `BING`      — the float software pipeline (float-trained stage-I
//!                   weights at full precision), 5000-window budget — the
//!                   paper's software reference;
//!   * `FPGA`      — the accelerator path: the same weights quantized to the
//!                   i8 deployment template, 1000-window budget (the paper's
//!                   hardware configuration);
//!   * `BIN`       — BING's binarized bitwise fast path, for context.
//!
//! The paper reports FPGA-DR ≈ 94.72% vs BING ≈ 97.63% at 1000 proposals —
//! a small quality gap from quantization + the reduced window budget. The
//! reproduction target is that *shape*: FPGA within a few points of BING,
//! both curves saturating with #WIN.
//!
//! A second section serves the **full detection cascade** (proposals →
//! stage-II SVM → greedy NMS → Platt confidence) through the sharded
//! `ServerRuntime` and reports recall-at-k of the served detections against
//! ground truth — the quality of the *product* the serving API returns, not
//! just the proposal pool. Machine-readable record: `BENCH_detect.json`.
//!
//! Run: `cargo bench --bench fig5_quality`

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::{Duration, Instant};

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{BBox, Pyramid, Stage1Weights};
use bingflow::config::{default_sizes, CascadeConfig, ServingConfig};
use bingflow::coordinator::DetectRequest;
use bingflow::data::{GtBox, SyntheticDataset};
use bingflow::metrics::{dr_curve, mabo_curve, ImageEval};
use bingflow::serving::ServerRuntime;
use bingflow::svm::{train_stage1, Stage2Calibration, SvmTrainConfig};

const N_IMAGES: usize = 48;
const IOU_THRESH: f32 = 0.4; // paper §4.2 default
const DETECT_IOU: f32 = 0.5; // detection recall uses the stricter PASCAL bar

fn collect(
    sw: &SoftwareBing,
    ds: &SyntheticDataset,
    top_k: usize,
) -> (Vec<Vec<BBox>>, Vec<Vec<GtBox>>) {
    let mut proposals = Vec::new();
    let mut gts = Vec::new();
    for sample in ds.iter() {
        proposals.push(
            sw.propose(&sample.image, top_k)
                .into_iter()
                .map(|p| p.bbox)
                .collect(),
        );
        gts.push(sample.boxes);
    }
    (proposals, gts)
}

fn main() {
    let sizes = default_sizes();
    let pyramid = Pyramid::new(sizes.clone());
    let stage2 = Stage2Calibration::identity(sizes.clone());

    // Budget-scaled workload: the CI smoke run (BENCH_BUDGET_MS=15)
    // exercises every code path on a handful of images; the default budget
    // measures the real split.
    let fast = harness::budget() < Duration::from_millis(100);
    let n_images = if fast { 6 } else { N_IMAGES };
    let n_train = if fast { 8 } else { 24 };

    // train stage-I on the disjoint train split (float model), then derive
    // the two deployment variants the figure compares
    eprintln!("[fig5] training stage-I SVM on the synthetic train split...");
    let train_ds = SyntheticDataset::voc_like_train(n_train);
    let model = train_stage1(&train_ds, &SvmTrainConfig::default());
    let float_mode = ScoringMode::hi_precision(&model.w);
    let quant_weights = Stage1Weights::quantize(&model.w);

    let ds = SyntheticDataset::voc_like_val(n_images);

    // BING software reference: float weights, 5000-window budget
    let bing = SoftwareBing::new(
        pyramid.clone(),
        quant_weights.clone(), // unused by HiPrecision scoring
        stage2.clone(),
        float_mode,
    );
    let (bing_props, gts) = collect(&bing, &ds, 5000);

    // FPGA path: quantized i8 weights, 1000-window budget
    let fpga = SoftwareBing::new(
        pyramid.clone(),
        quant_weights.clone(),
        stage2.clone(),
        ScoringMode::Exact,
    );
    let (fpga_props, _) = collect(&fpga, &ds, 1000);

    // binarized CPU fast path
    let bin = SoftwareBing::new(
        pyramid.clone(),
        quant_weights.clone(),
        stage2.clone(),
        ScoringMode::Binarized { nw: 3, ng: 6 },
    );
    let (bin_props, _) = collect(&bin, &ds, 1000);

    let n_wins = [1, 5, 10, 25, 50, 100, 250, 500, 1000];
    println!(
        "Fig. 5: proposal quality on synthetic VOC-like val ({n_images} images, IoU {IOU_THRESH})"
    );
    println!(
        "{:>6} {:>10} {:>10} {:>10} {:>11} {:>11} {:>11}",
        "#WIN", "DR BING", "DR FPGA", "DR BIN", "MABO BING", "MABO FPGA", "MABO BIN"
    );
    fn eval<'a>(props: &'a [Vec<BBox>], gts: &'a [Vec<GtBox>]) -> Vec<ImageEval<'a>> {
        props
            .iter()
            .zip(gts)
            .map(|(p, g)| ImageEval { proposals: p, gt: g })
            .collect()
    }
    let e_bing = eval(&bing_props, &gts);
    let e_fpga = eval(&fpga_props, &gts);
    let e_bin = eval(&bin_props, &gts);
    let dr_b = dr_curve(&e_bing, &n_wins, IOU_THRESH);
    let dr_f = dr_curve(&e_fpga, &n_wins, IOU_THRESH);
    let dr_n = dr_curve(&e_bin, &n_wins, IOU_THRESH);
    let mb_b = mabo_curve(&e_bing, &n_wins);
    let mb_f = mabo_curve(&e_fpga, &n_wins);
    let mb_n = mabo_curve(&e_bin, &n_wins);
    for i in 0..n_wins.len() {
        println!(
            "{:>6} {:>10.4} {:>10.4} {:>10.4} {:>11.4} {:>11.4} {:>11.4}",
            n_wins[i],
            dr_b.value[i],
            dr_f.value[i],
            dr_n.value[i],
            mb_b.value[i],
            mb_f.value[i],
            mb_n.value[i]
        );
    }
    let last = n_wins.len() - 1;
    println!(
        "\nheadline: DR@1000 — BING(float) {:.2}% vs FPGA(quantized) {:.2}% \
         (paper: 97.63% vs 94.72%)",
        dr_b.value[last] * 100.0,
        dr_f.value[last] * 100.0
    );

    // ---- served-path detections: recall-at-k through the full cascade ---
    // Quality of what `ServerRuntime::submit_detect` actually returns: the
    // FPGA-config proposal pool, NMS-deduplicated and confidence-calibrated,
    // measured against GT at the PASCAL detection bar.
    println!("\nserved cascade: recall-at-k via ServerRuntime::submit_detect ({n_images} images)");
    let mut json = harness::JsonReport::new("detect");
    let serve_cfg = ServingConfig {
        top_k: 1000,
        shards: 2,
        workers: 2,
        cascade: CascadeConfig { top_k: 100, nms_thresh: 0.6, ..Default::default() },
        ..Default::default()
    };
    let backend = Arc::new(SoftwareBing::new(
        pyramid,
        quant_weights,
        stage2.clone(),
        ScoringMode::Exact,
    ));
    let rt: ServerRuntime<SoftwareBing> = ServerRuntime::new(backend, stage2, serve_cfg);
    let mut det_boxes: Vec<Vec<BBox>> = Vec::new();
    let mut lat_ms: Vec<f64> = Vec::new();
    let t0 = Instant::now();
    for sample in ds.iter() {
        let resp = rt
            .submit_detect(DetectRequest::new(sample.image.clone()))
            .expect("submission admitted")
            .wait()
            .expect("serving completes");
        lat_ms.push(resp.latency.as_secs_f64() * 1e3);
        det_boxes.push(resp.items.iter().map(|d| d.bbox).collect());
    }
    let wall = t0.elapsed();
    rt.shutdown();

    let det_evals = eval(&det_boxes, &gts);
    let ks = [1, 5, 10, 25, 50, 100];
    let recall = dr_curve(&det_evals, &ks, DETECT_IOU);
    let det_mabo = mabo_curve(&det_evals, &ks);
    println!("{:>6} {:>12} {:>12}", "k", "recall@k", "MABO");
    for i in 0..ks.len() {
        println!("{:>6} {:>12.4} {:>12.4}", ks[i], recall.value[i], det_mabo.value[i]);
        json.record_fields(
            &format!("recall_at_{}", ks[i]),
            &[
                ("k", ks[i] as f64),
                ("recall", recall.value[i] as f64),
                ("mabo", det_mabo.value[i] as f64),
            ],
        );
    }
    lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = lat_ms[lat_ms.len() / 2];
    let p_max = *lat_ms.last().unwrap();
    let throughput = n_images as f64 / wall.as_secs_f64();
    println!(
        "served latency p50 {p50:.2} ms, max {p_max:.2} ms; throughput {throughput:.1} images/s"
    );
    json.record_fields(
        "served_latency",
        &[("p50_ms", p50), ("max_ms", p_max), ("throughput_ips", throughput)],
    );
    json.note("images", n_images as f64);
    json.note("detect_iou", DETECT_IOU as f64);
    json.note("recall_at_100", recall.value[ks.len() - 1] as f64);
    json.note("dr_fpga_at_1000", dr_f.value[last] as f64);
    json.note("dr_bing_at_1000", dr_b.value[last] as f64);
    json.write_and_announce();
}
