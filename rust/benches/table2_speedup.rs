//! E2 — regenerate **Table 2**: speedup and power efficiency of the
//! accelerator (simulated at the paper's clocks) against the Intel i7 and
//! ARM A53 software baselines.
//!
//! The i7 row uses our *measured* multithreaded rust baseline on this
//! machine's CPU, normalized the way the paper normalizes (fps ratio); the
//! ARM row uses the paper's published A53 figures (16 fps, 3.5 W — the paper
//! itself takes these from pidramble), scaled by our measured single-thread
//! ratio. Absolute numbers differ from the paper's testbed; the *ratios*
//! are the reproduction target.
//!
//! Run: `cargo bench --bench table2_speedup`
//!
//! Emits `BENCH_e2e.json` at the repo root (EXPERIMENTS.md §Perf).

#[path = "harness.rs"]
mod harness;

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::{AcceleratorConfig, Device};
use bingflow::data::{SceneConfig, SyntheticDataset};
use bingflow::dataflow::{power_estimate, Accelerator};
use bingflow::svm::Stage2Calibration;

/// Paper-workload pyramid (BING ladder on a VOC-sized frame).
fn paper_pyramid() -> Pyramid {
    let ladder = [10usize, 20, 40, 80, 160, 320];
    Pyramid::new(
        ladder
            .iter()
            .flat_map(|&h| ladder.iter().map(move |&w| (h, w)))
            .collect(),
    )
}

fn main() {
    let mut rep = harness::JsonReport::new("e2e");
    let pyramid = paper_pyramid();
    let ds = SyntheticDataset::new(
        SceneConfig { width: 500, height: 375, ..Default::default() },
        2007,
        1,
    );
    let img = ds.sample(0).image;
    let weights = default_stage1();
    let stage2 = Stage2Calibration::identity(pyramid.sizes.clone());

    // ---- software baselines (measured) ---------------------------------
    harness::header("software BING baselines (this machine)");
    let mut sw = SoftwareBing::new(pyramid.clone(), weights.clone(), stage2, ScoringMode::Exact);
    let mt = harness::bench(|| {
        harness::black_box(sw.propose(&img, 1000));
    });
    rep.row("software BING, multithreaded (i7 proxy)", &mt);
    sw.parallel = false;
    let st = harness::bench(|| {
        harness::black_box(sw.propose(&img, 1000));
    });
    rep.row("software BING, single-thread (ARM proxy)", &st);

    // ---- accelerator (simulated cycles at paper clocks) ----------------
    let accel = Accelerator::new(
        AcceleratorConfig { pipelines: 4, heap_capacity: 1000, ..Default::default() },
        pyramid,
        weights,
    );
    let report = accel.run_image(&img);

    let cpu_fps_measured = mt.per_sec();

    // two baseline anchorings:
    //  (a) the paper's published figures (i7-3940XM 300 fps @55 W, A53
    //      16 fps @3.5 W) — the apples-to-apples reproduction of Table 2;
    //  (b) our measured multithreaded baseline on THIS machine (same role
    //      as the i7 row: "traditional desktop CPU platform").
    let anchors = [
        ("Intel i7 (paper anchor)", 300.0, 55.0),
        ("ARM A53 (paper anchor)", 16.0, 3.5),
        ("this CPU (measured)", cpu_fps_measured, 55.0),
    ];

    println!("\nTable 2: speedup and power efficiency");
    println!(
        "{:<26} {:>22} {:>22}",
        "", "Kintex UltraScale+", "Artix-7 Low Volt."
    );
    println!(
        "{:<26} {:>10} {:>11} {:>10} {:>11}",
        "", "Speedup", "Power eff.", "Speedup", "Power eff."
    );
    for (name, base_fps, base_w) in anchors {
        let mut row = format!("{name:<26}");
        for device in [Device::KintexUltraScalePlus, Device::Artix7LowVolt] {
            let fps = report.fps(device.clock_hz()).expect("simulation ran cycles");
            let power = power_estimate(device, report.activity);
            let speedup = fps / base_fps;
            let eff = (fps / (power.total_mw() / 1000.0)) / (base_fps / base_w);
            row += &format!(" {speedup:>9.2}x {eff:>10.0}x");
        }
        println!("{row}");
    }
    println!(
        "\npaper:      i7 → 3.67x / >220x (Kintex), 0.12x / 66x (Artix)\n\
         paper:      A53 → 68x / >250x (Kintex), 2.2x / >60x (Artix)"
    );
    let fps_kintex = report.fps(100.0e6).expect("simulation ran cycles");
    let fps_artix = report.fps(3.3e6).expect("simulation ran cycles");
    println!(
        "\naccelerator: {} cycles/image → {:.0} fps @100MHz, {:.1} fps @3.3MHz",
        report.total_cycles, fps_kintex, fps_artix
    );

    rep.note("cpu_fps_multithreaded", cpu_fps_measured);
    rep.note("cpu_fps_single_thread", st.per_sec());
    rep.note("accel_cycles_per_image", report.total_cycles as f64);
    rep.note("accel_fps_kintex_100mhz", fps_kintex);
    rep.note("accel_fps_artix_3p3mhz", fps_artix);
    rep.note(
        "speedup_kintex_vs_measured_cpu",
        fps_kintex / cpu_fps_measured.max(1e-12),
    );
    rep.write_and_announce();
}
