//! E6 ablation — **scalability**: the paper claims the architecture "could
//! be extended as more pipelines". Sweep the pipeline count and the heap
//! capacity; report cycles, fps at both paper clocks, and the resource cost
//! of each point (the area-performance trade-off a designer would read off).
//!
//! Run: `cargo bench --bench ablation_scaling`

#[path = "harness.rs"]
mod harness;

use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::{AcceleratorConfig, Device};
use bingflow::data::{SceneConfig, SyntheticDataset};
use bingflow::dataflow::{resource_estimate, Accelerator, WorkloadGeometry};

fn main() {
    // paper workload: full BING ladder on a VOC-sized frame
    let ladder = [10usize, 20, 40, 80, 160, 320];
    let pyramid = Pyramid::new(
        ladder
            .iter()
            .flat_map(|&h| ladder.iter().map(move |&w| (h, w)))
            .collect(),
    );
    let img = SyntheticDataset::new(
        SceneConfig { width: 500, height: 375, ..Default::default() },
        2007,
        1,
    )
    .sample(0)
    .image;

    println!("Pipeline scaling (paper pyramid, Kintex US+ resources)");
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>9} {:>9} {:>7}",
        "pipelines", "cycles", "fps@100MHz", "fps@3.3MHz", "LUT", "FF", "fits?"
    );
    let mut prev_cycles = None;
    for pipelines in [1usize, 2, 4, 8, 16] {
        let cfg = AcceleratorConfig {
            pipelines,
            heap_capacity: 1000,
            device: Device::KintexUltraScalePlus,
            ..Default::default()
        };
        let accel = Accelerator::new(cfg.clone(), pyramid.clone(), default_stage1());
        let report = accel.run_image(&img);
        let res = resource_estimate(&cfg, &WorkloadGeometry::paper());
        let speedup = prev_cycles
            .map(|p: u64| format!("  ({:.2}x vs prev)", p as f64 / report.total_cycles as f64))
            .unwrap_or_default();
        println!(
            "{pipelines:<10} {:>12} {:>12.1} {:>12.2} {:>9} {:>9} {:>7}{speedup}",
            report.total_cycles,
            report.fps(100.0e6).expect("simulation ran cycles"),
            report.fps(3.3e6).expect("simulation ran cycles"),
            res.lut,
            res.ff,
            if res.fits(Device::KintexUltraScalePlus) { "yes" } else { "NO" },
        );
        prev_cycles = Some(report.total_cycles);
    }

    println!("\nHeap capacity (top-n) sweep — sorting-module cost");
    println!("{:<10} {:>12} {:>12}", "capacity", "cycles", "fps@100MHz");
    for cap in [64usize, 128, 256, 512, 1000, 2000] {
        let cfg = AcceleratorConfig { heap_capacity: cap, ..Default::default() };
        let accel = Accelerator::new(cfg, pyramid.clone(), default_stage1());
        let report = accel.run_image(&img);
        println!(
            "{cap:<10} {:>12} {:>12.1}",
            report.total_cycles,
            report.fps(100.0e6).expect("simulation ran cycles")
        );
    }
}
