//! Open-loop video serving benchmark: replay synthetic per-session frame
//! traces (Poisson and bursty arrivals) through the sharded `ServerRuntime`
//! and compare **full recompute** (stateless requests — every frame scored
//! from scratch) against the **incremental** temporal-coherence path
//! (session requests + session-affinity routing, so each shard's dirty-tile
//! frame cache stays warm).
//!
//! Open loop: the trace clock paces arrivals no matter how fast the server
//! drains them — a slow server accumulates queueing instead of slowing the
//! arrival process, so p99 and the deadline-miss count reflect genuine
//! overload rather than coordinated omission (the closed-loop
//! `serve_bench` measures the complementary capacity-tracking view).
//!
//! Frames are pre-generated before the clock starts; the replay loop only
//! clones and submits, so scene synthesis cost never skews arrival times.
//!
//! Emits `BENCH_video.json` at the repo root (field dictionary in
//! EXPERIMENTS.md §Video). Budget honours `BENCH_BUDGET_MS` — CI smoke
//! runs it with a few milliseconds so bench bitrot fails the build.
//!
//! ```bash
//! cargo bench --bench video_bench            # or: make video-bench
//! ```

#[path = "harness.rs"]
mod harness;

use std::sync::Arc;
use std::time::{Duration, Instant};

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::{RoutePolicyKind, ServingConfig};
use bingflow::coordinator::{ProposalRequest, ResponseError};
use bingflow::data::{SceneConfig, SyntheticVideo};
use bingflow::image::ImageRgb;
use bingflow::serving::ServerRuntime;
use bingflow::svm::Stage2Calibration;
use bingflow::temporal::trace::{self, TraceEvent};

const TOP_K: usize = 60;
const SESSIONS: u64 = 2;
const JITTER: u32 = 2;
const DEADLINE_MS: u64 = 250;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32)]
}

fn software() -> Arc<SoftwareBing> {
    Arc::new(SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    ))
}

fn clip(seed: u64) -> SyntheticVideo {
    SyntheticVideo::new(SceneConfig { width: 96, height: 96, ..Default::default() }, seed, JITTER)
}

fn runtime() -> ServerRuntime<SoftwareBing> {
    ServerRuntime::new(
        software(),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards: 2,
            policy: RoutePolicyKind::SessionAffinity,
            workers: 2,
            top_k: TOP_K,
            deadline_ms: Some(DEADLINE_MS),
            ..Default::default()
        },
    )
}

/// Per-session arrival traces merged into one globally ordered stream.
fn make_trace(frames: usize, rate_hz: f64, bursty: bool) -> Vec<TraceEvent> {
    let mut events = Vec::with_capacity(frames * SESSIONS as usize);
    for s in 0..SESSIONS {
        let offsets = if bursty {
            trace::arrival_offsets_bursty(frames, rate_hz, 4, 0xBEE5 ^ s)
        } else {
            trace::arrival_offsets_poisson(frames, rate_hz, 0xBEE5 ^ s)
        };
        for (f, &at_ms) in offsets.iter().enumerate() {
            events.push(TraceEvent {
                at_ms,
                session: s,
                seed: 40 + s,
                frame: f as u64,
                width: 96,
                height: 96,
            });
        }
    }
    events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
    events
}

/// Latency percentile from a sorted sample (conservative upper pick).
fn pct(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() as f64 * q).ceil() as usize).clamp(1, sorted_ms.len()) - 1;
    sorted_ms[idx]
}

struct Cell {
    wall_s: f64,
    frames_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    deadline_miss: u64,
    tiles_recomputed: u64,
    tiles_skipped: u64,
    prior_hits: u64,
}

/// Replay one trace open-loop. `incremental = false` drops the session id
/// from every request — same frames, same arrivals, but each frame is a
/// stateless full recompute (the baseline column).
fn run_cell(events: &[TraceEvent], frames: &[ImageRgb], incremental: bool) -> Cell {
    let rt = runtime();
    let t0 = Instant::now();
    let mut handles = Vec::with_capacity(events.len());
    for (ev, frame) in events.iter().zip(frames) {
        let target = t0 + Duration::from_secs_f64(ev.at_ms.max(0.0) / 1000.0);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let mut req = ProposalRequest::new(frame.clone());
        if incremental {
            req = req.session(ev.session);
        }
        handles.push(rt.submit_request(req).ok());
    }
    let mut deadline_miss = 0u64;
    let mut latencies: Vec<f64> = Vec::with_capacity(handles.len());
    for h in handles.into_iter().flatten() {
        match h.wait() {
            Ok(resp) => latencies.push(resp.latency.as_secs_f64() * 1e3),
            Err(ResponseError::DeadlineExceeded) => deadline_miss += 1,
            Err(_) => {}
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let cell = Cell {
        wall_s,
        frames_per_s: latencies.len() as f64 / wall_s.max(1e-9),
        p50_ms: pct(&latencies, 0.50),
        p99_ms: pct(&latencies, 0.99),
        deadline_miss,
        tiles_recomputed: rt.metrics.tiles_recomputed.get(),
        tiles_skipped: rt.metrics.tiles_skipped.get(),
        prior_hits: rt.metrics.prior_hits.get(),
    };
    rt.shutdown();
    cell
}

fn main() {
    // scale frames-per-session with the budget; the arrival rate is picked
    // so the whole trace spans roughly half the budget, keeping the
    // open-loop replay inside the time box
    let budget_ms = harness::budget().as_millis() as usize;
    let frames_per_session = (budget_ms / 8).clamp(4, 96);
    let rate_hz = (frames_per_session as f64 * 1000.0) / (budget_ms as f64 * 0.5).max(1.0);

    // bit-identity spot check on every bench run: the session path must
    // reproduce the stateless baseline frame for frame (the property tests
    // prove it per kernel; this guards the bench's own wiring)
    {
        let rt = runtime();
        let c = clip(40);
        for f in 0..4 {
            let frame = c.frame(f);
            let want = rt.serve(ProposalRequest::new(frame.clone())).unwrap().items;
            let got = rt.serve(ProposalRequest::new(frame).session(77)).unwrap().items;
            assert_eq!(got, want, "incremental frame {f} diverged from full recompute");
        }
        rt.shutdown();
    }

    let mut json = harness::JsonReport::new("video");
    json.note("sessions", SESSIONS as f64);
    json.note("frames_per_session", frames_per_session as f64);
    json.note("rate_hz", rate_hz);
    json.note("jitter_px", JITTER as f64);
    json.note("deadline_ms", DEADLINE_MS as f64);

    println!("\n=== video_bench — open-loop trace replay ===");
    println!(
        "{:<24} {:>7} {:>12} {:>12} {:>7} {:>10}",
        "mode x arrivals", "frames", "p50", "p99", "miss", "rate"
    );

    let mut p50 = std::collections::BTreeMap::new();
    for (arrivals, bursty) in [("poisson", false), ("bursty", true)] {
        let events = make_trace(frames_per_session, rate_hz, bursty);
        let frames: Vec<ImageRgb> =
            events.iter().map(|ev| clip(ev.seed).frame(ev.frame)).collect();
        for (mode, incremental) in [("full", false), ("incremental", true)] {
            let cell = run_cell(&events, &frames, incremental);
            let label = format!("{mode}_{arrivals}");
            println!(
                "{label:<24} {:>7} {:>9.2} ms {:>9.2} ms {:>7} {:>8.1}/s",
                events.len(),
                cell.p50_ms,
                cell.p99_ms,
                cell.deadline_miss,
                cell.frames_per_s
            );
            json.record_fields(
                &label,
                &[
                    ("frames", events.len() as f64),
                    ("wall_s", cell.wall_s),
                    ("frames_per_s", cell.frames_per_s),
                    ("p50_ms", cell.p50_ms),
                    ("p99_ms", cell.p99_ms),
                    ("deadline_miss", cell.deadline_miss as f64),
                    ("tiles_recomputed", cell.tiles_recomputed as f64),
                    ("tiles_skipped", cell.tiles_skipped as f64),
                    ("prior_hits", cell.prior_hits as f64),
                ],
            );
            p50.insert(label, cell.p50_ms);
        }
    }
    if let (Some(&full), Some(&inc)) = (p50.get("full_poisson"), p50.get("incremental_poisson")) {
        if inc > 0.0 {
            json.note("poisson_p50_speedup", full / inc);
        }
    }
    json.write_and_announce();
}
