//! Hot-path micro-benchmarks — the profiling surface for the L3 perf pass
//! (EXPERIMENTS.md §Perf): gradient, scoring variants, NMS winner scan,
//! heap top-k, resize, and the end-to-end software pipeline.
//!
//! Run: `cargo bench --bench hotpath`

#[path = "harness.rs"]
mod harness;

use bingflow::baseline::{rank_and_select, ScoringMode, SoftwareBing};
use bingflow::bing::{
    default_stage1, gradient_map, score_map, winners_from_scores, BinarizedScorer, Pyramid,
};
use bingflow::data::SyntheticDataset;
use bingflow::sort::{top_k_select, BubbleHeap};
use bingflow::svm::Stage2Calibration;

fn main() {
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let big = img.resize_nearest(320, 320);
    let weights = default_stage1();

    harness::header("stage kernels (320x320 scale)");
    let s = harness::bench(|| {
        harness::black_box(gradient_map(&big));
    });
    harness::report("gradient_map 320x320", &s);
    let g = gradient_map(&big);

    let s = harness::bench(|| {
        harness::black_box(score_map(&g, &weights));
    });
    harness::report("score_map (exact, 64 MAC) 313x313", &s);
    let px = 313.0 * 313.0;
    println!(
        "  -> {:.2} GMAC/s",
        px * 64.0 / s.median.as_secs_f64() / 1e9
    );

    let scorer = BinarizedScorer::new(&weights, 3, 6);
    let s = harness::bench(|| {
        harness::black_box(scorer.score_map(&g));
    });
    harness::report("score_map (binarized nw=3 ng=6)", &s);

    let smap = score_map(&g, &weights);
    let s = harness::bench(|| {
        harness::black_box(winners_from_scores(&smap));
    });
    harness::report("nms winners_from_scores 313x313", &s);

    harness::header("resize + sorting substrates");
    let s = harness::bench(|| {
        harness::black_box(img.resize_nearest(320, 320));
    });
    harness::report("resize_nearest 192->320", &s);

    let stream: Vec<i64> = (0..100_000)
        .map(|i| ((i as u64).wrapping_mul(2654435761) % 1_000_003) as i64)
        .collect();
    let s = harness::bench(|| {
        let mut h = BubbleHeap::new(1000);
        for &v in &stream {
            h.push(v);
        }
        harness::black_box(h.len());
    });
    harness::report("bubble heap top-1000 of 100k", &s);
    let s = harness::bench(|| {
        harness::black_box(top_k_select(&stream, 1000));
    });
    harness::report("select_nth top-1000 of 100k", &s);

    harness::header("end-to-end software pipeline (default pyramid)");
    let pyramid = Pyramid::new(bingflow::config::default_sizes());
    let stage2 = Stage2Calibration::identity(pyramid.sizes.clone());
    let sw = SoftwareBing::new(
        pyramid.clone(),
        weights.clone(),
        stage2.clone(),
        ScoringMode::Exact,
    );
    let s = harness::bench(|| {
        harness::black_box(sw.propose(&img, 1000));
    });
    harness::report("SoftwareBing::propose (parallel)", &s);

    let candidates = sw.candidates(&img);
    let s = harness::bench(|| {
        harness::black_box(rank_and_select(&candidates, &pyramid, &stage2, img.w, img.h, 1000));
    });
    harness::report("stage-II + top-k over candidates", &s);
    println!("  candidates/image: {}", candidates.len());
}
