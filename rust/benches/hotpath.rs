//! Hot-path micro-benchmarks — the profiling surface for the L3 perf pass
//! (EXPERIMENTS.md §Perf): gradient, scoring variants (including the
//! retained pre-PR-2 repack scorer as the before/after anchor), NMS winner
//! scan, heap top-k, resize, and the end-to-end software pipeline.
//!
//! Emits `BENCH_hotpath.json` at the repo root.
//!
//! Run: `cargo bench --bench hotpath`

#[path = "harness.rs"]
mod harness;

use bingflow::baseline::{rank_and_select, ScaleScratch, ScoringMode, SoftwareBing};
use bingflow::bing::{
    default_stage1, gradient_map, score_map, winners_from_scores, BinarizedScorer,
    BinarizedScratch, Pyramid, ScoreMap,
};
use bingflow::data::SyntheticDataset;
use bingflow::simd::ScoreKernel;
use bingflow::sort::{top_k_select, BubbleHeap};
use bingflow::svm::Stage2Calibration;

fn main() {
    let mut rep = harness::JsonReport::new("hotpath");
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let big = img.resize_nearest(320, 320);
    let weights = default_stage1();

    harness::header("stage kernels (320x320 scale)");
    let s = harness::bench(|| {
        harness::black_box(gradient_map(&big));
    });
    rep.row("gradient_map 320x320", &s);
    let g = gradient_map(&big);

    let s = harness::bench(|| {
        harness::black_box(score_map(&g, &weights));
    });
    rep.row("score_map (exact, 64 MAC) 313x313", &s);
    let px = 313.0 * 313.0;
    println!(
        "  -> {:.2} GMAC/s",
        px * 64.0 / s.median.as_secs_f64() / 1e9
    );

    let scorer = BinarizedScorer::new(&weights, 3, 6);
    // the retained reference scorer (per-pixel 64-bit repack) is the
    // pre-PR-2 "before" row; the incremental scorer must beat it ≥5×
    let s_ref = harness::bench(|| {
        harness::black_box(scorer.score_map_reference(&g));
    });
    rep.row("score_map binarized (reference repack)", &s_ref);
    let s_inc = harness::bench(|| {
        harness::black_box(scorer.score_map(&g));
    });
    rep.row("score_map (binarized nw=3 ng=6)", &s_inc);
    let mut bscratch = BinarizedScratch::default();
    let mut bout = ScoreMap::default();
    let s_into = harness::bench(|| {
        scorer.score_map_into(&g, &mut bscratch, &mut bout);
        harness::black_box(bout.data.len());
    });
    rep.row("score_map binarized into (scratch reuse)", &s_into);
    let speedup = s_ref.median.as_secs_f64() / s_inc.median.as_secs_f64().max(1e-12);
    println!("  -> incremental speedup over reference: {speedup:.2}x");
    rep.note("speedup_binarized_incremental_vs_reference", speedup);
    rep.note(
        "speedup_binarized_scratch_vs_reference",
        s_ref.median.as_secs_f64() / s_into.median.as_secs_f64().max(1e-12),
    );
    assert_eq!(
        scorer.score_map(&g),
        scorer.score_map_reference(&g),
        "incremental scorer diverged from the reference oracle"
    );

    // kernel dispatch sweep (PR 8): one row per score path — the reference
    // repack, the SWAR fallback, and whatever vector unit the host has
    // (AVX2 / NEON; degrades to SWAR on scalar-only machines). Every path
    // is asserted bit-identical against the reference oracle before it is
    // timed, so a fast-but-wrong kernel fails the bench, not the eval.
    harness::header("kernel dispatch (score_map_into_with)");
    let native = ScoreKernel::detect();
    let oracle = scorer.score_map_reference(&g);
    for kernel in [ScoreKernel::Reference, ScoreKernel::Swar, native] {
        scorer.score_map_into_with(&g, &mut bscratch, &mut bout, kernel);
        assert_eq!(
            bout, oracle,
            "kernel {kernel} diverged from the reference oracle"
        );
    }
    let s_kref = harness::bench(|| {
        scorer.score_map_into_with(&g, &mut bscratch, &mut bout, ScoreKernel::Reference);
        harness::black_box(bout.data.len());
    });
    rep.row("score_map kernel=reference", &s_kref);
    let s_kswar = harness::bench(|| {
        scorer.score_map_into_with(&g, &mut bscratch, &mut bout, ScoreKernel::Swar);
        harness::black_box(bout.data.len());
    });
    rep.row("score_map kernel=swar", &s_kswar);
    let s_knative = harness::bench(|| {
        scorer.score_map_into_with(&g, &mut bscratch, &mut bout, native);
        harness::black_box(bout.data.len());
    });
    rep.row(&format!("score_map kernel=simd ({native})"), &s_knative);
    let simd_speedup = s_kswar.median.as_secs_f64() / s_knative.median.as_secs_f64().max(1e-12);
    println!("  -> native kernel: {native}, speedup over swar: {simd_speedup:.2}x");
    rep.note("speedup_simd_vs_swar", simd_speedup);
    rep.note(
        "speedup_simd_vs_reference",
        s_ref.median.as_secs_f64() / s_knative.median.as_secs_f64().max(1e-12),
    );
    rep.note(
        "simd_lanes",
        native.lanes() as f64,
    );

    let smap = score_map(&g, &weights);
    let s = harness::bench(|| {
        harness::black_box(winners_from_scores(&smap));
    });
    rep.row("nms winners_from_scores 313x313", &s);

    harness::header("resize + sorting substrates");
    let s = harness::bench(|| {
        harness::black_box(img.resize_nearest(320, 320));
    });
    rep.row("resize_nearest 192->320", &s);

    let stream: Vec<i64> = (0..100_000)
        .map(|i| ((i as u64).wrapping_mul(2654435761) % 1_000_003) as i64)
        .collect();
    let s = harness::bench(|| {
        let mut h = BubbleHeap::new(1000);
        for &v in &stream {
            h.push(v);
        }
        harness::black_box(h.len());
    });
    rep.row("bubble heap top-1000 of 100k", &s);
    let s = harness::bench(|| {
        harness::black_box(top_k_select(&stream, 1000));
    });
    rep.row("select_nth top-1000 of 100k", &s);

    harness::header("end-to-end software pipeline (default pyramid)");
    let pyramid = Pyramid::new(bingflow::config::default_sizes());
    let stage2 = Stage2Calibration::identity(pyramid.sizes.clone());
    let mut sw = SoftwareBing::new(
        pyramid.clone(),
        weights.clone(),
        stage2.clone(),
        ScoringMode::Exact,
    );
    let s = harness::bench(|| {
        harness::black_box(sw.propose(&img, 1000));
    });
    rep.row("SoftwareBing::propose (parallel)", &s);
    sw.parallel = false;
    let s = harness::bench(|| {
        harness::black_box(sw.propose(&img, 1000));
    });
    rep.row("SoftwareBing::propose (serial)", &s);
    sw.parallel = true;

    let mut scratch = ScaleScratch::new();
    let s = harness::bench(|| {
        harness::black_box(sw.candidates_for_scale_scratch(&img, 15, &mut scratch).len());
    });
    rep.row("candidates_for_scale 128x128 (scratch)", &s);

    let candidates = sw.candidates(&img);
    let s = harness::bench(|| {
        harness::black_box(rank_and_select(&candidates, &pyramid, &stage2, img.w, img.h, 1000));
    });
    rep.row("stage-II + top-k over candidates", &s);
    println!("  candidates/image: {}", candidates.len());
    rep.note("candidates_per_image", candidates.len() as f64);

    rep.write_and_announce();
}
