//! Minimal benchmark harness (the environment has no criterion): warmup +
//! auto-calibrated iteration count + robust statistics, printed as aligned
//! rows so `cargo bench` output reads like the paper's tables.
//!
//! Included per-bench via `#[path = "harness.rs"] mod harness;` — each bench
//! uses a different subset, hence the module-wide dead_code allowance.
#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }
}

/// Measure `f`, returning robust stats. Auto-calibrates the iteration count
/// to spend roughly `budget` wall time (default 0.6 s per benchmark).
pub fn bench<F: FnMut()>(mut f: F) -> Stats {
    bench_with_budget(Duration::from_millis(600), &mut f)
}

pub fn bench_with_budget<F: FnMut()>(budget: Duration, f: &mut F) -> Stats {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / once.as_secs_f64()).clamp(3.0, 10_000.0) as u32;

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / iters;
    Stats { iters, mean, median: samples[samples.len() / 2], min: samples[0] }
}

/// Print one result row: `name  median  mean  min  rate`.
pub fn report(name: &str, stats: &Stats) {
    println!(
        "{name:<44} {:>12} {:>12} {:>12} {:>12.1}/s  (n={})",
        fmt_dur(stats.median),
        fmt_dur(stats.mean),
        fmt_dur(stats.min),
        stats.per_sec(),
        stats.iters,
    );
}

/// Print a table header for `report` rows.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "median", "mean", "min", "rate"
    );
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
