//! Minimal benchmark harness (the environment has no criterion): warmup +
//! auto-calibrated iteration count + robust statistics, printed as aligned
//! rows so `cargo bench` output reads like the paper's tables, plus a
//! machine-readable `BENCH_<name>.json` record at the repo root so every
//! perf PR captures before/after numbers (EXPERIMENTS.md §Perf).
//!
//! The per-benchmark time budget honours the `BENCH_BUDGET_MS` environment
//! variable (default 600 ms) — CI smoke-runs the benches with a few
//! milliseconds so bench bitrot fails the build instead of being discovered
//! at measurement time.
//!
//! Included per-bench via `#[path = "harness.rs"] mod harness;` — each bench
//! uses a different subset, hence the module-wide dead_code allowance.
#![allow(dead_code)]

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use bingflow::util::json::Json;

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.median.as_secs_f64().max(1e-12)
    }
}

/// Per-benchmark wall-time budget: `BENCH_BUDGET_MS` override or 600 ms.
pub fn budget() -> Duration {
    match std::env::var("BENCH_BUDGET_MS") {
        Ok(ms) => Duration::from_millis(
            ms.parse::<u64>()
                .unwrap_or_else(|_| panic!("BENCH_BUDGET_MS must be an integer, got `{ms}`")),
        ),
        Err(_) => Duration::from_millis(600),
    }
}

/// Measure `f`, returning robust stats. Auto-calibrates the iteration count
/// to spend roughly [`budget`] wall time per benchmark.
pub fn bench<F: FnMut()>(mut f: F) -> Stats {
    bench_with_budget(budget(), &mut f)
}

pub fn bench_with_budget<F: FnMut()>(budget: Duration, f: &mut F) -> Stats {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / once.as_secs_f64()).clamp(3.0, 10_000.0) as u32;

    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<Duration>() / iters;
    Stats { iters, mean, median: samples[samples.len() / 2], min: samples[0] }
}

/// Print one result row: `name  median  mean  min  rate`.
pub fn report(name: &str, stats: &Stats) {
    println!(
        "{name:<44} {:>12} {:>12} {:>12} {:>12.1}/s  (n={})",
        fmt_dur(stats.median),
        fmt_dur(stats.mean),
        fmt_dur(stats.min),
        stats.per_sec(),
        stats.iters,
    );
}

/// Print a table header for `report` rows.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>14}",
        "benchmark", "median", "mean", "min", "rate"
    );
}

pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects rows + derived figures and writes `BENCH_<name>.json` at the
/// repo root — the machine-readable perf trajectory (EXPERIMENTS.md §Perf).
pub struct JsonReport {
    name: &'static str,
    entries: Vec<Json>,
    derived: BTreeMap<String, Json>,
}

impl JsonReport {
    pub fn new(name: &'static str) -> Self {
        Self { name, entries: Vec::new(), derived: BTreeMap::new() }
    }

    /// Record one measured row (same data as the printed table).
    pub fn record(&mut self, name: &str, stats: &Stats) {
        let mut row = BTreeMap::new();
        row.insert("name".to_string(), Json::Str(name.to_string()));
        row.insert("iters".to_string(), Json::Num(stats.iters as f64));
        row.insert("median_ns".to_string(), Json::Num(stats.median.as_nanos() as f64));
        row.insert("mean_ns".to_string(), Json::Num(stats.mean.as_nanos() as f64));
        row.insert("min_ns".to_string(), Json::Num(stats.min.as_nanos() as f64));
        row.insert("per_sec".to_string(), Json::Num(stats.per_sec()));
        self.entries.push(Json::Obj(row));
    }

    /// Print + record in one step.
    pub fn row(&mut self, name: &str, stats: &Stats) {
        report(name, stats);
        self.record(name, stats);
    }

    /// Record one free-form row — for benches whose figures are not
    /// iteration `Stats` (closed-loop latency percentiles, throughput).
    /// Lands in `entries` alongside the Stats rows.
    pub fn record_fields(&mut self, name: &str, fields: &[(&str, f64)]) {
        let mut row = BTreeMap::new();
        row.insert("name".to_string(), Json::Str(name.to_string()));
        for (key, value) in fields {
            row.insert((*key).to_string(), Json::Num(*value));
        }
        self.entries.push(Json::Obj(row));
    }

    /// Attach a derived figure (speedup ratio, candidate count, …).
    pub fn note(&mut self, key: &str, value: f64) {
        self.derived.insert(key.to_string(), Json::Num(value));
    }

    /// Write `BENCH_<name>.json` atomically (tmp file + rename) at the repo
    /// root and return its path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str(self.name.to_string()));
        top.insert("budget_ms".to_string(), Json::Num(budget().as_millis() as f64));
        if let Ok(since) = std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH) {
            top.insert("unix_time".to_string(), Json::Num(since.as_secs() as f64));
        }
        top.insert("entries".to_string(), Json::Arr(self.entries.clone()));
        top.insert("derived".to_string(), Json::Obj(self.derived.clone()));
        let doc = Json::Obj(top);

        // benches run with cwd = rust/; the record lives at the repo root
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
        let path = root.join(format!("BENCH_{}.json", self.name));
        let tmp = root.join(format!("BENCH_{}.json.tmp", self.name));
        std::fs::write(&tmp, doc.to_string() + "\n")?;
        std::fs::rename(&tmp, &path)?;
        Ok(path)
    }

    /// `write`, reporting the outcome on stdout (benches must not fail the
    /// run just because the checkout is read-only).
    pub fn write_and_announce(&self) {
        match self.write() {
            Ok(path) => println!("\nwrote {}", path.display()),
            Err(e) => println!("\nWARNING: could not write BENCH_{}.json: {e}", self.name),
        }
    }
}
