//! The detection-cascade acceptance suite (ISSUE 6):
//!
//! 1. **Cascade parity** — served detections are identical across all three
//!    proposal backends (software / engine / sim), every shard count and
//!    every routing policy, and equal the direct [`CascadeDetector`] oracle:
//!    the cascade inherits the proposal stage's bit-parity contract because
//!    both paths run the same `rank_and_select` + `run_cascade` code.
//! 2. **Greedy-NMS properties** — idempotence, the pairwise-IoU invariant,
//!    score-sorted output, determinism and top-score survival over seeded
//!    random box soups. (Kept-count monotonicity in the IoU threshold is
//!    deliberately NOT asserted: greedy NMS does not have that property —
//!    raising the threshold can keep an extra mid-score box that then
//!    suppresses several lower ones.)
//! 3. **Confidence head goldens** — `PlattScaling` against closed-form
//!    sigmoid values, and `train_platt` rescoring on separable data.
//! 4. **Error surface** — the [`ServeError`] umbrella carries both phases
//!    through one `?`-friendly signature.

use std::sync::Arc;

use bingflow::metrics::iou;
use bingflow::nms::greedy_nms;
use bingflow::prelude::*;
use bingflow::svm::train_platt;
use bingflow::util::rng;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (16, 32), (32, 32)]
}

fn backends() -> Vec<Arc<dyn ProposalBackend>> {
    let pyramid = Pyramid::new(sizes());
    vec![
        Arc::new(SoftwareBing::new(
            pyramid.clone(),
            default_stage1(),
            Stage2Calibration::identity(sizes()),
            ScoringMode::Exact,
        )),
        Arc::new(EngineBackend::new(
            Arc::new(MockEngine::new(default_stage1(), sizes())),
            pyramid.clone(),
        )),
        Arc::new(SimulatedAccelerator::new(
            AcceleratorConfig::default(),
            pyramid,
            default_stage1(),
        )),
    ]
}

fn bb(x0: u32, y0: u32, x1: u32, y1: u32) -> BBox {
    BBox { x0, y0, x1, y1 }
}

/// Seeded random box soup with clustered overlaps (so NMS actually bites).
fn box_soup(seed: u64, n: usize) -> Vec<(BBox, f32)> {
    let mut r = rng(seed);
    (0..n)
        .map(|_| {
            let cx = r.range_u32_inclusive(0, 160);
            let cy = r.range_u32_inclusive(0, 120);
            let w = r.range_u32_inclusive(8, 48);
            let h = r.range_u32_inclusive(8, 48);
            let score = (r.f64() * 200.0 - 100.0) as f32;
            (bb(cx, cy, cx + w, cy + h), score)
        })
        .collect()
}

// ---------------------------------------------------------------- parity --

#[test]
fn served_cascade_is_bit_identical_across_backends_shards_and_policies() {
    let cfg_base = ServingConfig {
        top_k: 80,
        workers: 2,
        cascade: CascadeConfig { top_k: 20, nms_thresh: 0.45, ..Default::default() },
        ..Default::default()
    };
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;

    // the oracle: direct cascade over the software backend
    let oracle = CascadeDetector::new(
        backends().remove(0),
        Stage2Calibration::identity(sizes()),
        CascadeParams::from_config(&cfg_base.cascade),
        cfg_base.top_k,
    );
    let want = oracle.detect(&img).unwrap();
    assert!(!want.is_empty(), "degenerate scene: the oracle found nothing");

    for backend in backends() {
        let name = backend.name();
        for shards in [1usize, 2] {
            for policy in [
                RoutePolicyKind::RoundRobin,
                RoutePolicyKind::LeastLoaded,
                RoutePolicyKind::ScaleAffinity,
            ] {
                let cfg = ServingConfig { shards, policy, ..cfg_base.clone() };
                let rt: ServerRuntime =
                    ServerRuntime::new(backend.clone(), Stage2Calibration::identity(sizes()), cfg);
                let resp = rt.detect(img.clone()).unwrap().wait().unwrap();
                assert_eq!(
                    resp.items, want,
                    "cascade diverged: backend `{name}` x {shards} shards x {policy:?}"
                );
                rt.shutdown();
            }
        }
    }
}

#[test]
fn detect_batch_matches_per_image_oracle() {
    let cfg = ServingConfig { shards: 2, top_k: 60, workers: 2, ..Default::default() };
    let oracle = CascadeDetector::new(
        backends().remove(0),
        Stage2Calibration::identity(sizes()),
        CascadeParams::from_config(&cfg.cascade),
        cfg.top_k,
    );
    let ds = SyntheticDataset::voc_like_val(4);
    let images: Vec<_> = ds.iter().map(|s| s.image).collect();
    let rt: ServerRuntime = ServerRuntime::new(
        backends().remove(1),
        Stage2Calibration::identity(sizes()),
        cfg,
    );
    let results = rt.detect_batch(images.clone());
    assert_eq!(results.len(), images.len());
    for (img, resp) in images.iter().zip(results) {
        let resp = resp.expect("healthy run");
        assert_eq!(resp.items, oracle.detect(img).unwrap());
    }
    rt.shutdown();
}

#[test]
fn per_request_overrides_cap_and_floor_served_detections() {
    let rt: ServerRuntime = ServerRuntime::new(
        backends().remove(0),
        Stage2Calibration::identity(sizes()),
        ServingConfig { top_k: 80, workers: 2, ..Default::default() },
    );
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;

    let full = rt.detect(img.clone()).unwrap().wait().unwrap().items;
    assert!(!full.is_empty());

    let capped = rt
        .submit_detect(DetectRequest::new(img.clone()).top_k(2))
        .unwrap()
        .wait()
        .unwrap()
        .items;
    assert!(capped.len() <= 2);
    assert_eq!(capped[..], full[..capped.len()], "the cap must be a prefix");

    let floored = rt
        .submit_detect(DetectRequest::new(img).min_confidence(0.9))
        .unwrap()
        .wait()
        .unwrap()
        .items;
    assert!(floored.iter().all(|d| d.confidence >= 0.9));
    assert!(floored.len() <= full.len());
    rt.shutdown();
}

// ------------------------------------------------------ NMS properties --

#[test]
fn prop_nms_is_idempotent() {
    for seed in 0..6 {
        for thresh in [0.3f32, 0.5, 0.7] {
            let kept = greedy_nms(box_soup(seed, 120), thresh);
            assert_eq!(
                greedy_nms(kept.clone(), thresh),
                kept,
                "seed {seed} thresh {thresh}: NMS of its own output changed it"
            );
        }
    }
}

#[test]
fn prop_kept_boxes_are_pairwise_below_threshold() {
    for seed in 0..6 {
        for thresh in [0.3f32, 0.5, 0.7] {
            let kept = greedy_nms(box_soup(seed, 120), thresh);
            for i in 0..kept.len() {
                for j in (i + 1)..kept.len() {
                    assert!(
                        iou(&kept[i].0, &kept[j].0) < thresh,
                        "seed {seed}: kept boxes {i},{j} overlap >= {thresh}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_output_is_score_sorted_and_deterministic() {
    for seed in 0..6 {
        let soup = box_soup(seed, 120);
        let kept = greedy_nms(soup.clone(), 0.5);
        for pair in kept.windows(2) {
            assert!(pair[0].1 >= pair[1].1, "seed {seed}: output not score-sorted");
        }
        // determinism: same input (even reshuffled) → same output
        let mut shuffled = soup;
        rng(seed ^ 0xdead).shuffle(&mut shuffled);
        assert_eq!(greedy_nms(shuffled, 0.5), kept, "seed {seed}: order-dependent result");
    }
}

#[test]
fn prop_top_score_always_survives() {
    for seed in 0..6 {
        let soup = box_soup(seed, 120);
        let best = soup
            .iter()
            .cloned()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let kept = greedy_nms(soup, 0.5);
        assert_eq!(kept[0].1, best.1, "seed {seed}: the top-scored box was suppressed");
    }
}

#[test]
fn two_boxes_suppression_is_monotone_in_threshold() {
    // with exactly two boxes the kept count IS monotone in the threshold
    // (the general-case counterexample needs a third box to chain through)
    let a = (bb(0, 0, 19, 19), 2.0);
    let b = (bb(5, 5, 24, 24), 1.0); // IoU(a, b) = 225/575 ≈ 0.391
    let pair = vec![a, b];
    let mut last = 0;
    for thresh in [0.1f32, 0.3, 0.39, 0.4, 0.6, 1.0] {
        let kept = greedy_nms(pair.clone(), thresh).len();
        assert!(kept >= last, "two-box suppression went backwards at {thresh}");
        last = kept;
    }
    assert_eq!(last, 2, "at thresh 1.0 both distinct boxes must survive");
}

#[test]
fn prop_topk_prefix_holds_on_random_soups() {
    for seed in 0..4 {
        let soup = box_soup(seed, 150);
        let full = greedy_nms(soup.clone(), 0.5);
        for k in [0usize, 1, 3, 10, full.len(), full.len() + 5] {
            assert_eq!(
                bingflow::nms::greedy_nms_topk(soup.clone(), 0.5, k),
                full[..k.min(full.len())],
                "seed {seed}, k {k}"
            );
        }
    }
}

// ------------------------------------------------- confidence goldens --

#[test]
fn platt_identity_matches_closed_form_sigmoid() {
    let p = PlattScaling::identity();
    // golden values: σ(0)=1/2, σ(±ln 3)=3/4, 1/4
    let ln3 = 3f32.ln();
    assert_eq!(p.confidence(0.0), 0.5);
    assert!((p.confidence(ln3) - 0.75).abs() < 1e-6);
    assert!((p.confidence(-ln3) - 0.25).abs() < 1e-6);
    // a scaled head shifts the decision point: σ(2·1.5 − 3) = 0.5
    let q = PlattScaling::new(2.0, -3.0);
    assert!((q.confidence(1.5) - 0.5).abs() < 1e-6);
}

#[test]
fn trained_platt_rescoring_golden() {
    // separable (score, label) data around ±3: the fitted head must be
    // increasing, cross 1/2 near the midpoint, and saturate on both flanks
    let samples: Vec<(f32, bool)> = (0..300)
        .map(|i| {
            let is_object = i % 2 == 0;
            let jitter = (i as f32 * 0.61).cos() * 0.4;
            (if is_object { 3.0 + jitter } else { -3.0 + jitter }, is_object)
        })
        .collect();
    let p = train_platt(&samples, 11);
    assert!(p.a > 0.0);
    assert!(p.confidence(3.0) > 0.9);
    assert!(p.confidence(-3.0) < 0.1);
    let mid = p.confidence(0.0);
    assert!((0.25..=0.75).contains(&mid), "midpoint confidence drifted: {mid}");
    // deterministic: the golden refit reproduces bit-exactly
    assert_eq!(train_platt(&samples, 11), p);
}

#[test]
fn cascade_confidences_are_the_platt_map_of_the_scores() {
    let params = CascadeParams { platt: PlattScaling::new(0.01, -0.5), ..Default::default() };
    let proposals: Vec<Proposal> = (0..8)
        .map(|i| {
            let o = i as u32 * 30;
            Proposal { bbox: bb(o, 0, o + 9, 9), score: 100.0 - i as f32 * 10.0 }
        })
        .collect();
    let dets = run_cascade(&proposals, &params);
    assert_eq!(dets.len(), 8, "disjoint boxes: NMS keeps all");
    for d in &dets {
        let want = params.platt.confidence(d.score);
        assert_eq!(d.confidence, want, "confidence must be platt(score)");
    }
}

// ------------------------------------------------------- error surface --

#[test]
fn serve_error_umbrella_spans_both_phases() {
    fn detect_one(rt: &ServerRuntime, img: ImageRgb) -> Result<Vec<Detection>, ServeError> {
        // one `?`-friendly signature across admission and resolution
        Ok(rt.detect(img)?.wait()?.items)
    }

    let rt: ServerRuntime = ServerRuntime::new(
        backends().remove(0),
        Stage2Calibration::identity(sizes()),
        ServingConfig { workers: 2, ..Default::default() },
    );
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    assert!(!detect_one(&rt, img.clone()).unwrap().is_empty());

    // submit-phase failure surfaces as ServeError::Submit
    rt.drain_shard(0);
    assert_eq!(
        detect_one(&rt, img).unwrap_err(),
        ServeError::Submit(SubmitError::Unroutable)
    );
    rt.shutdown();
}

#[test]
fn cancelled_detect_resolves_with_a_typed_error() {
    let rt: ServerRuntime = ServerRuntime::new(
        backends().remove(2),
        Stage2Calibration::identity(sizes()),
        ServingConfig { workers: 2, ..Default::default() },
    );
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let handle = rt.detect(img).unwrap();
    handle.cancel();
    match handle.wait() {
        // the race is legal: cancellation is best-effort, a finished image
        // still resolves Ok
        Ok(resp) => assert!(!resp.items.is_empty()),
        Err(e) => assert_eq!(e, ResponseError::Cancelled),
    }
    rt.shutdown();
}
