//! Temporal-coherence acceptance (ISSUE 10): the dirty-tile incremental
//! recompute path must be **bit-identical** to full recompute for every
//! scoring mode, score kernel, tile size and jitter pattern — including the
//! halo edge cases (zero change, whole-frame change, border tiles,
//! mid-session dimension change). On top of the kernel-level property
//! sweep, the serving-level soaks prove that prior-seeded ranking never
//! changes the output, that a session-pinned stream survives a mid-stream
//! shard drain with exact `cache_invalidations` accounting, and that a
//! recorded trace replays bit-identically through the runtime.

use std::sync::Arc;

use bingflow::baseline::{rank_and_select, rank_and_select_seeded, ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::{RoutePolicyKind, ServingConfig, TemporalConfig};
use bingflow::coordinator::ProposalRequest;
use bingflow::data::{SceneConfig, SyntheticVideo};
use bingflow::image::ImageRgb;
use bingflow::serving::ServerRuntime;
use bingflow::simd::{KernelChoice, ScoreKernel};
use bingflow::svm::Stage2Calibration;
use bingflow::telemetry::ServeMetrics;
use bingflow::temporal::{scale_candidates_for_ticket, trace, SessionStore};

const TOP_K: usize = 60;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32)]
}

fn software(mode: ScoringMode, kernel: ScoreKernel) -> SoftwareBing {
    SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        mode,
    )
    .with_kernel(KernelChoice::Fixed(kernel))
}

/// Every scoring mode the pipeline ships; the HiPrecision weights are an
/// arbitrary signed pattern (any weights must hold the identity).
fn modes() -> Vec<ScoringMode> {
    let mut hi = [[0i32; 8]; 8];
    for (dy, row) in hi.iter_mut().enumerate() {
        for (dx, w) in row.iter_mut().enumerate() {
            *w = (dy as i32 - 3) * (dx as i32 + 1) - 5;
        }
    }
    vec![
        ScoringMode::Exact,
        ScoringMode::Binarized { nw: 3, ng: 6 },
        ScoringMode::HiPrecision(hi),
    ]
}

/// Every kernel runnable on this host (the binarized path dispatches on
/// these; Exact/HiPrecision ignore them).
fn kernels() -> Vec<ScoreKernel> {
    let mut v = vec![ScoreKernel::Reference, ScoreKernel::Swar];
    for k in [ScoreKernel::Avx2, ScoreKernel::Neon] {
        if k.is_available() {
            v.push(k);
        }
    }
    v
}

/// Play `frames` through one session and assert, frame by frame and scale
/// by scale, that the incremental path reproduces the full recompute of the
/// ticket's canonical frame bitwise.
fn assert_clip_bit_identical(sw: &SoftwareBing, tile: usize, frames: &[ImageRgb]) {
    let store = SessionStore::new(TemporalConfig { tile, pixel_threshold: 0 }, sizes().len());
    let m = ServeMetrics::default();
    for (i, f) in frames.iter().enumerate() {
        let ticket = store.begin_frame(9, f, &m);
        for s in 0..sizes().len() {
            let got = scale_candidates_for_ticket(sw, s, &ticket);
            let want = sw.candidates_for_scale(ticket.frame().as_ref(), s);
            assert_eq!(
                got, want,
                "frame {i} scale {s} tile {tile} mode {:?}: incremental diverged",
                sw.mode
            );
        }
    }
}

#[test]
fn incremental_matches_full_for_every_mode_kernel_tile_and_jitter() {
    for mode in modes() {
        // the kernel only reaches the binarized scorer; sweeping it for the
        // other modes would re-run identical cells
        let kernel_set = if matches!(mode, ScoringMode::Binarized { .. }) {
            kernels()
        } else {
            vec![ScoreKernel::Swar]
        };
        for kernel in kernel_set {
            let sw = software(mode, kernel);
            for tile in [8usize, 16, 33] {
                for jitter in [0u32, 1, 3] {
                    let video = SyntheticVideo::new(
                        SceneConfig { width: 64, height: 64, ..Default::default() },
                        1000 + tile as u64 + jitter as u64,
                        jitter,
                    );
                    let frames: Vec<ImageRgb> = (0..4).map(|f| video.frame(f)).collect();
                    assert_clip_bit_identical(&sw, tile, &frames);
                }
            }
        }
    }
}

#[test]
fn halo_edge_cases_stay_bit_identical() {
    // hand-built frame deltas that stress the ±1 gradient dilation and the
    // 7-row score halo exactly where they can go wrong: tile borders,
    // image borders, empty and full dirty sets, and a mid-session
    // dimension change
    let base = |w: usize, h: usize| {
        ImageRgb::from_fn(w, h, |x, y| {
            [((x * 31 + y * 7) % 253) as u8, ((x ^ y) % 251) as u8, ((x + 2 * y) % 249) as u8]
        })
    };
    let (w, h) = (80usize, 56usize);
    let mut corner_tl = base(w, h);
    corner_tl.put(0, 0, [255, 0, 255]);
    let mut corner_br = base(w, h);
    corner_br.put(w - 1, h - 1, [0, 255, 0]);
    let b0 = base(w, h);
    let inverted = ImageRgb::from_fn(w, h, |x, y| {
        let p = b0.get(x, y);
        [255 - p[0], 255 - p[1], 255 - p[2]]
    });
    let mut stripe = base(w, h);
    for x in 0..w {
        stripe.put(x, 15, [1, 2, 3]);
        stripe.put(x, 16, [4, 5, 6]); // straddles the tile-16 boundary
    }
    let clip: Vec<ImageRgb> = vec![
        base(w, h),
        base(w, h), // zero change: empty dirty set, cached maps reused
        corner_tl,  // top-left border tile, halo clamps at row 0
        corner_br,  // bottom-right tile, halo clamps at the last score row
        inverted,   // whole-frame change: every tile dirty
        stripe,
        base(64, 64), // dimension change: forces full recompute
        base(64, 64),
    ];
    for mode in [ScoringMode::Exact, ScoringMode::Binarized { nw: 3, ng: 6 }] {
        for tile in [8usize, 16, 33] {
            assert_clip_bit_identical(&software(mode, ScoreKernel::Swar), tile, &clip);
        }
    }
}

#[test]
fn prior_seeding_never_changes_the_ranking() {
    let sw = software(ScoringMode::Exact, ScoreKernel::Swar);
    let img = SyntheticVideo::new(
        SceneConfig { width: 96, height: 96, ..Default::default() },
        77,
        0,
    )
    .frame(0);
    let candidates = sw.candidates(&img);
    let pyramid = Pyramid::new(sizes());
    let stage2 = Stage2Calibration::identity(sizes());
    let want = rank_and_select(&candidates, &pyramid, &stage2, img.w, img.h, TOP_K);

    // real priors: the previous ranking's own winners
    let winners =
        rank_and_select_seeded(&candidates, &pyramid, &stage2, img.w, img.h, TOP_K, &[]).winners;
    assert!(!winners.is_empty());
    // every candidate as a prior: the seeding pass pushes the whole stream
    let all: Vec<(u16, u16, u16)> =
        candidates.iter().map(|c| (c.scale_idx as u16, c.y, c.x)).collect();
    let cases: Vec<(&str, Vec<(u16, u16, u16)>)> = vec![
        ("no priors", vec![]),
        ("stale miss", vec![(0, 999, 999)]),
        ("previous winners", winners.clone()),
        ("every candidate", all.clone()),
    ];
    for (name, priors) in cases {
        let got =
            rank_and_select_seeded(&candidates, &pyramid, &stage2, img.w, img.h, TOP_K, &priors);
        assert_eq!(got.proposals, want, "priors `{name}` changed the ranking");
        match name {
            "stale miss" => assert_eq!(got.prior_hits, 0, "a miss is not a hit"),
            "previous winners" => assert_eq!(got.prior_hits, winners.len() as u64),
            "every candidate" => assert_eq!(got.prior_hits, candidates.len() as u64),
            _ => assert_eq!(got.prior_hits, 0),
        }
    }
}

fn session_runtime(shards: usize) -> ServerRuntime<SoftwareBing> {
    ServerRuntime::new(
        Arc::new(software(ScoringMode::Exact, ScoreKernel::Swar)),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards,
            policy: RoutePolicyKind::SessionAffinity,
            workers: 2,
            top_k: TOP_K,
            ..Default::default()
        },
    )
}

#[test]
fn session_stream_survives_mid_stream_drain_with_exact_invalidation_count() {
    let video = SyntheticVideo::new(
        SceneConfig { width: 96, height: 96, ..Default::default() },
        11,
        2,
    );
    let frames: Vec<ImageRgb> = (0..8).map(|f| video.frame(f)).collect();
    let reference = software(ScoringMode::Exact, ScoreKernel::Swar);
    let expected: Vec<_> = frames.iter().map(|f| reference.propose(f, TOP_K)).collect();

    let rt = session_runtime(3);
    const SID: u64 = 5; // home shard: 5 % 3 == 2
    for (i, f) in frames.iter().enumerate() {
        if i == 4 {
            rt.drain_shard(2); // yank the pinned shard mid-stream
        }
        let resp = rt.serve(ProposalRequest::new(f.clone()).session(SID)).unwrap();
        assert_eq!(resp.items, expected[i], "frame {i} diverged across the drain");
    }
    assert_eq!(rt.metrics.cache_invalidations.get(), 1, "exactly one re-pin");
    assert_eq!(rt.metrics.route_fallbacks.get(), 1);
    // frames 0..4 on the home shard, 4..8 on the circular re-pin target
    assert_eq!(rt.metrics.shard(2).unwrap().images.get(), 4);
    assert_eq!(rt.metrics.shard(0).unwrap().images.get(), 4);
    // the session now has store entries on both shards it visited
    assert_eq!(rt.metrics.sessions_active.get(), 2);

    // the pin must stick on the re-pin target even after the home resumes
    rt.resume_shard(2);
    let resp = rt.serve(ProposalRequest::new(frames[7].clone()).session(SID)).unwrap();
    assert_eq!(resp.items, expected[7]);
    assert_eq!(rt.metrics.shard(0).unwrap().images.get(), 5, "pin flapped back");
    assert_eq!(rt.metrics.cache_invalidations.get(), 1, "no extra invalidation");
    rt.shutdown();
}

#[test]
fn static_clip_skips_every_tile_and_reuses_priors() {
    let video = SyntheticVideo::new(
        SceneConfig { width: 96, height: 96, ..Default::default() },
        23,
        0, // zero jitter: every frame is the first frame
    );
    let frame = video.frame(0);
    let reference = software(ScoringMode::Exact, ScoreKernel::Swar);
    let want = reference.propose(&frame, TOP_K);

    let rt = session_runtime(1);
    for i in 0..3 {
        let resp = rt.serve(ProposalRequest::new(video.frame(i)).session(1)).unwrap();
        assert_eq!(resp.items, want, "static frame {i} diverged");
    }
    let per_frame = rt.metrics.tiles_recomputed.get();
    assert!(per_frame > 0, "the first frame recomputes every tile");
    assert_eq!(
        rt.metrics.tiles_skipped.get(),
        2 * per_frame,
        "identical frames must skip every tile"
    );
    assert!(rt.metrics.prior_hits.get() > 0, "repeated winners must hit the priors");
    assert_eq!(rt.metrics.sessions_active.get(), 1);
    rt.shutdown();
}

#[test]
fn recorded_trace_replays_bit_identically_through_the_runtime() {
    let path = std::env::temp_dir()
        .join(format!("bingflow_temporal_replay_{}.jsonl", std::process::id()));
    let offsets = trace::arrival_offsets_poisson(6, 200.0, 3);
    let events: Vec<trace::TraceEvent> = offsets
        .iter()
        .enumerate()
        .map(|(i, &at_ms)| trace::TraceEvent {
            at_ms,
            session: (i % 2) as u64,
            seed: 50 + (i % 2) as u64,
            frame: (i / 2) as u64,
            width: 96,
            height: 96,
        })
        .collect();
    trace::save(&path, &events).unwrap();
    let replay = trace::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(replay, events, "trace must round-trip losslessly");

    let reference = software(ScoringMode::Exact, ScoreKernel::Swar);
    let rt = session_runtime(2);
    for ev in &replay {
        let frame = SyntheticVideo::new(
            SceneConfig { width: ev.width, height: ev.height, ..Default::default() },
            ev.seed,
            2,
        )
        .frame(ev.frame);
        let want = reference.propose(&frame, TOP_K);
        let resp = rt.serve(ProposalRequest::new(frame).session(ev.session)).unwrap();
        assert_eq!(resp.items, want, "replayed event diverged from the oracle");
    }
    assert_eq!(rt.metrics.sessions_active.get(), 2);
    rt.shutdown();
}
