//! The ProposalBackend seam + stage-graph acceptance suite (ISSUE 3):
//!
//! 1. `Coordinator<SimulatedAccelerator>` == `Coordinator<SoftwareBing>` ==
//!    `Coordinator<EngineBackend>` == `baseline::rank_and_select` on
//!    synthetic images — one generic serving code path, bit-identical
//!    proposals across all three backends.
//! 2. The stage-graph `Accelerator` stays within the old batch model's
//!    documented overlap bounds (the former `SCALE_SWAP_CYCLES = 8` /
//!    `SCALE_FLUSH_CYCLES = 64` contributions, now derived by the driver),
//!    while producing bit-identical candidates.
//! 3. The `PipelineDriver`'s stall/starve accounting is invariant to NMS
//!    FIFO depth changes above the high-water mark (property test over
//!    several geometries).

use std::sync::Arc;

use bingflow::backend::{EngineBackend, ProposalBackend, SimulatedAccelerator};
use bingflow::baseline::{rank_and_select, ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Proposal, Pyramid};
use bingflow::config::{AcceleratorConfig, ServingConfig};
use bingflow::coordinator::Coordinator;
use bingflow::data::SyntheticDataset;
use bingflow::dataflow::Accelerator;
use bingflow::image::ImageRgb;
use bingflow::runtime::MockEngine;
use bingflow::svm::Stage2Calibration;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (16, 32), (32, 32), (64, 64)]
}

fn software() -> SoftwareBing {
    SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    )
}

/// Serve one image through a coordinator over `backend` and return the
/// proposals — the single generic code path every backend flows through.
fn serve<B: ProposalBackend + ?Sized + 'static>(
    backend: Arc<B>,
    img: &ImageRgb,
    top_k: usize,
) -> (Vec<Proposal>, u64) {
    let coord = Coordinator::with_backend(
        backend,
        Stage2Calibration::identity(sizes()),
        ServingConfig { top_k, ..Default::default() },
    );
    let resp = coord
        .submit(img.clone())
        .expect("submission admitted")
        .wait()
        .expect("serving completes");
    let sim_cycles = coord.metrics.sim_cycles.get();
    coord.shutdown();
    (resp.items, sim_cycles)
}

#[test]
fn coordinator_serves_all_three_backends_bit_identically() {
    let pyramid = Pyramid::new(sizes());
    let stage2 = Stage2Calibration::identity(sizes());
    let sw_reference = software();
    let top_k = 150;
    for i in 0..3 {
        let img = SyntheticDataset::voc_like_val(3).sample(i).image;
        // ground truth: the reference ranking over the baseline's candidates
        let want = rank_and_select(
            &sw_reference.candidates(&img),
            &pyramid,
            &stage2,
            img.w,
            img.h,
            top_k,
        );

        let (via_software, sw_cycles) = serve(Arc::new(software()), &img, top_k);
        let (via_engine, en_cycles) = serve(
            Arc::new(EngineBackend::new(
                Arc::new(MockEngine::new(default_stage1(), sizes())),
                pyramid.clone(),
            )),
            &img,
            top_k,
        );
        let (via_sim, sim_cycles) = serve(
            Arc::new(SimulatedAccelerator::new(
                AcceleratorConfig::default(),
                pyramid.clone(),
                default_stage1(),
            )),
            &img,
            top_k,
        );

        assert_eq!(via_software, want, "software backend != rank_and_select on sample {i}");
        assert_eq!(via_engine, want, "engine backend != rank_and_select on sample {i}");
        assert_eq!(via_sim, want, "simulator backend != rank_and_select on sample {i}");

        // cycle telemetry: only the simulator feeds ServeMetrics::sim_cycles
        assert_eq!(sw_cycles, 0, "software backend must not report sim cycles");
        assert_eq!(en_cycles, 0, "engine backend must not report sim cycles");
        assert!(sim_cycles > 0, "simulator cycles must surface through ServeMetrics");
    }
}

#[test]
fn dyn_dispatch_uses_the_same_generic_path() {
    // runtime backend selection (the CLI's --backend flag) goes through
    // Coordinator<dyn ProposalBackend>; it must behave exactly like the
    // statically-typed coordinators above
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let backend: Arc<dyn ProposalBackend> = Arc::new(SimulatedAccelerator::new(
        AcceleratorConfig::default(),
        Pyramid::new(sizes()),
        default_stage1(),
    ));
    assert_eq!(backend.name(), "sim");
    let (via_dyn, cycles) = serve(backend, &img, 80);
    assert_eq!(via_dyn, software().propose(&img, 80));
    assert!(cycles > 0);
}

#[test]
fn stage_graph_cycles_match_the_documented_overlap_bounds() {
    // The pre-refactor model charged `fetch_done + SCALE_SWAP_CYCLES (8)`
    // for overlapped scales and `cycles + SCALE_FLUSH_CYCLES (64)` for the
    // final / non-overlapped ones. The driver now derives both overheads
    // from the stage graph; for the default geometry the derivation must
    // reproduce the documented constants — and therefore the old model's
    // totals — exactly, with bit-identical candidates.
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let sw = software();
    for overlap in [true, false] {
        let cfg = AcceleratorConfig { overlap_scales: overlap, ..Default::default() };
        let accel = Accelerator::new(cfg, Pyramid::new(sizes()), default_stage1());
        let report = accel.run_image(&img);
        assert_eq!(report.candidates, sw.candidates(&img), "candidates diverged");

        let last = report.per_scale.len() - 1;
        let old_model_total: u64 = report
            .per_scale
            .iter()
            .enumerate()
            .map(|(idx, s)| {
                if overlap && idx < last {
                    s.fetch_done_cycle + 8
                } else {
                    s.cycles + 64
                }
            })
            .sum();
        assert_eq!(
            report.total_cycles, old_model_total,
            "stage-graph totals left the documented bounds (overlap={overlap})"
        );
        for s in &report.per_scale {
            assert_eq!(s.swap_cycles, 8, "derived swap != documented constant");
            assert_eq!(s.flush_cycles, 64, "derived flush != documented constant");
            assert!(
                s.fetch_done_cycle <= s.cycles,
                "fetch front past the drain tail on {:?}",
                s.scale
            );
        }
    }
}

#[test]
fn prop_stall_starve_counters_invariant_to_fifo_depth_above_high_water() {
    // Property: once the NMS FIFO never fills, its depth is invisible —
    // the driver's backpressure/starve accounting and the cycle totals
    // must be bit-equal for every depth strictly above the high-water
    // mark. Probed across pipeline counts and both cache modes.
    let ds = SyntheticDataset::voc_like_val(2);
    for (pipelines, ping_pong) in [(1usize, true), (2, false), (4, true), (8, true)] {
        for case in 0..2 {
            let img = ds.sample(case).image;
            let probe_cfg = AcceleratorConfig {
                pipelines,
                ping_pong,
                nms_fifo_depth: 8192, // effectively unbounded (winners ≤ 144/scale)
                ..Default::default()
            };
            let probe = Accelerator::new(probe_cfg, Pyramid::new(sizes()), default_stage1())
                .run_image(&img);
            let high_water = probe
                .per_scale
                .iter()
                .map(|s| s.fifo_max_occupancy)
                .max()
                .unwrap();
            assert!(high_water > 0, "degenerate probe");

            for depth in [high_water + 1, high_water + 7, 4096] {
                let cfg = AcceleratorConfig {
                    pipelines,
                    ping_pong,
                    nms_fifo_depth: depth,
                    ..Default::default()
                };
                let got = Accelerator::new(cfg, Pyramid::new(sizes()), default_stage1())
                    .run_image(&img);
                let ctx = format!(
                    "pipelines={pipelines} ping_pong={ping_pong} case={case} depth={depth}"
                );
                assert_eq!(got.total_cycles, probe.total_cycles, "cycles changed: {ctx}");
                for (g, p) in got.per_scale.iter().zip(&probe.per_scale) {
                    assert_eq!(g.cycles, p.cycles, "{ctx}");
                    assert_eq!(g.fetch_done_cycle, p.fetch_done_cycle, "{ctx}");
                    assert_eq!(g.kernel_starves, p.kernel_starves, "{ctx}");
                    assert_eq!(g.cache_starves, p.cache_starves, "{ctx}");
                    assert_eq!(g.fifo_max_occupancy, p.fifo_max_occupancy, "{ctx}");
                    assert_eq!(g.backpressure_stalls, 0, "{ctx}: FIFO above high water stalled");
                    assert_eq!(g.fifo_full_stalls, 0, "{ctx}: FIFO above high water filled");
                }
            }
        }
    }
}
