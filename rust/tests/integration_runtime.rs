//! Integration: the runtime layer against the AOT artifacts.
//!
//! The manifest checks run whenever `artifacts/` exists (and skip with a
//! notice when absent, so plain `cargo test` passes in a fresh checkout).
//! The PJRT execution tests additionally require the `pjrt` cargo feature —
//! without it the engine type does not exist and the tests are compiled out.

use std::path::{Path, PathBuf};

use bingflow::config::default_sizes;
use bingflow::runtime::Manifest;

/// `artifacts/` lives at the repository root; integration tests run with
/// cwd = `rust/` (the package dir), so resolve via the manifest dir.
fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: no artifacts/ — run `make artifacts`");
        None
    }
}

#[test]
fn manifest_matches_default_pyramid() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = Manifest::load(&dir).expect("manifest parses");
    manifest
        .check_pyramid(&default_sizes())
        .expect("artifacts cover the default pyramid");
    for scale in &manifest.scales {
        assert!(
            manifest.artifact_path(scale).exists(),
            "missing artifact {}",
            scale.file
        );
    }
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::artifacts_dir;

    use bingflow::bing::{winners_from_mask, Stage1Weights};
    use bingflow::config::default_sizes;
    use bingflow::data::SyntheticDataset;
    use bingflow::runtime::{Manifest, MockEngine, PjrtEngine, ScaleExecutor};

    #[test]
    fn pjrt_outputs_match_mock_engine_bit_exactly() {
        let Some(dir) = artifacts_dir() else { return };
        let sizes = default_sizes();
        let pjrt = PjrtEngine::from_dir(&dir, &sizes).expect("engine loads");
        // the weights baked into the HLOs: trained file if present, else default
        let weights = Stage1Weights::load_or_default(&dir);
        let mock = MockEngine::new(weights, sizes.clone());

        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        for (idx, &(h, w)) in sizes.iter().enumerate() {
            let resized = img.resize_nearest(w, h);
            let a = pjrt.execute(idx, &resized).expect("pjrt executes");
            let b = mock.execute(idx, &resized).expect("mock executes");
            assert_eq!(a.oh, b.oh);
            assert_eq!(a.ow, b.ow);
            // integer-valued f32: bit-exact equality is the contract
            assert_eq!(a.scores, b.scores, "score mismatch at scale {h}x{w}");
            assert_eq!(a.mask, b.mask, "mask mismatch at scale {h}x{w}");
        }
    }

    #[test]
    fn pjrt_winners_roundtrip_through_mask() {
        let Some(dir) = artifacts_dir() else { return };
        let sizes = default_sizes();
        let pjrt = PjrtEngine::from_dir(&dir, &sizes).expect("engine loads");
        let img = SyntheticDataset::voc_like_val(2).sample(1).image;
        let mut total = 0usize;
        for (idx, &(h, w)) in sizes.iter().enumerate() {
            let resized = img.resize_nearest(w, h);
            let out = pjrt.execute(idx, &resized).unwrap();
            let winners = winners_from_mask(&out.scores, &out.mask, out.oh, out.ow);
            // one winner per NMS block — count matches the block tiling
            let expect = out.oh.div_ceil(5) * out.ow.div_ceil(5);
            assert_eq!(winners.len(), expect, "scale {h}x{w}");
            total += winners.len();
        }
        assert!(total > 100, "implausibly few candidates: {total}");
    }

    #[test]
    fn pjrt_rejects_wrong_input_shape() {
        let Some(dir) = artifacts_dir() else { return };
        let sizes = default_sizes();
        let pjrt = PjrtEngine::from_dir(&dir, &sizes).expect("engine loads");
        let img = SyntheticDataset::voc_like_val(1).sample(0).image; // 192x192
        assert!(pjrt.execute(0, &img).is_err(), "shape check must fire");
    }

    #[test]
    fn pjrt_engine_is_reentrant_across_threads() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(&dir).unwrap();
        let pjrt = std::sync::Arc::new(PjrtEngine::load(&manifest).unwrap());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let full_sizes = manifest.sizes();
        let mut handles = Vec::new();
        for t in 0..4 {
            let pjrt = pjrt.clone();
            let img = img.clone();
            let full_sizes = full_sizes.clone();
            handles.push(std::thread::spawn(move || {
                let idx = t % full_sizes.len();
                let (h, w) = full_sizes[idx];
                let resized = img.resize_nearest(w, h);
                pjrt.execute(idx, &resized).unwrap().scores.len()
            }));
        }
        for h in handles {
            assert!(h.join().unwrap() > 0);
        }
    }
}
