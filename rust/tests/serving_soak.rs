//! Concurrency soak for the sharded serving runtime (ISSUE 5 acceptance):
//! many client threads submitting against a multi-shard `ServerRuntime`
//! through every `RoutePolicy` while one shard drains and resumes
//! mid-flight. Invariants:
//!
//! * no response is lost or duplicated (unique ids, exact counts),
//! * every response is bit-identical to `SoftwareBing::propose` for its
//!   image — across policies, shard counts and a mid-soak drain,
//! * the shared metrics sink accounts for every image exactly once.
//!
//! The chaos section (ISSUE 7 acceptance) re-runs the soak over a
//! [`ChaosBackend`] injecting deterministic panics/transients/latency:
//! every non-shed request must either succeed bit-identically to the
//! fault-free oracle or fail with a typed error, retry accounting must be
//! exact, and a poisoned shard must quarantine and then restore once its
//! fault window closes.

use std::collections::HashSet;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use bingflow::backend::{EngineBackend, ProposalBackend, SimulatedAccelerator};
use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Proposal, Pyramid};
use bingflow::config::{
    AcceleratorConfig, ResilienceConfig, RoutePolicyKind, ServingConfig,
};
use bingflow::coordinator::{DetectRequest, ProposalRequest, ResponseError};
use bingflow::data::{SceneConfig, SyntheticDataset};
use bingflow::detect::{CascadeDetector, CascadeParams, DetectionBackend};
use bingflow::fault::{ChaosBackend, FaultPlan};
use bingflow::image::ImageRgb;
use bingflow::runtime::MockEngine;
use bingflow::serving::{ServerRuntime, ShardHealth};
use bingflow::svm::Stage2Calibration;

const TOP_K: usize = 60;
const CLIENTS: usize = 6;
const ROUNDS: usize = 5;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32)]
}

fn software() -> Arc<SoftwareBing> {
    Arc::new(SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    ))
}

/// A mixed-size workload: one small frame (96×96) so `ScaleAffinity`
/// exercises both shard groups, two canonical 192×192 frames.
fn workload() -> Vec<ImageRgb> {
    let small = SyntheticDataset::new(
        SceneConfig { width: 96, height: 96, ..Default::default() },
        2007,
        1,
    )
    .sample(0)
    .image;
    let ds = SyntheticDataset::voc_like_val(2);
    vec![small, ds.sample(0).image, ds.sample(1).image]
}

fn soak(policy: RoutePolicyKind, shards: usize) {
    let images = workload();
    let reference = software();
    let expected: Vec<Vec<Proposal>> =
        images.iter().map(|img| reference.propose(img, TOP_K)).collect();

    let runtime: ServerRuntime<SoftwareBing> = ServerRuntime::new(
        software(),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards,
            policy,
            workers: 2,
            queue_depth: 4,
            top_k: TOP_K,
            ..Default::default()
        },
    );

    let seen_ids: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for client in 0..CLIENTS {
            let runtime = &runtime;
            let images = &images;
            let expected = &expected;
            let seen_ids = &seen_ids;
            s.spawn(move || {
                for round in 0..ROUNDS {
                    let pick = (client + round) % images.len();
                    let handle = runtime
                        .submit(images[pick].clone())
                        .expect("healthy runtime admits every request");
                    let id = handle.id();
                    let resp = handle.wait().expect("admitted request resolves");
                    assert_eq!(resp.id, id, "handle/response id mismatch");
                    assert_eq!(
                        resp.items, expected[pick],
                        "policy {policy:?}: image {pick} diverged from SoftwareBing::propose"
                    );
                    seen_ids.lock().unwrap().push(id);
                }
            });
        }
        // mid-soak rolling restart of one shard: the router steers away,
        // in-flight work on the shard completes, then it rejoins
        let runtime = &runtime;
        s.spawn(move || {
            runtime.drain_shard(1);
            assert!(runtime.shard(1).is_draining());
            runtime.resume_shard(1);
        });
    });

    let total = (CLIENTS * ROUNDS) as u64;
    let ids = seen_ids.into_inner().unwrap();
    let unique: HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(ids.len() as u64, total, "lost responses");
    assert_eq!(unique.len() as u64, total, "duplicated response ids");
    assert_eq!(runtime.metrics.requests.get(), total);
    assert_eq!(runtime.metrics.images_done.get(), total);
    assert_eq!(runtime.metrics.deadline_misses.get(), 0);
    assert_eq!(runtime.metrics.cancellations.get(), 0);
    assert_eq!(runtime.metrics.worker_lost.get(), 0);
    // every image's scales executed exactly once fleet-wide
    assert_eq!(
        runtime.metrics.scale_executions.get(),
        total * sizes().len() as u64
    );
    let routed: u64 = (0..shards)
        .map(|i| runtime.metrics.shard(i).unwrap().images.get())
        .sum();
    assert_eq!(routed, total, "router lane accounting diverged");
    runtime.shutdown();
}

#[test]
fn round_robin_soak_with_mid_flight_drain() {
    soak(RoutePolicyKind::RoundRobin, 3);
}

#[test]
fn least_loaded_soak_with_mid_flight_drain() {
    soak(RoutePolicyKind::LeastLoaded, 3);
}

#[test]
fn scale_affinity_soak_with_mid_flight_drain() {
    soak(RoutePolicyKind::ScaleAffinity, 4);
}

#[test]
fn every_policy_shard_count_backend_combination_is_bit_identical() {
    // The acceptance sweep: (policy x shard count x backend) — every cell
    // must reproduce `SoftwareBing::propose` exactly through the routed,
    // dyn-dispatched serving path.
    let images = workload();
    let reference = software();
    let expected: Vec<Vec<Proposal>> =
        images.iter().map(|img| reference.propose(img, TOP_K)).collect();
    let pyramid = Pyramid::new(sizes());

    let backends: Vec<Arc<dyn ProposalBackend>> = vec![
        software(),
        Arc::new(EngineBackend::new(
            Arc::new(MockEngine::new(default_stage1(), sizes())),
            pyramid.clone(),
        )),
        Arc::new(SimulatedAccelerator::new(
            AcceleratorConfig::default(),
            pyramid,
            default_stage1(),
        )),
    ];
    for backend in backends {
        for policy in [
            RoutePolicyKind::RoundRobin,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::ScaleAffinity,
        ] {
            for shards in [1usize, 2, 3] {
                let runtime: ServerRuntime = ServerRuntime::new(
                    backend.clone(),
                    Stage2Calibration::identity(sizes()),
                    ServingConfig {
                        shards,
                        policy,
                        workers: 2,
                        top_k: TOP_K,
                        ..Default::default()
                    },
                );
                for (pick, img) in images.iter().enumerate() {
                    let resp = runtime.submit(img.clone()).unwrap().wait().unwrap();
                    assert_eq!(
                        resp.items, expected[pick],
                        "backend `{}` x {policy:?} x {shards} shards: image {pick} diverged",
                        backend.name()
                    );
                }
                if backend.name() == "sim" {
                    assert!(
                        runtime.metrics.sim_cycles.get() > 0,
                        "simulator cycles must flow through the sharded runtime"
                    );
                }
                runtime.shutdown();
            }
        }
    }
}

#[test]
fn two_shard_soak_under_every_policy() {
    for policy in [
        RoutePolicyKind::RoundRobin,
        RoutePolicyKind::LeastLoaded,
        RoutePolicyKind::ScaleAffinity,
    ] {
        soak(policy, 2);
    }
}

// ── chaos soak (ISSUE 7) ────────────────────────────────────────────────

/// Mixed proposal/detect load from 6 client threads over a fault-injecting
/// backend with retries enabled. Invariants:
///
/// * every success is bit-identical to the fault-free oracle (proposals to
///   `SoftwareBing::propose`, detections to the direct `CascadeDetector`);
/// * every failure is a typed retryable-class error — nothing panics out
///   of the runtime, nothing hangs, nothing is silently dropped;
/// * no response id is lost or duplicated;
/// * retry accounting is exact: admitted submissions equal first attempts
///   plus re-submissions (hedging is off, so no third term).
#[test]
fn chaos_soak_mixed_load_is_bit_identical_or_typed() {
    const CHAOS_CLIENTS: usize = 6;
    const CHAOS_ROUNDS: usize = 6;

    let images = workload();
    let reference = software();
    let expected: Vec<Vec<Proposal>> =
        images.iter().map(|img| reference.propose(img, TOP_K)).collect();

    let cfg = ServingConfig {
        shards: 3,
        workers: 2,
        top_k: TOP_K,
        resilience: ResilienceConfig {
            retry_max_attempts: 6,
            retry_backoff_ms: 0,
            // lenient breaker: every shard shares the one chaos backend,
            // so this test is about the request path, not quarantine
            quarantine_failures: 1000,
            supervisor_window: 16,
            ..Default::default()
        },
        ..Default::default()
    };
    let detect_oracle = CascadeDetector::new(
        software(),
        Stage2Calibration::identity(sizes()),
        CascadeParams::from_config(&cfg.cascade),
        cfg.top_k,
    );
    let expected_det: Vec<_> =
        images.iter().map(|img| detect_oracle.detect(img).unwrap()).collect();

    let chaos = Arc::new(ChaosBackend::new(
        software(),
        FaultPlan {
            panic_p: 0.10,
            transient_p: 0.25,
            latency_p: 0.05,
            latency: Duration::from_micros(200),
            ..FaultPlan::zero(42)
        },
    ));
    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::new(
        chaos.clone(),
        Stage2Calibration::identity(sizes()),
        cfg,
    );

    let ids: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    let failures: Mutex<Vec<ResponseError>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for client in 0..CHAOS_CLIENTS {
            let runtime = &runtime;
            let images = &images;
            let expected = &expected;
            let expected_det = &expected_det;
            let ids = &ids;
            let failures = &failures;
            s.spawn(move || {
                for round in 0..CHAOS_ROUNDS {
                    let pick = (client + round) % images.len();
                    // even clients pump proposals, odd clients detections
                    if client % 2 == 0 {
                        match runtime.serve(ProposalRequest::new(images[pick].clone())) {
                            Ok(resp) => {
                                assert_eq!(
                                    resp.items, expected[pick],
                                    "chaos survivor diverged from the fault-free oracle"
                                );
                                ids.lock().unwrap().push(resp.id);
                            }
                            Err(e) => failures.lock().unwrap().push(e),
                        }
                    } else {
                        match runtime.serve_detect(DetectRequest::new(images[pick].clone())) {
                            Ok(resp) => {
                                assert_eq!(
                                    resp.items, expected_det[pick],
                                    "chaos detect survivor diverged from the direct cascade"
                                );
                                ids.lock().unwrap().push(resp.id);
                            }
                            Err(e) => failures.lock().unwrap().push(e),
                        }
                    }
                }
            });
        }
    });

    let total = (CHAOS_CLIENTS * CHAOS_ROUNDS) as u64;
    let ids = ids.into_inner().unwrap();
    let failures = failures.into_inner().unwrap();
    assert_eq!(
        ids.len() as u64 + failures.len() as u64,
        total,
        "every request must resolve exactly once"
    );
    let unique: HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(unique.len(), ids.len(), "duplicated response ids under chaos");
    // failures may only be the typed retryable-class errors that survive
    // an exhausted retry budget — never a rejection, cancel or deadline
    for f in &failures {
        assert!(
            matches!(f, ResponseError::WorkerLost | ResponseError::Transient),
            "unexpected failure class under chaos: {f:?}"
        );
    }
    // the schedule at seed 42 injects faults well inside this call volume
    assert!(chaos.injected_total() > 0, "chaos never fired — test is vacuous");
    let m = &runtime.metrics;
    assert!(m.retries.get() > 0, "faults were injected but nothing retried");
    assert_eq!(m.hedges_fired.get(), 0, "hedging is disabled in this soak");
    // exact accounting: every admitted submission is either a request's
    // first attempt or a counted re-submission
    assert_eq!(
        m.requests.get(),
        total + m.retries.get(),
        "admitted submissions != first attempts + retries"
    );
    assert!(
        m.worker_lost.get() + m.transient_errors.get() >= m.retries.get(),
        "retries without recorded fault outcomes"
    );
    runtime.wait_idle();
    runtime.shutdown();
}

/// A two-shard fleet where shard 1's backend panics on every call: the
/// supervisor must quarantine it (traffic routes around, requests still
/// succeed bit-identically via retry), and once the fault window closes
/// the breaker must half-open, probe, and restore the shard to `Healthy`.
#[test]
fn chaos_quarantine_then_recovery_restores_the_shard() {
    let images = workload();
    let reference = software();
    let expected: Vec<Vec<Proposal>> =
        images.iter().map(|img| reference.propose(img, TOP_K)).collect();

    let clean_plan = FaultPlan::zero(1);
    let poison_plan = FaultPlan { seed: 2, panic_p: 1.0, ..clean_plan.clone() };
    let shard0 = Arc::new(ChaosBackend::new(software(), clean_plan));
    let shard1 = Arc::new(ChaosBackend::new(software(), poison_plan));

    let runtime: ServerRuntime<ChaosBackend<SoftwareBing>> = ServerRuntime::from_backends(
        vec![shard0, shard1.clone()],
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            workers: 2,
            top_k: TOP_K,
            policy: RoutePolicyKind::RoundRobin,
            resilience: ResilienceConfig {
                retry_max_attempts: 4,
                retry_backoff_ms: 0,
                supervisor_window: 8,
                degrade_failures: 2,
                quarantine_failures: 3,
                quarantine_cooldown_ms: 50,
                probe_successes: 2,
                ..Default::default()
            },
            ..Default::default()
        },
    );

    // phase 1: drive load until the breaker trips on the poisoned shard —
    // every request must still succeed bit-identically via failover
    for i in 0..12 {
        let pick = i % images.len();
        let resp = runtime
            .serve(ProposalRequest::new(images[pick].clone()))
            .expect("failover must absorb a single poisoned shard");
        assert_eq!(resp.items, expected[pick], "failover response diverged");
        if runtime.shard_health(1) == ShardHealth::Quarantined {
            break;
        }
    }
    assert_eq!(
        runtime.shard_health(1),
        ShardHealth::Quarantined,
        "a shard panicking on every call must trip the breaker"
    );
    assert!(runtime.metrics.shards_quarantined.get() >= 1);

    // phase 2: close the fault window, wait out the cooldown, and drive
    // probe traffic until the breaker restores the shard
    shard1.set_enabled(false);
    std::thread::sleep(Duration::from_millis(80));
    let mut restored = false;
    for i in 0..40 {
        let pick = i % images.len();
        let resp = runtime
            .serve(ProposalRequest::new(images[pick].clone()))
            .expect("probe-phase requests must succeed");
        assert_eq!(resp.items, expected[pick], "probe-phase response diverged");
        if runtime.shard_health(1) == ShardHealth::Healthy {
            restored = true;
            break;
        }
    }
    assert!(restored, "recovered shard was never restored to Healthy");
    assert!(runtime.metrics.shards_restored.get() >= 1);

    // the restored shard serves real traffic again, still bit-identically
    let routed_before = runtime.metrics.shard(1).unwrap().images.get();
    for i in 0..4 {
        let pick = i % images.len();
        let resp = runtime.serve(ProposalRequest::new(images[pick].clone())).unwrap();
        assert_eq!(resp.items, expected[pick]);
    }
    assert!(
        runtime.metrics.shard(1).unwrap().images.get() > routed_before,
        "restored shard received no traffic"
    );
    runtime.shutdown();
}
