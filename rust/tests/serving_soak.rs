//! Concurrency soak for the sharded serving runtime (ISSUE 5 acceptance):
//! many client threads submitting against a multi-shard `ServerRuntime`
//! through every `RoutePolicy` while one shard drains and resumes
//! mid-flight. Invariants:
//!
//! * no response is lost or duplicated (unique ids, exact counts),
//! * every response is bit-identical to `SoftwareBing::propose` for its
//!   image — across policies, shard counts and a mid-soak drain,
//! * the shared metrics sink accounts for every image exactly once.

use std::collections::HashSet;
use std::sync::{Arc, Mutex};

use bingflow::backend::{EngineBackend, ProposalBackend, SimulatedAccelerator};
use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Proposal, Pyramid};
use bingflow::config::{AcceleratorConfig, RoutePolicyKind, ServingConfig};
use bingflow::data::{SceneConfig, SyntheticDataset};
use bingflow::image::ImageRgb;
use bingflow::runtime::MockEngine;
use bingflow::serving::ServerRuntime;
use bingflow::svm::Stage2Calibration;

const TOP_K: usize = 60;
const CLIENTS: usize = 6;
const ROUNDS: usize = 5;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32)]
}

fn software() -> Arc<SoftwareBing> {
    Arc::new(SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    ))
}

/// A mixed-size workload: one small frame (96×96) so `ScaleAffinity`
/// exercises both shard groups, two canonical 192×192 frames.
fn workload() -> Vec<ImageRgb> {
    let small = SyntheticDataset::new(
        SceneConfig { width: 96, height: 96, ..Default::default() },
        2007,
        1,
    )
    .sample(0)
    .image;
    let ds = SyntheticDataset::voc_like_val(2);
    vec![small, ds.sample(0).image, ds.sample(1).image]
}

fn soak(policy: RoutePolicyKind, shards: usize) {
    let images = workload();
    let reference = software();
    let expected: Vec<Vec<Proposal>> =
        images.iter().map(|img| reference.propose(img, TOP_K)).collect();

    let runtime: ServerRuntime<SoftwareBing> = ServerRuntime::new(
        software(),
        Stage2Calibration::identity(sizes()),
        ServingConfig {
            shards,
            policy,
            workers: 2,
            queue_depth: 4,
            top_k: TOP_K,
            ..Default::default()
        },
    );

    let seen_ids: Mutex<Vec<u64>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for client in 0..CLIENTS {
            let runtime = &runtime;
            let images = &images;
            let expected = &expected;
            let seen_ids = &seen_ids;
            s.spawn(move || {
                for round in 0..ROUNDS {
                    let pick = (client + round) % images.len();
                    let handle = runtime
                        .submit(images[pick].clone())
                        .expect("healthy runtime admits every request");
                    let id = handle.id();
                    let resp = handle.wait().expect("admitted request resolves");
                    assert_eq!(resp.id, id, "handle/response id mismatch");
                    assert_eq!(
                        resp.items, expected[pick],
                        "policy {policy:?}: image {pick} diverged from SoftwareBing::propose"
                    );
                    seen_ids.lock().unwrap().push(id);
                }
            });
        }
        // mid-soak rolling restart of one shard: the router steers away,
        // in-flight work on the shard completes, then it rejoins
        let runtime = &runtime;
        s.spawn(move || {
            runtime.drain_shard(1);
            assert!(runtime.shard(1).is_draining());
            runtime.resume_shard(1);
        });
    });

    let total = (CLIENTS * ROUNDS) as u64;
    let ids = seen_ids.into_inner().unwrap();
    let unique: HashSet<u64> = ids.iter().copied().collect();
    assert_eq!(ids.len() as u64, total, "lost responses");
    assert_eq!(unique.len() as u64, total, "duplicated response ids");
    assert_eq!(runtime.metrics.requests.get(), total);
    assert_eq!(runtime.metrics.images_done.get(), total);
    assert_eq!(runtime.metrics.deadline_misses.get(), 0);
    assert_eq!(runtime.metrics.cancellations.get(), 0);
    assert_eq!(runtime.metrics.worker_lost.get(), 0);
    // every image's scales executed exactly once fleet-wide
    assert_eq!(
        runtime.metrics.scale_executions.get(),
        total * sizes().len() as u64
    );
    let routed: u64 = (0..shards)
        .map(|i| runtime.metrics.shard(i).unwrap().images.get())
        .sum();
    assert_eq!(routed, total, "router lane accounting diverged");
    runtime.shutdown();
}

#[test]
fn round_robin_soak_with_mid_flight_drain() {
    soak(RoutePolicyKind::RoundRobin, 3);
}

#[test]
fn least_loaded_soak_with_mid_flight_drain() {
    soak(RoutePolicyKind::LeastLoaded, 3);
}

#[test]
fn scale_affinity_soak_with_mid_flight_drain() {
    soak(RoutePolicyKind::ScaleAffinity, 4);
}

#[test]
fn every_policy_shard_count_backend_combination_is_bit_identical() {
    // The acceptance sweep: (policy x shard count x backend) — every cell
    // must reproduce `SoftwareBing::propose` exactly through the routed,
    // dyn-dispatched serving path.
    let images = workload();
    let reference = software();
    let expected: Vec<Vec<Proposal>> =
        images.iter().map(|img| reference.propose(img, TOP_K)).collect();
    let pyramid = Pyramid::new(sizes());

    let backends: Vec<Arc<dyn ProposalBackend>> = vec![
        software(),
        Arc::new(EngineBackend::new(
            Arc::new(MockEngine::new(default_stage1(), sizes())),
            pyramid.clone(),
        )),
        Arc::new(SimulatedAccelerator::new(
            AcceleratorConfig::default(),
            pyramid,
            default_stage1(),
        )),
    ];
    for backend in backends {
        for policy in [
            RoutePolicyKind::RoundRobin,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::ScaleAffinity,
        ] {
            for shards in [1usize, 2, 3] {
                let runtime: ServerRuntime = ServerRuntime::new(
                    backend.clone(),
                    Stage2Calibration::identity(sizes()),
                    ServingConfig {
                        shards,
                        policy,
                        workers: 2,
                        top_k: TOP_K,
                        ..Default::default()
                    },
                );
                for (pick, img) in images.iter().enumerate() {
                    let resp = runtime.submit(img.clone()).unwrap().wait().unwrap();
                    assert_eq!(
                        resp.items, expected[pick],
                        "backend `{}` x {policy:?} x {shards} shards: image {pick} diverged",
                        backend.name()
                    );
                }
                if backend.name() == "sim" {
                    assert!(
                        runtime.metrics.sim_cycles.get() > 0,
                        "simulator cycles must flow through the sharded runtime"
                    );
                }
                runtime.shutdown();
            }
        }
    }
}

#[test]
fn two_shard_soak_under_every_policy() {
    for policy in [
        RoutePolicyKind::RoundRobin,
        RoutePolicyKind::LeastLoaded,
        RoutePolicyKind::ScaleAffinity,
    ] {
        soak(policy, 2);
    }
}
