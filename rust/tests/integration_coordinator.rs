//! Integration: coordinator behaviour under load, failure injection and
//! shutdown — the serving-robustness surface, including the request
//! lifecycle (typed errors, deadlines, cancellation, worker loss).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::bail;
use bingflow::backend::{EngineBackend, ProposalBackend, ScaleCandidates};
use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::ServingConfig;
use bingflow::coordinator::{Coordinator, Response, ResponseError, SubmitError};
use bingflow::data::SyntheticDataset;
use bingflow::image::ImageRgb;
use bingflow::runtime::{MockEngine, ScaleExecutor, ScaleOutput};
use bingflow::svm::Stage2Calibration;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32), (64, 64)]
}

fn software() -> SoftwareBing {
    SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    )
}

fn coordinator(engine: Arc<dyn ScaleExecutor>, cfg: ServingConfig) -> Coordinator<EngineBackend> {
    Coordinator::new(
        engine,
        Pyramid::new(sizes()),
        Stage2Calibration::identity(sizes()),
        cfg,
    )
}

/// Engine that fails on one scale — the failure-injection harness.
struct FlakyEngine {
    inner: MockEngine,
    fail_scale: usize,
    calls: AtomicU64,
}

impl ScaleExecutor for FlakyEngine {
    fn execute(&self, scale_idx: usize, resized: &ImageRgb) -> anyhow::Result<ScaleOutput> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        if scale_idx == self.fail_scale {
            bail!("injected failure on scale {scale_idx}");
        }
        self.inner.execute(scale_idx, resized)
    }

    fn sizes(&self) -> &[(usize, usize)] {
        self.inner.sizes()
    }
}

/// Backend that *panics* on one scale — the worker-loss harness (a failed
/// scale degrades; a panicked one must surface as `WorkerLost`).
struct PoisonedBackend {
    inner: SoftwareBing,
    panic_scale: usize,
}

impl ProposalBackend for PoisonedBackend {
    fn name(&self) -> &'static str {
        "poisoned"
    }

    fn pyramid(&self) -> &Pyramid {
        &self.inner.pyramid
    }

    fn scale_candidates(
        &self,
        img: &ImageRgb,
        scale_idx: usize,
    ) -> anyhow::Result<ScaleCandidates> {
        if scale_idx == self.panic_scale {
            panic!("poisoned backend: scale {scale_idx}");
        }
        self.inner.scale_candidates(img, scale_idx)
    }
}

/// Backend whose scale work blocks until the test opens a gate — makes
/// cancellation races deterministic.
struct GatedBackend {
    inner: SoftwareBing,
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl GatedBackend {
    fn open(gate: &Arc<(Mutex<bool>, Condvar)>) {
        let (lock, cvar) = &**gate;
        *lock.lock().unwrap() = true;
        cvar.notify_all();
    }
}

impl ProposalBackend for GatedBackend {
    fn name(&self) -> &'static str {
        "gated"
    }

    fn pyramid(&self) -> &Pyramid {
        &self.inner.pyramid
    }

    fn scale_candidates(
        &self,
        img: &ImageRgb,
        scale_idx: usize,
    ) -> anyhow::Result<ScaleCandidates> {
        let (lock, cvar) = &*self.gate;
        let mut open = lock.lock().unwrap();
        while !*open {
            open = cvar.wait(open).unwrap();
        }
        drop(open);
        self.inner.scale_candidates(img, scale_idx)
    }
}

/// Backend that sleeps per scale — the in-flight deadline harness.
struct SlowBackend {
    inner: SoftwareBing,
    delay: Duration,
}

impl ProposalBackend for SlowBackend {
    fn name(&self) -> &'static str {
        "slow"
    }

    fn pyramid(&self) -> &Pyramid {
        &self.inner.pyramid
    }

    fn scale_candidates(
        &self,
        img: &ImageRgb,
        scale_idx: usize,
    ) -> anyhow::Result<ScaleCandidates> {
        std::thread::sleep(self.delay);
        self.inner.scale_candidates(img, scale_idx)
    }
}

#[test]
fn sustained_load_completes_and_counts() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(
        engine,
        ServingConfig { workers: 4, queue_depth: 8, max_batch: 4, ..Default::default() },
    );
    let n = 24;
    let ds = SyntheticDataset::voc_like_val(n);
    let results = coord.serve_batch(ds.iter().map(|s| s.image).collect());
    assert_eq!(results.len(), n);
    assert!(results.iter().all(|r| r.is_ok()));
    assert_eq!(coord.metrics.images_done.get(), n as u64);
    assert_eq!(coord.metrics.scale_executions.get(), (n * sizes().len()) as u64);
    // latencies recorded for every image
    assert_eq!(coord.metrics.e2e_latency.count(), n as u64);
    coord.shutdown();
}

#[test]
fn failed_scale_degrades_gracefully() {
    let engine = Arc::new(FlakyEngine {
        inner: MockEngine::new(default_stage1(), sizes()),
        fail_scale: 1,
        calls: AtomicU64::new(0),
    });
    let coord = coordinator(engine.clone(), ServingConfig::default());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let resp = coord
        .submit(img.clone())
        .unwrap()
        .wait()
        .expect("must still respond");
    // proposals come only from the two healthy scales
    assert!(!resp.items.is_empty());
    let healthy = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord2 = coordinator(healthy, ServingConfig::default());
    let full = coord2.submit(img).unwrap().wait().unwrap();
    assert!(resp.items.len() <= full.items.len());
    assert_eq!(engine.calls.load(Ordering::Relaxed), 3);
    coord.shutdown();
    coord2.shutdown();
}

#[test]
fn panicking_backend_surfaces_worker_lost_instead_of_wedging() {
    // Regression (ISSUE 5): a panicking scale used to strand the image —
    // `done_tx` was dropped unsent and `serve_batch` panicked on
    // `recv().expect(...)`. It must now resolve as `WorkerLost`.
    let backend = Arc::new(PoisonedBackend { inner: software(), panic_scale: 1 });
    let coord = Coordinator::with_backend(
        backend,
        Stage2Calibration::identity(sizes()),
        ServingConfig::default(),
    );
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let err = coord.submit(img.clone()).unwrap().wait().unwrap_err();
    assert_eq!(err, ResponseError::WorkerLost);
    assert_eq!(coord.metrics.worker_lost.get(), 1);
    assert_eq!(coord.metrics.images_done.get(), 0);

    // the batch path must carry the loss as a value, not a panic
    let results = coord.serve_batch(vec![img.clone(), img]);
    assert_eq!(results.len(), 2);
    for r in results {
        assert_eq!(r.unwrap_err(), ResponseError::WorkerLost);
    }
    // and the serving loop survives: metrics kept counting
    assert_eq!(coord.metrics.worker_lost.get(), 3);
    coord.shutdown();
}

#[test]
fn closed_coordinator_returns_shutting_down_not_assert() {
    // Regression (ISSUE 5): submit on a closed coordinator used to
    // `assert!`, unwinding the caller and leaking the partial image.
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(engine, ServingConfig::default());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let ok = coord.submit(img.clone()).unwrap();
    coord.close();
    assert_eq!(coord.submit(img).unwrap_err(), SubmitError::ShuttingDown);
    // the pre-close request still completes in full
    assert!(!ok.wait().unwrap().items.is_empty());
    coord.wait_idle();
    assert_eq!(coord.queued_tasks(), 0, "rolled-back/finished slots must drain");
    coord.shutdown();
}

#[test]
fn concurrent_close_never_asserts_or_hangs() {
    // Probabilistic mid-image coverage for the shutdown rollback: many
    // submitters race a close(). Every submit must either be admitted (and
    // then resolve) or be refused as ShuttingDown — nothing may panic,
    // hang, or lose a response.
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = Arc::new(coordinator(
        engine,
        ServingConfig { workers: 2, queue_depth: 2, ..Default::default() },
    ));
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    std::thread::scope(|s| {
        for _ in 0..4 {
            let coord = coord.clone();
            let img = img.clone();
            s.spawn(move || {
                for _ in 0..8 {
                    match coord.submit(img.clone()) {
                        Ok(handle) => {
                            // admitted requests resolve even across close()
                            let _ = handle.wait().expect("admitted request resolves");
                        }
                        Err(e) => assert_eq!(e, SubmitError::ShuttingDown),
                    }
                }
            });
        }
        let coord = coord.clone();
        s.spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            coord.close();
        });
    });
    coord.wait_idle();
    assert_eq!(coord.queued_tasks(), 0);
}

#[test]
fn cancellation_resolves_as_cancelled_and_skips_remaining_scales() {
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let backend = Arc::new(GatedBackend { inner: software(), gate: gate.clone() });
    let coord = Coordinator::with_backend(
        backend,
        Stage2Calibration::identity(sizes()),
        ServingConfig { workers: 1, ..Default::default() },
    );
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let handle = coord.submit(img).unwrap();
    // the gate is still closed: no scale can *complete* before we cancel,
    // so the resolution is deterministically Cancelled
    handle.cancel();
    GatedBackend::open(&gate);
    assert_eq!(handle.wait().unwrap_err(), ResponseError::Cancelled);
    assert_eq!(coord.metrics.cancellations.get(), 1);
    coord.wait_idle();
    // the image never finalized: no proposals were ranked, no e2e latency
    // recorded (scale tasks that had already passed the cancellation check
    // may have executed, but their output was discarded)
    assert_eq!(coord.metrics.images_done.get(), 0);
    assert_eq!(coord.metrics.e2e_latency.count(), 0);
    coord.shutdown();
}

#[test]
fn slow_backend_misses_its_deadline_cooperatively() {
    let backend = Arc::new(SlowBackend { inner: software(), delay: Duration::from_millis(25) });
    let coord = Coordinator::with_backend(
        backend,
        Stage2Calibration::identity(sizes()),
        ServingConfig { workers: 1, deadline_ms: Some(1), ..Default::default() },
    );
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    // total work ≥ 25 ms against a 1 ms deadline: the miss is certain, and
    // must surface as a typed error (never a hang or a silent slow Ok)
    let err = coord.submit(img).unwrap().wait().unwrap_err();
    assert_eq!(err, ResponseError::DeadlineExceeded);
    assert_eq!(coord.metrics.deadline_misses.get(), 1);
    assert_eq!(coord.metrics.images_done.get(), 0);
    coord.shutdown();
}

#[test]
fn saturated_queue_deadline_submit_resolves_deadline_exceeded() {
    // The TimedOut rollback path: a deadlined submit against a saturated
    // admission gate either times out mid-image (already-enqueued scale
    // tasks roll back to no-ops) or squeaks in and expires in flight — in
    // both cases the request must resolve DeadlineExceeded, nothing may
    // leak, and the saturating traffic completes untouched.
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    let backend = Arc::new(GatedBackend { inner: software(), gate: gate.clone() });
    let coord = Arc::new(Coordinator::with_backend(
        backend,
        Stage2Calibration::identity(sizes()),
        ServingConfig { queue_depth: 1, workers: 2, ..Default::default() },
    ));
    // enough gate-blocked scale tasks to cover every pool worker, with
    // spares that stay parked behind the depth-1 admission queue
    let n_preload = bingflow::util::pool::global().threads() + 4;
    let per_thread = (n_preload + 3) / 4;
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let mut loaders = Vec::new();
    for _ in 0..4 {
        let coord = coord.clone();
        let img = img.clone();
        loaders.push(std::thread::spawn(move || {
            // no deadline: these may block at the gate until it opens
            let handles: Vec<_> = (0..per_thread)
                .map(|_| coord.submit(img.clone()).expect("open coordinator admits"))
                .collect();
            for handle in handles {
                handle.wait().expect("saturating request completes");
            }
        }));
    }
    std::thread::sleep(Duration::from_millis(100)); // let the pool saturate
    let outcome = coord.submit_deadline(img, Some(Instant::now() + Duration::from_millis(150)));
    // hold the gate shut until the deadline has certainly passed, so even
    // an admitted request cannot finish in time
    std::thread::sleep(Duration::from_millis(250));
    GatedBackend::open(&gate);
    match outcome {
        Err(e) => assert_eq!(e, SubmitError::DeadlineExceeded, "saturated gate must time out"),
        Ok(handle) => {
            let err = handle.wait().expect_err("cannot finish after its deadline");
            assert_eq!(err, ResponseError::DeadlineExceeded);
        }
    }
    assert!(coord.metrics.deadline_misses.get() >= 1);
    for loader in loaders {
        loader.join().expect("saturating clients finish cleanly");
    }
    coord.wait_idle();
    assert_eq!(coord.queued_tasks(), 0, "rolled-back slots must drain");
}

#[test]
fn explicit_deadline_overrides_config() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(engine, ServingConfig::default());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    // generous explicit deadline: serves normally
    let resp = coord
        .submit_deadline(img, Some(Instant::now() + Duration::from_secs(30)))
        .unwrap()
        .wait()
        .unwrap();
    assert!(!resp.items.is_empty());
    assert_eq!(coord.metrics.deadline_misses.get(), 0);
    coord.shutdown();
}

#[test]
fn interleaved_submissions_return_to_correct_callers() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(engine, ServingConfig { workers: 8, ..Default::default() });
    let ds = SyntheticDataset::voc_like_val(8);
    // submit all first, then collect — forces interleaving in the pool
    let pairs: Vec<_> = ds
        .iter()
        .map(|s| {
            let handle = coord.submit(s.image.clone()).unwrap();
            (s.image, handle)
        })
        .collect();
    let mut seen_ids = std::collections::HashSet::new();
    for (img, handle) in pairs {
        let resp: Response = handle.wait().unwrap();
        assert!(seen_ids.insert(resp.id), "duplicate response id");
        // proposal geometry must be consistent with THIS image's size
        for p in &resp.items {
            assert!((p.bbox.x1 as usize) < img.w && (p.bbox.y1 as usize) < img.h);
        }
    }
    coord.shutdown();
}

#[test]
fn shutdown_is_idempotent_and_clean() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(engine, ServingConfig::default());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let _ = coord.submit(img).unwrap().wait().unwrap();
    coord.close(); // explicit close before Drop
    coord.shutdown(); // Drop must not double-join
}

#[test]
fn single_worker_preserves_correctness() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord1 = coordinator(
        engine.clone(),
        ServingConfig { workers: 1, ..Default::default() },
    );
    let coord8 = coordinator(engine, ServingConfig { workers: 8, ..Default::default() });
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let a = coord1.submit(img.clone()).unwrap().wait().unwrap();
    let b = coord8.submit(img).unwrap().wait().unwrap();
    assert_eq!(a.items, b.items, "worker count changed results");
    coord1.shutdown();
    coord8.shutdown();
}
