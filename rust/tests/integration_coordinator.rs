//! Integration: coordinator behaviour under load, failure injection and
//! shutdown — the serving-robustness surface.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::bail;
use bingflow::backend::EngineBackend;
use bingflow::bing::{default_stage1, Pyramid};
use bingflow::config::ServingConfig;
use bingflow::coordinator::Coordinator;
use bingflow::data::SyntheticDataset;
use bingflow::image::ImageRgb;
use bingflow::runtime::{MockEngine, ScaleExecutor, ScaleOutput};
use bingflow::svm::Stage2Calibration;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32), (64, 64)]
}

fn coordinator(engine: Arc<dyn ScaleExecutor>, cfg: ServingConfig) -> Coordinator<EngineBackend> {
    Coordinator::new(
        engine,
        Pyramid::new(sizes()),
        Stage2Calibration::identity(sizes()),
        cfg,
    )
}

/// Engine that fails on one scale — the failure-injection harness.
struct FlakyEngine {
    inner: MockEngine,
    fail_scale: usize,
    calls: AtomicU64,
}

impl ScaleExecutor for FlakyEngine {
    fn execute(&self, scale_idx: usize, resized: &ImageRgb) -> anyhow::Result<ScaleOutput> {
        self.calls.fetch_add(1, Ordering::Relaxed);
        if scale_idx == self.fail_scale {
            bail!("injected failure on scale {scale_idx}");
        }
        self.inner.execute(scale_idx, resized)
    }

    fn sizes(&self) -> &[(usize, usize)] {
        self.inner.sizes()
    }
}

#[test]
fn sustained_load_completes_and_counts() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(
        engine,
        ServingConfig { workers: 4, queue_depth: 8, max_batch: 4, ..Default::default() },
    );
    let n = 24;
    let ds = SyntheticDataset::voc_like_val(n);
    let responses = coord.serve_batch(ds.iter().map(|s| s.image).collect());
    assert_eq!(responses.len(), n);
    assert_eq!(coord.metrics.images_done.get(), n as u64);
    assert_eq!(coord.metrics.scale_executions.get(), (n * sizes().len()) as u64);
    // latencies recorded for every image
    assert_eq!(coord.metrics.e2e_latency.count(), n as u64);
    coord.shutdown();
}

#[test]
fn failed_scale_degrades_gracefully() {
    let engine = Arc::new(FlakyEngine {
        inner: MockEngine::new(default_stage1(), sizes()),
        fail_scale: 1,
        calls: AtomicU64::new(0),
    });
    let coord = coordinator(engine.clone(), ServingConfig::default());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let resp = coord.submit(img.clone()).recv().expect("must still respond");
    // proposals come only from the two healthy scales
    assert!(!resp.proposals.is_empty());
    let healthy = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord2 = coordinator(healthy, ServingConfig::default());
    let full = coord2.submit(img).recv().unwrap();
    assert!(resp.proposals.len() <= full.proposals.len());
    assert_eq!(engine.calls.load(Ordering::Relaxed), 3);
    coord.shutdown();
    coord2.shutdown();
}

#[test]
fn interleaved_submissions_return_to_correct_callers() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(engine, ServingConfig { workers: 8, ..Default::default() });
    let ds = SyntheticDataset::voc_like_val(8);
    // submit all first, then collect — forces interleaving in the pool
    let pairs: Vec<_> = ds
        .iter()
        .map(|s| {
            let rx = coord.submit(s.image.clone());
            (s.image, rx)
        })
        .collect();
    let mut seen_ids = std::collections::HashSet::new();
    for (img, rx) in pairs {
        let resp = rx.recv().unwrap();
        assert!(seen_ids.insert(resp.id), "duplicate response id");
        // proposal geometry must be consistent with THIS image's size
        for p in &resp.proposals {
            assert!((p.bbox.x1 as usize) < img.w && (p.bbox.y1 as usize) < img.h);
        }
    }
    coord.shutdown();
}

#[test]
fn shutdown_is_idempotent_and_clean() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = coordinator(engine, ServingConfig::default());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let _ = coord.submit(img).recv().unwrap();
    coord.shutdown(); // explicit shutdown; Drop must not double-join
}

#[test]
fn single_worker_preserves_correctness() {
    let engine = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord1 = coordinator(
        engine.clone(),
        ServingConfig { workers: 1, ..Default::default() },
    );
    let coord8 = coordinator(engine, ServingConfig { workers: 8, ..Default::default() });
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let a = coord1.submit(img.clone()).recv().unwrap();
    let b = coord8.submit(img).recv().unwrap();
    assert_eq!(a.proposals, b.proposals, "worker count changed results");
    coord1.shutdown();
    coord8.shutdown();
}
