//! The **sim/SW/HLO parity invariant** (DESIGN.md §8): the software
//! baseline, the dataflow simulator and the PJRT path must produce
//! *bit-identical* candidate streams and proposals. This is what makes the
//! simulator's cycle counts (Tables 2/3) and the quality numbers (Fig. 5)
//! attributable to the same computation the paper's FPGA performs.

use std::sync::Arc;

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::Pyramid;
use bingflow::config::{AcceleratorConfig, ServingConfig};
use bingflow::coordinator::Coordinator;
use bingflow::data::SyntheticDataset;
use bingflow::dataflow::Accelerator;
use bingflow::runtime::MockEngine;
use bingflow::svm::Stage2Calibration;

fn small_sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (16, 32), (32, 32), (64, 32), (64, 64)]
}

#[test]
fn baseline_and_simulator_agree_on_candidates() {
    let sizes = small_sizes();
    let weights = bingflow::bing::default_stage1();
    let pyramid = Pyramid::new(sizes.clone());
    let sw = SoftwareBing::new(
        pyramid.clone(),
        weights.clone(),
        Stage2Calibration::identity(sizes),
        ScoringMode::Exact,
    );
    let accel = Accelerator::new(AcceleratorConfig::default(), pyramid, weights);
    for i in 0..3 {
        let img = SyntheticDataset::voc_like_val(3).sample(i).image;
        assert_eq!(
            accel.run_image(&img).candidates,
            sw.candidates(&img),
            "divergence on sample {i}"
        );
    }
}

#[test]
fn simulator_config_does_not_change_functional_output() {
    // timing knobs (pipelines, ping-pong, fifo depth) must never change
    // *what* is computed — only when
    let sizes = small_sizes();
    let weights = bingflow::bing::default_stage1();
    let pyramid = Pyramid::new(sizes);
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let reference = Accelerator::new(AcceleratorConfig::default(), pyramid.clone(), weights.clone())
        .run_image(&img)
        .candidates;
    for (pipelines, ping_pong, fifo) in
        [(1, true, 64), (2, false, 4), (8, true, 1), (4, false, 256)]
    {
        let cfg = AcceleratorConfig {
            pipelines,
            ping_pong,
            nms_fifo_depth: fifo,
            ..Default::default()
        };
        let got = Accelerator::new(cfg, pyramid.clone(), weights.clone())
            .run_image(&img)
            .candidates;
        assert_eq!(got, reference, "config ({pipelines},{ping_pong},{fifo}) changed values");
    }
}

#[test]
fn coordinator_with_mock_engine_matches_baseline_proposals() {
    let sizes = small_sizes();
    let weights = bingflow::bing::default_stage1();
    let stage2 = Stage2Calibration::identity(sizes.clone());
    let pyramid = Pyramid::new(sizes.clone());
    let coord = Coordinator::new(
        Arc::new(MockEngine::new(weights.clone(), sizes.clone())),
        pyramid.clone(),
        stage2.clone(),
        ServingConfig { top_k: 200, ..Default::default() },
    );
    let sw = SoftwareBing::new(pyramid, weights, stage2, ScoringMode::Exact);
    for i in 0..3 {
        let img = SyntheticDataset::voc_like_val(3).sample(i).image;
        let resp = coord.submit(img.clone()).unwrap().wait().unwrap();
        assert_eq!(resp.items, sw.propose(&img, 200), "sample {i}");
    }
    coord.shutdown();
}

#[cfg(feature = "pjrt")]
#[test]
fn full_three_way_parity_via_pjrt() {
    use bingflow::bing::Stage1Weights;
    use bingflow::config::default_sizes;
    use bingflow::runtime::PjrtEngine;
    use std::path::Path;

    // HLO path == baseline == simulator, on the real artifacts. artifacts/
    // lives at the repo root; tests run with cwd = rust/ (the package dir).
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("../artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts/ — run `make artifacts`");
        return;
    }
    let sizes = default_sizes();
    let weights = Stage1Weights::load_or_default(&dir);
    let stage2 = Stage2Calibration::identity(sizes.clone());
    let pyramid = Pyramid::new(sizes.clone());

    let engine = Arc::new(PjrtEngine::from_dir(&dir, &sizes).expect("engine loads"));
    let coord = Coordinator::new(
        engine,
        pyramid.clone(),
        stage2.clone(),
        ServingConfig { top_k: 500, ..Default::default() },
    );
    let sw = SoftwareBing::new(pyramid.clone(), weights.clone(), stage2, ScoringMode::Exact);
    let accel = Accelerator::new(AcceleratorConfig::default(), pyramid, weights);

    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let via_pjrt = coord.submit(img.clone()).unwrap().wait().unwrap().items;
    let via_sw = sw.propose(&img, 500);
    assert_eq!(via_pjrt, via_sw, "PJRT != software baseline");
    assert_eq!(accel.run_image(&img).candidates, sw.candidates(&img), "sim != baseline");
    coord.shutdown();
}
