//! Smoke tests for the engine-backend seam: the default (no-`pjrt`) build
//! must serve end-to-end through [`MockEngine`] alone — no `artifacts/` HLO
//! files on disk (CI has none), no XLA system libraries — and agree
//! bit-exactly with the reference `baseline::rank_and_select` pipeline.

use std::sync::Arc;

use bingflow::baseline::{rank_and_select, ScoringMode, SoftwareBing};
use bingflow::bing::{default_stage1, winners_from_mask, Candidate, Pyramid};
use bingflow::config::ServingConfig;
use bingflow::coordinator::Coordinator;
use bingflow::data::SyntheticDataset;
use bingflow::runtime::{MockEngine, ScaleExecutor};
use bingflow::svm::Stage2Calibration;

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (32, 32), (64, 32)]
}

/// The serving recipe, driven by hand through the seam: engine execute →
/// mask winners → candidates → stage-II + bubble-heap top-k. Must equal the
/// software baseline end-to-end on a synthetic image.
#[test]
fn mock_engine_matches_rank_and_select_without_artifacts() {
    let engine: Arc<dyn ScaleExecutor> = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let pyramid = Pyramid::new(sizes());
    let stage2 = Stage2Calibration::identity(sizes());
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;

    let mut candidates = Vec::new();
    for (idx, &(h, w)) in sizes().iter().enumerate() {
        let resized = img.resize_nearest(w, h);
        let out = engine.execute(idx, &resized).expect("mock engine executes");
        for win in winners_from_mask(&out.scores, &out.mask, out.oh, out.ow) {
            candidates.push(Candidate {
                scale_idx: idx,
                x: win.x,
                y: win.y,
                score: win.score,
            });
        }
    }
    // the pyramid yields 4 + 25 + 60 = 89 NMS winners; keep top_k below that
    assert_eq!(candidates.len(), 89);
    let via_engine = rank_and_select(&candidates, &pyramid, &stage2, img.w, img.h, 80);

    let sw = SoftwareBing::new(pyramid, default_stage1(), stage2, ScoringMode::Exact);
    assert_eq!(via_engine, sw.propose(&img, 80));
    assert_eq!(via_engine.len(), 80);
}

/// The same parity through the real coordinator, constructed exactly the way
/// a default build constructs it (MockEngine as the `ScaleExecutor`).
#[test]
fn coordinator_over_mock_engine_serves_without_artifacts() {
    let engine: Arc<dyn ScaleExecutor> = Arc::new(MockEngine::new(default_stage1(), sizes()));
    let coord = Coordinator::new(
        engine,
        Pyramid::new(sizes()),
        Stage2Calibration::identity(sizes()),
        ServingConfig { top_k: 64, ..Default::default() },
    );
    let img = SyntheticDataset::voc_like_val(1).sample(0).image;
    let resp = coord
        .submit(img.clone())
        .expect("submission admitted")
        .wait()
        .expect("serving completes");

    let sw = SoftwareBing::new(
        Pyramid::new(sizes()),
        default_stage1(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    );
    assert_eq!(resp.items, sw.propose(&img, 64));
    coord.shutdown();
}

/// The seam itself: a `ScaleExecutor` trait object reports the pyramid it
/// was built for and rejects mis-sized inputs — the properties the
/// coordinator relies on regardless of backend.
#[test]
fn scale_executor_contract_holds_for_mock_engine() {
    let engine: Arc<dyn ScaleExecutor> = Arc::new(MockEngine::new(default_stage1(), sizes()));
    assert_eq!(engine.sizes(), &sizes()[..]);
    let wrong = SyntheticDataset::voc_like_val(1).sample(0).image; // 192x192
    assert!(engine.execute(0, &wrong).is_err(), "shape check must fire");
}
