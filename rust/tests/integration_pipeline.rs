//! Integration: the full training → weights → pipeline → evaluation loop,
//! exercising the system the way `examples/train_svm.rs` + `evaluate.rs` do.

use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{window_to_box, Pyramid, Stage1Weights};
use bingflow::data::SyntheticDataset;
use bingflow::metrics::{detection_rate, iou_u32, mabo, ImageEval};
use bingflow::svm::{
    train_stage1, train_stage2, CalibSample, Stage2Calibration, SvmTrainConfig, WeightBundle,
};

fn sizes() -> Vec<(usize, usize)> {
    vec![(16, 16), (16, 32), (32, 16), (32, 32), (64, 64), (128, 128)]
}

/// Train a small model end-to-end and return the deployable bundle.
fn train_small() -> WeightBundle {
    let ds = SyntheticDataset::voc_like_train(12);
    let cfg = SvmTrainConfig { epochs: 6, ..Default::default() };
    let stage1 = Stage1Weights::quantize(&train_stage1(&ds, &cfg).w);
    let pyramid = Pyramid::new(sizes());
    let sw = SoftwareBing::new(
        pyramid.clone(),
        stage1.clone(),
        Stage2Calibration::identity(sizes()),
        ScoringMode::Exact,
    );
    let mut samples = Vec::new();
    for sample in ds.iter() {
        for c in sw.candidates(&sample.image) {
            let b = window_to_box(
                c.x,
                c.y,
                pyramid.sizes[c.scale_idx],
                sample.image.w,
                sample.image.h,
            );
            let hit = sample.boxes.iter().any(|gt| {
                iou_u32((b.x0, b.y0, b.x1, b.y1), (gt.x0, gt.y0, gt.x1, gt.y1)) >= 0.5
            });
            samples.push(CalibSample {
                scale_idx: c.scale_idx,
                raw_score: c.score,
                is_object: hit,
            });
        }
    }
    WeightBundle { stage1, stage2: train_stage2(&sizes(), &samples, 3) }
}

#[test]
fn trained_pipeline_beats_default_template_on_dr() {
    let bundle = train_small();
    let val = SyntheticDataset::voc_like_val(12);
    let run = |stage1: Stage1Weights, stage2: Stage2Calibration| -> f64 {
        let sw = SoftwareBing::new(Pyramid::new(sizes()), stage1, stage2, ScoringMode::Exact);
        let mut proposals = Vec::new();
        let mut gts = Vec::new();
        for s in val.iter() {
            proposals.push(
                sw.propose(&s.image, 300)
                    .into_iter()
                    .map(|p| p.bbox)
                    .collect::<Vec<_>>(),
            );
            gts.push(s.boxes);
        }
        let evals: Vec<ImageEval> = proposals
            .iter()
            .zip(&gts)
            .map(|(p, g)| ImageEval { proposals: p, gt: g })
            .collect();
        detection_rate(&evals, 300, 0.4)
    };
    let trained = run(bundle.stage1.clone(), bundle.stage2.clone());
    let default = run(
        bingflow::bing::default_stage1(),
        Stage2Calibration::identity(sizes()),
    );
    assert!(
        trained >= default,
        "training should not hurt: trained {trained:.3} vs default {default:.3}"
    );
    assert!(trained > 0.5, "trained DR@300 too low: {trained:.3}");
}

#[test]
fn weight_bundle_roundtrips_through_disk() {
    let bundle = train_small();
    let dir = std::env::temp_dir().join("bingflow-pipeline-test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("svm_weights.json");
    bundle.save(&path).unwrap();
    let back = WeightBundle::load(&path).unwrap();
    assert_eq!(back, bundle);
    // the rust loader used by aot-parity must read the same stage-I
    let w = Stage1Weights::load_or_default(&dir);
    assert_eq!(w, bundle.stage1);
}

#[test]
fn mabo_improves_with_more_windows() {
    let bundle = train_small();
    let sw = SoftwareBing::new(
        Pyramid::new(sizes()),
        bundle.stage1,
        bundle.stage2,
        ScoringMode::Exact,
    );
    let val = SyntheticDataset::voc_like_val(6);
    let mut proposals = Vec::new();
    let mut gts = Vec::new();
    for s in val.iter() {
        proposals.push(
            sw.propose(&s.image, 1000)
                .into_iter()
                .map(|p| p.bbox)
                .collect::<Vec<_>>(),
        );
        gts.push(s.boxes);
    }
    let evals: Vec<ImageEval> = proposals
        .iter()
        .zip(&gts)
        .map(|(p, g)| ImageEval { proposals: p, gt: g })
        .collect();
    let m10 = mabo(&evals, 10);
    let m100 = mabo(&evals, 100);
    let m1000 = mabo(&evals, 1000);
    assert!(m10 <= m100 && m100 <= m1000, "MABO not monotone: {m10} {m100} {m1000}");
    assert!(m1000 > 0.4, "MABO@1000 too low: {m1000}");
}

#[test]
fn binarized_fast_path_close_to_exact_on_quality() {
    let bundle = train_small();
    let val = SyntheticDataset::voc_like_val(8);
    let quality = |mode: ScoringMode| -> f64 {
        let sw = SoftwareBing::new(
            Pyramid::new(sizes()),
            bundle.stage1.clone(),
            bundle.stage2.clone(),
            mode,
        );
        let mut proposals = Vec::new();
        let mut gts = Vec::new();
        for s in val.iter() {
            proposals.push(
                sw.propose(&s.image, 300)
                    .into_iter()
                    .map(|p| p.bbox)
                    .collect::<Vec<_>>(),
            );
            gts.push(s.boxes);
        }
        let evals: Vec<ImageEval> = proposals
            .iter()
            .zip(&gts)
            .map(|(p, g)| ImageEval { proposals: p, gt: g })
            .collect();
        detection_rate(&evals, 300, 0.4)
    };
    let exact = quality(ScoringMode::Exact);
    let binarized = quality(ScoringMode::Binarized { nw: 3, ng: 6 });
    assert!(
        binarized >= exact - 0.25,
        "binarized collapsed: {binarized:.3} vs exact {exact:.3}"
    );
}
