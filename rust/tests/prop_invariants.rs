//! Property-based invariants (in-tree harness — no proptest crate offline):
//! randomized inputs from the deterministic RNG, hundreds of cases per
//! property, shrink-free but seed-reported for reproduction.

use bingflow::baseline::{rank_and_select, ScaleScratch, ScoringMode, SoftwareBing};
use bingflow::bing::{
    default_stage1, gradient_map, window_to_box, winners_from_scores, BinarizedScorer, Candidate,
    Pyramid, ScoreMap, Stage1Weights,
};
use bingflow::config::NMS_BLOCK;
use bingflow::image::ImageRgb;
use bingflow::quant::FixedFormat;
use bingflow::sort::{top_k_sort_baseline, BubbleHeap};
use bingflow::svm::Stage2Calibration;
use bingflow::util::json::Json;
use bingflow::util::rng;

/// Run `f` over `cases` random seeds, reporting the failing seed.
fn forall(cases: u64, f: impl Fn(u64)) {
    for seed in 0..cases {
        f(seed); // panics carry the seed via the assert messages below
    }
}

#[test]
fn prop_heap_equals_full_sort() {
    forall(200, |seed| {
        let mut r = rng(seed);
        let n = r.range_usize(1, 400);
        let k = r.range_usize(1, 64);
        let data: Vec<i64> = (0..n).map(|_| r.next_u64() as i64 % 10_000).collect();
        let mut heap = BubbleHeap::new(k);
        for &v in &data {
            heap.push(v);
        }
        assert_eq!(
            heap.into_sorted_desc(),
            top_k_sort_baseline(&data, k),
            "seed {seed}: heap != sort for n={n} k={k}"
        );
    });
}

#[test]
fn prop_heap_counters_partition() {
    forall(100, |seed| {
        let mut r = rng(seed ^ 0xabc);
        let k = r.range_usize(1, 32);
        let n = r.range_usize(1, 300) as u64;
        let mut heap = BubbleHeap::new(k);
        for _ in 0..n {
            heap.push(r.next_u64() as i64);
        }
        assert_eq!(heap.accepted + heap.rejected, n, "seed {seed}");
    });
}

#[test]
fn prop_nms_winners_unique_per_block_and_maximal() {
    forall(150, |seed| {
        let mut r = rng(seed ^ 0x5a5a);
        let w = r.range_usize(1, 40);
        let h = r.range_usize(1, 40);
        let data: Vec<i32> = (0..w * h).map(|_| (r.next_u64() % 4001) as i32 - 2000).collect();
        let s = ScoreMap { w, h, data };
        let winners = winners_from_scores(&s);
        assert_eq!(
            winners.len(),
            w.div_ceil(NMS_BLOCK) * h.div_ceil(NMS_BLOCK),
            "seed {seed}: one winner per block"
        );
        let mut seen_blocks = std::collections::HashSet::new();
        for win in &winners {
            let block = (win.y as usize / NMS_BLOCK, win.x as usize / NMS_BLOCK);
            assert!(seen_blocks.insert(block), "seed {seed}: duplicate block");
            // maximality within its block
            let by = block.0 * NMS_BLOCK;
            let bx = block.1 * NMS_BLOCK;
            for y in by..(by + NMS_BLOCK).min(h) {
                for x in bx..(bx + NMS_BLOCK).min(w) {
                    assert!(s.get(x, y) <= win.score, "seed {seed}: non-maximal winner");
                }
            }
        }
    });
}

#[test]
fn prop_window_to_box_always_in_bounds_and_ordered() {
    forall(300, |seed| {
        let mut r = rng(seed ^ 0x77);
        let sh = r.range_usize(8, 300);
        let sw = r.range_usize(8, 300);
        let ow = r.range_usize(9, 600);
        let oh = r.range_usize(9, 600);
        let x = r.range_usize(0, sw.saturating_sub(7).max(1)) as u16;
        let y = r.range_usize(0, sh.saturating_sub(7).max(1)) as u16;
        let b = window_to_box(x, y, (sh, sw), ow, oh);
        assert!(b.x0 <= b.x1 && b.y0 <= b.y1, "seed {seed}: degenerate box");
        assert!((b.x1 as usize) < ow && (b.y1 as usize) < oh, "seed {seed}: out of bounds");
    });
}

#[test]
fn prop_quantizer_bounded_error_and_monotone() {
    forall(200, |seed| {
        let mut r = rng(seed ^ 0xf17e);
        let frac = (r.next_u64() % 8) as u32;
        let fmt = FixedFormat::new(10, frac);
        let lsb = 1.0 / (1u64 << frac) as f64;
        let a = (r.f64() - 0.5) * 1000.0;
        let b = (r.f64() - 0.5) * 1000.0;
        let qa = fmt.quantize(a);
        let qb = fmt.quantize(b);
        // bounded rounding error inside the representable range
        if a.abs() < 1000.0 {
            assert!(
                (qa.to_f64() - a).abs() <= lsb / 2.0 + 1e-12,
                "seed {seed}: error beyond half-LSB"
            );
        }
        // monotonicity
        if a <= b {
            assert!(qa.raw <= qb.raw, "seed {seed}: quantizer not monotone");
        }
    });
}

#[test]
fn prop_rank_and_select_is_sorted_prefix_of_all_candidates() {
    forall(60, |seed| {
        let mut r = rng(seed ^ 0xbeef);
        let sizes = vec![(16usize, 16usize), (32, 32)];
        let pyramid = Pyramid::new(sizes.clone());
        let stage2 = Stage2Calibration::identity(sizes);
        let n = r.range_usize(1, 200);
        let candidates: Vec<Candidate> = (0..n)
            .map(|_| Candidate {
                scale_idx: r.range_usize(0, 2),
                x: r.range_usize(0, 9) as u16,
                y: r.range_usize(0, 9) as u16,
                score: (r.next_u64() % 100_000) as i32 - 50_000,
            })
            .collect();
        let k = r.range_usize(1, 80);
        let selected = rank_and_select(&candidates, &pyramid, &stage2, 192, 192, k);
        assert_eq!(selected.len(), k.min(n), "seed {seed}");
        for pair in selected.windows(2) {
            assert!(pair[0].score >= pair[1].score, "seed {seed}: not sorted");
        }
        // the k-th kept score must be >= every dropped score
        if let Some(last) = selected.last() {
            let dropped_max = candidates
                .iter()
                .map(|c| stage2.apply(c.scale_idx, c.score))
                .filter(|&s| s > last.score)
                .count();
            assert!(
                dropped_max < k.min(n).max(1) + 1,
                "seed {seed}: top-k violated"
            );
        }
    });
}

/// The incremental SWAR scorer is bit-identical to the retained reference
/// repack scorer across random images, random weights and every `(nw, ng)`
/// regime — the tentpole equivalence contract of the PR-2 perf pass.
#[test]
fn prop_incremental_binarized_scorer_matches_reference() {
    forall(40, |seed| {
        let mut r = rng(seed ^ 0xb1a5);
        let w = r.range_usize(8, 48);
        let h = r.range_usize(8, 48);
        let img = ImageRgb::from_fn(w, h, |_, _| {
            let v = r.next_u64();
            [(v & 0xff) as u8, (v >> 8 & 0xff) as u8, (v >> 16 & 0xff) as u8]
        });
        let g = gradient_map(&img);
        let weights = if r.bool_p(0.5) {
            default_stage1()
        } else {
            let mut wts = [[0i8; 8]; 8];
            for row in &mut wts {
                for v in row.iter_mut() {
                    *v = (r.next_u64() % 25) as i8 - 12;
                }
            }
            Stage1Weights { w: wts }
        };
        let nw = r.range_usize(1, 5);
        let ng = r.range_usize(1, 9);
        let scorer = BinarizedScorer::new(&weights, nw, ng);
        assert_eq!(
            scorer.score_map(&g),
            scorer.score_map_reference(&g),
            "seed {seed}: incremental != reference for {w}x{h} nw={nw} ng={ng}"
        );
    });
}

/// A dirty, reused scratch arena must produce the same candidates as a fresh
/// one for every scoring mode — the zero-alloc serving path is purely an
/// allocation optimization, never a semantic change.
#[test]
fn prop_scratch_arena_matches_fresh_allocation_path() {
    let sizes = vec![(16usize, 16usize), (32, 24), (64, 64), (16, 48)];
    let modes = [
        ScoringMode::Exact,
        ScoringMode::Binarized { nw: 2, ng: 4 },
        ScoringMode::Binarized { nw: 3, ng: 6 },
    ];
    forall(12, |seed| {
        // one dirty arena per case, reused across every (mode, scale) visit
        let mut dirty = ScaleScratch::new();
        let mut r = rng(seed ^ 0xa3e4);
        let img = ImageRgb::from_fn(80, 64, |x, y| {
            let v = x as u64 * 31 + y as u64 * 17 + r.next_u64() % 7;
            [(v % 256) as u8, (v * 3 % 256) as u8, ((x + y) % 256) as u8]
        });
        for &mode in &modes {
            let sw = SoftwareBing::new(
                Pyramid::new(sizes.clone()),
                default_stage1(),
                Stage2Calibration::identity(sizes.clone()),
                mode,
            );
            // visit scales in a scrambled order so the arena is always dirty
            for _ in 0..sizes.len() {
                let scale_idx = r.range_usize(0, sizes.len());
                let reused = sw.candidates_for_scale_scratch(&img, scale_idx, &mut dirty);
                let fresh =
                    sw.candidates_for_scale_scratch(&img, scale_idx, &mut ScaleScratch::new());
                assert_eq!(reused, fresh, "seed {seed}: scratch diverged on scale {scale_idx}");
            }
        }
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(r: &mut bingflow::util::Rng, depth: usize) -> Json {
        match if depth == 0 { r.range_usize(0, 4) } else { r.range_usize(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(r.bool_p(0.5)),
            2 => Json::Num((r.next_u64() % 100_000) as f64 / 8.0 - 6000.0),
            3 => Json::Str(format!("s{}", r.next_u64() % 1000)),
            4 => Json::Arr((0..r.range_usize(0, 5)).map(|_| random_json(r, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..r.range_usize(0, 5) {
                    m.insert(format!("k{i}"), random_json(r, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    forall(200, |seed| {
        let mut r = rng(seed ^ 0x1234);
        let doc = random_json(&mut r, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("seed {seed}: {e} on `{text}`"));
        assert_eq!(back, doc, "seed {seed}");
    });
}

#[test]
fn prop_proposals_deterministic_across_runs() {
    let sizes = vec![(16usize, 16usize), (32, 32), (64, 32)];
    let sw = SoftwareBing::new(
        Pyramid::new(sizes.clone()),
        bingflow::bing::default_stage1(),
        Stage2Calibration::identity(sizes),
        ScoringMode::Exact,
    );
    forall(10, |seed| {
        let mut r = rng(seed);
        let img = ImageRgb::from_fn(96, 80, |x, y| {
            let v = (x as u64 * 31 + y as u64 * 17 + seed * 7) % 256;
            [(v as u8), ((v * 3) % 256) as u8, ((x + y) % 256) as u8]
        });
        let _ = &mut r;
        let a = sw.propose(&img, 64);
        let b = sw.propose(&img, 64);
        assert_eq!(a, b, "seed {seed}: nondeterminism");
    });
}
