//! The pluggable serving seam: one [`ProposalBackend`] trait, three
//! interchangeable implementations, one generic coordinator over all of
//! them (the way Faster R-CNN made region proposals a swappable module
//! inside a larger serving system).
//!
//! ```text
//!   Coordinator<B: ProposalBackend + ?Sized>
//!        │  scale_candidates(image, scale_idx)        — on pool workers
//!        ├── SoftwareBing          the optimized CPU pipeline (Table 2's
//!        │                         "desktop platform"), scratch-arena path
//!        ├── EngineBackend         resize + ScaleExecutor (MockEngine or
//!        │                         PJRT AOT executables) — the PR-1 seam
//!        └── SimulatedAccelerator  the cycle-accurate dataflow stage graph;
//!                                  surfaces simulated cycles through
//!                                  ServeMetrics::sim_cycles
//! ```
//!
//! All three return bit-identical candidates on the same image (the parity
//! contract; proven end to end in `tests/backend_parity.rs`), so swapping
//! backends changes *what is measured* — wall-clock, engine latency or
//! simulated silicon cycles — never *what is computed*.

use std::sync::Arc;

use anyhow::Result;

use crate::baseline::{with_scale_scratch, SoftwareBing};
use crate::bing::{winners_from_mask, Candidate, Pyramid, Stage1Weights, Winner};
use crate::config::AcceleratorConfig;
use crate::dataflow::Accelerator;
use crate::image::ImageRgb;
use crate::runtime::ScaleExecutor;

/// One scale's worth of backend output.
#[derive(Debug)]
pub struct ScaleCandidates {
    /// NMS winners in block raster order — bit-identical across backends.
    pub candidates: Vec<Candidate>,
    /// Simulated-cycle cost of this scale when the backend models time
    /// (the dataflow simulator); `None` for wall-clock-only backends.
    pub sim_cycles: Option<u64>,
}

/// A proposal generator the coordinator can serve: given an image and a
/// pyramid scale index, produce that scale's candidate windows.
///
/// Implementations must be thread-safe — the coordinator fans
/// `scale_candidates` calls for one image out over the shared worker pool.
pub trait ProposalBackend: Send + Sync {
    /// Short name for logs and telemetry ("software", "engine", "sim").
    fn name(&self) -> &'static str;

    /// The pyramid this backend was built for (the coordinator derives its
    /// per-image fan-out and validates stage-II coverage from it).
    fn pyramid(&self) -> &Pyramid;

    /// Candidates for one (image, scale). `img` is the *original* image —
    /// resizing is part of the backend's pipeline, mirroring the paper
    /// where the resize module feeds the kernel-computing module.
    fn scale_candidates(&self, img: &ImageRgb, scale_idx: usize) -> Result<ScaleCandidates>;

    /// [`Self::scale_candidates`] for a frame of a video session. Backends
    /// with per-session caches (currently [`SoftwareBing`], through
    /// [`crate::temporal`]) recompute only what the frame's dirty tiles
    /// invalidate; the default ignores the ticket and scores the canonical
    /// frame from scratch — bit-identical either way, so session requests
    /// are safe on every backend.
    fn scale_candidates_session(
        &self,
        scale_idx: usize,
        ticket: &crate::temporal::FrameTicket,
    ) -> Result<ScaleCandidates> {
        self.scale_candidates(ticket.frame().as_ref(), scale_idx)
    }
}

fn to_candidates(winners: Vec<Winner>, scale_idx: usize) -> Vec<Candidate> {
    winners
        .into_iter()
        .map(|win| Candidate { scale_idx, x: win.x, y: win.y, score: win.score })
        .collect()
}

/// The software BING pipeline as a backend: resize → CalcGrad → SVM-I →
/// block NMS on the calling pool thread, through its persistent scratch
/// arena (zero steady-state allocation).
impl ProposalBackend for SoftwareBing {
    fn name(&self) -> &'static str {
        "software"
    }

    fn pyramid(&self) -> &Pyramid {
        &self.pyramid
    }

    fn scale_candidates(&self, img: &ImageRgb, scale_idx: usize) -> Result<ScaleCandidates> {
        Ok(ScaleCandidates {
            candidates: self.candidates_for_scale(img, scale_idx),
            sim_cycles: None,
        })
    }

    fn scale_candidates_session(
        &self,
        scale_idx: usize,
        ticket: &crate::temporal::FrameTicket,
    ) -> Result<ScaleCandidates> {
        Ok(ScaleCandidates {
            candidates: crate::temporal::scale_candidates_for_ticket(self, scale_idx, ticket),
            sim_cycles: None,
        })
    }
}

/// Per-scale engine executables behind the [`ScaleExecutor`] seam — the
/// mock (pure-rust twin) or PJRT AOT path. Resize happens here, on the
/// pool worker's scratch arena, because the executables take the already
/// resized image (the paper's resize module is L3's job).
pub struct EngineBackend {
    engine: Arc<dyn ScaleExecutor>,
    pyramid: Pyramid,
}

impl EngineBackend {
    pub fn new(engine: Arc<dyn ScaleExecutor>, pyramid: Pyramid) -> Self {
        assert_eq!(
            engine.sizes(),
            &pyramid.sizes[..],
            "engine pyramid must match serving pyramid"
        );
        Self { engine, pyramid }
    }

    pub fn engine(&self) -> &Arc<dyn ScaleExecutor> {
        &self.engine
    }
}

impl ProposalBackend for EngineBackend {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn pyramid(&self) -> &Pyramid {
        &self.pyramid
    }

    fn scale_candidates(&self, img: &ImageRgb, scale_idx: usize) -> Result<ScaleCandidates> {
        let (h, w) = self.pyramid.sizes[scale_idx];
        let out = with_scale_scratch(|scratch| {
            let resized = scratch.resize(img, w, h);
            self.engine.execute(scale_idx, resized)
        })?;
        let candidates =
            to_candidates(winners_from_mask(&out.scores, &out.mask, out.oh, out.ow), scale_idx);
        Ok(ScaleCandidates { candidates, sim_cycles: None })
    }
}

/// The cycle-accurate dataflow simulator as a serving backend: every scale
/// request steps the resize → kernel → sort stage graph and reports the
/// simulated cycle cost alongside the (bit-identical) candidates — so a
/// serving run doubles as an accelerator sizing experiment, with cycle
/// telemetry aggregated in `ServeMetrics::sim_cycles`.
pub struct SimulatedAccelerator {
    accel: Accelerator,
}

impl SimulatedAccelerator {
    pub fn new(config: AcceleratorConfig, pyramid: Pyramid, weights: Stage1Weights) -> Self {
        Self { accel: Accelerator::new(config, pyramid, weights) }
    }

    /// The wrapped cycle model (for direct `run_image` experiments).
    pub fn accelerator(&self) -> &Accelerator {
        &self.accel
    }
}

impl ProposalBackend for SimulatedAccelerator {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn pyramid(&self) -> &Pyramid {
        &self.accel.pyramid
    }

    fn scale_candidates(&self, img: &ImageRgb, scale_idx: usize) -> Result<ScaleCandidates> {
        let (stats, winners) = self.accel.run_scale(img, scale_idx);
        Ok(ScaleCandidates {
            candidates: to_candidates(winners, scale_idx),
            sim_cycles: Some(stats.cycles),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::ScoringMode;
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;
    use crate::runtime::MockEngine;
    use crate::svm::Stage2Calibration;

    fn sizes() -> Vec<(usize, usize)> {
        vec![(16, 16), (32, 32)]
    }

    fn backends() -> Vec<Arc<dyn ProposalBackend>> {
        let pyramid = Pyramid::new(sizes());
        vec![
            Arc::new(SoftwareBing::new(
                pyramid.clone(),
                default_stage1(),
                Stage2Calibration::identity(sizes()),
                ScoringMode::Exact,
            )),
            Arc::new(EngineBackend::new(
                Arc::new(MockEngine::new(default_stage1(), sizes())),
                pyramid.clone(),
            )),
            Arc::new(SimulatedAccelerator::new(
                AcceleratorConfig::default(),
                pyramid,
                default_stage1(),
            )),
        ]
    }

    #[test]
    fn all_backends_agree_per_scale() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let all = backends();
        for scale_idx in 0..sizes().len() {
            let reference = all[0].scale_candidates(&img, scale_idx).unwrap();
            for b in &all[1..] {
                let got = b.scale_candidates(&img, scale_idx).unwrap();
                assert_eq!(
                    got.candidates,
                    reference.candidates,
                    "backend `{}` diverged on scale {scale_idx}",
                    b.name()
                );
            }
        }
    }

    #[test]
    fn only_the_simulator_reports_cycles() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        for b in backends() {
            let out = b.scale_candidates(&img, 0).unwrap();
            match b.name() {
                "sim" => assert!(out.sim_cycles.unwrap() > 0, "sim must report cycles"),
                _ => assert_eq!(out.sim_cycles, None, "{} must not report cycles", b.name()),
            }
        }
    }

    #[test]
    #[should_panic(expected = "must match serving pyramid")]
    fn engine_backend_rejects_mismatched_pyramid() {
        let _ = EngineBackend::new(
            Arc::new(MockEngine::new(default_stage1(), sizes())),
            Pyramid::new(vec![(64, 64)]),
        );
    }
}
