//! The software BING baseline — the "traditional desktop CPU platform"
//! comparator of Table 2.
//!
//! A well-optimized control-flow implementation of the full proposal
//! pipeline: pyramid resize → CalcGrad → SVM-I (exact or binarized bitwise
//! scoring) → 5×5 block NMS → stage-II calibration → top-k heap. Scales are
//! processed in parallel with rayon (the paper's i7 numbers use
//! multi-threading + subword parallelism; the binarized scorer is the
//! subword part).
//!
//! This module is *also* the functional reference for the accelerator: the
//! quantized outputs are bit-identical to the HLO path and the dataflow
//! simulator (integration_parity.rs proves it).

use crate::bing::{
    gradient_map, score_map, score_map_i32, window_to_box, winners_from_scores, BinarizedScorer,
    Candidate, Proposal, Pyramid, Stage1Weights,
};
use crate::image::ImageRgb;
use crate::sort::BubbleHeap;
use crate::svm::Stage2Calibration;

/// Scoring backend for the baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoringMode {
    /// Exact integer dot products (what the FPGA datapath computes).
    Exact,
    /// BING's binarized approximation (`nw` weight bases, `ng` bit planes) —
    /// the published CPU fast path.
    Binarized { nw: usize, ng: usize },
    /// High-precision weights (`round(w_float · 1024)`) — the float software
    /// reference of the Fig. 5 quantization ablation.
    HiPrecision([[i32; 8]; 8]),
}

impl ScoringMode {
    /// Carry float-trained weights at 1/1024 resolution.
    pub fn hi_precision(float_w: &[[f64; 8]; 8]) -> Self {
        let mut w = [[0i32; 8]; 8];
        for dy in 0..8 {
            for dx in 0..8 {
                w[dy][dx] = (float_w[dy][dx] * 1024.0).round() as i32;
            }
        }
        ScoringMode::HiPrecision(w)
    }
}

/// The software pipeline, bundling weights + pyramid + calibration.
pub struct SoftwareBing {
    pub pyramid: Pyramid,
    pub weights: Stage1Weights,
    pub stage2: Stage2Calibration,
    pub mode: ScoringMode,
    /// Run scales on the rayon pool (true for the i7-comparator benches).
    pub parallel: bool,
}

/// A scored proposal before the final heap (public for ablations).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Ranked {
    key: i64,
    proposal: Proposal,
}

impl Eq for Ranked {}

impl Ord for Ranked {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

impl PartialOrd for Ranked {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl SoftwareBing {
    pub fn new(
        pyramid: Pyramid,
        weights: Stage1Weights,
        stage2: Stage2Calibration,
        mode: ScoringMode,
    ) -> Self {
        assert_eq!(
            pyramid.sizes, stage2.sizes,
            "stage-II calibration must cover exactly the pyramid scales"
        );
        Self { pyramid, weights, stage2, mode, parallel: true }
    }

    /// Per-scale candidate extraction (resize → grad → score → block NMS).
    pub fn candidates_for_scale(&self, img: &ImageRgb, scale_idx: usize) -> Vec<Candidate> {
        let (h, w) = self.pyramid.sizes[scale_idx];
        let resized = img.resize_nearest(w, h);
        let g = gradient_map(&resized);
        let s = match self.mode {
            ScoringMode::Exact => score_map(&g, &self.weights),
            ScoringMode::Binarized { nw, ng } => {
                BinarizedScorer::new(&self.weights, nw, ng).score_map(&g)
            }
            ScoringMode::HiPrecision(w) => score_map_i32(&g, &w),
        };
        winners_from_scores(&s)
            .into_iter()
            .map(|win| Candidate { scale_idx, x: win.x, y: win.y, score: win.score })
            .collect()
    }

    /// All candidates across the pyramid (paper: the kernel-computing module
    /// output before the sorting module).
    pub fn candidates(&self, img: &ImageRgb) -> Vec<Candidate> {
        let n = self.pyramid.sizes.len();
        if self.parallel {
            crate::util::parallel_map(n, crate::util::default_threads(), |i| {
                self.candidates_for_scale(img, i)
            })
            .into_iter()
            .flatten()
            .collect()
        } else {
            (0..n).flat_map(|i| self.candidates_for_scale(img, i)).collect()
        }
    }

    /// Full pipeline: candidates → stage-II calibration → top-k heap →
    /// proposals in original coordinates, descending calibrated score.
    pub fn propose(&self, img: &ImageRgb, top_k: usize) -> Vec<Proposal> {
        let candidates = self.candidates(img);
        rank_and_select(
            &candidates,
            &self.pyramid,
            &self.stage2,
            img.w,
            img.h,
            top_k,
        )
    }
}

/// Stage-II + bubble-pushing-heap top-k, shared with the coordinator so the
/// serving path and the baseline rank identically.
pub fn rank_and_select(
    candidates: &[Candidate],
    pyramid: &Pyramid,
    stage2: &Stage2Calibration,
    orig_w: usize,
    orig_h: usize,
    top_k: usize,
) -> Vec<Proposal> {
    let mut heap = BubbleHeap::new(top_k);
    for c in candidates {
        let calibrated = stage2.apply(c.scale_idx, c.score);
        // deterministic total order: calibrated score (as sortable bits),
        // then scale/position as tie-breaks
        let key = ((sortable_f32(calibrated) as i64) << 24)
            | ((c.scale_idx as i64 & 0xff) << 16)
            | ((c.y as i64 & 0xff) << 8)
            | (c.x as i64 & 0xff);
        let bbox = window_to_box(c.x, c.y, pyramid.sizes[c.scale_idx], orig_w, orig_h);
        heap.push(Ranked { key, proposal: Proposal { bbox, score: calibrated } });
    }
    heap.into_sorted_desc().into_iter().map(|r| r.proposal).collect()
}

/// Map f32 to an order-preserving i32 (IEEE-754 trick), so the heap's Ord is
/// total and NaN-free by construction.
fn sortable_f32(v: f32) -> i32 {
    let b = v.to_bits();
    // classic IEEE-754 total-order key: flip all bits of negatives, set the
    // sign bit of positives (ascending u32) — then recenter into i32
    let u = if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 };
    (u ^ 0x8000_0000) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;

    fn small_pipeline(mode: ScoringMode) -> SoftwareBing {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            mode,
        )
    }

    #[test]
    fn sortable_f32_preserves_order() {
        let vals = [-1e9f32, -2.5, -0.0, 0.0, 1e-20, 3.25, 7e8];
        for w in vals.windows(2) {
            assert!(sortable_f32(w[0]) <= sortable_f32(w[1]), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn proposes_sorted_descending() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let props = small_pipeline(ScoringMode::Exact).propose(&img, 50);
        assert!(!props.is_empty());
        for w in props.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn proposals_stay_in_image() {
        let ds = SyntheticDataset::voc_like_val(2);
        let img = ds.sample(1).image;
        for p in small_pipeline(ScoringMode::Exact).propose(&img, 100) {
            assert!((p.bbox.x1 as usize) < img.w);
            assert!((p.bbox.y1 as usize) < img.h);
        }
    }

    #[test]
    fn parallel_and_serial_agree() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let mut sw = small_pipeline(ScoringMode::Exact);
        let par = sw.propose(&img, 40);
        sw.parallel = false;
        let ser = sw.propose(&img, 40);
        assert_eq!(par, ser);
    }

    #[test]
    fn top_k_truncates() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let sw = small_pipeline(ScoringMode::Exact);
        assert_eq!(sw.propose(&img, 5).len(), 5);
    }

    #[test]
    fn binarized_mode_runs_and_ranks_similarly() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let exact = small_pipeline(ScoringMode::Exact).propose(&img, 20);
        let binar =
            small_pipeline(ScoringMode::Binarized { nw: 3, ng: 6 }).propose(&img, 20);
        assert_eq!(binar.len(), 20);
        // the top-20 sets should overlap substantially (approximation quality)
        let hits = binar
            .iter()
            .filter(|b| exact.iter().any(|e| e.bbox == b.bbox))
            .count();
        assert!(hits >= 10, "binarized top-k diverged too far: {hits}/20");
    }

    #[test]
    #[should_panic(expected = "calibration must cover")]
    fn mismatched_stage2_rejected() {
        let _ = SoftwareBing::new(
            Pyramid::new(vec![(16, 16)]),
            default_stage1(),
            Stage2Calibration::identity(vec![(32, 32)]),
            ScoringMode::Exact,
        );
    }
}
