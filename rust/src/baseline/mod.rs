//! The software BING baseline — the "traditional desktop CPU platform"
//! comparator of Table 2.
//!
//! A well-optimized control-flow implementation of the full proposal
//! pipeline: pyramid resize → CalcGrad → SVM-I (exact or binarized bitwise
//! scoring) → 5×5 block NMS → stage-II calibration → top-k heap. Scales run
//! on the persistent process-wide worker pool (the paper's i7 numbers use
//! multi-threading + subword parallelism; the binarized scorer is the
//! subword part), and every per-scale stage writes into a reusable
//! [`ScaleScratch`] arena, so steady-state serving does no heap allocation
//! on the scale path.
//!
//! This module is *also* the functional reference for the accelerator: the
//! quantized outputs are bit-identical to the HLO path and the dataflow
//! simulator (integration_parity.rs proves it).

use std::cell::RefCell;

use crate::bing::{
    gradient_map_into, score_map_i32_into, score_map_into, window_to_box,
    winners_from_scores_into, BinarizedScorer, BinarizedScratch, Candidate, Proposal, Pyramid,
    ScoreMap, Stage1Weights, Winner,
};
use crate::image::{ImageGray, ImageRgb};
use crate::simd::ScoreKernel;
use crate::sort::BubbleHeap;
use crate::svm::Stage2Calibration;

/// Scoring backend for the baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoringMode {
    /// Exact integer dot products (what the FPGA datapath computes).
    Exact,
    /// BING's binarized approximation (`nw` weight bases, `ng` bit planes) —
    /// the published CPU fast path.
    Binarized { nw: usize, ng: usize },
    /// High-precision weights (`round(w_float · 1024)`) — the float software
    /// reference of the Fig. 5 quantization ablation.
    HiPrecision([[i32; 8]; 8]),
}

impl ScoringMode {
    /// Carry float-trained weights at 1/1024 resolution.
    pub fn hi_precision(float_w: &[[f64; 8]; 8]) -> Self {
        let mut w = [[0i32; 8]; 8];
        for dy in 0..8 {
            for dx in 0..8 {
                w[dy][dx] = (float_w[dy][dx] * 1024.0).round() as i32;
            }
        }
        ScoringMode::HiPrecision(w)
    }
}

/// Reusable per-scale buffers — the scratch arena threaded through
/// [`SoftwareBing::candidates_for_scale_scratch`] and the coordinator's
/// workers. Every buffer grows to the largest scale it has seen and then
/// stays put, so the steady-state request path performs no heap allocation
/// for resize, gradient, scoring or NMS.
#[derive(Debug, Default)]
pub struct ScaleScratch {
    resized: ImageRgb,
    grad: ImageGray,
    scores: ScoreMap,
    winners: Vec<Winner>,
    binarized: BinarizedScratch,
}

impl ScaleScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize `img` to `w`×`h` into the arena's resize buffer and return it —
    /// the resize-module entry point the coordinator's workers use.
    pub fn resize(&mut self, img: &ImageRgb, w: usize, h: usize) -> &ImageRgb {
        img.resize_nearest_into(w, h, &mut self.resized);
        &self.resized
    }
}

thread_local! {
    /// One persistent arena per worker thread (the pool threads live for the
    /// process, so these amortize to zero allocation across requests).
    static SCALE_SCRATCH: RefCell<ScaleScratch> = RefCell::new(ScaleScratch::new());
}

/// Run `f` with the calling thread's persistent [`ScaleScratch`]. Do not
/// nest calls (the arena is a `RefCell`); per-scale stages never do.
pub fn with_scale_scratch<R>(f: impl FnOnce(&mut ScaleScratch) -> R) -> R {
    SCALE_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Binarized scorer derived from `(weights, nw, ng)` at construction so the
/// greedy basis decomposition is off the per-scale path.
#[derive(Debug)]
struct CachedScorer {
    nw: usize,
    ng: usize,
    weights: Stage1Weights,
    scorer: BinarizedScorer,
}

/// The software pipeline, bundling weights + pyramid + calibration.
pub struct SoftwareBing {
    pub pyramid: Pyramid,
    pub weights: Stage1Weights,
    pub stage2: Stage2Calibration,
    pub mode: ScoringMode,
    /// Run scales on the shared worker pool (true for the i7-comparator
    /// benches).
    pub parallel: bool,
    /// Which scoring kernel executes the binarized score phase (PR 8):
    /// [`ScoreKernel::detect`] by default, overridable via the `--kernel`
    /// CLI flag / `scoring.kernel` config key. All kernels are
    /// bit-identical, so this is purely a speed knob.
    pub kernel: ScoreKernel,
    /// Built by [`Self::new`] when `mode` is binarized; invalidated (and
    /// transparently rebuilt per call) if `mode`/`weights` are mutated later.
    scorer: Option<CachedScorer>,
}

/// A scored proposal before the final heap (public for ablations).
#[derive(Debug, Clone, Copy, PartialEq)]
struct Ranked {
    key: RankKey,
    proposal: Proposal,
}

/// Deterministic total order for the top-k heap: calibrated score (as
/// order-preserving bits), then scale / y / x as tie-breaks. Each tie-break
/// field carries its full 16 bits — score maps exceed 300 windows per axis
/// on the paper pyramid, so the old 8-bit packing collided equal-score
/// candidates and made their order layout-dependent (fixed in PR 2).
type RankKey = (i32, u16, u16, u16);

impl Eq for Ranked {}

impl Ord for Ranked {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

impl PartialOrd for Ranked {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl SoftwareBing {
    pub fn new(
        pyramid: Pyramid,
        weights: Stage1Weights,
        stage2: Stage2Calibration,
        mode: ScoringMode,
    ) -> Self {
        assert_eq!(
            pyramid.sizes, stage2.sizes,
            "stage-II calibration must cover exactly the pyramid scales"
        );
        let scorer = match mode {
            ScoringMode::Binarized { nw, ng } => Some(CachedScorer {
                nw,
                ng,
                weights: weights.clone(),
                scorer: BinarizedScorer::new(&weights, nw, ng),
            }),
            _ => None,
        };
        Self {
            pyramid,
            weights,
            stage2,
            mode,
            parallel: true,
            kernel: ScoreKernel::detect(),
            scorer,
        }
    }

    /// Builder-style kernel override (resolves availability: forcing a
    /// vector kernel this host lacks lands on SWAR).
    pub fn with_kernel(mut self, kernel: crate::simd::KernelChoice) -> Self {
        self.kernel = kernel.resolve();
        self
    }

    /// Per-scale candidate extraction (resize → grad → score → block NMS)
    /// using the calling thread's persistent scratch arena.
    pub fn candidates_for_scale(&self, img: &ImageRgb, scale_idx: usize) -> Vec<Candidate> {
        with_scale_scratch(|scratch| self.candidates_for_scale_scratch(img, scale_idx, scratch))
    }

    /// [`Self::candidates_for_scale`] against an explicit arena: all heavy
    /// intermediates (resized image, gradient map, score map, winner list,
    /// binarized bit planes) live in `scratch` and are reused across calls.
    pub fn candidates_for_scale_scratch(
        &self,
        img: &ImageRgb,
        scale_idx: usize,
        scratch: &mut ScaleScratch,
    ) -> Vec<Candidate> {
        let (h, w) = self.pyramid.sizes[scale_idx];
        img.resize_nearest_into(w, h, &mut scratch.resized);
        gradient_map_into(&scratch.resized, &mut scratch.grad);
        match self.mode {
            ScoringMode::Exact => {
                score_map_into(&scratch.grad, &self.weights, &mut scratch.scores)
            }
            ScoringMode::Binarized { nw, ng } => {
                let cached = self
                    .scorer
                    .as_ref()
                    .filter(|c| c.nw == nw && c.ng == ng && c.weights == self.weights);
                match cached {
                    Some(c) => c.scorer.score_map_into_with(
                        &scratch.grad,
                        &mut scratch.binarized,
                        &mut scratch.scores,
                        self.kernel,
                    ),
                    // mode/weights were mutated after construction: fall back
                    // to a freshly derived scorer (correct, just slower)
                    None => BinarizedScorer::new(&self.weights, nw, ng).score_map_into_with(
                        &scratch.grad,
                        &mut scratch.binarized,
                        &mut scratch.scores,
                        self.kernel,
                    ),
                }
            }
            ScoringMode::HiPrecision(w) => {
                score_map_i32_into(&scratch.grad, &w, &mut scratch.scores)
            }
        }
        winners_from_scores_into(&scratch.scores, &mut scratch.winners);
        scratch
            .winners
            .iter()
            .map(|win| Candidate { scale_idx, x: win.x, y: win.y, score: win.score })
            .collect()
    }

    /// All candidates across the pyramid (paper: the kernel-computing module
    /// output before the sorting module).
    pub fn candidates(&self, img: &ImageRgb) -> Vec<Candidate> {
        let n = self.pyramid.sizes.len();
        if self.parallel && n > 1 {
            // fork-join on the persistent pool: the caller participates and
            // `default_threads() - 1` workers assist (the deleted
            // `parallel_map` shim did exactly this, one hop removed)
            crate::util::pool::global()
                .scope_map(n, crate::util::default_threads().saturating_sub(1), |i| {
                    self.candidates_for_scale(img, i)
                })
                .into_iter()
                .flatten()
                .collect()
        } else {
            (0..n).flat_map(|i| self.candidates_for_scale(img, i)).collect()
        }
    }

    /// The cached binarized scorer, when `mode` is binarized and the cache
    /// still matches the live `(nw, ng, weights)` triple — the temporal
    /// incremental path ([`crate::temporal`]) scores dirty bands through
    /// exactly the scorer the full path would use, so band outputs are
    /// bit-identical to full-map rows.
    pub fn binarized_scorer(&self) -> Option<&BinarizedScorer> {
        let ScoringMode::Binarized { nw, ng } = self.mode else {
            return None;
        };
        self.scorer
            .as_ref()
            .filter(|c| c.nw == nw && c.ng == ng && c.weights == self.weights)
            .map(|c| &c.scorer)
    }

    /// Full pipeline: candidates → stage-II calibration → top-k heap →
    /// proposals in original coordinates, descending calibrated score.
    pub fn propose(&self, img: &ImageRgb, top_k: usize) -> Vec<Proposal> {
        let candidates = self.candidates(img);
        rank_and_select(
            &candidates,
            &self.pyramid,
            &self.stage2,
            img.w,
            img.h,
            top_k,
        )
    }
}

/// Stage-II + bubble-pushing-heap top-k, shared with the coordinator so the
/// serving path and the baseline rank identically. Sugar for
/// [`rank_and_select_seeded`] with no priors.
pub fn rank_and_select(
    candidates: &[Candidate],
    pyramid: &Pyramid,
    stage2: &Stage2Calibration,
    orig_w: usize,
    orig_h: usize,
    top_k: usize,
) -> Vec<Proposal> {
    rank_and_select_seeded(candidates, pyramid, stage2, orig_w, orig_h, top_k, &[]).proposals
}

/// Output of [`rank_and_select_seeded`]: the ranked proposals plus the
/// side-band the temporal serving path feeds forward.
#[derive(Debug, Clone, Default)]
pub struct RankedSelection {
    /// Top-k proposals in original coordinates, descending calibrated score.
    pub proposals: Vec<Proposal>,
    /// `(scale_idx, y, x)` of each selected proposal, aligned with
    /// `proposals` — the priors for the session's next frame.
    pub winners: Vec<(u16, u16, u16)>,
    /// Candidates that matched a prior position and were pushed in the
    /// seeding pass (`ServeMetrics::prior_hits`).
    pub prior_hits: u64,
}

/// [`rank_and_select`] with previous-frame proposal priors: candidates whose
/// `(scale, y, x)` matched a prior are pushed into the heap *first*, so on
/// temporally coherent frames the top-k eviction threshold starts near its
/// final value and the fast-reject below prunes most of the stream without
/// key or box construction.
///
/// Bit-identical to the unseeded ranking for any `priors`: the heap's final
/// top-k set is independent of push order (keys form a unique total order —
/// score bits, then scale/y/x — and `push` drops exactly the items `<=` the
/// root of a full heap), and the output ordering comes from the final sort
/// in `into_sorted_desc`, not from arrival order.
pub fn rank_and_select_seeded(
    candidates: &[Candidate],
    pyramid: &Pyramid,
    stage2: &Stage2Calibration,
    orig_w: usize,
    orig_h: usize,
    top_k: usize,
    priors: &[(u16, u16, u16)],
) -> RankedSelection {
    if top_k == 0 {
        return RankedSelection::default();
    }
    let mut heap = BubbleHeap::new(top_k);
    let mut consider = |heap: &mut BubbleHeap<Ranked>, c: &Candidate| {
        let calibrated = stage2.apply(c.scale_idx, c.score);
        let score_key = sortable_f32(calibrated);
        // Fast reject: once the heap is full, a candidate whose *best
        // possible* key (maximal tie-breaks) cannot beat the heap minimum
        // would be rejected by `push` anyway — skip the key and
        // `window_to_box` construction entirely. Bit-identical by
        // construction: `push` drops any item `<=` the root.
        if heap.is_full() {
            if let Some(min) = heap.min() {
                if (score_key, u16::MAX, u16::MAX, u16::MAX) <= min.key {
                    return;
                }
            }
        }
        let key = (score_key, c.scale_idx as u16, c.y, c.x);
        let bbox = window_to_box(c.x, c.y, pyramid.sizes[c.scale_idx], orig_w, orig_h);
        heap.push(Ranked { key, proposal: Proposal { bbox, score: calibrated } });
    };
    let mut prior_hits = 0u64;
    let mut sorted_priors;
    let priors: &[(u16, u16, u16)] = if priors.is_empty() {
        priors
    } else {
        sorted_priors = priors.to_vec();
        sorted_priors.sort_unstable();
        // Seeding pass: last frame's winners are the best guess at this
        // frame's, so push the candidates at those positions before the rest.
        for c in candidates {
            if sorted_priors.binary_search(&(c.scale_idx as u16, c.y, c.x)).is_ok() {
                prior_hits += 1;
                consider(&mut heap, c);
            }
        }
        &sorted_priors
    };
    for c in candidates {
        if !priors.is_empty()
            && priors.binary_search(&(c.scale_idx as u16, c.y, c.x)).is_ok()
        {
            continue; // already pushed in the seeding pass
        }
        consider(&mut heap, c);
    }
    let ranked = heap.into_sorted_desc();
    let winners = ranked.iter().map(|r| (r.key.1, r.key.2, r.key.3)).collect();
    let proposals = ranked.into_iter().map(|r| r.proposal).collect();
    RankedSelection { proposals, winners, prior_hits }
}

/// Map f32 to an order-preserving i32 (IEEE-754 trick), so the heap's Ord is
/// total and NaN-free by construction.
fn sortable_f32(v: f32) -> i32 {
    let b = v.to_bits();
    // classic IEEE-754 total-order key: flip all bits of negatives, set the
    // sign bit of positives (ascending u32) — then recenter into i32
    let u = if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 };
    (u ^ 0x8000_0000) as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;

    fn small_pipeline(mode: ScoringMode) -> SoftwareBing {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            mode,
        )
    }

    #[test]
    fn sortable_f32_preserves_order() {
        let vals = [-1e9f32, -2.5, -0.0, 0.0, 1e-20, 3.25, 7e8];
        for w in vals.windows(2) {
            assert!(sortable_f32(w[0]) <= sortable_f32(w[1]), "{} vs {}", w[0], w[1]);
        }
    }

    #[test]
    fn proposes_sorted_descending() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let props = small_pipeline(ScoringMode::Exact).propose(&img, 50);
        assert!(!props.is_empty());
        for w in props.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    #[test]
    fn proposals_stay_in_image() {
        let ds = SyntheticDataset::voc_like_val(2);
        let img = ds.sample(1).image;
        for p in small_pipeline(ScoringMode::Exact).propose(&img, 100) {
            assert!((p.bbox.x1 as usize) < img.w);
            assert!((p.bbox.y1 as usize) < img.h);
        }
    }

    #[test]
    fn parallel_and_serial_agree() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let mut sw = small_pipeline(ScoringMode::Exact);
        let par = sw.propose(&img, 40);
        sw.parallel = false;
        let ser = sw.propose(&img, 40);
        assert_eq!(par, ser);
    }

    #[test]
    fn top_k_truncates() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let sw = small_pipeline(ScoringMode::Exact);
        assert_eq!(sw.propose(&img, 5).len(), 5);
    }

    #[test]
    fn binarized_mode_runs_and_ranks_similarly() {
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let exact = small_pipeline(ScoringMode::Exact).propose(&img, 20);
        let binar =
            small_pipeline(ScoringMode::Binarized { nw: 3, ng: 6 }).propose(&img, 20);
        assert_eq!(binar.len(), 20);
        // the top-20 sets should overlap substantially (approximation quality)
        let hits = binar
            .iter()
            .filter(|b| exact.iter().any(|e| e.bbox == b.bbox))
            .count();
        assert!(hits >= 10, "binarized top-k diverged too far: {hits}/20");
    }

    #[test]
    fn kernel_choice_never_changes_proposals() {
        use crate::simd::KernelChoice;
        let ds = SyntheticDataset::voc_like_val(1);
        let img = ds.sample(0).image;
        let auto = small_pipeline(ScoringMode::Binarized { nw: 2, ng: 4 }).propose(&img, 30);
        for choice in ["swar", "avx2", "neon", "reference"] {
            let forced = small_pipeline(ScoringMode::Binarized { nw: 2, ng: 4 })
                .with_kernel(choice.parse::<KernelChoice>().unwrap())
                .propose(&img, 30);
            assert_eq!(auto, forced, "kernel {choice} changed the proposal set");
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_arena_across_modes_and_scales() {
        let ds = SyntheticDataset::voc_like_val(2);
        let modes = [
            ScoringMode::Exact,
            ScoringMode::Binarized { nw: 3, ng: 6 },
            ScoringMode::Binarized { nw: 2, ng: 4 },
        ];
        // one dirty arena across every (mode, image, scale) combination —
        // visiting scales large→small→large so stale buffer contents would
        // surface immediately
        let mut dirty = ScaleScratch::new();
        for mode in modes {
            let sw = small_pipeline(mode);
            for s in ds.iter() {
                for &scale_idx in &[2usize, 0, 1, 2, 0] {
                    let reused =
                        sw.candidates_for_scale_scratch(&s.image, scale_idx, &mut dirty);
                    let fresh = sw.candidates_for_scale_scratch(
                        &s.image,
                        scale_idx,
                        &mut ScaleScratch::new(),
                    );
                    assert_eq!(reused, fresh, "scratch reuse diverged on scale {scale_idx}");
                }
            }
        }
    }

    #[test]
    fn tie_break_distinguishes_coordinates_beyond_255() {
        // score maps reach >300 windows on the paper pyramid; the pre-PR-2
        // packed key masked x/y to 8 bits, so x=300 collided with x=44
        // (300 & 0xff == 44) and the winner depended on submission order
        let sizes = vec![(16usize, 320usize)];
        let pyramid = Pyramid::new(sizes.clone());
        let stage2 = Stage2Calibration::identity(sizes);
        let a = Candidate { scale_idx: 0, x: 300, y: 0, score: 77 };
        let b = Candidate { scale_idx: 0, x: 44, y: 0, score: 77 };
        let ab = rank_and_select(&[a, b], &pyramid, &stage2, 640, 32, 1);
        let ba = rank_and_select(&[b, a], &pyramid, &stage2, 640, 32, 1);
        assert_eq!(ab, ba, "tie order depends on input layout");
        let expect = window_to_box(300, 0, (16, 320), 640, 32);
        assert_eq!(ab[0].bbox, expect, "higher-x candidate must win the tie");

        // same regression on the y axis
        let sizes = vec![(320usize, 16usize)];
        let pyramid = Pyramid::new(sizes.clone());
        let stage2 = Stage2Calibration::identity(sizes);
        let a = Candidate { scale_idx: 0, x: 0, y: 299, score: 5 };
        let b = Candidate { scale_idx: 0, x: 0, y: 43, score: 5 }; // 299 & 0xff == 43
        let ab = rank_and_select(&[a, b], &pyramid, &stage2, 32, 640, 1);
        let ba = rank_and_select(&[b, a], &pyramid, &stage2, 32, 640, 1);
        assert_eq!(ab, ba);
        assert_eq!(ab[0].bbox, window_to_box(0, 299, (320, 16), 32, 640));
    }

    #[test]
    fn heap_min_fast_reject_matches_exhaustive_ranking() {
        // many more candidates than k, lots of duplicate scores → the fast
        // reject fires constantly; compare against sort-everything
        let sizes = vec![(16usize, 16usize), (32, 32)];
        let pyramid = Pyramid::new(sizes.clone());
        let stage2 = Stage2Calibration::identity(sizes);
        let candidates: Vec<Candidate> = (0..500)
            .map(|i| Candidate {
                scale_idx: i % 2,
                x: (i as u16 * 7) % 9,
                y: (i as u16 * 13) % 9,
                score: ((i as i32) * 37) % 50 - 25,
            })
            .collect();
        for k in [1usize, 7, 40, 499, 500, 600] {
            let got = rank_and_select(&candidates, &pyramid, &stage2, 128, 128, k);
            // exhaustive reference: build every key, full sort, truncate
            let mut all: Vec<Ranked> = candidates
                .iter()
                .map(|c| {
                    let calibrated = stage2.apply(c.scale_idx, c.score);
                    Ranked {
                        key: (sortable_f32(calibrated), c.scale_idx as u16, c.y, c.x),
                        proposal: Proposal {
                            bbox: window_to_box(c.x, c.y, pyramid.sizes[c.scale_idx], 128, 128),
                            score: calibrated,
                        },
                    }
                })
                .collect();
            all.sort_unstable_by(|a, b| b.cmp(a));
            all.truncate(k);
            let want: Vec<Proposal> = all.into_iter().map(|r| r.proposal).collect();
            assert_eq!(got, want, "fast reject changed the top-{k}");
        }
    }

    #[test]
    fn seeding_never_changes_the_selection() {
        let sizes = vec![(16usize, 16usize), (32, 32)];
        let pyramid = Pyramid::new(sizes.clone());
        let stage2 = Stage2Calibration::identity(sizes);
        let candidates: Vec<Candidate> = (0..400)
            .map(|i| Candidate {
                scale_idx: i % 2,
                x: (i as u16 * 11) % 9,
                y: (i as u16 * 17) % 9,
                score: ((i as i32) * 53) % 60 - 30,
            })
            .collect();
        for k in [1usize, 8, 50, 400] {
            let base = rank_and_select(&candidates, &pyramid, &stage2, 128, 128, k);
            // seed with the true winners, a garbage prior set, and a mix
            let winners =
                rank_and_select_seeded(&candidates, &pyramid, &stage2, 128, 128, k, &[])
                    .winners;
            let garbage: Vec<(u16, u16, u16)> = (0..k as u16).map(|i| (9, i, i)).collect();
            let mut mixed = winners.clone();
            mixed.extend_from_slice(&garbage);
            for priors in [&winners, &garbage, &mixed] {
                let seeded = rank_and_select_seeded(
                    &candidates, &pyramid, &stage2, 128, 128, k, priors,
                );
                assert_eq!(seeded.proposals, base, "k={k}: seeding changed the top-k");
                assert_eq!(seeded.winners.len(), seeded.proposals.len());
            }
        }
        // exact-prior seeding reports one hit per candidate at a prior spot
        let sel = rank_and_select_seeded(&candidates, &pyramid, &stage2, 128, 128, 8, &[]);
        let reseeded = rank_and_select_seeded(
            &candidates, &pyramid, &stage2, 128, 128, 8, &sel.winners,
        );
        assert!(reseeded.prior_hits >= 8, "hits {} < 8", reseeded.prior_hits);
    }

    #[test]
    fn winners_align_with_proposals() {
        let sizes = vec![(16usize, 16usize)];
        let pyramid = Pyramid::new(sizes.clone());
        let stage2 = Stage2Calibration::identity(sizes);
        let candidates = [
            Candidate { scale_idx: 0, x: 3, y: 5, score: 10 },
            Candidate { scale_idx: 0, x: 7, y: 1, score: 30 },
            Candidate { scale_idx: 0, x: 2, y: 2, score: 20 },
        ];
        let sel = rank_and_select_seeded(&candidates, &pyramid, &stage2, 64, 64, 2, &[]);
        assert_eq!(sel.winners, vec![(0, 1, 7), (0, 2, 2)]);
        assert_eq!(sel.proposals.len(), 2);
        assert_eq!(sel.proposals[0].bbox, window_to_box(7, 1, (16, 16), 64, 64));
    }

    #[test]
    #[should_panic(expected = "calibration must cover")]
    fn mismatched_stage2_rejected() {
        let _ = SoftwareBing::new(
            Pyramid::new(vec![(16, 16)]),
            default_stage1(),
            Stage2Calibration::identity(vec![(32, 32)]),
            ScoringMode::Exact,
        );
    }
}
