//! Typed configuration for the whole stack.
//!
//! A deliberately small, dependency-free config system: typed structs with
//! documented defaults, overridable from a flat `key = value` text file
//! (see [`Config::from_file`]) and from CLI flags in `main.rs`. The format is
//! a strict subset of TOML (scalars only), enough for experiment sweeps
//! without pulling serde into the request path.

mod parse;

pub use parse::{parse_kv, ConfigError};

use std::path::Path;

/// Window size of the BING feature (8×8 normed gradients). Fixed by the
/// algorithm; exposed for documentation rather than tuning.
pub const WIN: usize = 8;

/// NMS block size (paper: 5×5 blocks of the score map).
pub const NMS_BLOCK: usize = 5;

/// Padding sentinel for NMS blocks; must match `python/compile/common.py`.
pub const NEG_SENTINEL: i32 = -(1 << 20);

/// The pyramid of resized-image sizes `(h, w)`.
///
/// Must agree with `python/compile/common.py::DEFAULT_SIZES` — the runtime
/// cross-checks against `artifacts/manifest.txt` at startup.
pub fn default_sizes() -> Vec<(usize, usize)> {
    let ladder = [16usize, 32, 64, 128];
    let mut v = Vec::with_capacity(16);
    for &h in &ladder {
        for &w in &ladder {
            v.push((h, w));
        }
    }
    v
}

/// Which FPGA device model the dataflow simulator targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    /// Artix-7 low-voltage (xc7a100tlftg256-2L) @ 3.3 MHz — always-on mode.
    Artix7LowVolt,
    /// Kintex UltraScale+ (xcku3p-ffva676-3-e) @ 100 MHz — real-time mode.
    KintexUltraScalePlus,
}

impl Device {
    /// Clock frequency in Hz (paper §4.1).
    pub fn clock_hz(self) -> f64 {
        match self {
            Device::Artix7LowVolt => 3.3e6,
            Device::KintexUltraScalePlus => 100.0e6,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Device::Artix7LowVolt => "Artix-7 Low Volt. @ 3.3MHz",
            Device::KintexUltraScalePlus => "Kintex UltraScale+ @ 100MHz",
        }
    }
}

/// Geometry of the simulated accelerator (paper defaults in comments).
#[derive(Debug, Clone)]
pub struct AcceleratorConfig {
    /// Number of parallel kernel-computing pipelines (paper demonstrates 4).
    pub pipelines: usize,
    /// Vertical batch height: pixels fetched per cycle per worker (paper: 4).
    pub batch_pixels: usize,
    /// Depth of the FIFO smoothing the NMS output stream.
    pub nms_fifo_depth: usize,
    /// Capacity of the bubble-pushing heap (top-n per scale).
    pub heap_capacity: usize,
    /// Ping-pong cache enabled (ablation E5 turns it off).
    pub ping_pong: bool,
    /// Overlap scale transitions (drain of scale i overlaps fetch of i+1);
    /// disable for the strict-barrier ablation.
    pub overlap_scales: bool,
    /// Device model for clock/resource/power accounting.
    pub device: Device,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self {
            pipelines: 4,
            batch_pixels: 4,
            nms_fifo_depth: 64,
            heap_capacity: 128,
            ping_pong: true,
            overlap_scales: true,
            device: Device::KintexUltraScalePlus,
        }
    }
}

/// Which routing policy a sharded `serving::ServerRuntime` uses to pick a
/// backend shard per request (see `serving::make_policy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RoutePolicyKind {
    /// Uniform spraying over the non-draining shards.
    #[default]
    RoundRobin,
    /// Join-the-shortest-queue by outstanding (queued + executing) scale
    /// tasks — admission tokens are released when execution starts, so a
    /// queued-only signal would read 0 under normal load.
    LeastLoaded,
    /// Pin large frames to a dedicated shard group (the paper's
    /// multi-pipeline split).
    ScaleAffinity,
    /// Pin video sessions to shards so their temporal frame caches stay
    /// warm (see [`crate::temporal`]); sessionless requests round-robin.
    SessionAffinity,
}

impl RoutePolicyKind {
    /// Canonical CLI/config spelling ("rr" | "least" | "affinity" | "session").
    pub fn name(self) -> &'static str {
        match self {
            RoutePolicyKind::RoundRobin => "rr",
            RoutePolicyKind::LeastLoaded => "least",
            RoutePolicyKind::ScaleAffinity => "affinity",
            RoutePolicyKind::SessionAffinity => "session",
        }
    }
}

impl std::str::FromStr for RoutePolicyKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rr" | "round-robin" => Ok(RoutePolicyKind::RoundRobin),
            "least" | "least-loaded" => Ok(RoutePolicyKind::LeastLoaded),
            "affinity" | "scale-affinity" => Ok(RoutePolicyKind::ScaleAffinity),
            "session" | "session-affinity" => Ok(RoutePolicyKind::SessionAffinity),
            other => Err(format!(
                "unknown policy `{other}` (expected rr|least|affinity|session)"
            )),
        }
    }
}

/// Temporal-coherence (video session) knobs — how the per-session frame
/// caches in [`crate::temporal`] decide what to recompute between
/// consecutive frames. The incremental path is bit-identical to full
/// recompute for every setting; these only move the work/skip boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TemporalConfig {
    /// Side length (pixels) of the square dirty-detection tiles laid over
    /// the source frame.
    pub tile: usize,
    /// Per-channel absolute pixel difference a tile must exceed to count
    /// as dirty. 0 = any changed byte dirties its tile, which keeps the
    /// served frame byte-for-byte the submitted frame; > 0 trades exact
    /// input fidelity for more skipped tiles (the session's canonical
    /// frame then retains the cached pixels of clean tiles).
    pub pixel_threshold: u8,
}

impl Default for TemporalConfig {
    fn default() -> Self {
        Self { tile: 16, pixel_threshold: 0 }
    }
}

/// Detection-cascade defaults: what happens *after* the proposal stage
/// when a request asks for detections (proposals → greedy IoU NMS → Platt
/// confidence calibration). Per-request overrides come in through
/// `coordinator::DetectRequest`; these are the fallbacks.
#[derive(Debug, Clone, PartialEq)]
pub struct CascadeConfig {
    /// Greedy-NMS IoU threshold applied to the ranked proposals.
    pub nms_thresh: f32,
    /// Maximum detections returned per image (after NMS).
    pub top_k: usize,
    /// Minimum calibrated confidence; detections below it are dropped.
    pub min_confidence: f32,
    /// Platt scale `a` in `confidence = sigmoid(a·score + b)`.
    pub platt_a: f64,
    /// Platt offset `b` in `confidence = sigmoid(a·score + b)`.
    pub platt_b: f64,
}

impl Default for CascadeConfig {
    fn default() -> Self {
        Self {
            nms_thresh: 0.5,
            top_k: 100,
            min_confidence: 0.0,
            platt_a: 1.0,
            platt_b: 0.0,
        }
    }
}

/// Self-healing and fault-injection knobs for the sharded runtime: the
/// retry/hedge policy, the shard supervisor's circuit breaker, the
/// brownout (load-shedding) controller, and the seeded chaos plan the
/// `ChaosBackend` wrapper injects faults from. All off/neutral by default —
/// the fault-free serving path is byte-for-byte the PR-4/5 behavior.
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceConfig {
    /// Total attempts per request (1 = no retries). Retries prefer a shard
    /// the request has not tried yet.
    pub retry_max_attempts: u32,
    /// Base backoff between attempts in milliseconds (linear: attempt `i`
    /// sleeps `i * backoff`, capped by the remaining deadline budget).
    pub retry_backoff_ms: u64,
    /// Fire a hedged second attempt if the primary has not resolved after
    /// this many milliseconds. `None` disables hedging.
    pub hedge_after_ms: Option<u64>,
    /// Sliding-window length (request outcomes per shard) the supervisor
    /// judges shard health over.
    pub supervisor_window: usize,
    /// Failures within the window that mark a shard `Degraded`.
    pub degrade_failures: usize,
    /// Failures within the window that trip the breaker (`Quarantined`).
    pub quarantine_failures: usize,
    /// How long a quarantined shard sits out before half-opening into
    /// `Recovering` (probe traffic allowed again).
    pub quarantine_cooldown_ms: u64,
    /// Consecutive probe successes required to restore a `Recovering`
    /// shard to `Healthy`; one probe failure re-quarantines.
    pub probe_successes: usize,
    /// Master switch for the brownout (load-shedding) controller.
    pub brownout: bool,
    /// Fleet queue depth (queued scale tasks summed over shards) at which
    /// brownout level 1 engages; 2x engages level 2.
    pub brownout_queue_depth: usize,
    /// Deadline-miss rate (over the recent outcome window) at which
    /// brownout level 1 engages; 2x engages level 2.
    pub brownout_miss_rate: f64,
    /// Proposal `top_k` cap applied at brownout level ≥ 1.
    pub brownout_top_k: usize,
    /// Pyramid scale stride applied at brownout level ≥ 2.
    pub brownout_scale_stride: usize,
    /// Seed for the fault-injection plan; `None` = chaos disabled. Set by
    /// `serve --chaos-seed` or `resilience.chaos_seed`.
    pub chaos_seed: Option<u64>,
    /// Per-scale-task probability of an injected panic.
    pub chaos_panic_p: f64,
    /// Per-scale-task probability of an injected transient `Err`.
    pub chaos_transient_p: f64,
    /// Per-scale-task probability of injected latency.
    pub chaos_latency_p: f64,
    /// Injected latency duration in milliseconds.
    pub chaos_latency_ms: u64,
    /// Per-scale-task probability of injected silent corruption (scores or
    /// boxes deterministically perturbed; caught by the `integrity`
    /// validators when they are enabled).
    pub chaos_corrupt_p: f64,
    /// Per-scale-task probability of an injected hang (the task blocks
    /// far past any deadline, modeling a wedged worker).
    pub chaos_hang_p: f64,
    /// Injected hang duration in milliseconds. Should dwarf the serving
    /// deadline — a hang is a wedged worker, not a slow one.
    pub chaos_hang_ms: u64,
}

impl Default for ResilienceConfig {
    fn default() -> Self {
        Self {
            retry_max_attempts: 1,
            retry_backoff_ms: 1,
            hedge_after_ms: None,
            supervisor_window: 16,
            degrade_failures: 4,
            quarantine_failures: 8,
            quarantine_cooldown_ms: 250,
            probe_successes: 3,
            brownout: false,
            brownout_queue_depth: 64,
            brownout_miss_rate: 0.2,
            brownout_top_k: 100,
            brownout_scale_stride: 2,
            chaos_seed: None,
            chaos_panic_p: 0.02,
            chaos_transient_p: 0.05,
            chaos_latency_p: 0.05,
            chaos_latency_ms: 2,
            chaos_corrupt_p: 0.0,
            chaos_hang_p: 0.0,
            chaos_hang_ms: 1000,
        }
    }
}

/// Silent-data-corruption defense knobs (see [`crate::integrity`]): the
/// structural validators at the backend seam and the golden-probe audit
/// sampler. Validation is on by default — it is a handful of compares per
/// candidate and changes nothing on uncorrupted outputs; audits re-execute
/// 1-in-N requests, so they are opt-in.
#[derive(Debug, Clone, PartialEq)]
pub struct IntegrityConfig {
    /// Run the structural invariant validators on every scale result and
    /// finished response (violations abort the request as `Corrupt`).
    pub validate: bool,
    /// Audit every Nth request through the scalar reference oracle;
    /// 0 disables auditing.
    pub audit_rate: u64,
    /// On an audit mismatch implicating a multi-lane SIMD kernel, latch
    /// the one-way fleet-wide demotion to the SWAR scalar kernel.
    pub demote_on_mismatch: bool,
}

impl Default for IntegrityConfig {
    fn default() -> Self {
        Self { validate: true, audit_rate: 0, demote_on_mismatch: true }
    }
}

/// Serving-layer knobs for the sharded runtime and its shard coordinators.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Maximum images batched into one scheduling round.
    pub max_batch: usize,
    /// Worker tasks executing per-scale HLOs concurrently (per shard; the
    /// shared pool is sized to `workers * shards`, clamped).
    pub workers: usize,
    /// Bounded-queue capacity between router and workers, per shard
    /// (backpressure).
    pub queue_depth: usize,
    /// Final number of proposals returned per image (paper evaluates 1000;
    /// the default pyramid yields ≤ ~1500 candidates).
    pub top_k: usize,
    /// Per-scale candidate cap before stage-II (paper's top-n).
    pub top_n_per_scale: usize,
    /// Backend replicas behind the request router (the paper's replicated
    /// pipelines). 1 = the classic single-coordinator deployment.
    pub shards: usize,
    /// How the router picks a shard per request.
    pub policy: RoutePolicyKind,
    /// Default per-request deadline in milliseconds; `None` disables
    /// deadline enforcement (requests may block at the gate indefinitely).
    pub deadline_ms: Option<u64>,
    /// Detection-cascade defaults for `submit_detect` requests.
    pub cascade: CascadeConfig,
    /// Self-healing (retry/supervisor/brownout) and chaos knobs.
    pub resilience: ResilienceConfig,
    /// Silent-data-corruption defense (validators + golden-probe audits).
    pub integrity: IntegrityConfig,
    /// Temporal-coherence (video session) knobs.
    pub temporal: TemporalConfig,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            workers: 4,
            queue_depth: 64,
            top_k: 1000,
            top_n_per_scale: 128,
            shards: 1,
            policy: RoutePolicyKind::default(),
            deadline_ms: None,
            cascade: CascadeConfig::default(),
            resilience: ResilienceConfig::default(),
            integrity: IntegrityConfig::default(),
            temporal: TemporalConfig::default(),
        }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub accel: AcceleratorConfig,
    pub serving: ServingConfig,
    /// Pyramid scales; must match the artifacts manifest.
    pub sizes: Vec<(usize, usize)>,
    /// Directory holding `*.hlo.txt` + `manifest.txt`.
    pub artifacts_dir: String,
    /// Stage-I scoring kernel selection (`--kernel auto|swar|avx2|neon`):
    /// `Auto` dispatches on the host's vector features at startup; every
    /// choice is bit-identical (see [`crate::simd`]).
    pub kernel: crate::simd::KernelChoice,
    /// Pin pool workers to cores (`pool.pin`, default on). Must be set
    /// before the first pool use to affect worker spawn.
    pub pool_pin: bool,
}

impl Config {
    pub fn new() -> Self {
        Self {
            accel: AcceleratorConfig::default(),
            serving: ServingConfig::default(),
            sizes: default_sizes(),
            artifacts_dir: "artifacts".to_string(),
            kernel: crate::simd::KernelChoice::Auto,
            pool_pin: true,
        }
    }

    /// Load overrides from a flat `key = value` file. Unknown keys error —
    /// sweeps should fail loudly, not silently no-op.
    pub fn from_file(path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::Io(path.display().to_string(), e.to_string()))?;
        let mut cfg = Config::new();
        cfg.apply_text(&text)?;
        Ok(cfg)
    }

    /// Apply `key = value` lines to this config.
    pub fn apply_text(&mut self, text: &str) -> Result<(), ConfigError> {
        for (key, value) in parse_kv(text)? {
            self.apply(&key, &value)?;
        }
        Ok(())
    }

    /// Apply a single override.
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let bad = |k: &str, v: &str| ConfigError::BadValue(k.to_string(), v.to_string());
        match key {
            "accel.pipelines" => {
                self.accel.pipelines = value.parse().map_err(|_| bad(key, value))?
            }
            "accel.batch_pixels" => {
                self.accel.batch_pixels = value.parse().map_err(|_| bad(key, value))?
            }
            "accel.nms_fifo_depth" => {
                self.accel.nms_fifo_depth = value.parse().map_err(|_| bad(key, value))?
            }
            "accel.heap_capacity" => {
                self.accel.heap_capacity = value.parse().map_err(|_| bad(key, value))?
            }
            "accel.ping_pong" => {
                self.accel.ping_pong = value.parse().map_err(|_| bad(key, value))?
            }
            "accel.overlap_scales" => {
                self.accel.overlap_scales = value.parse().map_err(|_| bad(key, value))?
            }
            "accel.device" => {
                self.accel.device = match value {
                    "artix7" => Device::Artix7LowVolt,
                    "kintex" => Device::KintexUltraScalePlus,
                    _ => return Err(bad(key, value)),
                }
            }
            "serving.max_batch" => {
                self.serving.max_batch = value.parse().map_err(|_| bad(key, value))?
            }
            "serving.workers" => {
                self.serving.workers = value.parse().map_err(|_| bad(key, value))?
            }
            "serving.queue_depth" => {
                self.serving.queue_depth = value.parse().map_err(|_| bad(key, value))?
            }
            "serving.top_k" => {
                self.serving.top_k = value.parse().map_err(|_| bad(key, value))?
            }
            "serving.top_n_per_scale" => {
                self.serving.top_n_per_scale = value.parse().map_err(|_| bad(key, value))?
            }
            "serving.shards" => {
                self.serving.shards = value.parse().map_err(|_| bad(key, value))?
            }
            "serving.policy" => {
                self.serving.policy = value.parse().map_err(|_| bad(key, value))?
            }
            // 0 disables the deadline (flat-file configs have no `None`)
            "serving.deadline_ms" => {
                let ms: u64 = value.parse().map_err(|_| bad(key, value))?;
                self.serving.deadline_ms = (ms > 0).then_some(ms);
            }
            "cascade.nms_thresh" => {
                let t: f32 = value.parse().map_err(|_| bad(key, value))?;
                if !(0.0..=1.0).contains(&t) {
                    return Err(bad(key, value));
                }
                self.serving.cascade.nms_thresh = t;
            }
            "cascade.top_k" => {
                self.serving.cascade.top_k = value.parse().map_err(|_| bad(key, value))?
            }
            "cascade.min_confidence" => {
                let c: f32 = value.parse().map_err(|_| bad(key, value))?;
                if !(0.0..=1.0).contains(&c) {
                    return Err(bad(key, value));
                }
                self.serving.cascade.min_confidence = c;
            }
            "cascade.platt_a" => {
                self.serving.cascade.platt_a = value.parse().map_err(|_| bad(key, value))?
            }
            "cascade.platt_b" => {
                self.serving.cascade.platt_b = value.parse().map_err(|_| bad(key, value))?
            }
            "resilience.retry_max_attempts" => {
                let n: u32 = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.resilience.retry_max_attempts = n;
            }
            "resilience.retry_backoff_ms" => {
                self.serving.resilience.retry_backoff_ms =
                    value.parse().map_err(|_| bad(key, value))?
            }
            // 0 disables hedging (flat-file configs have no `None`)
            "resilience.hedge_after_ms" => {
                let ms: u64 = value.parse().map_err(|_| bad(key, value))?;
                self.serving.resilience.hedge_after_ms = (ms > 0).then_some(ms);
            }
            "resilience.supervisor_window" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.resilience.supervisor_window = n;
            }
            "resilience.degrade_failures" => {
                self.serving.resilience.degrade_failures =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "resilience.quarantine_failures" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.resilience.quarantine_failures = n;
            }
            "resilience.quarantine_cooldown_ms" => {
                self.serving.resilience.quarantine_cooldown_ms =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "resilience.probe_successes" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.resilience.probe_successes = n;
            }
            "resilience.brownout" => {
                self.serving.resilience.brownout = value.parse().map_err(|_| bad(key, value))?
            }
            "resilience.brownout_queue_depth" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.resilience.brownout_queue_depth = n;
            }
            "resilience.brownout_miss_rate" => {
                let r: f64 = value.parse().map_err(|_| bad(key, value))?;
                if !(r > 0.0 && r <= 1.0) {
                    return Err(bad(key, value));
                }
                self.serving.resilience.brownout_miss_rate = r;
            }
            "resilience.brownout_top_k" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.resilience.brownout_top_k = n;
            }
            "resilience.brownout_scale_stride" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.resilience.brownout_scale_stride = n;
            }
            "resilience.chaos_seed" => {
                self.serving.resilience.chaos_seed =
                    Some(value.parse().map_err(|_| bad(key, value))?)
            }
            "resilience.chaos_panic_p"
            | "resilience.chaos_transient_p"
            | "resilience.chaos_latency_p"
            | "resilience.chaos_corrupt_p"
            | "resilience.chaos_hang_p" => {
                let p: f64 = value.parse().map_err(|_| bad(key, value))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(bad(key, value));
                }
                match key {
                    "resilience.chaos_panic_p" => self.serving.resilience.chaos_panic_p = p,
                    "resilience.chaos_transient_p" => {
                        self.serving.resilience.chaos_transient_p = p
                    }
                    "resilience.chaos_latency_p" => self.serving.resilience.chaos_latency_p = p,
                    "resilience.chaos_corrupt_p" => self.serving.resilience.chaos_corrupt_p = p,
                    _ => self.serving.resilience.chaos_hang_p = p,
                }
            }
            "resilience.chaos_latency_ms" => {
                self.serving.resilience.chaos_latency_ms =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "resilience.chaos_hang_ms" => {
                self.serving.resilience.chaos_hang_ms =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "integrity.validate" => {
                self.serving.integrity.validate = value.parse().map_err(|_| bad(key, value))?
            }
            // 0 disables auditing (flat-file configs have no `None`)
            "integrity.audit_rate" => {
                self.serving.integrity.audit_rate =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "integrity.demote_on_mismatch" => {
                self.serving.integrity.demote_on_mismatch =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "temporal.tile" => {
                let n: usize = value.parse().map_err(|_| bad(key, value))?;
                if n == 0 {
                    return Err(bad(key, value));
                }
                self.serving.temporal.tile = n;
            }
            "temporal.pixel_threshold" => {
                self.serving.temporal.pixel_threshold =
                    value.parse().map_err(|_| bad(key, value))?
            }
            "sizes" => {
                self.sizes = parse::parse_sizes(value).ok_or_else(|| bad(key, value))?
            }
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "scoring.kernel" => {
                self.kernel = value.parse().map_err(|_| bad(key, value))?
            }
            "pool.pin" => self.pool_pin = value.parse().map_err(|_| bad(key, value))?,
            _ => return Err(ConfigError::UnknownKey(key.to_string())),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_python_pyramid() {
        let sizes = default_sizes();
        assert_eq!(sizes.len(), 16);
        assert_eq!(sizes[0], (16, 16));
        assert_eq!(sizes[15], (128, 128));
    }

    #[test]
    fn apply_overrides() {
        let mut cfg = Config::new();
        cfg.apply_text(
            "accel.pipelines = 8\naccel.device = artix7\nserving.top_k = 500\nsizes = 16x16,32x64\n",
        )
        .unwrap();
        assert_eq!(cfg.accel.pipelines, 8);
        assert_eq!(cfg.accel.device, Device::Artix7LowVolt);
        assert_eq!(cfg.serving.top_k, 500);
        assert_eq!(cfg.sizes, vec![(16, 16), (32, 64)]);
    }

    #[test]
    fn kernel_and_pool_keys_apply() {
        use crate::simd::{KernelChoice, ScoreKernel};
        let mut cfg = Config::new();
        assert_eq!(cfg.kernel, KernelChoice::Auto);
        assert!(cfg.pool_pin, "pinning defaults on");
        cfg.apply_text("scoring.kernel = swar\npool.pin = false\n").unwrap();
        assert_eq!(cfg.kernel, KernelChoice::Fixed(ScoreKernel::Swar));
        assert!(!cfg.pool_pin);
        assert!(cfg.apply("scoring.kernel", "sse9").is_err());
        assert!(cfg.apply("pool.pin", "maybe").is_err());
    }

    #[test]
    fn serving_runtime_overrides_parse() {
        let mut cfg = Config::new();
        cfg.apply_text(
            "serving.shards = 4\nserving.policy = affinity\nserving.deadline_ms = 250\n",
        )
        .unwrap();
        assert_eq!(cfg.serving.shards, 4);
        assert_eq!(cfg.serving.policy, RoutePolicyKind::ScaleAffinity);
        assert_eq!(cfg.serving.deadline_ms, Some(250));
        cfg.apply("serving.deadline_ms", "0").unwrap();
        assert_eq!(cfg.serving.deadline_ms, None, "0 must disable the deadline");
        assert!(cfg.apply("serving.policy", "random").is_err());
    }

    #[test]
    fn cascade_overrides_parse_and_validate() {
        let mut cfg = Config::new();
        cfg.apply_text("cascade.nms_thresh = 0.4\ncascade.top_k = 25\n")
            .unwrap();
        cfg.apply_text(
            "cascade.min_confidence = 0.1\ncascade.platt_a = 0.002\ncascade.platt_b = -1.5\n",
        )
        .unwrap();
        assert_eq!(cfg.serving.cascade.nms_thresh, 0.4);
        assert_eq!(cfg.serving.cascade.top_k, 25);
        assert_eq!(cfg.serving.cascade.min_confidence, 0.1);
        assert_eq!(cfg.serving.cascade.platt_a, 0.002);
        assert_eq!(cfg.serving.cascade.platt_b, -1.5);
        // thresholds are ratios — out-of-range values must fail loudly
        assert!(cfg.apply("cascade.nms_thresh", "1.5").is_err());
        assert!(cfg.apply("cascade.min_confidence", "-0.2").is_err());
    }

    #[test]
    fn resilience_defaults_are_neutral() {
        let r = ResilienceConfig::default();
        assert_eq!(r.retry_max_attempts, 1, "no retries unless asked");
        assert_eq!(r.hedge_after_ms, None, "no hedging unless asked");
        assert!(!r.brownout, "no load shedding unless asked");
        assert_eq!(r.chaos_seed, None, "no fault injection unless asked");
    }

    #[test]
    fn resilience_overrides_parse_and_validate() {
        let mut cfg = Config::new();
        cfg.apply_text(
            "resilience.retry_max_attempts = 3\nresilience.retry_backoff_ms = 5\n\
             resilience.hedge_after_ms = 40\nresilience.supervisor_window = 32\n\
             resilience.degrade_failures = 6\nresilience.quarantine_failures = 12\n\
             resilience.quarantine_cooldown_ms = 100\nresilience.probe_successes = 2\n\
             resilience.brownout = true\nresilience.brownout_queue_depth = 16\n\
             resilience.brownout_miss_rate = 0.1\nresilience.brownout_top_k = 50\n\
             resilience.brownout_scale_stride = 4\nresilience.chaos_seed = 42\n\
             resilience.chaos_panic_p = 0.01\nresilience.chaos_transient_p = 0.2\n\
             resilience.chaos_latency_p = 0.3\nresilience.chaos_latency_ms = 7\n\
             resilience.chaos_corrupt_p = 0.15\nresilience.chaos_hang_p = 0.05\n\
             resilience.chaos_hang_ms = 2000\n",
        )
        .unwrap();
        let r = &cfg.serving.resilience;
        assert_eq!(r.retry_max_attempts, 3);
        assert_eq!(r.retry_backoff_ms, 5);
        assert_eq!(r.hedge_after_ms, Some(40));
        assert_eq!(r.supervisor_window, 32);
        assert_eq!(r.degrade_failures, 6);
        assert_eq!(r.quarantine_failures, 12);
        assert_eq!(r.quarantine_cooldown_ms, 100);
        assert_eq!(r.probe_successes, 2);
        assert!(r.brownout);
        assert_eq!(r.brownout_queue_depth, 16);
        assert_eq!(r.brownout_miss_rate, 0.1);
        assert_eq!(r.brownout_top_k, 50);
        assert_eq!(r.brownout_scale_stride, 4);
        assert_eq!(r.chaos_seed, Some(42));
        assert_eq!(r.chaos_panic_p, 0.01);
        assert_eq!(r.chaos_transient_p, 0.2);
        assert_eq!(r.chaos_latency_p, 0.3);
        assert_eq!(r.chaos_latency_ms, 7);
        assert_eq!(r.chaos_corrupt_p, 0.15);
        assert_eq!(r.chaos_hang_p, 0.05);
        assert_eq!(r.chaos_hang_ms, 2000);
        cfg.apply("resilience.hedge_after_ms", "0").unwrap();
        assert_eq!(cfg.serving.resilience.hedge_after_ms, None, "0 disables hedging");
        // degenerate values fail loudly, they don't clamp
        assert!(cfg.apply("resilience.retry_max_attempts", "0").is_err());
        assert!(cfg.apply("resilience.supervisor_window", "0").is_err());
        assert!(cfg.apply("resilience.quarantine_failures", "0").is_err());
        assert!(cfg.apply("resilience.probe_successes", "0").is_err());
        assert!(cfg.apply("resilience.brownout_scale_stride", "0").is_err());
        assert!(cfg.apply("resilience.brownout_miss_rate", "0.0").is_err());
        assert!(cfg.apply("resilience.brownout_miss_rate", "1.5").is_err());
        assert!(cfg.apply("resilience.chaos_panic_p", "1.1").is_err());
        assert!(cfg.apply("resilience.chaos_transient_p", "-0.1").is_err());
        assert!(cfg.apply("resilience.chaos_corrupt_p", "1.5").is_err());
        assert!(cfg.apply("resilience.chaos_hang_p", "-0.5").is_err());
    }

    #[test]
    fn integrity_overrides_parse_and_validate() {
        let cfg = Config::new();
        let i = &cfg.serving.integrity;
        assert!(i.validate, "structural validation defaults on (it is nearly free)");
        assert_eq!(i.audit_rate, 0, "audits cost a re-execution: opt-in");
        assert!(i.demote_on_mismatch, "a SIMD mismatch should demote by default");
        let mut cfg = Config::new();
        cfg.apply_text(
            "integrity.validate = false\nintegrity.audit_rate = 8\n\
             integrity.demote_on_mismatch = false\n",
        )
        .unwrap();
        let i = &cfg.serving.integrity;
        assert!(!i.validate);
        assert_eq!(i.audit_rate, 8);
        assert!(!i.demote_on_mismatch);
        assert!(cfg.apply("integrity.audit_rate", "sometimes").is_err());
        assert!(cfg.apply("integrity.validate", "2").is_err());
    }

    #[test]
    fn policy_kind_round_trips_names() {
        for kind in [
            RoutePolicyKind::RoundRobin,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::ScaleAffinity,
            RoutePolicyKind::SessionAffinity,
        ] {
            assert_eq!(kind.name().parse::<RoutePolicyKind>().unwrap(), kind);
        }
        assert_eq!(
            "least-loaded".parse::<RoutePolicyKind>().unwrap(),
            RoutePolicyKind::LeastLoaded
        );
        assert_eq!(
            "session-affinity".parse::<RoutePolicyKind>().unwrap(),
            RoutePolicyKind::SessionAffinity
        );
    }

    #[test]
    fn temporal_overrides_parse_and_validate() {
        let cfg = Config::new();
        assert_eq!(cfg.serving.temporal, TemporalConfig::default());
        assert_eq!(cfg.serving.temporal.tile, 16);
        assert_eq!(cfg.serving.temporal.pixel_threshold, 0, "exact-input default");
        let mut cfg = Config::new();
        cfg.apply_text("temporal.tile = 8\ntemporal.pixel_threshold = 3\n").unwrap();
        assert_eq!(cfg.serving.temporal.tile, 8);
        assert_eq!(cfg.serving.temporal.pixel_threshold, 3);
        assert!(cfg.apply("temporal.tile", "0").is_err(), "zero tile is degenerate");
        assert!(cfg.apply("temporal.pixel_threshold", "300").is_err(), "u8 range");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = Config::new();
        assert!(matches!(
            cfg.apply("no.such.key", "1"),
            Err(ConfigError::UnknownKey(_))
        ));
    }

    #[test]
    fn bad_value_rejected() {
        let mut cfg = Config::new();
        assert!(cfg.apply("accel.pipelines", "many").is_err());
        assert!(cfg.apply("accel.device", "virtex").is_err());
    }

    #[test]
    fn device_clocks_match_paper() {
        assert_eq!(Device::Artix7LowVolt.clock_hz(), 3.3e6);
        assert_eq!(Device::KintexUltraScalePlus.clock_hz(), 100.0e6);
    }
}
