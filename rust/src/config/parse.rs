//! Flat `key = value` parser (strict subset of TOML) used by [`super::Config`].

use std::fmt;

/// Errors from config parsing / application.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigError {
    Io(String, String),
    /// Line failed to parse as `key = value`.
    Syntax(usize, String),
    UnknownKey(String),
    BadValue(String, String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::Io(path, e) => write!(f, "config {path}: {e}"),
            ConfigError::Syntax(line, text) => {
                write!(f, "config line {line}: expected `key = value`, got `{text}`")
            }
            ConfigError::UnknownKey(k) => write!(f, "unknown config key `{k}`"),
            ConfigError::BadValue(k, v) => write!(f, "bad value for `{k}`: `{v}`"),
        }
    }
}

impl std::error::Error for ConfigError {}

/// Parse `key = value` lines; `#` starts a comment; blank lines skipped.
/// Values may be quoted with `"` (quotes stripped).
pub fn parse_kv(text: &str) -> Result<Vec<(String, String)>, ConfigError> {
    let mut out = Vec::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            Some(pos) => &raw[..pos],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            return Err(ConfigError::Syntax(idx + 1, raw.to_string()));
        };
        let key = key.trim().to_string();
        let mut value = value.trim();
        if value.len() >= 2 && value.starts_with('"') && value.ends_with('"') {
            value = &value[1..value.len() - 1];
        }
        if key.is_empty() {
            return Err(ConfigError::Syntax(idx + 1, raw.to_string()));
        }
        out.push((key, value.to_string()));
    }
    Ok(out)
}

/// Parse `16x16,32x64` into a size list.
pub fn parse_sizes(value: &str) -> Option<Vec<(usize, usize)>> {
    let mut sizes = Vec::new();
    for tok in value.split(',') {
        let (h, w) = tok.trim().split_once(['x', 'X'])?;
        sizes.push((h.trim().parse().ok()?, w.trim().parse().ok()?));
    }
    if sizes.is_empty() {
        None
    } else {
        Some(sizes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_comments_blanks_quotes() {
        let kv = parse_kv("# top\n\n a = 1 # trailing\nb = \"x y\"\n").unwrap();
        assert_eq!(
            kv,
            vec![
                ("a".to_string(), "1".to_string()),
                ("b".to_string(), "x y".to_string())
            ]
        );
    }

    #[test]
    fn rejects_missing_equals() {
        let err = parse_kv("just words\n").unwrap_err();
        assert!(matches!(err, ConfigError::Syntax(1, _)));
    }

    #[test]
    fn sizes_roundtrip() {
        assert_eq!(parse_sizes("16x16, 32X64"), Some(vec![(16, 16), (32, 64)]));
        assert_eq!(parse_sizes(""), None);
        assert_eq!(parse_sizes("16"), None);
    }
}
