//! # bingflow
//!
//! A reproduction of *"A Scalable Pipelined Dataflow Accelerator for Object
//! Region Proposals on FPGA Platform"* (Fu, Yang, Dai, Chen, Zhao — cs.DC
//! 2018) as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the serving stack: a sharded [`serving`] runtime
//!   (request router over replicated backend shards, pluggable
//!   `RoutePolicy`, deadline-aware admission, cooperative cancellation,
//!   graceful per-shard drain) whose per-shard executor is the
//!   [`coordinator`] (dynamic batcher, per-scale scheduler, SVM stage-II +
//!   top-k assembly, generic over the pluggable [`backend`] seam — the
//!   software pipeline, the engine executables and the cycle simulator are
//!   interchangeable `ProposalBackend`s) — and, one trait level above, the
//!   end-to-end detection cascade ([`detect`]: proposals → stage-II SVM →
//!   greedy NMS → Platt confidence, served through the same runtime as
//!   `DetectRequest`/`DetectResponse`; `use bingflow::prelude::*` pulls in
//!   the whole serving surface) — plus every substrate the paper
//!   depends on — a cycle-level FPGA dataflow simulator built as a
//!   streaming stage graph ([`dataflow`], driven by
//!   [`dataflow::stage::PipelineDriver`]), the software BING baseline
//!   ([`baseline`]), the bubble-pushing heap sorter ([`sort`]), a linear
//!   SVM trainer ([`svm`]), quality metrics ([`metrics`]) and a synthetic
//!   VOC-like dataset ([`data`]).
//! * **L2/L1 (python/, build time only)** — per-scale JAX graphs built from
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/`, loaded and
//!   executed from the request path through [`runtime`] (PJRT via the `xla`
//!   crate). Python never runs at serve time.
//!
//! Numerical contract: the HLO path, the software baseline's quantized path
//! and the dataflow simulator all implement the *same* integer semantics
//! (see `python/compile/common.py` and [`bing`]), so their outputs are
//! bit-identical — the "sim/SW parity" invariant that makes the simulator's
//! cycle counts credible.
//!
//! ## Build, test, bench
//!
//! The default build is fully offline — only `anyhow` and std — with
//! [`runtime::MockEngine`] as the [`runtime::ScaleExecutor`] backend
//! (bit-identical to the HLO path by the parity contract):
//!
//! ```bash
//! cargo build --release && cargo test -q   # tier-1 verify, from the repo root
//! cargo bench --bench hotpath              # + 6 more paper-table benches
//! cargo run --release --example quickstart # examples/*.rs, mock engine
//! ```
//!
//! The PJRT production path (`PjrtEngine`, the `xla` crate) is gated behind
//! the non-default `pjrt` cargo feature. As shipped it compiles against the
//! vendored API stub in `rust/xla-stub/` (every runtime entry point errors,
//! and callers fall back to the mock engine) — that keeps the path
//! compile-checked offline:
//!
//! ```bash
//! cargo check --features pjrt              # compile-only gate (CI keeps it alive)
//! ```
//!
//! To *execute* real HLO, first point the `xla` dependency in
//! `rust/Cargo.toml` at the actual xla-rs crate (no source changes needed),
//! then:
//!
//! ```bash
//! make artifacts                           # lower the HLOs (needs JAX)
//! cargo run --release --features pjrt -- serve --engine pjrt
//! ```
//!
//! CI (`.github/workflows/ci.yml`) enforces fmt, clippy (`-D warnings`),
//! build, tests, the `pjrt` compile check, and the Python parity suite.

pub mod backend;
pub mod baseline;
pub mod bing;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dataflow;
pub mod detect;
pub mod fault;
pub mod image;
pub mod integrity;
pub mod metrics;
pub mod nms;
pub mod prelude;
pub mod quant;
pub mod runtime;
pub mod serving;
pub mod simd;
pub mod sort;
pub mod svm;
pub mod telemetry;
pub mod temporal;
pub mod util;

pub use bing::{Candidate, Proposal};
pub use config::Config;
pub use detect::Detection;
