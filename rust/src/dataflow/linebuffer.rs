//! Line buffer + memory window — the "tiered cache" of the kernel-computing
//! module (paper §3.3, built after Xilinx XAPP793).
//!
//! A line buffer holds the last `rows` image rows in BRAM; the memory window
//! is the small register file (rows × taps) sliding over it. The model
//! tracks fill state (a consumer stage can only fire once its vertical
//! neighborhood is resident) and charges BRAM bits + FF bits to the resource
//! model.

/// Cycle/resource model of one line buffer with its memory window.
#[derive(Debug, Clone)]
pub struct LineBuffer {
    /// buffered rows (window height), e.g. 3 for CalcGrad, 8 for SVM-I
    pub rows: usize,
    /// row length in elements
    pub width: usize,
    /// element width in bits (8 for pixels/gradients, 19 for scores)
    pub elem_bits: u32,
    /// window taps per row (8 for SVM, 3 for CalcGrad, 5 for NMS)
    pub taps: usize,

    /// elements written so far (fill state)
    written: u64,
    /// lifetime writes (activity for the power model)
    pub writes: u64,
}

impl LineBuffer {
    pub fn new(rows: usize, width: usize, elem_bits: u32, taps: usize) -> Self {
        assert!(rows > 0 && width > 0 && taps > 0);
        Self { rows, width, elem_bits, taps, written: 0, writes: 0 }
    }

    /// BRAM bits the buffer occupies.
    pub fn bram_bits(&self) -> u64 {
        self.rows as u64 * self.width as u64 * self.elem_bits as u64
    }

    /// Register (FF) bits of the sliding memory window.
    pub fn window_ff_bits(&self) -> u64 {
        self.rows as u64 * self.taps as u64 * self.elem_bits as u64
    }

    /// Accept one incoming element (column-of-batch write).
    pub fn write(&mut self, n: usize) {
        self.written += n as u64;
        self.writes += n as u64;
    }

    /// Can the consumer produce output for column `col` of output row
    /// `out_row`? True once all `rows` vertical neighbours of that column
    /// are resident, i.e. the producer has advanced `rows-1` full rows plus
    /// `col+taps` elements past the output origin.
    pub fn window_ready(&self, out_row: usize, col: usize) -> bool {
        let needed = (out_row + self.rows - 1) as u64 * self.width as u64
            + (col + self.taps) as u64;
        self.written >= needed
    }

    /// Warm-up latency in elements before the first window is ready.
    pub fn warmup_elems(&self) -> u64 {
        (self.rows as u64 - 1) * self.width as u64 + self.taps as u64
    }

    /// Reset fill state for the next image/scale (buffers are reused).
    pub fn reset(&mut self) {
        self.written = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_not_ready_until_warmup() {
        let mut lb = LineBuffer::new(3, 10, 8, 3);
        assert!(!lb.window_ready(0, 0));
        lb.write(22); // need (3-1)*10 + 3 = 23
        assert!(!lb.window_ready(0, 0));
        lb.write(1);
        assert!(lb.window_ready(0, 0));
        assert_eq!(lb.warmup_elems(), 23);
    }

    #[test]
    fn deeper_columns_need_more_fill() {
        let mut lb = LineBuffer::new(8, 16, 8, 8);
        lb.write(((8 - 1) * 16 + 8) as usize);
        assert!(lb.window_ready(0, 0));
        assert!(!lb.window_ready(0, 1));
        assert!(!lb.window_ready(1, 0));
    }

    #[test]
    fn resource_accounting() {
        let lb = LineBuffer::new(8, 320, 8, 8);
        assert_eq!(lb.bram_bits(), 8 * 320 * 8);
        assert_eq!(lb.window_ff_bits(), 8 * 8 * 8);
    }

    #[test]
    fn reset_clears_fill_not_activity() {
        let mut lb = LineBuffer::new(3, 4, 8, 3);
        lb.write(12);
        lb.reset();
        assert!(!lb.window_ready(0, 0));
        assert_eq!(lb.writes, 12);
    }
}
