//! BRAM bank model: port-limited on-chip memory with access accounting.
//!
//! The paper partitions each image into four blocks, each served by one BRAM
//! port ("only one port of the configured BRAMs is assigned for each block
//! while two dual-port or four single-port BRAM are required for processing
//! each image"). This model enforces the port limit per cycle and counts
//! accesses for the activity-based power model.

/// Xilinx 7-series/US+ BRAM tile: 18 Kbit.
pub const BRAM18_BITS: u64 = 18 * 1024;

/// A banked on-chip memory with a fixed number of ports.
#[derive(Debug, Clone)]
pub struct BramBank {
    /// total capacity in bits
    pub bits: u64,
    /// simultaneous accesses per cycle
    pub ports: u32,
    /// accesses granted in the current cycle (reset by `next_cycle`)
    in_flight: u32,
    /// lifetime access count (power model: toggling activity)
    pub accesses: u64,
    /// cycles in which at least one access was denied for port conflicts
    pub conflict_cycles: u64,
    conflicted_this_cycle: bool,
}

impl BramBank {
    pub fn new(bits: u64, ports: u32) -> Self {
        assert!(ports > 0);
        Self {
            bits,
            ports,
            in_flight: 0,
            accesses: 0,
            conflict_cycles: 0,
            conflicted_this_cycle: false,
        }
    }

    /// Number of physical BRAM18 tiles this bank occupies (resource model).
    pub fn tiles(&self) -> u32 {
        self.bits.div_ceil(BRAM18_BITS) as u32
    }

    /// Request one access this cycle; false = port conflict, retry next cycle.
    pub fn access(&mut self) -> bool {
        if self.in_flight >= self.ports {
            if !self.conflicted_this_cycle {
                self.conflict_cycles += 1;
                self.conflicted_this_cycle = true;
            }
            return false;
        }
        self.in_flight += 1;
        self.accesses += 1;
        true
    }

    /// Advance to the next clock cycle (ports free up).
    pub fn next_cycle(&mut self) {
        self.in_flight = 0;
        self.conflicted_this_cycle = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_limit_enforced_per_cycle() {
        let mut b = BramBank::new(BRAM18_BITS, 2);
        assert!(b.access());
        assert!(b.access());
        assert!(!b.access());
        assert_eq!(b.conflict_cycles, 1);
        b.next_cycle();
        assert!(b.access());
        assert_eq!(b.accesses, 3);
    }

    #[test]
    fn conflict_cycles_counted_once_per_cycle() {
        let mut b = BramBank::new(BRAM18_BITS, 1);
        assert!(b.access());
        assert!(!b.access());
        assert!(!b.access());
        assert_eq!(b.conflict_cycles, 1);
    }

    #[test]
    fn tile_count_rounds_up() {
        assert_eq!(BramBank::new(1, 1).tiles(), 1);
        assert_eq!(BramBank::new(BRAM18_BITS, 1).tiles(), 1);
        assert_eq!(BramBank::new(BRAM18_BITS + 1, 1).tiles(), 2);
        // a 320-pixel RGB row stripe of 4 rows: 320*3*8*4 bits = 30720 → 2 tiles
        assert_eq!(BramBank::new(320 * 3 * 8 * 4, 2).tiles(), 2);
    }
}
