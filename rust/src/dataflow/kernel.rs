//! Kernel-computing module model (paper §3.3, Fig. 4): CalcGrad → SVM-I →
//! NMS as serially-connected streaming workspaces, each with its tiered
//! cache (line buffer + memory window), replicated across `pipelines`.
//!
//! The *values* flowing through are taken from the functional twins in
//! [`crate::bing`] (bit-exact parity by construction); this module models
//! *when* each token exists: line-buffer warm-ups, initiation intervals,
//! pipeline occupancy and the bursty NMS output.

use std::any::Any;

use super::linebuffer::LineBuffer;
use super::stage::{Port, PortIo, Stage, StageStatus, Token};
use crate::bing::WIN;
use crate::config::NMS_BLOCK;

/// Progress counters translating "pixels processed" into downstream token
/// counts for one scale `(h, w)`.
#[derive(Debug)]
pub struct KernelModule {
    /// resized-image geometry
    pub h: usize,
    pub w: usize,
    /// parallel pipelines (paper: 4)
    pub pipelines: usize,
    /// per-pipeline initiation interval in cycles per 4-pixel batch
    pub batch_ii: u64,

    /// tiered caches (one set per pipeline; identical, so modeled once and
    /// multiplied in the resource model)
    pub grad_lb: LineBuffer,
    pub svm_lb: LineBuffer,
    pub nms_lb: LineBuffer,

    /// per-pipeline busy countdown (cycles until the pipeline frees)
    busy: Vec<u64>,
    /// input pixels accepted into the pipelines
    pub px_in: u64,
    /// completed input pixels (through CalcGrad)
    pub px_done: u64,
    /// cycles with ≥1 busy pipeline
    pub busy_cycles: u64,
    /// cycles all pipelines idle while input was expected (starvation)
    pub starve_cycles: u64,
}

impl KernelModule {
    pub fn new(h: usize, w: usize, pipelines: usize) -> Self {
        let ow = w - WIN + 1;
        Self {
            h,
            w,
            pipelines,
            batch_ii: 4, // 4 vertical pixels per batch, 1 px/cycle/pipeline
            grad_lb: LineBuffer::new(3, w, 8, 3),
            svm_lb: LineBuffer::new(WIN, w, 8, WIN),
            nms_lb: LineBuffer::new(NMS_BLOCK, ow, 19, NMS_BLOCK),
            busy: vec![0; pipelines],
            px_in: 0,
            px_done: 0,
            busy_cycles: 0,
            starve_cycles: 0,
        }
    }

    /// Total input pixels for this scale.
    pub fn total_px(&self) -> u64 {
        (self.h * self.w) as u64
    }

    /// Does a pipeline have a free slot for a new batch this cycle?
    pub fn free_pipeline(&self) -> bool {
        self.px_in < self.total_px() && self.busy.iter().any(|&b| b == 0)
    }

    /// Hand one batch (4 vertical pixels) to a free pipeline. Call only when
    /// [`Self::free_pipeline`] is true.
    pub fn assign_batch(&mut self) {
        let slot = self
            .busy
            .iter_mut()
            .find(|b| **b == 0)
            .expect("assign_batch without a free pipeline");
        *slot = self.batch_ii;
        self.px_in += 4.min(self.total_px() - self.px_in);
    }

    /// End-of-cycle bookkeeping: advance every busy pipeline one clock and
    /// retire batches whose initiation interval elapsed.
    pub fn advance_cycle(&mut self) {
        let total = self.total_px();
        let mut any_busy = false;
        let mut retired_px = 0u64;
        for b in &mut self.busy {
            if *b > 0 {
                any_busy = true;
                *b -= 1;
                if *b == 0 {
                    retired_px += 4;
                }
            }
        }
        if retired_px > 0 {
            let px = retired_px.min(total - self.px_done);
            self.px_done += px;
            self.grad_lb.write(px as usize);
            self.svm_lb.write(px as usize);
        }
        if any_busy {
            self.busy_cycles += 1;
        } else if self.px_in < self.total_px() {
            self.starve_cycles += 1;
        }
    }

    /// Gradient pixels produced so far: CalcGrad needs the row below, so its
    /// output trails the input by one batch-row group (4 rows) plus the
    /// 3-tap horizontal window.
    pub fn grad_count(&self) -> u64 {
        self.px_done
            .saturating_sub(4 * self.w as u64 + 2)
            .min((self.h * self.w) as u64)
    }

    /// SVM-I scores produced so far, in score-map raster order: score
    /// `(sy, sx)` exists once gradient pixel `(sy+7, sx+7)` exists.
    pub fn score_count(&self) -> u64 {
        let g = self.grad_count();
        let w = self.w as u64;
        let ow = w - WIN as u64 + 1;
        let oh = self.h as u64 - WIN as u64 + 1;
        if g == 0 {
            return 0;
        }
        // last gradient pixel index g-1 → (gy, gx)
        let gy = (g - 1) / w;
        let gx = (g - 1) % w;
        if gy < WIN as u64 - 1 {
            return 0;
        }
        let sy = gy - (WIN as u64 - 1); // rows before sy are fully enabled
        let full_rows = sy.min(oh);
        let partial = if sy < oh {
            // within row `sy`: scores with sx+7 <= gx
            (gx + 1).saturating_sub(WIN as u64 - 1).min(ow)
        } else {
            0
        };
        (full_rows * ow + partial).min(oh * ow)
    }

    /// Completion: the whole image has drained through CalcGrad.
    pub fn drained(&self) -> bool {
        self.px_done >= self.total_px()
    }

    /// When drained, downstream counters see everything.
    pub fn final_score_count(&self) -> u64 {
        let ow = (self.w - WIN + 1) as u64;
        let oh = (self.h - WIN + 1) as u64;
        oh * ow
    }

    /// Effective score count used by the NMS stage (flushes on drain).
    pub fn scores_visible(&self) -> u64 {
        if self.drained() {
            self.final_score_count()
        } else {
            self.score_count()
        }
    }

    /// Width-register swap latency at a scale boundary: the deepest tiered
    /// cache re-points one row per cycle while the old stream drains.
    pub fn swap_cycles(&self) -> u64 {
        self.grad_lb
            .rows
            .max(self.svm_lb.rows)
            .max(self.nms_lb.rows) as u64
    }

    /// Full flush: invalidate and re-point every line-buffer row of every
    /// tier (two clocks per row: clear valid bit, load new geometry).
    pub fn flush_cycles(&self) -> u64 {
        2 * (self.grad_lb.rows + self.svm_lb.rows + self.nms_lb.rows) as u64
    }
}

/// Precompute, for each NMS winner (in block raster order), the score-count
/// threshold after which its 5×5 block is complete and the winner is emitted
/// into the output FIFO. Shared by the accelerator's cycle loop.
pub fn winner_emit_thresholds(oh: usize, ow: usize) -> Vec<u64> {
    let mut out = Vec::new();
    let mut by = 0;
    while by < oh {
        let last_y = (by + NMS_BLOCK - 1).min(oh - 1);
        let mut bx = 0;
        while bx < ow {
            let last_x = (bx + NMS_BLOCK - 1).min(ow - 1);
            out.push((last_y * ow + last_x) as u64 + 1);
            bx += NMS_BLOCK;
        }
        by += NMS_BLOCK;
    }
    out
}

/// The kernel-computing module as a pipeline [`Stage`]: pulls batches from
/// the upstream cache port, advances the CalcGrad→SVM-I pipelines, and
/// emits NMS winners (by index, in block raster order) into the downstream
/// FIFO port as their 5×5 blocks complete. Backpressure from a full FIFO
/// stalls the whole stage — no new batch is issued that cycle — exactly the
/// fidelity rule the old hand-rolled loop implemented.
#[derive(Debug)]
pub struct KernelStage {
    pub kernel: KernelModule,
    /// score-count threshold after which winner `i` is emitted
    thresholds: Vec<u64>,
    /// winners pushed into the output FIFO so far
    pub emitted: usize,
    /// cycles the NMS output was blocked by FIFO backpressure
    pub backpressure_stalls: u64,
}

impl KernelStage {
    pub fn new(kernel: KernelModule) -> Self {
        let thresholds = winner_emit_thresholds(kernel.h - WIN + 1, kernel.w - WIN + 1);
        Self { kernel, thresholds, emitted: 0, backpressure_stalls: 0 }
    }

    /// NMS winners this scale will emit (one per 5×5 score block).
    pub fn expected_winners(&self) -> usize {
        self.thresholds.len()
    }
}

impl Stage for KernelStage {
    fn name(&self) -> &'static str {
        "kernel"
    }

    fn step(&mut self, _cycle: u64, io: &mut PortIo<'_>) -> StageStatus {
        let down = io
            .downstream
            .as_deref_mut()
            .expect("kernel stage needs a downstream port");
        // NMS→FIFO backpressure (a fidelity rule, not an optimization):
        // when a completed winner cannot enter the full FIFO, the NMS stage
        // stalls and the stall propagates up the kernel pipelines — no new
        // batch is issued this cycle.
        let visible = self.kernel.scores_visible();
        let pending =
            self.emitted < self.thresholds.len() && self.thresholds[self.emitted] <= visible;
        let blocked = pending && !down.can_push();
        if blocked {
            self.backpressure_stalls += 1;
        }
        // the cache streams one batch per cycle into whichever pipeline is
        // free (paper: the continuous stream keeps the pipelines loaded).
        // The pull is unconditional when a pipeline is free: a failed pull
        // is a real stream discontinuity, and the upstream channel records
        // it (the ping-pong cache's starve counter — previously dead,
        // because the old loop pre-checked readiness and never let the
        // cache see the request it could not serve).
        if !blocked && self.kernel.free_pipeline() {
            if let Some(up) = io.upstream.as_deref_mut() {
                if up.pull().is_some() {
                    self.kernel.assign_batch();
                }
            }
        }
        let starves_before = self.kernel.starve_cycles;
        self.kernel.advance_cycle();
        let starved = self.kernel.starve_cycles > starves_before;
        // NMS: emit winners whose 5×5 block completed this cycle
        let visible = self.kernel.scores_visible();
        while self.emitted < self.thresholds.len() && self.thresholds[self.emitted] <= visible {
            if down.push(self.emitted as Token) {
                self.emitted += 1;
            } else {
                break; // FIFO filled mid-burst: stall counted next cycle
            }
        }
        if blocked {
            StageStatus::Stalled
        } else if self.emitted == self.thresholds.len() {
            StageStatus::Done
        } else if starved {
            StageStatus::Starved
        } else {
            StageStatus::Active
        }
    }

    /// All winners emitted: leftover upstream batches (possible when the
    /// fetch granularity differs from the pipeline batch size) are
    /// abandoned, matching the old loop's termination rule.
    fn done(&self, _up: Option<&dyn Port>) -> bool {
        self.emitted == self.thresholds.len()
    }

    /// Winner emission counts its own completion — once every NMS block
    /// has emitted, nothing upstream can revoke it, so a still-fetching
    /// resizer (fetch granularity below the 4-px pipeline batch) is
    /// abandoned instead of deadlocking the driver.
    fn done_terminal(&self) -> bool {
        true
    }

    fn swap_cycles(&self) -> u64 {
        self.kernel.swap_cycles()
    }

    fn flush_cycles(&self) -> u64 {
        self.kernel.flush_cycles()
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the kernel with `batches_per_cycle` available batches.
    fn run_to_drain(h: usize, w: usize, pipelines: usize, feed: usize) -> u64 {
        let mut k = KernelModule::new(h, w, pipelines);
        let mut cycles = 0u64;
        while !k.drained() {
            cycles += 1;
            assert!(cycles < 1_000_000, "kernel never drained");
            let mut fed = 0;
            while fed < feed && k.free_pipeline() {
                k.assign_batch();
                fed += 1;
            }
            k.advance_cycle();
        }
        cycles
    }

    #[test]
    fn pipelines_consume_and_drain() {
        // 16x16 = 256 px = 64 batches; 4 pipes II=4, 1 batch/cycle feed
        let cycles = run_to_drain(16, 16, 4, 1);
        assert!((64..200).contains(&cycles), "implausible cycle count {cycles}");
    }

    #[test]
    fn single_pipeline_is_four_times_slower() {
        let c1 = run_to_drain(32, 32, 1, 1);
        let c4 = run_to_drain(32, 32, 4, 1);
        assert!(c1 > 3 * c4, "scaling broken: 1-pipe {c1} vs 4-pipe {c4}");
    }

    #[test]
    fn score_count_matches_closed_form() {
        let mut k = KernelModule::new(16, 16, 4);
        while !k.drained() {
            if k.free_pipeline() {
                k.assign_batch();
            }
            k.advance_cycle();
        }
        assert_eq!(k.scores_visible(), 9 * 9);
    }

    #[test]
    fn score_count_monotone_during_run() {
        let mut k = KernelModule::new(24, 16, 2);
        let mut last = 0u64;
        while !k.drained() {
            if k.free_pipeline() {
                k.assign_batch();
            }
            k.advance_cycle();
            let s = k.scores_visible();
            assert!(s >= last);
            last = s;
        }
        assert_eq!(last, (24 - 7) as u64 * (16 - 7) as u64);
    }

    #[test]
    fn emit_thresholds_cover_all_blocks_in_order() {
        let th = winner_emit_thresholds(9, 9);
        assert_eq!(th.len(), 4); // 2x2 blocks
        assert_eq!(*th.last().unwrap(), 81);
        assert!(th.iter().all(|&t| t <= 81));
    }

    #[test]
    fn starvation_counted_when_no_batches() {
        let mut k = KernelModule::new(16, 16, 2);
        k.advance_cycle();
        assert_eq!(k.starve_cycles, 1);
    }

    #[test]
    #[should_panic(expected = "without a free pipeline")]
    fn over_assignment_panics() {
        let mut k = KernelModule::new(16, 16, 1);
        k.assign_batch();
        k.assign_batch();
    }

    #[test]
    fn kernel_stage_emits_every_winner_in_block_order() {
        use crate::dataflow::fifo::Fifo;
        let mut stage = KernelStage::new(KernelModule::new(16, 16, 4));
        let n = stage.expected_winners();
        assert_eq!(n, 4); // 9×9 score map → 2×2 NMS blocks
        let mut supply: Fifo<Token> = Fifo::new(256);
        for _ in 0..(16 * 16 / 4) {
            supply.push(1);
        }
        let mut out: Fifo<Token> = Fifo::new(256);
        let mut cycles = 0u64;
        while !Stage::done(&stage, None) {
            cycles += 1;
            assert!(cycles < 100_000, "kernel stage never drained");
            let mut io = PortIo {
                upstream: Some(&mut supply),
                downstream: Some(&mut out),
            };
            Stage::step(&mut stage, cycles, &mut io);
        }
        assert_eq!(stage.emitted, n);
        let mut got = Vec::new();
        while let Some(t) = out.pop() {
            got.push(t);
        }
        assert_eq!(got, (0..n as Token).collect::<Vec<_>>());
    }

    #[test]
    fn full_output_fifo_backpressures_the_stage() {
        use crate::dataflow::fifo::Fifo;
        let mut stage = KernelStage::new(KernelModule::new(16, 16, 4));
        let mut supply: Fifo<Token> = Fifo::new(256);
        for _ in 0..(16 * 16 / 4) {
            supply.push(1);
        }
        let mut out: Fifo<Token> = Fifo::new(1); // nobody pops
        for cycle in 1..=2_000 {
            let mut io = PortIo {
                upstream: Some(&mut supply),
                downstream: Some(&mut out),
            };
            Stage::step(&mut stage, cycle, &mut io);
        }
        assert_eq!(stage.emitted, 1, "only one winner fits the 1-deep FIFO");
        assert!(stage.backpressure_stalls > 0, "stall never counted");
        assert!(!Stage::done(&stage, None));
    }
}
