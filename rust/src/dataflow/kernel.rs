//! Kernel-computing module model (paper §3.3, Fig. 4): CalcGrad → SVM-I →
//! NMS as serially-connected streaming workspaces, each with its tiered
//! cache (line buffer + memory window), replicated across `pipelines`.
//!
//! The *values* flowing through are taken from the functional twins in
//! [`crate::bing`] (bit-exact parity by construction); this module models
//! *when* each token exists: line-buffer warm-ups, initiation intervals,
//! pipeline occupancy and the bursty NMS output.

use super::linebuffer::LineBuffer;
use crate::bing::WIN;
use crate::config::NMS_BLOCK;

/// Progress counters translating "pixels processed" into downstream token
/// counts for one scale `(h, w)`.
#[derive(Debug)]
pub struct KernelModule {
    /// resized-image geometry
    pub h: usize,
    pub w: usize,
    /// parallel pipelines (paper: 4)
    pub pipelines: usize,
    /// per-pipeline initiation interval in cycles per 4-pixel batch
    pub batch_ii: u64,

    /// tiered caches (one set per pipeline; identical, so modeled once and
    /// multiplied in the resource model)
    pub grad_lb: LineBuffer,
    pub svm_lb: LineBuffer,
    pub nms_lb: LineBuffer,

    /// per-pipeline busy countdown (cycles until the pipeline frees)
    busy: Vec<u64>,
    /// input pixels accepted into the pipelines
    pub px_in: u64,
    /// completed input pixels (through CalcGrad)
    pub px_done: u64,
    /// cycles with ≥1 busy pipeline
    pub busy_cycles: u64,
    /// cycles all pipelines idle while input was expected (starvation)
    pub starve_cycles: u64,
}

impl KernelModule {
    pub fn new(h: usize, w: usize, pipelines: usize) -> Self {
        let ow = w - WIN + 1;
        Self {
            h,
            w,
            pipelines,
            batch_ii: 4, // 4 vertical pixels per batch, 1 px/cycle/pipeline
            grad_lb: LineBuffer::new(3, w, 8, 3),
            svm_lb: LineBuffer::new(WIN, w, 8, WIN),
            nms_lb: LineBuffer::new(NMS_BLOCK, ow, 19, NMS_BLOCK),
            busy: vec![0; pipelines],
            px_in: 0,
            px_done: 0,
            busy_cycles: 0,
            starve_cycles: 0,
        }
    }

    /// Total input pixels for this scale.
    pub fn total_px(&self) -> u64 {
        (self.h * self.w) as u64
    }

    /// Does a pipeline have a free slot for a new batch this cycle?
    pub fn free_pipeline(&self) -> bool {
        self.px_in < self.total_px() && self.busy.iter().any(|&b| b == 0)
    }

    /// Hand one batch (4 vertical pixels) to a free pipeline. Call only when
    /// [`Self::free_pipeline`] is true.
    pub fn assign_batch(&mut self) {
        let slot = self
            .busy
            .iter_mut()
            .find(|b| **b == 0)
            .expect("assign_batch without a free pipeline");
        *slot = self.batch_ii;
        self.px_in += 4.min(self.total_px() - self.px_in);
    }

    /// End-of-cycle bookkeeping: advance every busy pipeline one clock and
    /// retire batches whose initiation interval elapsed.
    pub fn advance_cycle(&mut self) {
        let total = self.total_px();
        let mut any_busy = false;
        let mut retired_px = 0u64;
        for b in &mut self.busy {
            if *b > 0 {
                any_busy = true;
                *b -= 1;
                if *b == 0 {
                    retired_px += 4;
                }
            }
        }
        if retired_px > 0 {
            let px = retired_px.min(total - self.px_done);
            self.px_done += px;
            self.grad_lb.write(px as usize);
            self.svm_lb.write(px as usize);
        }
        if any_busy {
            self.busy_cycles += 1;
        } else if self.px_in < self.total_px() {
            self.starve_cycles += 1;
        }
    }

    /// Gradient pixels produced so far: CalcGrad needs the row below, so its
    /// output trails the input by one batch-row group (4 rows) plus the
    /// 3-tap horizontal window.
    pub fn grad_count(&self) -> u64 {
        self.px_done
            .saturating_sub(4 * self.w as u64 + 2)
            .min((self.h * self.w) as u64)
    }

    /// SVM-I scores produced so far, in score-map raster order: score
    /// `(sy, sx)` exists once gradient pixel `(sy+7, sx+7)` exists.
    pub fn score_count(&self) -> u64 {
        let g = self.grad_count();
        let w = self.w as u64;
        let ow = w - WIN as u64 + 1;
        let oh = self.h as u64 - WIN as u64 + 1;
        if g == 0 {
            return 0;
        }
        // last gradient pixel index g-1 → (gy, gx)
        let gy = (g - 1) / w;
        let gx = (g - 1) % w;
        if gy < WIN as u64 - 1 {
            return 0;
        }
        let sy = gy - (WIN as u64 - 1); // rows before sy are fully enabled
        let full_rows = sy.min(oh);
        let partial = if sy < oh {
            // within row `sy`: scores with sx+7 <= gx
            (gx + 1).saturating_sub(WIN as u64 - 1).min(ow)
        } else {
            0
        };
        (full_rows * ow + partial).min(oh * ow)
    }

    /// Completion: the whole image has drained through CalcGrad.
    pub fn drained(&self) -> bool {
        self.px_done >= self.total_px()
    }

    /// When drained, downstream counters see everything.
    pub fn final_score_count(&self) -> u64 {
        let ow = (self.w - WIN + 1) as u64;
        let oh = (self.h - WIN + 1) as u64;
        oh * ow
    }

    /// Effective score count used by the NMS stage (flushes on drain).
    pub fn scores_visible(&self) -> u64 {
        if self.drained() {
            self.final_score_count()
        } else {
            self.score_count()
        }
    }
}

/// Precompute, for each NMS winner (in block raster order), the score-count
/// threshold after which its 5×5 block is complete and the winner is emitted
/// into the output FIFO. Shared by the accelerator's cycle loop.
pub fn winner_emit_thresholds(oh: usize, ow: usize) -> Vec<u64> {
    let mut out = Vec::new();
    let mut by = 0;
    while by < oh {
        let last_y = (by + NMS_BLOCK - 1).min(oh - 1);
        let mut bx = 0;
        while bx < ow {
            let last_x = (bx + NMS_BLOCK - 1).min(ow - 1);
            out.push((last_y * ow + last_x) as u64 + 1);
            bx += NMS_BLOCK;
        }
        by += NMS_BLOCK;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the kernel with `batches_per_cycle` available batches.
    fn run_to_drain(h: usize, w: usize, pipelines: usize, feed: usize) -> u64 {
        let mut k = KernelModule::new(h, w, pipelines);
        let mut cycles = 0u64;
        while !k.drained() {
            cycles += 1;
            assert!(cycles < 1_000_000, "kernel never drained");
            let mut fed = 0;
            while fed < feed && k.free_pipeline() {
                k.assign_batch();
                fed += 1;
            }
            k.advance_cycle();
        }
        cycles
    }

    #[test]
    fn pipelines_consume_and_drain() {
        // 16x16 = 256 px = 64 batches; 4 pipes II=4, 1 batch/cycle feed
        let cycles = run_to_drain(16, 16, 4, 1);
        assert!((64..200).contains(&cycles), "implausible cycle count {cycles}");
    }

    #[test]
    fn single_pipeline_is_four_times_slower() {
        let c1 = run_to_drain(32, 32, 1, 1);
        let c4 = run_to_drain(32, 32, 4, 1);
        assert!(c1 > 3 * c4, "scaling broken: 1-pipe {c1} vs 4-pipe {c4}");
    }

    #[test]
    fn score_count_matches_closed_form() {
        let mut k = KernelModule::new(16, 16, 4);
        while !k.drained() {
            if k.free_pipeline() {
                k.assign_batch();
            }
            k.advance_cycle();
        }
        assert_eq!(k.scores_visible(), 9 * 9);
    }

    #[test]
    fn score_count_monotone_during_run() {
        let mut k = KernelModule::new(24, 16, 2);
        let mut last = 0u64;
        while !k.drained() {
            if k.free_pipeline() {
                k.assign_batch();
            }
            k.advance_cycle();
            let s = k.scores_visible();
            assert!(s >= last);
            last = s;
        }
        assert_eq!(last, (24 - 7) as u64 * (16 - 7) as u64);
    }

    #[test]
    fn emit_thresholds_cover_all_blocks_in_order() {
        let th = winner_emit_thresholds(9, 9);
        assert_eq!(th.len(), 4); // 2x2 blocks
        assert_eq!(*th.last().unwrap(), 81);
        assert!(th.iter().all(|&t| t <= 81));
    }

    #[test]
    fn starvation_counted_when_no_batches() {
        let mut k = KernelModule::new(16, 16, 2);
        k.advance_cycle();
        assert_eq!(k.starve_cycles, 1);
    }

    #[test]
    #[should_panic(expected = "without a free pipeline")]
    fn over_assignment_panics() {
        let mut k = KernelModule::new(16, 16, 1);
        k.assign_batch();
        k.assign_batch();
    }
}
