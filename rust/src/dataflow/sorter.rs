//! Sorting-module model: the bubble-pushing heap with dual-port-memory
//! timing (paper §3.1, after Zabołotny 2011).
//!
//! Functional behaviour is exactly [`crate::sort::BubbleHeap`]; the cycle
//! model charges 1 cycle for a rejected candidate (root comparison only) and
//! an initiation interval of 2 cycles for accepted ones (the dual-port
//! memory pipelines one comparator level per port; sift latency ⌈log₂ cap⌉
//! levels overlaps across items).

use std::any::Any;

use super::stage::{Port, PortIo, Stage, StageStatus};
use crate::sort::BubbleHeap;

/// Heap-sorter timing wrapper.
#[derive(Debug)]
pub struct HeapSorter<T: Ord> {
    pub heap: BubbleHeap<T>,
    /// cycles the sorter is still busy with the current sift
    busy: u64,
    /// total busy cycles (power activity)
    pub busy_cycles: u64,
    /// items processed
    pub items: u64,
}

impl<T: Ord> HeapSorter<T> {
    pub fn new(capacity: usize) -> Self {
        Self { heap: BubbleHeap::new(capacity), busy: 0, busy_cycles: 0, items: 0 }
    }

    /// Initiation interval of an accepted push: the dual-port heapsort
    /// pipelines one comparator level per memory port, so a new item can
    /// enter every 2 clocks regardless of depth (Zabołotny §3 — the sift
    /// *latency* is still ⌈log₂ cap⌉ levels, but levels overlap). Perf-pass
    /// change #1 (EXPERIMENTS.md §Perf): previously modeled as a serial
    /// ⌈log₂ cap⌉ per item, which made the sorter the bottleneck on winner
    /// bursts and inflated Table 3 by ~27%.
    const ACCEPT_II: u64 = 2;

    /// Sift latency in comparator levels (resource/latency documentation).
    pub fn sift_latency(&self) -> u64 {
        (usize::BITS - self.heap.capacity().max(2).leading_zeros()) as u64
    }

    /// Can the sorter accept a candidate this cycle?
    pub fn ready(&self) -> bool {
        self.busy == 0
    }

    /// One clock. `item`: a candidate popped from the NMS FIFO this cycle
    /// (only when `ready()`); returns true if it was consumed.
    pub fn tick(&mut self, item: Option<T>) -> bool {
        if self.busy > 0 {
            self.busy -= 1;
            self.busy_cycles += 1;
            return false;
        }
        if let Some(v) = item {
            self.items += 1;
            let accepted = self.heap.push(v);
            // rejected: root comparison only (this cycle); accepted: the
            // pipelined sift blocks the ports for ACCEPT_II − 1 more clocks
            if accepted {
                self.busy = Self::ACCEPT_II - 1;
            }
            self.busy_cycles += 1;
            true
        } else {
            false
        }
    }

    pub fn is_idle(&self) -> bool {
        self.busy == 0
    }
}

/// The sorting module as the sink [`Stage`] of the pipeline graph: pulls
/// winner indices from the NMS FIFO (one per initiation interval) and feeds
/// `(score, index)` keys through the bubble-pushing heap.
#[derive(Debug)]
pub struct SorterStage {
    pub sorter: HeapSorter<(i32, usize)>,
    /// winner scores in emit (block raster) order — token `i` carries score
    /// `scores[i]`
    scores: Vec<i32>,
    /// winners consumed from the FIFO so far
    pub sorted: usize,
}

impl SorterStage {
    pub fn new(sorter: HeapSorter<(i32, usize)>, scores: Vec<i32>) -> Self {
        Self { sorter, scores, sorted: 0 }
    }
}

impl Stage for SorterStage {
    fn name(&self) -> &'static str {
        "sorter"
    }

    fn step(&mut self, _cycle: u64, io: &mut PortIo<'_>) -> StageStatus {
        let up = io
            .upstream
            .as_deref_mut()
            .expect("sorter stage needs an upstream port");
        if self.sorter.ready() {
            if let Some(token) = up.pull() {
                let idx = token as usize;
                self.sorter.tick(Some((self.scores[idx], idx)));
                self.sorted += 1;
                StageStatus::Active
            } else {
                StageStatus::Starved
            }
        } else {
            // mid-sift: the dual-port memory is occupied for II−1 clocks
            self.sorter.tick(None);
            StageStatus::Active
        }
    }

    fn done(&self, upstream: Option<&dyn Port>) -> bool {
        self.sorter.is_idle() && upstream.is_none_or(|p| !p.can_pull())
    }

    /// The heap keeps its contents across scales; swapping is re-arming the
    /// input comparator, one initiation interval.
    fn swap_cycles(&self) -> u64 {
        HeapSorter::<(i32, usize)>::ACCEPT_II
    }

    /// Full flush drains the pipelined sift and resets the fill pointer:
    /// two clocks per comparator level.
    fn flush_cycles(&self) -> u64 {
        2 * self.sorter.sift_latency()
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_at_pipelined_initiation_interval() {
        let mut s = HeapSorter::new(8);
        assert!(s.tick(Some(5)));
        assert!(!s.ready(), "ports busy for II-1 cycles");
        let mut waited = 0;
        while !s.ready() {
            s.tick(None);
            waited += 1;
        }
        assert_eq!(waited, 1, "accept II must be 2 cycles");
        assert!(s.sift_latency() >= 3, "latency metadata preserved");
    }

    #[test]
    fn rejected_items_cost_one_cycle() {
        let mut s = HeapSorter::new(2);
        s.tick(Some(10));
        while !s.ready() {
            s.tick(None);
        }
        s.tick(Some(20));
        while !s.ready() {
            s.tick(None);
        }
        // heap full at {10, 20}; 1 is rejected at the door
        assert!(s.tick(Some(1)));
        assert!(s.ready(), "rejection must not start a sift");
    }

    #[test]
    fn functional_result_is_top_k() {
        let mut s = HeapSorter::new(3);
        let mut feed: Vec<i32> = (0..50).map(|i| (i * 37) % 101).collect();
        let mut idx = 0;
        let mut guard = 0;
        while idx < feed.len() && guard < 10_000 {
            guard += 1;
            if s.ready() {
                if s.tick(Some(feed[idx])) {
                    idx += 1;
                }
            } else {
                s.tick(None);
            }
        }
        let mut expect = std::mem::take(&mut feed);
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(s.heap.into_sorted_desc(), expect[..3].to_vec());
    }

    #[test]
    fn throughput_counts_items() {
        let mut s = HeapSorter::new(4);
        let mut fed = 0u64;
        for i in 0..200 {
            if s.ready() {
                if s.tick(Some(i % 17)) {
                    fed += 1;
                }
            } else {
                s.tick(None);
            }
        }
        assert_eq!(s.items, fed);
        assert!(s.busy_cycles > 0);
    }
}
