//! Power model: static + activity-scaled dynamic power per device,
//! calibrated against the paper's C/RTL co-simulation numbers (Table 3:
//! Artix-7 LV 97 mW total / 15 mW dynamic @ 3.3 MHz; Kintex US+ 821 mW /
//! 350 mW @ 100 MHz).
//!
//! `P_total = P_static(device) + c_dyn(device) · f_MHz · activity`, where
//! `activity` is the datapath busy fraction reported by the cycle simulator
//! (≈1.0 for the fully streaming paper workload).

use crate::config::Device;

/// One power estimate in milliwatts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerReport {
    pub static_mw: f64,
    pub dynamic_mw: f64,
}

impl PowerReport {
    pub fn total_mw(&self) -> f64 {
        self.static_mw + self.dynamic_mw
    }
}

/// Device leakage (static) power, mW — Table 3 totals minus dynamic.
fn static_mw(device: Device) -> f64 {
    match device {
        Device::Artix7LowVolt => 82.0,        // 97 − 15
        Device::KintexUltraScalePlus => 471.0, // 821 − 350
    }
}

/// Dynamic power per MHz at full datapath activity, mW/MHz.
///
/// Calibration: Artix LV 15 mW @ 3.3 MHz → 4.545; Kintex US+ 350 mW
/// @ 100 MHz → 3.5 (the US+ node is more efficient per toggle).
fn dyn_mw_per_mhz(device: Device) -> f64 {
    match device {
        Device::Artix7LowVolt => 15.0 / 3.3,
        Device::KintexUltraScalePlus => 350.0 / 100.0,
    }
}

/// Estimate power at the device's nominal clock.
pub fn estimate(device: Device, activity: f64) -> PowerReport {
    estimate_at(device, device.clock_hz(), activity)
}

/// Estimate power at an arbitrary clock (frequency-scaling ablations).
pub fn estimate_at(device: Device, clock_hz: f64, activity: f64) -> PowerReport {
    let activity = activity.clamp(0.0, 1.0);
    let f_mhz = clock_hz / 1.0e6;
    PowerReport {
        static_mw: static_mw(device),
        dynamic_mw: dyn_mw_per_mhz(device) * f_mhz * activity,
    }
}

/// Energy efficiency in frames per joule (fps per watt).
pub fn frames_per_joule(fps: f64, power: &PowerReport) -> f64 {
    fps / (power.total_mw() / 1000.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_table3_at_full_activity() {
        let artix = estimate(Device::Artix7LowVolt, 1.0);
        assert!((artix.total_mw() - 97.0).abs() < 1.0, "{}", artix.total_mw());
        assert!((artix.dynamic_mw - 15.0).abs() < 0.5);

        let kintex = estimate(Device::KintexUltraScalePlus, 1.0);
        assert!((kintex.total_mw() - 821.0).abs() < 1.0, "{}", kintex.total_mw());
        assert!((kintex.dynamic_mw - 350.0).abs() < 0.5);
    }

    #[test]
    fn idle_design_pays_only_leakage() {
        let p = estimate(Device::KintexUltraScalePlus, 0.0);
        assert_eq!(p.dynamic_mw, 0.0);
        assert!((p.total_mw() - 471.0).abs() < 1e-9);
    }

    #[test]
    fn dynamic_scales_with_clock() {
        let slow = estimate_at(Device::KintexUltraScalePlus, 50.0e6, 1.0);
        let fast = estimate_at(Device::KintexUltraScalePlus, 100.0e6, 1.0);
        assert!((fast.dynamic_mw / slow.dynamic_mw - 2.0).abs() < 1e-9);
    }

    #[test]
    fn activity_clamped() {
        let p = estimate(Device::Artix7LowVolt, 2.0);
        assert!((p.dynamic_mw - 15.0).abs() < 0.5);
    }

    #[test]
    fn efficiency_metric() {
        let p = estimate(Device::KintexUltraScalePlus, 1.0);
        let eff = frames_per_joule(1100.0, &p);
        // paper: 1100 fps at 0.821 W → ≈ 1340 frames/J
        assert!((eff - 1340.0).abs() < 15.0, "{eff}");
    }
}
