//! The streaming-stage abstraction: a [`Stage`] trait with FIFO [`Port`]s
//! and a generic [`PipelineDriver`] that cycle-steps a linear stage graph.
//!
//! The paper's accelerator is a cascade of synchronous streaming modules
//! (resize → kernel computing → sort) glued by buffering structures (the
//! ping-pong cache, the NMS FIFO). Before this refactor the cycle simulator
//! hard-coded that sequencing inside `Accelerator::run_scale`; now each
//! module implements [`Stage`], each buffer implements [`Port`], and the
//! driver owns the per-cycle schedule, the stall/starve accounting and the
//! scale-boundary overheads (swap/flush latencies are *derived* from the
//! stages' drain schedules instead of per-call constants).
//!
//! ```text
//!   stage[0] ──channel[0]──► stage[1] ──channel[1]──► … ──► stage[n-1]
//! ```
//!
//! One driver cycle steps every stage once, in topological order, handing
//! stage `i` its upstream channel `i-1` and downstream channel `i` — the
//! same order the hand-rolled loop used, so the ported accelerator is
//! cycle-identical to the old model (asserted in `tests/backend_parity.rs`).

use std::any::Any;

/// The value flowing through a [`Port`]: a batch-fragment size on the
/// resize→kernel edge, a winner index on the NMS→sorter edge. Stages that
/// only need the token's existence ignore the payload.
pub type Token = u64;

/// What a stage did with its cycle — the driver's accounting signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageStatus {
    /// Did useful work this cycle.
    Active,
    /// Blocked by downstream backpressure (output port full).
    Stalled,
    /// Waiting on upstream input (input port empty).
    Starved,
    /// Drained: no work this cycle and none will ever arrive.
    Done,
}

/// A synchronous FIFO channel between two stages. Implemented by the NMS
/// [`super::fifo::Fifo`] and the [`super::pingpong::PingPongCache`]; the
/// implementations keep their own occupancy/stall statistics.
pub trait Port: Any {
    /// Would a `push` succeed this cycle? Side-effect free (no stall
    /// accounting) — producers use it to *sense* backpressure.
    fn can_push(&self) -> bool;
    /// Try to enqueue one token; `false` on backpressure (the
    /// implementation may count the rejection as a producer stall).
    fn push(&mut self, token: Token) -> bool;
    /// Would a `pull` succeed this cycle? Side-effect free.
    fn can_pull(&self) -> bool;
    /// Try to dequeue one token (the implementation may count a failed
    /// pull as a consumer starve).
    fn pull(&mut self) -> Option<Token>;
    /// No tokens buffered anywhere in the channel.
    fn is_empty(&self) -> bool;
    /// End-of-stream: publish any buffered partial group to the consumer
    /// (the ping-pong cache's partial tail lane). Default: nothing to do.
    fn flush(&mut self) {}
    /// Cycles this channel needs to drain/reset at a scale boundary —
    /// its contribution to the pipeline's flush barrier.
    fn flush_cycles(&self) -> u64 {
        0
    }
    /// Downcast hook for typed statistics extraction after a run.
    fn as_any(&self) -> &dyn Any;
}

/// The ports visible to one stage for one cycle: its upstream channel
/// (`None` for the source stage) and its downstream channel (`None` for
/// the sink stage).
pub struct PortIo<'a> {
    pub upstream: Option<&'a mut dyn Port>,
    pub downstream: Option<&'a mut dyn Port>,
}

/// One streaming module of the pipeline.
pub trait Stage: Any {
    /// Short display name for deadlock reports and telemetry.
    fn name(&self) -> &'static str;

    /// Advance one clock: consume from `io.upstream`, work, produce into
    /// `io.downstream`. Called every driver cycle, including after the
    /// stage drained (hardware keeps clocking; drained stages no-op).
    fn step(&mut self, cycle: u64, io: &mut PortIo<'_>) -> StageStatus;

    /// Will this stage ever do useful work again, given its upstream
    /// channel? See [`PipelineDriver::is_done`] for how the driver
    /// combines the per-stage reports into pipeline termination.
    fn done(&self, upstream: Option<&dyn Port>) -> bool;

    /// Is this stage's doneness *permanent* — unrevokable by tokens that
    /// might still arrive upstream (the stage counts its own completion
    /// and abandons leftovers)? A terminally-done stage ends the pipeline
    /// from itself downward: producers above it can never influence the
    /// sink again and are abandoned mid-stream, the rule the old
    /// hand-rolled loop applied when the kernel had emitted every winner.
    /// Pass-through sinks whose `done()` merely means "quiescent right
    /// now" (the sorter) must keep the default `false`.
    fn done_terminal(&self) -> bool {
        false
    }

    /// Cycles this stage needs to reconfigure for the next scale *while
    /// the previous stream still drains* (width-register/lane swap).
    fn swap_cycles(&self) -> u64;

    /// Cycles this stage needs for a full drain + reset barrier at a
    /// non-overlapped scale boundary.
    fn flush_cycles(&self) -> u64 {
        self.swap_cycles()
    }

    /// Downcast hook for typed statistics extraction after a run.
    fn as_any(&self) -> &dyn Any;
}

/// Per-stage cycle accounting accumulated by the driver.
#[derive(Debug, Default, Clone)]
pub struct StageCounts {
    /// cycles the stage reported [`StageStatus::Active`]
    pub active: u64,
    /// cycles stalled on downstream backpressure
    pub stalled: u64,
    /// cycles starved of upstream input
    pub starved: u64,
    /// cycles idle after draining
    pub idle: u64,
    /// first cycle at which the stage's `done()` held (end of that
    /// stage's step). For the source stage this is the fetch-done cycle —
    /// the streaming front the next scale can overlap with. Mid-pipeline
    /// stages may report transiently (their input can refill); only the
    /// source's value is monotone.
    pub done_since: Option<u64>,
}

/// Generic cycle-stepper for a linear stage graph.
///
/// Build with alternating [`PipelineDriver::stage`] / [`PipelineDriver::channel`]
/// calls (`n` stages joined by `n-1` channels), then [`PipelineDriver::run`].
/// After the run, typed stage/channel statistics come back out through
/// [`PipelineDriver::stage_as`] / [`PipelineDriver::channel_as`].
#[derive(Default)]
pub struct PipelineDriver {
    stages: Vec<Box<dyn Stage>>,
    channels: Vec<Box<dyn Port>>,
    counts: Vec<StageCounts>,
    /// cycles stepped so far
    pub cycles: u64,
}

impl PipelineDriver {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a stage (must alternate with [`Self::channel`]).
    pub fn stage(mut self, s: impl Stage) -> Self {
        assert_eq!(
            self.stages.len(),
            self.channels.len(),
            "stage() must follow channel() (linear graph: s-c-s-c-s)"
        );
        self.stages.push(Box::new(s));
        self.counts.push(StageCounts::default());
        self
    }

    /// Append the channel feeding the *next* stage.
    pub fn channel(mut self, c: impl Port) -> Self {
        assert_eq!(
            self.channels.len() + 1,
            self.stages.len(),
            "channel() must follow stage() (linear graph: s-c-s-c-s)"
        );
        self.channels.push(Box::new(c));
        self
    }

    /// Pipeline termination: every stage from the first *terminally* done
    /// stage (see [`Stage::done_terminal`]) to the sink reports done —
    /// stages upstream of that cut are abandoned, since their output can
    /// never be consumed again (the old loop's rule: the kernel emitting
    /// its last winner ends the scale even if fetch tokens remain
    /// buffered). With no terminally-done stage, every stage must drain.
    pub fn is_done(&self) -> bool {
        let done_at = |i: usize| {
            let up = if i == 0 {
                None
            } else {
                Some(&*self.channels[i - 1])
            };
            self.stages[i].done(up)
        };
        let cut = (0..self.stages.len())
            .find(|&i| self.stages[i].done_terminal() && done_at(i))
            .unwrap_or(0);
        (cut..self.stages.len()).all(done_at)
    }

    /// Step every stage once, in topological order.
    pub fn step_cycle(&mut self) {
        self.cycles += 1;
        let cycle = self.cycles;
        for i in 0..self.stages.len() {
            let (before, rest) = self.channels.split_at_mut(i);
            let mut io = PortIo {
                upstream: before.last_mut().map(|c| &mut **c),
                downstream: rest.first_mut().map(|c| &mut **c),
            };
            let status = self.stages[i].step(cycle, &mut io);
            match status {
                StageStatus::Active => self.counts[i].active += 1,
                StageStatus::Stalled => self.counts[i].stalled += 1,
                StageStatus::Starved => self.counts[i].starved += 1,
                StageStatus::Done => self.counts[i].idle += 1,
            }
            if self.counts[i].done_since.is_none() {
                let up = if i == 0 {
                    None
                } else {
                    Some(&*self.channels[i - 1])
                };
                if self.stages[i].done(up) {
                    self.counts[i].done_since = Some(cycle);
                }
            }
        }
    }

    /// Cycle-step until every stage drains; returns total cycles. Panics
    /// past `budget` cycles (a deadlocked graph must fail loudly, not
    /// spin — same contract as the old hand-rolled loop).
    pub fn run(&mut self, budget: u64) -> u64 {
        assert!(
            !self.stages.is_empty() && self.stages.len() == self.channels.len() + 1,
            "pipeline graph must be n stages joined by n-1 channels"
        );
        while !self.is_done() {
            self.step_cycle();
            assert!(
                self.cycles <= budget,
                "pipeline deadlock after {} cycles: {}",
                self.cycles,
                self.describe()
            );
        }
        self.cycles
    }

    /// Reconfiguration gap when the next scale's fetch overlaps this
    /// scale's drain: every stage swaps its geometry registers in
    /// parallel, so the gap is the slowest stage's swap latency.
    pub fn swap_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.swap_cycles()).max().unwrap_or(0)
    }

    /// Full flush barrier at a non-overlapped scale boundary: the drain
    /// handshake walks the graph, so stage and channel resets serialize.
    pub fn flush_cycles(&self) -> u64 {
        self.stages.iter().map(|s| s.flush_cycles()).sum::<u64>()
            + self.channels.iter().map(|c| c.flush_cycles()).sum::<u64>()
    }

    /// Accounting for stage `idx`.
    pub fn counts(&self, idx: usize) -> &StageCounts {
        &self.counts[idx]
    }

    /// Typed view of stage `idx` (post-run statistics extraction).
    pub fn stage_as<T: 'static>(&self, idx: usize) -> Option<&T> {
        self.stages.get(idx)?.as_any().downcast_ref()
    }

    /// Typed view of channel `idx`.
    pub fn channel_as<T: 'static>(&self, idx: usize) -> Option<&T> {
        self.channels.get(idx)?.as_any().downcast_ref()
    }

    /// Human-readable pipeline state for deadlock panics.
    fn describe(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for (i, st) in self.stages.iter().enumerate() {
            let up = if i == 0 {
                None
            } else {
                Some(&*self.channels[i - 1])
            };
            let _ = write!(
                s,
                "{}{}[done={} act={} stall={} starve={}]",
                if i == 0 { "" } else { " -> " },
                st.name(),
                st.done(up),
                self.counts[i].active,
                self.counts[i].stalled,
                self.counts[i].starved,
            );
            if i < self.channels.len() {
                let _ = write!(
                    s,
                    " ={}=",
                    if self.channels[i].is_empty() { "empty" } else { "busy" }
                );
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::fifo::Fifo;

    /// Source producing `n` tokens, one per cycle (when the channel accepts).
    struct Source {
        remaining: u64,
    }

    impl Stage for Source {
        fn name(&self) -> &'static str {
            "source"
        }

        fn step(&mut self, _cycle: u64, io: &mut PortIo<'_>) -> StageStatus {
            let out = io.downstream.as_deref_mut().expect("source needs output");
            if self.remaining == 0 {
                return StageStatus::Done;
            }
            if out.push(self.remaining) {
                self.remaining -= 1;
                if self.remaining == 0 {
                    out.flush();
                    return StageStatus::Done;
                }
                StageStatus::Active
            } else {
                StageStatus::Stalled
            }
        }

        fn done(&self, _up: Option<&dyn Port>) -> bool {
            self.remaining == 0
        }

        fn swap_cycles(&self) -> u64 {
            3
        }

        fn flush_cycles(&self) -> u64 {
            5
        }

        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    /// Sink consuming one token every `ii` cycles.
    struct Sink {
        ii: u64,
        busy: u64,
        consumed: u64,
    }

    impl Stage for Sink {
        fn name(&self) -> &'static str {
            "sink"
        }

        fn step(&mut self, _cycle: u64, io: &mut PortIo<'_>) -> StageStatus {
            let up = io.upstream.as_deref_mut().expect("sink needs input");
            if self.busy > 0 {
                self.busy -= 1;
                return StageStatus::Active;
            }
            if up.pull().is_some() {
                self.consumed += 1;
                self.busy = self.ii - 1;
                StageStatus::Active
            } else {
                StageStatus::Starved
            }
        }

        fn done(&self, up: Option<&dyn Port>) -> bool {
            self.busy == 0 && up.is_none_or(|p| !p.can_pull())
        }

        fn swap_cycles(&self) -> u64 {
            2
        }

        fn as_any(&self) -> &dyn Any {
            self
        }
    }

    fn toy(n: u64, ii: u64, depth: usize) -> PipelineDriver {
        PipelineDriver::new()
            .stage(Source { remaining: n })
            .channel(Fifo::<Token>::new(depth))
            .stage(Sink { ii, busy: 0, consumed: 0 })
    }

    #[test]
    fn rate_matched_pipeline_runs_in_n_plus_drain() {
        let mut d = toy(16, 1, 4);
        let cycles = d.run(1_000);
        // 1 token/cycle both sides with a 1-cycle channel latency
        assert!((16..=18).contains(&cycles), "cycles {cycles}");
        assert_eq!(d.stage_as::<Sink>(1).unwrap().consumed, 16);
    }

    #[test]
    fn slow_sink_backpressures_the_source() {
        let mut d = toy(12, 3, 2);
        let cycles = d.run(1_000);
        assert!(cycles >= 12 * 3, "sink II must dominate: {cycles}");
        assert!(d.counts(0).stalled > 0, "source never felt backpressure");
        let fifo = d.channel_as::<Fifo<Token>>(0).unwrap();
        assert!(fifo.full_stalls > 0);
        assert_eq!(fifo.max_occupancy, 2);
    }

    #[test]
    fn source_done_cycle_recorded() {
        let mut d = toy(8, 1, 16);
        d.run(1_000);
        assert_eq!(d.counts(0).done_since, Some(8));
    }

    #[test]
    fn swap_is_max_and_flush_is_sum() {
        let d = toy(1, 1, 2);
        assert_eq!(d.swap_cycles(), 3); // max(source 3, sink 2)
        assert_eq!(d.flush_cycles(), 5 + 2); // source 5 + sink default(=swap 2) + fifo 0
    }

    #[test]
    #[should_panic(expected = "pipeline deadlock")]
    fn budget_overrun_panics_with_description() {
        // sink with an absurd II can't finish in the budget
        let mut d = toy(64, 1_000, 1);
        d.run(100);
    }

    #[test]
    #[should_panic(expected = "must follow")]
    fn builder_rejects_channel_before_stage() {
        let _ = PipelineDriver::new().channel(Fifo::<Token>::new(1));
    }
}
