//! Cycle-level dataflow simulator — the silicon substitute (DESIGN.md §2).
//!
//! The paper's accelerator is a synchronous streaming design; this module
//! reproduces its *structure* as an explicit stage graph, cycle-stepped by
//! a generic driver:
//!
//! ```text
//!            Stage                Port              Stage               Port          Stage
//!  DRAM/blocks → [Resizer: 4 workers] → PingPongCache → [KernelStage: P × (CalcGrad
//!      → SVM-I → NMS), tiered caches] → Fifo (streaming buffer) → [SorterStage:
//!      bubble-pushing heap]
//!
//!            └──────────────── PipelineDriver (dataflow::stage) ────────────────┘
//!              per-cycle schedule · stall/starve accounting · swap/flush latencies
//! ```
//!
//! Each hardware module implements [`stage::Stage`]; each buffering
//! structure (the ping-pong cache, the NMS FIFO) implements [`stage::Port`];
//! [`stage::PipelineDriver`] owns the per-cycle schedule that
//! `Accelerator::run_scale` used to hand-roll. Scale-boundary overheads
//! (the reconfiguration swap during overlapped drains, the full flush
//! barrier) are *derived* from the stages' drain schedules rather than
//! being per-call constants.
//!
//! Functional values come from the bit-exact twins in [`crate::bing`], so the
//! simulator's outputs equal the software baseline and the HLO path; the
//! simulator adds *time* (cycles, stalls, occupancy), from which the
//! Table 2/3 numbers (fps at the paper's clocks) and the ablations (ping-pong
//! cache, pipeline scaling, FIFO depth) are derived. [`resource`] and
//! [`power`] are the matching pre-RTL area/power models (Table 1/3).
//!
//! The whole simulator is servable at request time through
//! [`crate::backend::SimulatedAccelerator`] (one of the three
//! `ProposalBackend`s the coordinator can drive).

pub mod accel;
pub mod bram;
pub mod fifo;
pub mod kernel;
pub mod linebuffer;
pub mod pingpong;
pub mod power;
pub mod resizer;
pub mod resource;
pub mod sorter;
pub mod stage;

pub use accel::{Accelerator, ImageRunReport, ScaleStats};
pub use power::{estimate as power_estimate, PowerReport};
pub use resource::{estimate as resource_estimate, Resources, WorkloadGeometry};
pub use stage::{PipelineDriver, Port, Stage, StageStatus};
