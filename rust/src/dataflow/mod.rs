//! Cycle-level dataflow simulator — the silicon substitute (DESIGN.md §2).
//!
//! The paper's accelerator is a synchronous streaming design; this module
//! reproduces its *structure* cycle by cycle:
//!
//! ```text
//!  DRAM/blocks → [Resizer: 4 workers, rotation fetch] → PingPongCache
//!      → [KernelModule: P pipelines — CalcGrad → SVM-I → NMS, tiered caches]
//!      → Fifo (streaming buffer) → [HeapSorter: bubble-pushing heap]
//! ```
//!
//! Functional values come from the bit-exact twins in [`crate::bing`], so the
//! simulator's outputs equal the software baseline and the HLO path; the
//! simulator adds *time* (cycles, stalls, occupancy), from which the
//! Table 2/3 numbers (fps at the paper's clocks) and the ablations (ping-pong
//! cache, pipeline scaling, FIFO depth) are derived. [`resource`] and
//! [`power`] are the matching pre-RTL area/power models (Table 1/3).

pub mod accel;
pub mod bram;
pub mod fifo;
pub mod kernel;
pub mod linebuffer;
pub mod pingpong;
pub mod power;
pub mod resizer;
pub mod resource;
pub mod sorter;

pub use accel::{Accelerator, ImageRunReport, ScaleStats};
pub use power::{estimate as power_estimate, PowerReport};
pub use resource::{estimate as resource_estimate, Resources, WorkloadGeometry};
