//! The composed accelerator: resize module → kernel-computing module →
//! sorting module, cycle-stepped per scale, with the paper's streaming
//! structure (ping-pong cache, tiered caches, NMS FIFO, bubble-pushing heap).

use super::kernel::{winner_emit_thresholds, KernelModule};
use super::resizer::Resizer;
use super::sorter::HeapSorter;
use crate::bing::{
    gradient_map, score_map, winners_from_scores, Candidate, Pyramid, Stage1Weights, Winner,
};
use crate::config::AcceleratorConfig;
use crate::dataflow::fifo::Fifo;
use crate::image::ImageRgb;

/// Timing + occupancy statistics for one scale.
#[derive(Debug, Clone)]
pub struct ScaleStats {
    pub scale: (usize, usize),
    pub cycles: u64,
    /// cycle at which the resize module finished fetching (the streaming
    /// front; everything after is pipeline drain — overlappable with the
    /// next scale's fetch, see [`Accelerator::run_image`])
    pub fetch_done_cycle: u64,
    /// consumer starve cycles at the ping-pong cache (stream discontinuity)
    pub cache_starves: u64,
    /// kernel pipelines idle awaiting input
    pub kernel_starves: u64,
    /// cycles the kernel was stalled by NMS-FIFO backpressure
    pub backpressure_stalls: u64,
    /// NMS output FIFO high-water mark + overflow stalls
    pub fifo_max_occupancy: usize,
    pub fifo_full_stalls: u64,
    /// winners this scale emitted
    pub winners: usize,
}

/// Whole-image run report.
#[derive(Debug, Clone)]
pub struct ImageRunReport {
    pub per_scale: Vec<ScaleStats>,
    pub total_cycles: u64,
    /// candidate windows (all scales) in the same order/values as the
    /// software baseline — the parity surface
    pub candidates: Vec<Candidate>,
    /// fraction of cycles the datapath was streaming (power activity)
    pub activity: f64,
}

impl ImageRunReport {
    /// Frames/second at a given clock.
    pub fn fps(&self, clock_hz: f64) -> f64 {
        clock_hz / self.total_cycles.max(1) as f64
    }
}

/// Pipeline-flush overhead between scales without overlap (full drain +
/// reconfigure barrier), cycles.
const SCALE_FLUSH_CYCLES: u64 = 64;

/// Reconfiguration gap when scale transitions overlap (line-buffer width
/// swap while the previous stream drains), cycles.
const SCALE_SWAP_CYCLES: u64 = 8;

/// The accelerator model.
pub struct Accelerator {
    pub config: AcceleratorConfig,
    pub pyramid: Pyramid,
    pub weights: Stage1Weights,
}

impl Accelerator {
    pub fn new(config: AcceleratorConfig, pyramid: Pyramid, weights: Stage1Weights) -> Self {
        Self { config, pyramid, weights }
    }

    /// Run one scale: returns (stats, winners). Winner *values* are the
    /// functional twins' output (bit-exact with the baseline and the HLO
    /// path); the cycle count comes from stepping the streaming model.
    pub fn run_scale(&self, img: &ImageRgb, scale_idx: usize) -> (ScaleStats, Vec<Winner>) {
        let (h, w) = self.pyramid.sizes[scale_idx];

        // ---- functional twin (values) -----------------------------------
        let resized = img.resize_nearest(w, h);
        let g = gradient_map(&resized);
        let s = score_map(&g, &self.weights);
        let winners = winners_from_scores(&s);
        let thresholds = winner_emit_thresholds(s.h, s.w);
        debug_assert_eq!(thresholds.len(), winners.len());

        // ---- cycle model --------------------------------------------------
        let cfg = &self.config;
        let mut resizer = Resizer::new(
            img.w,
            img.h,
            (h, w),
            cfg.batch_pixels.max(1),
            32,
            cfg.ping_pong,
        );
        let mut kernel = KernelModule::new(h, w, cfg.pipelines.max(1));
        let mut fifo: Fifo<usize> = Fifo::new(cfg.nms_fifo_depth.max(1));
        let mut sorter: HeapSorter<(i32, usize)> = HeapSorter::new(cfg.heap_capacity.max(1));

        let mut emitted = 0usize; // winners pushed toward the FIFO
        let mut sorted = 0usize; // winners consumed by the sorter
        let mut cycles = 0u64;
        let mut fetch_done_cycle = 0u64;
        let mut backpressure_stalls = 0u64;
        let budget = ((h * w) as u64 + 4096) * 16; // runaway guard

        while sorted < winners.len() || !fifo.is_empty() || !sorter.is_idle() {
            cycles += 1;
            if cycles > budget {
                panic!(
                    "accelerator deadlock at scale {h}x{w}: sorted {sorted}/{} fifo {}",
                    winners.len(),
                    fifo.len()
                );
            }

            // resize module: fetch + fill ping-pong cache
            resizer.tick();
            if resizer.done_fetching() {
                if fetch_done_cycle == 0 {
                    fetch_done_cycle = cycles;
                }
                resizer.cache.flush(); // publish the partial tail lane
            }

            // NMS→FIFO backpressure (perf-pass change #3, a fidelity fix):
            // when completed winners cannot enter the full FIFO, the NMS
            // stage stalls and the stall propagates up the kernel pipelines
            // — no new batch is issued this cycle.
            let visible = kernel.scores_visible();
            let blocked = emitted < winners.len()
                && thresholds[emitted] <= visible
                && fifo.is_full();
            if blocked {
                backpressure_stalls += 1;
            }

            // kernel pipelines: the cache streams one batch per cycle into
            // whichever pipeline is free (paper: the continuous stream keeps
            // the pipelines fully loaded)
            if !blocked && resizer.cache.ready() && kernel.free_pipeline() {
                resizer.cache.drain();
                kernel.assign_batch();
            }
            kernel.advance_cycle();

            // NMS stage: emit winners whose 5×5 block completed
            let visible = kernel.scores_visible();
            while emitted < winners.len() && thresholds[emitted] <= visible {
                if fifo.push(emitted) {
                    emitted += 1;
                } else {
                    break; // FIFO full: stall counted above
                }
            }

            // sorting module (skipped entirely while idle with an empty
            // FIFO — perf-pass change #6, pure simulator-speed win)
            if sorter.ready() {
                if let Some(idx) = fifo.pop() {
                    let win = &winners[idx];
                    sorter.tick(Some((win.score, idx)));
                    sorted += 1;
                }
            } else {
                sorter.tick(None);
            }
        }

        let stats = ScaleStats {
            scale: (h, w),
            cycles,
            fetch_done_cycle: if fetch_done_cycle == 0 { cycles } else { fetch_done_cycle },
            cache_starves: resizer.cache.starve_cycles,
            kernel_starves: kernel.starve_cycles,
            backpressure_stalls,
            fifo_max_occupancy: fifo.max_occupancy,
            fifo_full_stalls: fifo.full_stalls,
            winners: winners.len(),
        };
        (stats, winners)
    }

    /// Run the full pyramid for one image.
    ///
    /// With `config.overlap_scales` (default, perf-pass change #2) the
    /// drain tail of scale *i* overlaps scale *i+1*'s fetch: in the
    /// streaming design the resize module starts loading the next scale as
    /// soon as its block BRAMs free up, while the kernel/NMS/sorter chain
    /// finishes the previous stream — so a non-final scale contributes only
    /// its fetch span plus a small reconfiguration gap. Disabling the flag
    /// restores the strict barrier (the ablation in `ablation_scaling`).
    pub fn run_image(&self, img: &ImageRgb) -> ImageRunReport {
        let mut per_scale = Vec::with_capacity(self.pyramid.sizes.len());
        let mut candidates = Vec::new();
        let mut total_cycles = 0u64;
        let mut busy_cycles = 0u64;
        let last = self.pyramid.sizes.len() - 1;
        for idx in 0..self.pyramid.sizes.len() {
            let (stats, winners) = self.run_scale(img, idx);
            let contribution = if self.config.overlap_scales && idx < last {
                stats.fetch_done_cycle + SCALE_SWAP_CYCLES
            } else {
                stats.cycles + SCALE_FLUSH_CYCLES
            };
            total_cycles += contribution;
            busy_cycles += contribution
                .saturating_sub(stats.kernel_starves.min(contribution));
            candidates.extend(winners.into_iter().map(|w| Candidate {
                scale_idx: idx,
                x: w.x,
                y: w.y,
                score: w.score,
            }));
            per_scale.push(stats);
        }
        let activity = (busy_cycles as f64 / total_cycles.max(1) as f64).min(1.0);
        ImageRunReport { per_scale, total_cycles, candidates, activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;

    fn accel(pipelines: usize, ping_pong: bool) -> Accelerator {
        let cfg = AcceleratorConfig { pipelines, ping_pong, ..Default::default() };
        Accelerator::new(
            cfg,
            Pyramid::new(vec![(16, 16), (32, 32), (64, 64)]),
            default_stage1(),
        )
    }

    fn test_image() -> ImageRgb {
        SyntheticDataset::voc_like_val(1).sample(0).image
    }

    #[test]
    fn produces_same_candidates_as_baseline() {
        use crate::baseline::{ScoringMode, SoftwareBing};
        use crate::svm::Stage2Calibration;
        let img = test_image();
        let a = accel(4, true);
        let report = a.run_image(&img);
        let sw = SoftwareBing::new(
            a.pyramid.clone(),
            a.weights.clone(),
            Stage2Calibration::identity(a.pyramid.sizes.clone()),
            ScoringMode::Exact,
        );
        assert_eq!(report.candidates, sw.candidates(&img));
    }

    #[test]
    fn cycle_count_tracks_pixel_volume() {
        let img = test_image();
        let report = accel(4, true).run_image(&img);
        let px: u64 = [(16u64, 16u64), (32, 32), (64, 64)]
            .iter()
            .map(|&(h, w)| h * w)
            .sum();
        // fully streaming design: cycles ≈ px/4 .. 3×px/4 including flushes
        assert!(report.total_cycles as f64 > px as f64 / 4.0 * 0.8);
        assert!(
            (report.total_cycles as f64) < px as f64 * 1.5,
            "cycles {} for {px} px — streaming broken",
            report.total_cycles
        );
    }

    #[test]
    fn more_pipelines_are_faster_until_fetch_bound() {
        let img = test_image();
        let c1 = accel(1, true).run_image(&img).total_cycles;
        let c4 = accel(4, true).run_image(&img).total_cycles;
        assert!(c1 > 2 * c4, "no pipeline scaling: {c1} vs {c4}");
    }

    #[test]
    fn ping_pong_outperforms_single_lane() {
        let img = test_image();
        let with = accel(4, true).run_image(&img).total_cycles;
        let without = accel(4, false).run_image(&img).total_cycles;
        assert!(without > with, "ping-pong not helping: {with} vs {without}");
    }

    #[test]
    fn fps_at_paper_clocks_is_plausible() {
        let img = test_image();
        let report = accel(4, true).run_image(&img);
        let fps_kintex = report.fps(100.0e6);
        // small 3-scale pyramid — must be far faster than the full workload
        assert!(fps_kintex > 1000.0, "implausibly slow: {fps_kintex}");
    }
}
