//! The composed accelerator: resize stage → kernel-computing stage →
//! sorting stage, joined by the ping-pong cache and the NMS FIFO and
//! cycle-stepped per scale by the generic [`PipelineDriver`] — the paper's
//! streaming structure as an explicit stage graph.

use super::fifo::Fifo;
use super::kernel::{KernelModule, KernelStage};
use super::pingpong::PingPongCache;
use super::resizer::Resizer;
use super::sorter::{HeapSorter, SorterStage};
use super::stage::{PipelineDriver, Token};
use crate::bing::{
    gradient_map, score_map, winners_from_scores, Candidate, Pyramid, Stage1Weights, Winner,
};
use crate::config::AcceleratorConfig;
use crate::image::ImageRgb;

/// Timing + occupancy statistics for one scale.
#[derive(Debug, Clone)]
pub struct ScaleStats {
    pub scale: (usize, usize),
    pub cycles: u64,
    /// cycle at which the resize module finished fetching (the streaming
    /// front; everything after is pipeline drain — overlappable with the
    /// next scale's fetch, see [`Accelerator::run_image`])
    pub fetch_done_cycle: u64,
    /// consumer starve cycles at the ping-pong cache: cycles a free kernel
    /// pipeline requested a batch the cache could not serve (stream
    /// discontinuity — the signal the E5 single-lane ablation exposes)
    pub cache_starves: u64,
    /// kernel pipelines idle awaiting input
    pub kernel_starves: u64,
    /// cycles the kernel was stalled by NMS-FIFO backpressure
    pub backpressure_stalls: u64,
    /// NMS output FIFO high-water mark + overflow stalls
    pub fifo_max_occupancy: usize,
    pub fifo_full_stalls: u64,
    /// winners this scale emitted
    pub winners: usize,
    /// reconfiguration gap charged when the next scale's fetch overlaps
    /// this scale's drain — the slowest stage's swap latency, derived by
    /// the driver from the stage graph (formerly the `SCALE_SWAP_CYCLES`
    /// constant; 8 for the default geometry)
    pub swap_cycles: u64,
    /// full drain + reconfigure barrier charged when scales do not overlap
    /// — the sum of every stage's and channel's reset latency (formerly
    /// the `SCALE_FLUSH_CYCLES` constant; 64 for the default geometry)
    pub flush_cycles: u64,
}

/// Whole-image run report.
#[derive(Debug, Clone)]
pub struct ImageRunReport {
    pub per_scale: Vec<ScaleStats>,
    pub total_cycles: u64,
    /// candidate windows (all scales) in the same order/values as the
    /// software baseline — the parity surface
    pub candidates: Vec<Candidate>,
    /// fraction of cycles the datapath was streaming (power activity)
    pub activity: f64,
}

impl ImageRunReport {
    /// Frames/second at a given clock.
    ///
    /// Contract: returns `None` when `total_cycles == 0` (an empty run —
    /// nothing was simulated) so the caller decides what an undefined
    /// frame rate means for its report; for `total_cycles > 0` the result
    /// is a finite, positive number — never NaN or infinity. (Earlier
    /// versions silently clamped the denominator with `.max(1)`, which
    /// reported `clock_hz` fps for an empty run.)
    pub fn fps(&self, clock_hz: f64) -> Option<f64> {
        if self.total_cycles == 0 {
            None
        } else {
            Some(clock_hz / self.total_cycles as f64)
        }
    }
}

/// Depth, in 4-pixel batches, of one ping-pong cache lane (paper §3.2: one
/// batch-column group per part, sized so a lane refill hides the fetch
/// rotation latency).
const CACHE_LANE_DEPTH: usize = 32;

/// The accelerator model.
pub struct Accelerator {
    pub config: AcceleratorConfig,
    pub pyramid: Pyramid,
    pub weights: Stage1Weights,
}

impl Accelerator {
    pub fn new(config: AcceleratorConfig, pyramid: Pyramid, weights: Stage1Weights) -> Self {
        Self { config, pyramid, weights }
    }

    /// Run one scale: returns (stats, winners). Winner *values* are the
    /// functional twins' output (bit-exact with the baseline and the HLO
    /// path); the cycle count comes from the [`PipelineDriver`] stepping
    /// the resize → kernel → sort stage graph.
    pub fn run_scale(&self, img: &ImageRgb, scale_idx: usize) -> (ScaleStats, Vec<Winner>) {
        let (h, w) = self.pyramid.sizes[scale_idx];

        // ---- functional twin (values) -----------------------------------
        let resized = img.resize_nearest(w, h);
        let g = gradient_map(&resized);
        let s = score_map(&g, &self.weights);
        let winners = winners_from_scores(&s);

        // ---- stage graph ------------------------------------------------
        let cfg = &self.config;
        let workers = cfg.batch_pixels.max(1);
        let kernel = KernelStage::new(KernelModule::new(h, w, cfg.pipelines.max(1)));
        debug_assert_eq!(kernel.expected_winners(), winners.len());
        let sorter = SorterStage::new(
            HeapSorter::new(cfg.heap_capacity.max(1)),
            winners.iter().map(|win| win.score).collect(),
        );
        let mut driver = PipelineDriver::new()
            .stage(Resizer::new(img.w, img.h, (h, w), workers))
            .channel(PingPongCache::new(CACHE_LANE_DEPTH, workers, cfg.ping_pong))
            .stage(kernel)
            .channel(Fifo::<Token>::new(cfg.nms_fifo_depth.max(1)))
            .stage(sorter);

        let budget = ((h * w) as u64 + 4096) * 16; // runaway guard
        let cycles = driver.run(budget);

        let cache = driver.channel_as::<PingPongCache>(0).expect("cache channel");
        let kernel = driver.stage_as::<KernelStage>(1).expect("kernel stage");
        let fifo = driver.channel_as::<Fifo<Token>>(1).expect("nms fifo channel");
        let stats = ScaleStats {
            scale: (h, w),
            cycles,
            fetch_done_cycle: driver.counts(0).done_since.unwrap_or(cycles),
            cache_starves: cache.starve_cycles,
            kernel_starves: kernel.kernel.starve_cycles,
            backpressure_stalls: kernel.backpressure_stalls,
            fifo_max_occupancy: fifo.max_occupancy,
            fifo_full_stalls: fifo.full_stalls,
            winners: winners.len(),
            swap_cycles: driver.swap_cycles(),
            flush_cycles: driver.flush_cycles(),
        };
        (stats, winners)
    }

    /// Run the full pyramid for one image.
    ///
    /// With `config.overlap_scales` (default) the drain tail of scale *i*
    /// overlaps scale *i+1*'s fetch: in the streaming design the resize
    /// module starts loading the next scale as soon as its block BRAMs free
    /// up, while the kernel/NMS/sorter chain finishes the previous stream —
    /// so a non-final scale contributes only its fetch span plus the
    /// reconfiguration gap the driver derives from the stage graph
    /// ([`ScaleStats::swap_cycles`]). Disabling the flag restores the
    /// strict barrier (the ablation in `ablation_scaling`), charging the
    /// full drain plus the derived flush barrier
    /// ([`ScaleStats::flush_cycles`]).
    pub fn run_image(&self, img: &ImageRgb) -> ImageRunReport {
        let mut per_scale = Vec::with_capacity(self.pyramid.sizes.len());
        let mut candidates = Vec::new();
        let mut total_cycles = 0u64;
        let mut busy_cycles = 0u64;
        let last = self.pyramid.sizes.len() - 1;
        for idx in 0..self.pyramid.sizes.len() {
            let (stats, winners) = self.run_scale(img, idx);
            let contribution = if self.config.overlap_scales && idx < last {
                stats.fetch_done_cycle + stats.swap_cycles
            } else {
                stats.cycles + stats.flush_cycles
            };
            total_cycles += contribution;
            busy_cycles += contribution
                .saturating_sub(stats.kernel_starves.min(contribution));
            candidates.extend(winners.into_iter().map(|w| Candidate {
                scale_idx: idx,
                x: w.x,
                y: w.y,
                score: w.score,
            }));
            per_scale.push(stats);
        }
        let activity = (busy_cycles as f64 / total_cycles.max(1) as f64).min(1.0);
        ImageRunReport { per_scale, total_cycles, candidates, activity }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;

    fn accel(pipelines: usize, ping_pong: bool) -> Accelerator {
        let cfg = AcceleratorConfig { pipelines, ping_pong, ..Default::default() };
        Accelerator::new(
            cfg,
            Pyramid::new(vec![(16, 16), (32, 32), (64, 64)]),
            default_stage1(),
        )
    }

    fn test_image() -> ImageRgb {
        SyntheticDataset::voc_like_val(1).sample(0).image
    }

    #[test]
    fn produces_same_candidates_as_baseline() {
        use crate::baseline::{ScoringMode, SoftwareBing};
        use crate::svm::Stage2Calibration;
        let img = test_image();
        let a = accel(4, true);
        let report = a.run_image(&img);
        let sw = SoftwareBing::new(
            a.pyramid.clone(),
            a.weights.clone(),
            Stage2Calibration::identity(a.pyramid.sizes.clone()),
            ScoringMode::Exact,
        );
        assert_eq!(report.candidates, sw.candidates(&img));
    }

    #[test]
    fn cycle_count_tracks_pixel_volume() {
        let img = test_image();
        let report = accel(4, true).run_image(&img);
        let px: u64 = [(16u64, 16u64), (32, 32), (64, 64)]
            .iter()
            .map(|&(h, w)| h * w)
            .sum();
        // fully streaming design: cycles ≈ px/4 .. 3×px/4 including flushes
        assert!(report.total_cycles as f64 > px as f64 / 4.0 * 0.8);
        assert!(
            (report.total_cycles as f64) < px as f64 * 1.5,
            "cycles {} for {px} px — streaming broken",
            report.total_cycles
        );
    }

    #[test]
    fn more_pipelines_are_faster_until_fetch_bound() {
        let img = test_image();
        let c1 = accel(1, true).run_image(&img).total_cycles;
        let c4 = accel(4, true).run_image(&img).total_cycles;
        assert!(c1 > 2 * c4, "no pipeline scaling: {c1} vs {c4}");
    }

    #[test]
    fn ping_pong_outperforms_single_lane() {
        let img = test_image();
        let with = accel(4, true).run_image(&img).total_cycles;
        let without = accel(4, false).run_image(&img).total_cycles;
        assert!(without > with, "ping-pong not helping: {with} vs {without}");
    }

    #[test]
    fn single_lane_refills_surface_as_cache_starves() {
        // the E5 ablation's stream-discontinuity signal: a free pipeline
        // asking an empty cache is recorded at the cache, and the single
        // lane (which stalls the stream on every refill) must starve the
        // kernel strictly more than the ping-pong configuration
        let img = test_image();
        let starves = |pp: bool| -> u64 {
            accel(4, pp)
                .run_image(&img)
                .per_scale
                .iter()
                .map(|s| s.cache_starves)
                .sum()
        };
        let (with, without) = (starves(true), starves(false));
        assert!(without > 0, "single lane never starved the kernel");
        assert!(without > with, "refill stalls invisible: {with} vs {without}");
    }

    #[test]
    fn fps_at_paper_clocks_is_plausible() {
        let img = test_image();
        let report = accel(4, true).run_image(&img);
        let fps_kintex = report.fps(100.0e6).expect("simulation ran cycles");
        // small 3-scale pyramid — must be far faster than the full workload
        assert!(fps_kintex > 1000.0, "implausibly slow: {fps_kintex}");
    }

    #[test]
    fn fps_is_none_for_an_empty_run() {
        let empty = ImageRunReport {
            per_scale: Vec::new(),
            total_cycles: 0,
            candidates: Vec::new(),
            activity: 0.0,
        };
        assert_eq!(empty.fps(100.0e6), None, "undefined fps must be None, not clock_hz");
    }

    #[test]
    fn sub_batch_fetch_granularity_still_terminates() {
        // accel.batch_pixels < 4: each fetch token carries fewer pixels
        // than the kernel's 4-px batch credit, so the kernel finishes with
        // the resizer mid-stream. The old loop tolerated the abandoned
        // fetcher (its termination ignored the resize module); the driver
        // must too, via the terminal-done cut — not deadlock-panic.
        let img = test_image();
        let pyramid = Pyramid::new(vec![(16, 16), (32, 32)]);
        let narrow = Accelerator::new(
            AcceleratorConfig { batch_pixels: 2, ..Default::default() },
            pyramid.clone(),
            default_stage1(),
        )
        .run_image(&img);
        let reference = Accelerator::new(
            AcceleratorConfig::default(),
            pyramid,
            default_stage1(),
        )
        .run_image(&img);
        assert!(narrow.total_cycles > 0);
        assert_eq!(
            narrow.candidates, reference.candidates,
            "fetch granularity must never change functional output"
        );
    }

    #[test]
    fn derived_scale_overheads_match_the_former_constants() {
        // The old model charged fixed SCALE_SWAP_CYCLES = 8 and
        // SCALE_FLUSH_CYCLES = 64 between scales. The driver now derives
        // both from the stage graph's drain schedule; for the default
        // geometry (4 fetch workers, 3/8/5-row line buffers, 32-deep cache
        // lanes, 128-entry heap) the derivation reproduces the documented
        // constants exactly.
        let img = test_image();
        let (stats, _) = accel(4, true).run_scale(&img, 0);
        assert_eq!(stats.swap_cycles, 8, "swap = slowest stage swap latency");
        assert_eq!(stats.flush_cycles, 64, "flush = sum of stage+channel resets");
    }
}
