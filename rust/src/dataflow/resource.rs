//! Resource model: parametric LUT/LUT-RAM/FF/BRAM/DSP estimates for the
//! accelerator configuration, calibrated against the paper's Vivado HLS
//! synthesis results (Table 1).
//!
//! The model is *structural*: each component contributes terms derived from
//! its geometry (pipelines, line-buffer widths, heap capacity, FIFO depth).
//! The per-primitive constants are calibrated so the paper's configuration
//! (4 pipelines, 500×375 source, 320-wide scales, 1000-entry heap) lands on
//! the published utilization — the standard way to build a pre-RTL
//! area model when the original RTL is unavailable.

use crate::config::{AcceleratorConfig, Device};

/// BRAM36 tile capacity (Table 1 counts BRAM36 tiles).
const BRAM36_BITS: u64 = 36 * 1024;

/// Resources of one device (availability) or one design (utilization).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Resources {
    pub lut: u64,
    pub lutram: u64,
    pub ff: u64,
    pub bram36: u64,
    pub dsp: u64,
    pub bufg: u64,
}

impl Resources {
    /// Device capacity tables (paper Table 1, "Available" columns).
    pub fn available(device: Device) -> Resources {
        match device {
            Device::Artix7LowVolt => Resources {
                lut: 63_400,
                lutram: 19_000,
                ff: 126_800,
                bram36: 135,
                dsp: 240,
                bufg: 32,
            },
            Device::KintexUltraScalePlus => Resources {
                lut: 162_720,
                lutram: 99_840,
                ff: 325_440,
                bram36: 360,
                dsp: 1_368,
                bufg: 256,
            },
        }
    }

    /// Utilization percentage per resource class against a device.
    pub fn percent_of(&self, device: Device) -> [(&'static str, f64); 5] {
        let avail = Resources::available(device);
        [
            ("LUT", 100.0 * self.lut as f64 / avail.lut as f64),
            ("LUT-RAM", 100.0 * self.lutram as f64 / avail.lutram as f64),
            ("FF", 100.0 * self.ff as f64 / avail.ff as f64),
            ("BRAM", 100.0 * self.bram36 as f64 / avail.bram36 as f64),
            ("DSP", 100.0 * self.dsp as f64 / avail.dsp as f64),
        ]
    }

    /// Does the design fit the device?
    pub fn fits(&self, device: Device) -> bool {
        let a = Resources::available(device);
        self.lut <= a.lut
            && self.lutram <= a.lutram
            && self.ff <= a.ff
            && self.bram36 <= a.bram36
            && self.dsp <= a.dsp
    }
}

/// Workload geometry the buffers must be sized for.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadGeometry {
    /// source image held in the four block BRAMs
    pub src_w: usize,
    pub src_h: usize,
    /// widest pyramid scale (sizes every line buffer)
    pub max_scale_w: usize,
}

impl WorkloadGeometry {
    /// The paper's evaluation workload: VOC2007 images (≈500×375), BING
    /// pyramid up to 320 px wide.
    pub fn paper() -> Self {
        Self { src_w: 500, src_h: 375, max_scale_w: 320 }
    }

    /// This repo's default synthetic workload (192² images, ≤128-px scales).
    pub fn synthetic() -> Self {
        Self { src_w: 192, src_h: 192, max_scale_w: 128 }
    }
}

// ---- calibrated per-component constants (see module docs) -----------------

/// control/AXI/handshake fabric
const LUT_CONTROL: u64 = 6_000;
const FF_CONTROL: u64 = 5_000;
/// resize module datapath (index arithmetic + muxing), excl. BRAM
const LUT_RESIZE: u64 = 2_500;
const FF_RESIZE: u64 = 1_800;
/// heap sorter (comparators + pointer logic)
const LUT_SORTER: u64 = 1_200;
const FF_SORTER: u64 = 900;
/// stage-II calibration + post-processing
const LUT_POST: u64 = 1_500;
const FF_POST: u64 = 1_100;
/// one kernel pipeline: CalcGrad + 64-MAC SVM array (LUT multipliers — the
/// i8 template makes them shift/add trees) + NMS comparators
const LUT_PER_PIPELINE: u64 = 700 + 64 * 150 + 500;
const FF_PER_PIPELINE: u64 = 9_950;
/// LUTRAM: shallow shift registers / small windows
const LUTRAM_BASE: u64 = 1_000;
const LUTRAM_PER_PIPELINE: u64 = 700;
const LUTRAM_PER_FIFO_SLOT: u64 = 6;
/// DSP: resize address arithmetic + stage-II multipliers; per pipeline: the
/// saturation/rounding corners HLS maps to DSP48
const DSP_BASE: u64 = 9;
const DSP_PER_PIPELINE: u64 = 4;

/// UltraScale+ platform overhead (wider AXI, clock management) observed as
/// the Kintex-vs-Artix delta in Table 1.
const LUT_ULTRASCALE_EXTRA: u64 = 2_100;
const FF_ULTRASCALE_EXTRA: u64 = 1_450;

/// Estimate the design's resource utilization.
pub fn estimate(cfg: &AcceleratorConfig, wl: &WorkloadGeometry) -> Resources {
    let p = cfg.pipelines.max(1) as u64;

    // ---- BRAM ----------------------------------------------------------
    // four source-image quadrant blocks (one port each)
    let quad_bits = (wl.src_w as u64 / 2) * (wl.src_h as u64 / 2) * 24;
    let bram_blocks = 4 * quad_bits.div_ceil(BRAM36_BITS);
    // tiered caches per pipeline: CalcGrad 3 rows ×8b, SVM 8 rows ×8b,
    // NMS 5 rows ×19b over the score width
    let w = wl.max_scale_w as u64;
    let lb_bits = 3 * w * 8 + 8 * w * 8 + 5 * (w - 7) * 19;
    let bram_linebufs = p * lb_bits.div_ceil(BRAM36_BITS).max(1);
    // ping-pong cache lanes (2 when enabled, 1 otherwise)
    let lanes = if cfg.ping_pong { 2 } else { 1 };
    let bram_cache = lanes * ((32 * 4 * 24u64).div_ceil(BRAM36_BITS)).max(1);
    // heap: capacity × (score 19b + coords 21b + scale 8b) on two ports
    let heap_bits = cfg.heap_capacity as u64 * 48;
    let bram_heap = 2 * heap_bits.div_ceil(BRAM36_BITS).max(1);
    // NMS output FIFO
    let fifo_bits = cfg.nms_fifo_depth as u64 * 48;
    let bram_fifo = fifo_bits.div_ceil(BRAM36_BITS).max(1);
    let bram36 = bram_blocks + bram_linebufs + bram_cache + bram_heap + bram_fifo + 2;

    // ---- LUT/FF/LUTRAM/DSP ----------------------------------------------
    let (mut lut, mut ff) = (
        LUT_CONTROL + LUT_RESIZE + LUT_SORTER + LUT_POST + p * LUT_PER_PIPELINE,
        FF_CONTROL + FF_RESIZE + FF_SORTER + FF_POST + p * FF_PER_PIPELINE,
    );
    let mut lutram =
        LUTRAM_BASE + p * LUTRAM_PER_PIPELINE + cfg.nms_fifo_depth as u64 * LUTRAM_PER_FIFO_SLOT;
    let mut bufg = 2;
    if cfg.device == Device::KintexUltraScalePlus {
        lut += LUT_ULTRASCALE_EXTRA;
        ff += FF_ULTRASCALE_EXTRA;
        // US+ HLS maps more small buffers into BRAM, fewer into LUTRAM
        lutram = lutram.saturating_sub(1_000);
        bufg = 8;
    }
    let dsp = DSP_BASE + DSP_PER_PIPELINE * p;

    Resources { lut, lutram, ff, bram36, dsp, bufg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;

    fn paper_cfg(device: Device) -> AcceleratorConfig {
        AcceleratorConfig {
            pipelines: 4,
            heap_capacity: 1000,
            nms_fifo_depth: 64,
            ping_pong: true,
            device,
            ..Default::default()
        }
    }

    #[test]
    fn artix_estimate_matches_table1_within_tolerance() {
        let est = estimate(&paper_cfg(Device::Artix7LowVolt), &WorkloadGeometry::paper());
        // paper: LUT 54453, LUTRAM 4166, FF 48611, DSP 25
        assert!((est.lut as f64 - 54_453.0).abs() / 54_453.0 < 0.05, "LUT {}", est.lut);
        assert!((est.ff as f64 - 48_611.0).abs() / 48_611.0 < 0.05, "FF {}", est.ff);
        assert!((est.lutram as f64 - 4_166.0).abs() / 4_166.0 < 0.15, "LUTRAM {}", est.lutram);
        assert_eq!(est.dsp, 25);
        // paper reports BRAM 135 — the full device; model must land close
        assert!((120..=160).contains(&est.bram36), "BRAM {}", est.bram36);
    }

    #[test]
    fn kintex_estimate_matches_table1_within_tolerance() {
        let est = estimate(
            &paper_cfg(Device::KintexUltraScalePlus),
            &WorkloadGeometry::paper(),
        );
        // paper: LUT 56504, LUTRAM 3157, FF 50079, BRAM 146, DSP 25, BUFG 8
        assert!((est.lut as f64 - 56_504.0).abs() / 56_504.0 < 0.05, "LUT {}", est.lut);
        assert!((est.ff as f64 - 50_079.0).abs() / 50_079.0 < 0.05, "FF {}", est.ff);
        assert!((est.bram36 as f64 - 146.0).abs() / 146.0 < 0.15, "BRAM {}", est.bram36);
        assert_eq!(est.dsp, 25);
        assert_eq!(est.bufg, 8);
        assert!(est.fits(Device::KintexUltraScalePlus));
    }

    #[test]
    fn resources_scale_with_pipelines() {
        let wl = WorkloadGeometry::paper();
        let mut cfg = paper_cfg(Device::KintexUltraScalePlus);
        let r4 = estimate(&cfg, &wl);
        cfg.pipelines = 8;
        let r8 = estimate(&cfg, &wl);
        assert!(r8.lut > r4.lut && r8.ff > r4.ff && r8.dsp > r4.dsp);
        // growth dominated by the pipeline term
        assert!((r8.lut - r4.lut) as f64 > 0.9 * 4.0 * LUT_PER_PIPELINE as f64);
    }

    #[test]
    fn synthetic_workload_is_smaller() {
        let cfg = paper_cfg(Device::KintexUltraScalePlus);
        let paper = estimate(&cfg, &WorkloadGeometry::paper());
        let synth = estimate(&cfg, &WorkloadGeometry::synthetic());
        assert!(synth.bram36 < paper.bram36);
    }

    #[test]
    fn percent_and_fits() {
        let est = estimate(&paper_cfg(Device::KintexUltraScalePlus), &WorkloadGeometry::paper());
        for (name, pct) in est.percent_of(Device::KintexUltraScalePlus) {
            assert!(pct > 0.0 && pct < 101.0, "{name} at {pct}%");
        }
    }
}
