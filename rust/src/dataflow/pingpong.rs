//! Ping-Pong cache model (paper §3.2, Fig. 3).
//!
//! Two cache lanes, each partitioned into four parts fed by the four
//! block-fetch workers in rotation. While lane A is drained by the kernel
//! pipelines (one batch per cycle, *continuous*), lane B is refilled; the
//! lanes swap when A empties and B is full. With ping-pong disabled
//! (ablation E5) there is a single lane: fill and drain strictly alternate,
//! and the output stream stalls during every refill — exactly the
//! discontinuity the paper's design removes.
//!
//! In the stage graph the cache is the [`Port`] between the resize stage
//! and the kernel-computing stage: `push` is a fetch-worker batch offer,
//! `pull` is a kernel-pipeline drain request.

use std::any::Any;

use super::stage::{Port, Token};

/// Cache-lane geometry: each lane holds one batch-column group per part.
#[derive(Debug, Clone)]
pub struct PingPongCache {
    /// batches a lane holds (lane depth)
    pub lane_depth: usize,
    /// number of parts (= fetch workers, paper: 4)
    pub parts: usize,
    /// true = two lanes (ping-pong), false = single lane (ablation)
    pub ping_pong: bool,

    // state
    fill: usize,        // batches currently in the filling lane
    avail: usize,       // batches ready in the draining lane
    /// cycles the consumer could not be served (stream discontinuities)
    pub starve_cycles: u64,
    /// batches delivered
    pub delivered: u64,
    /// batches accepted from the fetchers
    pub filled: u64,
}

impl PingPongCache {
    pub fn new(lane_depth: usize, parts: usize, ping_pong: bool) -> Self {
        assert!(lane_depth > 0 && parts > 0);
        Self {
            lane_depth,
            parts,
            ping_pong,
            fill: 0,
            avail: 0,
            starve_cycles: 0,
            delivered: 0,
            filled: 0,
        }
    }

    /// Fetch workers offer up to `n` batches this cycle (rotation fetch:
    /// one per part). Returns how many were accepted.
    pub fn offer(&mut self, n: usize) -> usize {
        let room = if self.ping_pong || self.avail == 0 {
            self.lane_depth - self.fill
        } else {
            // single lane still draining: fetchers must wait
            0
        };
        let take = n.min(room).min(self.parts);
        self.fill += take;
        self.filled += take as u64;
        // lane completion: swap (ping-pong) or publish (single lane, only
        // once the drain side is empty)
        if self.fill == self.lane_depth && self.avail == 0 {
            self.avail = self.fill;
            self.fill = 0;
        }
        take
    }

    /// Kernel pipelines request one batch this cycle. `true` = served.
    pub fn drain(&mut self) -> bool {
        if self.avail == 0 {
            self.starve_cycles += 1;
            return false;
        }
        self.avail -= 1;
        self.delivered += 1;
        // with ping-pong, a full fill lane swaps in immediately on empty
        if self.avail == 0 && self.fill == self.lane_depth {
            self.avail = self.fill;
            self.fill = 0;
        }
        true
    }

    /// Is a batch ready right now?
    pub fn ready(&self) -> bool {
        self.avail > 0
    }

    /// Can the fetchers deposit a batch this cycle? (Mirrors the room
    /// computation in [`Self::offer`] without side effects.)
    pub fn has_room(&self) -> bool {
        (self.ping_pong || self.avail == 0) && self.fill < self.lane_depth
    }

    /// End-of-image flush: publish a partially filled lane (the tail of the
    /// stream never completes a full lane; hardware drains it via the same
    /// swap path once the fetcher signals completion).
    pub fn flush(&mut self) {
        if self.avail == 0 && self.fill > 0 {
            self.avail = self.fill;
            self.fill = 0;
        }
    }
}

impl Port for PingPongCache {
    fn can_push(&self) -> bool {
        self.has_room()
    }

    fn push(&mut self, _token: Token) -> bool {
        self.offer(1) == 1
    }

    fn can_pull(&self) -> bool {
        self.ready()
    }

    fn pull(&mut self) -> Option<Token> {
        if self.drain() {
            Some(1)
        } else {
            None
        }
    }

    fn is_empty(&self) -> bool {
        self.avail == 0 && self.fill == 0
    }

    fn flush(&mut self) {
        PingPongCache::flush(self);
    }

    /// Scale-boundary reset: each of the `parts` column groups re-aims its
    /// write pointers; the groups reset in parallel, so the span is one
    /// lane drained at `parts` batches per cycle.
    fn flush_cycles(&self) -> u64 {
        (self.lane_depth / self.parts.max(1)) as u64
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive fetchers (4 batches/cycle) and a 1-batch/cycle consumer; count
    /// consumer starve cycles over a long run.
    fn run(ping_pong: bool, cycles: usize) -> (u64, u64) {
        let mut cache = PingPongCache::new(16, 4, ping_pong);
        for _ in 0..cycles {
            cache.offer(4);
            cache.drain();
        }
        (cache.delivered, cache.starve_cycles)
    }

    #[test]
    fn ping_pong_reaches_continuous_streaming() {
        let (delivered, starves) = run(true, 400);
        // after warm-up the stream must be continuous: ≥95% service rate
        assert!(delivered >= 380, "delivered only {delivered}/400");
        assert!(starves <= 20, "too many starves with ping-pong: {starves}");
    }

    #[test]
    fn single_lane_stalls_during_refill() {
        let (delivered_pp, _) = run(true, 400);
        let (delivered_single, starves_single) = run(false, 400);
        assert!(
            delivered_single < delivered_pp,
            "single lane should deliver less: {delivered_single} vs {delivered_pp}"
        );
        assert!(starves_single > 50, "single lane barely stalled: {starves_single}");
    }

    #[test]
    fn nothing_from_empty_cache() {
        let mut c = PingPongCache::new(8, 4, true);
        assert!(!c.drain());
        assert_eq!(c.starve_cycles, 1);
    }

    #[test]
    fn offer_respects_part_count() {
        let mut c = PingPongCache::new(64, 4, true);
        assert_eq!(c.offer(10), 4, "at most one batch per part per cycle");
    }

    #[test]
    fn port_view_is_consistent_with_offer_and_drain() {
        let mut c = PingPongCache::new(2, 4, false);
        assert!(c.has_room() && Port::can_push(&c));
        assert!(Port::push(&mut c, 1));
        assert!(Port::push(&mut c, 1)); // fills the single lane → published
        assert!(!c.has_room(), "single lane still draining must refuse fills");
        assert!(Port::can_pull(&c));
        assert_eq!(Port::pull(&mut c), Some(1));
        assert_eq!(Port::pull(&mut c), Some(1));
        assert!(Port::is_empty(&c));
        assert_eq!(Port::pull(&mut c), None);
        assert!(c.has_room(), "empty single lane accepts fills again");
    }

    #[test]
    fn conservation_of_batches() {
        let mut c = PingPongCache::new(8, 4, true);
        let mut offered = 0u64;
        for _ in 0..100 {
            offered += c.offer(4) as u64;
            c.drain();
        }
        // delivered + in-flight == accepted
        let in_flight = (c.avail + c.fill) as u64;
        assert_eq!(c.delivered + in_flight, offered);
    }
}
