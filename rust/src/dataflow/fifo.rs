//! Bounded FIFO with backpressure and occupancy statistics — the streaming
//! glue between pipeline stages (paper §3.3: "a FIFO structure is adopted as
//! streaming buffer to make sure the pipelines run smoothly").
//!
//! `Fifo<Token>` implements the stage graph's [`Port`], so the same
//! structure the kernel module's NMS output drains into is the channel the
//! [`super::stage::PipelineDriver`] places before the sorter.

use std::any::Any;
use std::collections::VecDeque;

use super::stage::{Port, Token};

/// A synchronous bounded FIFO. `push` fails (backpressure) when full; the
/// producer must retry next cycle. Occupancy statistics feed the FIFO-depth
/// ablation (E5/E6) and the resource model (depth × width bits).
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    q: VecDeque<T>,
    cap: usize,
    /// high-water mark of occupancy
    pub max_occupancy: usize,
    /// number of rejected pushes (producer stall cycles)
    pub full_stalls: u64,
    /// number of failed pops (consumer starve cycles)
    pub empty_stalls: u64,
    /// total accepted items
    pub pushed: u64,
}

impl<T> Fifo<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "FIFO capacity must be positive");
        Self {
            q: VecDeque::with_capacity(cap),
            cap,
            max_occupancy: 0,
            full_stalls: 0,
            empty_stalls: 0,
            pushed: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.cap
    }

    /// Try to enqueue; returns false (and counts a stall) when full.
    pub fn push(&mut self, item: T) -> bool {
        if self.is_full() {
            self.full_stalls += 1;
            return false;
        }
        self.q.push_back(item);
        self.pushed += 1;
        self.max_occupancy = self.max_occupancy.max(self.q.len());
        true
    }

    /// Try to dequeue; counts a starve when empty.
    pub fn pop(&mut self) -> Option<T> {
        match self.q.pop_front() {
            Some(v) => Some(v),
            None => {
                self.empty_stalls += 1;
                None
            }
        }
    }

    /// Non-destructive front peek (no starve accounting).
    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }
}

impl Port for Fifo<Token> {
    fn can_push(&self) -> bool {
        !self.is_full()
    }

    fn push(&mut self, token: Token) -> bool {
        Fifo::push(self, token)
    }

    fn can_pull(&self) -> bool {
        !Fifo::is_empty(self)
    }

    fn pull(&mut self) -> Option<Token> {
        self.pop()
    }

    fn is_empty(&self) -> bool {
        Fifo::is_empty(self)
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            assert!(f.push(i));
        }
        for i in 0..4 {
            assert_eq!(f.pop(), Some(i));
        }
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn backpressure_counts_stalls() {
        let mut f = Fifo::new(2);
        assert!(f.push(1));
        assert!(f.push(2));
        assert!(!f.push(3));
        assert!(!f.push(4));
        assert_eq!(f.full_stalls, 2);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn starvation_counted() {
        let mut f = Fifo::<u8>::new(1);
        assert_eq!(f.pop(), None);
        assert_eq!(f.empty_stalls, 1);
    }

    #[test]
    fn high_water_mark() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(i);
        }
        f.pop();
        f.pop();
        f.push(9);
        assert_eq!(f.max_occupancy, 5);
    }

    #[test]
    #[should_panic(expected = "capacity must be positive")]
    fn zero_capacity_rejected() {
        let _ = Fifo::<u8>::new(0);
    }
}
