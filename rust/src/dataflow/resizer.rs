//! Streaming resizer model (paper §3.2, Fig. 2).
//!
//! The original image is partitioned into four blocks held in BRAM, one port
//! per block; four workers fetch pixels in rotation and deposit them into the
//! downstream cache as vertical 4-pixel batches. Functionally the output
//! equals [`crate::image::ImageRgb::resize_nearest`] (asserted in tests);
//! this model adds the cycle/port behaviour.
//!
//! Since the stage refactor the resizer no longer owns its output buffer:
//! it is the *source* [`Stage`] of the pipeline graph, and the ping-pong
//! cache is the [`Port`] the driver places between it and the kernel module.

use std::any::Any;

use super::bram::BramBank;
use super::stage::{Port, PortIo, Stage, StageStatus, Token};

/// Cycle model of the resize module for one target scale.
#[derive(Debug)]
pub struct Resizer {
    /// fetch workers (= image blocks = cache parts; paper: 4)
    pub workers: usize,
    /// the four source-image block BRAMs
    pub blocks: Vec<BramBank>,
    /// pixels of the *resized* image still to produce
    remaining_px: u64,
    /// total resized pixels for this scale
    pub total_px: u64,
    /// cycles this resizer was active
    pub busy_cycles: u64,
}

impl Resizer {
    /// `src` geometry is used to size the block BRAMs; `(th, tw)` is the
    /// resize target.
    pub fn new(src_w: usize, src_h: usize, (th, tw): (usize, usize), workers: usize) -> Self {
        // each block holds a quarter of the source stripe: h/2 × w/2 RGB
        let block_bits = (src_w as u64 / 2).max(1) * (src_h as u64 / 2).max(1) * 24;
        let blocks = (0..workers)
            .map(|_| BramBank::new(block_bits, 1))
            .collect();
        Self {
            workers,
            blocks,
            remaining_px: (th * tw) as u64,
            total_px: (th * tw) as u64,
            busy_cycles: 0,
        }
    }

    /// One clock: workers fetch up to `workers` pixels (one per block port,
    /// rotation style) and offer them to the output port as one batch
    /// fragment. Returns pixels actually deposited.
    pub fn tick(&mut self, out: &mut dyn Port) -> u64 {
        for b in &mut self.blocks {
            b.next_cycle();
        }
        if self.remaining_px == 0 {
            return 0;
        }
        // rotation fetch: each worker hits its own block's single port;
        // together the four workers assemble one vertical 4-pixel batch
        let mut granted = 0usize;
        for b in self.blocks.iter_mut().take(self.workers) {
            if b.access() {
                granted += 1;
            }
        }
        if granted == 0 {
            return 0;
        }
        // one batch per cycle when the cache has room (final batch may be
        // partial; hardware pads it)
        if !out.push(granted as Token) {
            return 0;
        }
        let px = (granted as u64).min(self.remaining_px);
        self.busy_cycles += 1;
        self.remaining_px -= px;
        px
    }

    pub fn done_fetching(&self) -> bool {
        self.remaining_px == 0
    }
}

impl Stage for Resizer {
    fn name(&self) -> &'static str {
        "resize"
    }

    fn step(&mut self, _cycle: u64, io: &mut PortIo<'_>) -> StageStatus {
        let out = io
            .downstream
            .as_deref_mut()
            .expect("resize stage needs a downstream port");
        let px = self.tick(out);
        if self.done_fetching() {
            // end-of-image: publish the partial tail lane every cycle the
            // fetcher signals completion (idempotent, same as the old loop)
            out.flush();
            return StageStatus::Done;
        }
        if px > 0 {
            StageStatus::Active
        } else {
            StageStatus::Stalled
        }
    }

    fn done(&self, _up: Option<&dyn Port>) -> bool {
        self.done_fetching()
    }

    /// A drained fetcher never restarts within a scale.
    fn done_terminal(&self) -> bool {
        true
    }

    /// Lane swap at a scale boundary: each fetch worker reprograms its
    /// block BRAM base/stride register pair.
    fn swap_cycles(&self) -> u64 {
        2 * self.workers as u64
    }

    fn as_any(&self) -> &dyn Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::super::pingpong::PingPongCache;
    use super::*;
    use crate::image::ImageRgb;

    #[test]
    fn produces_all_pixels_eventually() {
        let mut r = Resizer::new(192, 192, (32, 32), 4);
        let mut cache = PingPongCache::new(16, 4, true);
        let mut produced = 0u64;
        for _ in 0..10_000 {
            produced += r.tick(&mut cache);
            cache.drain();
            if r.done_fetching() {
                break;
            }
        }
        assert!(r.done_fetching());
        assert_eq!(produced, 32 * 32);
    }

    #[test]
    fn block_brams_sized_for_quadrants() {
        let r = Resizer::new(320, 320, (16, 16), 4);
        // quadrant: 160×160×24b = 614400 bits = 34 tiles
        assert_eq!(r.blocks[0].tiles(), 34);
    }

    #[test]
    fn functional_twin_is_nearest_resize() {
        // the model's pixel *values* are defined to be resize_nearest's —
        // spot-check the contract the accelerator relies on
        let img = ImageRgb::from_fn(64, 48, |x, y| [(x * 3) as u8, (y * 5) as u8, 7]);
        let out = img.resize_nearest(16, 12);
        assert_eq!(out.get(0, 0), img.get(0, 0));
        assert_eq!(out.get(15, 11), img.get(60, 44));
    }

    #[test]
    fn ping_pong_disabled_still_completes() {
        let mut r = Resizer::new(128, 128, (16, 16), 4);
        let mut cache = PingPongCache::new(8, 4, false);
        for _ in 0..20_000 {
            r.tick(&mut cache);
            cache.drain();
        }
        assert!(r.done_fetching());
    }

    #[test]
    fn stage_reports_done_and_flushes_tail() {
        let mut r = Resizer::new(64, 64, (8, 8), 4);
        let mut cache = PingPongCache::new(32, 4, true);
        let mut io = PortIo { upstream: None, downstream: Some(&mut cache) };
        let mut last = StageStatus::Active;
        for _ in 0..10_000 {
            last = Stage::step(&mut r, 0, &mut io);
            if last == StageStatus::Done {
                break;
            }
            // consume so the cache never backpressures indefinitely
            if let Some(p) = io.downstream.as_deref_mut() {
                p.pull();
            }
        }
        assert_eq!(last, StageStatus::Done);
        // the 8×8 target is 16 batches — fewer than one 32-deep lane, so
        // only the end-of-stream flush can have published them
        let cache = io.downstream.take().unwrap();
        assert!(cache.can_pull(), "tail lane was not published");
    }
}
