//! Silent-data-corruption defense: structural validators at the backend
//! seam, a cheap output checksum, and the golden-probe auditor.
//!
//! The accelerator the paper targets lives in FPGA fabric, where
//! single-event upsets flip bits in BRAM and datapaths without raising any
//! error — and a corrupted proposal poisons everything downstream of the
//! RPN-feeds-detector contract. This module is the serving stack's answer,
//! in two rings:
//!
//! * **Ring 1 — structural invariants** ([`IntegrityPolicy`]): every scale
//!   result is checked against what *any* correct backend could produce —
//!   window coordinates inside the scale's score map, candidate counts
//!   bounded by the NMS block count, scores inside the bound implied by
//!   the stage-I weights — and every finished response against the
//!   response contract (≤ k proposals, descending scores, boxes inside
//!   the frame). A violation aborts the request with the typed
//!   `ResponseError::Corrupt`, which the retry machinery treats as
//!   retryable-on-another-shard: validated corruption never reaches a
//!   caller.
//! * **Ring 2 — golden-probe audits** ([`Auditor`]): structural checks
//!   cannot see a *plausible* wrong answer (a bit flip that lands inside
//!   all bounds), so a deterministic 1-in-N sampler re-executes audited
//!   requests through the `ScoreKernel::Reference` scalar path and
//!   compares bitwise. A mismatch is heavily weighted against the serving
//!   shard's circuit breaker, and — when a SIMD kernel produced the
//!   answer — latches a one-way fleet-wide demotion to the SWAR scalar
//!   kernel ([`crate::simd::demote_to_swar`]), trading throughput for
//!   provable correctness until an operator intervenes.

use std::sync::Arc;

use crate::baseline::SoftwareBing;
use crate::bing::{Candidate, Proposal, Pyramid, Stage1Weights};
use crate::config::NMS_BLOCK;
use crate::image::ImageRgb;
use crate::simd::ScoreKernel;
use crate::telemetry::ServeMetrics;

/// Universal |score| bound: no stage-I pass can exceed
/// `2 · 255 · 64 · 127` regardless of the weight vector. The factor 2
/// covers the binarized scorer's residual decomposition (`ŵ = w − r·𝟙`
/// gives `Σ|ŵᵢ| ≤ 2·Σ|wᵢ|`); 255 is the gradient ceiling; 64·127 bounds
/// `Σ|wᵢ|` for any `[[i8; 8]; 8]`. Fits comfortably in `i32`.
pub const MAX_SCORE_ABS_BOUND: i32 = 2 * 255 * 64 * 127;

/// A structural invariant a scale result or response failed. Carries
/// enough context to log a useful forensic line without the payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Violation {
    /// `scale_idx` outside the pyramid the policy was built for.
    ScaleOutOfRange { scale_idx: usize, n_scales: usize },
    /// A candidate tagged with a different scale than the task's.
    WrongScaleTag { expected: usize, got: usize },
    /// More candidates than the scale has NMS blocks.
    TooManyCandidates { scale_idx: usize, got: usize, cap: usize },
    /// A window origin outside the scale's score map.
    WindowOutOfBounds { scale_idx: usize, x: u16, y: u16, ow: usize, oh: usize },
    /// |score| beyond what the stage-I weights can produce.
    ScoreOutOfBounds { score: i32, bound: i32 },
    /// More proposals than the request asked for.
    TooManyProposals { got: usize, top_k: usize },
    /// Response scores not in descending order (index of the inversion).
    ScoresNotDescending { at: usize },
    /// A proposal box outside the original frame.
    BoxOutOfFrame { x1: u32, y1: u32, frame_w: usize, frame_h: usize },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Violation::ScaleOutOfRange { scale_idx, n_scales } => {
                write!(f, "scale index {scale_idx} out of range for {n_scales}-scale pyramid")
            }
            Violation::WrongScaleTag { expected, got } => {
                write!(f, "candidate tagged scale {got}, expected {expected}")
            }
            Violation::TooManyCandidates { scale_idx, got, cap } => {
                write!(f, "scale {scale_idx}: {got} candidates exceed the {cap}-block NMS cap")
            }
            Violation::WindowOutOfBounds { scale_idx, x, y, ow, oh } => {
                write!(f, "scale {scale_idx}: window ({x}, {y}) outside {ow}x{oh} score map")
            }
            Violation::ScoreOutOfBounds { score, bound } => {
                write!(f, "score {score} beyond the weight-implied bound ±{bound}")
            }
            Violation::TooManyProposals { got, top_k } => {
                write!(f, "{got} proposals exceed top_k = {top_k}")
            }
            Violation::ScoresNotDescending { at } => {
                write!(f, "proposal scores not descending at index {at}")
            }
            Violation::BoxOutOfFrame { x1, y1, frame_w, frame_h } => {
                write!(f, "box corner ({x1}, {y1}) outside {frame_w}x{frame_h} frame")
            }
        }
    }
}

impl std::error::Error for Violation {}

/// Structural invariant validators for one pyramid: what any correct
/// backend's output must look like, independent of image content. Cheap
/// enough to run on every scale task (a handful of compares per
/// candidate — noise next to resize + gradient + scoring).
#[derive(Debug, Clone)]
pub struct IntegrityPolicy {
    /// Per-scale score-map shapes `(oh, ow)`.
    shapes: Vec<(usize, usize)>,
    /// Per-scale NMS block counts (the candidate-count cap).
    caps: Vec<usize>,
    score_abs_bound: i32,
}

impl IntegrityPolicy {
    /// Policy with the universal weight-independent score bound
    /// ([`MAX_SCORE_ABS_BOUND`]) — zero false positives for any weights.
    pub fn new(pyramid: &Pyramid) -> Self {
        Self::with_score_bound(pyramid, MAX_SCORE_ABS_BOUND)
    }

    /// Policy with a caller-supplied |score| bound.
    pub fn with_score_bound(pyramid: &Pyramid, score_abs_bound: i32) -> Self {
        let shapes: Vec<_> = (0..pyramid.sizes.len()).map(|i| pyramid.score_shape(i)).collect();
        let caps = shapes
            .iter()
            .map(|&(oh, ow)| oh.div_ceil(NMS_BLOCK) * ow.div_ceil(NMS_BLOCK))
            .collect();
        Self { shapes, caps, score_abs_bound }
    }

    /// Policy with the tight bound for a concrete weight vector:
    /// `2 · 255 · Σ|wᵢ|` (the 2 covers the binarized residual path).
    pub fn tightened(pyramid: &Pyramid, weights: &Stage1Weights) -> Self {
        let sum_abs: i32 = weights.flat().iter().map(|&w| (w as i32).abs()).sum();
        Self::with_score_bound(pyramid, 2 * 255 * sum_abs)
    }

    /// The |score| bound this policy enforces.
    pub fn score_abs_bound(&self) -> i32 {
        self.score_abs_bound
    }

    /// Validate one scale task's output at the backend seam. Candidates
    /// arrive in block raster order (not ranked), so ordering is *not* an
    /// invariant here — that one belongs to [`Self::validate_response`].
    pub fn validate_scale(
        &self,
        scale_idx: usize,
        candidates: &[Candidate],
    ) -> Result<(), Violation> {
        let Some(&(oh, ow)) = self.shapes.get(scale_idx) else {
            return Err(Violation::ScaleOutOfRange { scale_idx, n_scales: self.shapes.len() });
        };
        let cap = self.caps[scale_idx];
        if candidates.len() > cap {
            return Err(Violation::TooManyCandidates {
                scale_idx,
                got: candidates.len(),
                cap,
            });
        }
        for c in candidates {
            if c.scale_idx != scale_idx {
                return Err(Violation::WrongScaleTag { expected: scale_idx, got: c.scale_idx });
            }
            if (c.x as usize) >= ow || (c.y as usize) >= oh {
                return Err(Violation::WindowOutOfBounds { scale_idx, x: c.x, y: c.y, ow, oh });
            }
            if c.score.unsigned_abs() > self.score_abs_bound as u32 {
                return Err(Violation::ScoreOutOfBounds {
                    score: c.score,
                    bound: self.score_abs_bound,
                });
            }
        }
        Ok(())
    }

    /// Validate a finished response against the request contract: at most
    /// `top_k` proposals, scores descending, every box inside the frame.
    pub fn validate_response(
        proposals: &[Proposal],
        top_k: usize,
        frame_w: usize,
        frame_h: usize,
    ) -> Result<(), Violation> {
        if proposals.len() > top_k {
            return Err(Violation::TooManyProposals { got: proposals.len(), top_k });
        }
        for (i, p) in proposals.iter().enumerate() {
            if i > 0 && p.score > proposals[i - 1].score {
                return Err(Violation::ScoresNotDescending { at: i });
            }
            if p.bbox.x1 as usize >= frame_w
                || p.bbox.y1 as usize >= frame_h
                || p.bbox.x0 > p.bbox.x1
                || p.bbox.y0 > p.bbox.y1
            {
                return Err(Violation::BoxOutOfFrame {
                    x1: p.bbox.x1,
                    y1: p.bbox.y1,
                    frame_w,
                    frame_h,
                });
            }
        }
        Ok(())
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    bytes.iter().fold(h, |h, &b| (h ^ b as u64).wrapping_mul(FNV_PRIME))
}

/// FNV-1a checksum over a candidate slice — a cheap fingerprint for
/// logging, audit comparison and cross-shard result attestation.
pub fn checksum_candidates(candidates: &[Candidate]) -> u64 {
    candidates.iter().fold(FNV_OFFSET, |h, c| {
        let h = fnv1a(h, &(c.scale_idx as u32).to_le_bytes());
        let h = fnv1a(h, &c.x.to_le_bytes());
        let h = fnv1a(h, &c.y.to_le_bytes());
        fnv1a(h, &c.score.to_le_bytes())
    })
}

/// FNV-1a checksum over a response's proposals (bit pattern of the f32
/// score, so it distinguishes everything `==` distinguishes and more).
pub fn checksum_proposals(proposals: &[Proposal]) -> u64 {
    proposals.iter().fold(FNV_OFFSET, |h, p| {
        let h = fnv1a(h, &p.bbox.x0.to_le_bytes());
        let h = fnv1a(h, &p.bbox.y0.to_le_bytes());
        let h = fnv1a(h, &p.bbox.x1.to_le_bytes());
        let h = fnv1a(h, &p.bbox.y1.to_le_bytes());
        fnv1a(h, &p.score.to_bits().to_le_bytes())
    })
}

/// The golden-probe auditor: deterministic 1-in-N sampling of served
/// proposal responses, re-executed through the scalar
/// `ScoreKernel::Reference` oracle and compared bitwise.
///
/// The determinism mirrors the fault layer's: whether a request is
/// audited is a pure function of its admission ordinal, so audit
/// coverage reproduces run to run and costs exactly `1/rate` extra
/// backend work.
pub struct Auditor {
    /// Audit every `rate`-th request (0 = disabled; see `should_audit`).
    rate: u64,
    /// The fault-free scalar oracle (Reference kernel, no chaos wrapper).
    oracle: Arc<SoftwareBing>,
    /// The kernel the production path scores with — a mismatch implicates
    /// it when it is a multi-lane SIMD kernel.
    production_kernel: ScoreKernel,
    demote_on_mismatch: bool,
    metrics: Arc<ServeMetrics>,
}

impl Auditor {
    pub fn new(
        oracle: Arc<SoftwareBing>,
        rate: u64,
        production_kernel: ScoreKernel,
        demote_on_mismatch: bool,
        metrics: Arc<ServeMetrics>,
    ) -> Self {
        Self { rate, oracle, production_kernel, demote_on_mismatch, metrics }
    }

    /// Deterministic sampler: audit the requests whose admission ordinal
    /// is ≡ 0 (mod rate). Rate 0 disables auditing entirely.
    pub fn should_audit(&self, ordinal: u64) -> bool {
        self.rate > 0 && ordinal % self.rate == 0
    }

    /// Re-execute `img` through the reference oracle and compare the
    /// served proposals bitwise. Returns `true` on a clean match.
    ///
    /// On mismatch: tally `audit_mismatches`, and — when the production
    /// kernel is multi-lane SIMD and demotion is enabled — latch the
    /// fleet-wide SWAR demotion (tallying `kernel_demotions` exactly once
    /// across the fleet). The caller is responsible for weighting the
    /// outcome against its shard's circuit breaker.
    pub fn audit(&self, img: &ImageRgb, top_k: usize, served: &[Proposal]) -> bool {
        self.metrics.audits_run.inc();
        let expected = self.oracle.propose(img, top_k);
        if checksum_proposals(&expected) == checksum_proposals(served) && expected == served {
            return true;
        }
        self.metrics.audit_mismatches.inc();
        eprintln!(
            "integrity: golden-probe mismatch (kernel {}, served {} vs expected {} proposals)",
            self.production_kernel.name(),
            served.len(),
            expected.len(),
        );
        if self.demote_on_mismatch && self.production_kernel.lanes() > 1 {
            self.record_simd_mismatch();
        }
        false
    }

    /// Latch the fleet-wide kernel demotion for a mismatch implicating a
    /// SIMD kernel (split out so tests can drive it without an image).
    pub fn record_simd_mismatch(&self) {
        if crate::simd::demote_to_swar() {
            self.metrics.kernel_demotions.inc();
            eprintln!(
                "integrity: demoting kernel {} fleet-wide to swar after audit mismatch",
                self.production_kernel.name()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::ScoringMode;
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;
    use crate::svm::Stage2Calibration;

    fn sizes() -> Vec<(usize, usize)> {
        vec![(16, 16), (32, 32)]
    }

    fn software() -> Arc<SoftwareBing> {
        Arc::new(SoftwareBing::new(
            Pyramid::new(sizes()),
            default_stage1(),
            Stage2Calibration::identity(sizes()),
            ScoringMode::Exact,
        ))
    }

    #[test]
    fn clean_backend_output_passes_scale_validation() {
        use crate::backend::ProposalBackend;
        let sw = software();
        let policy = IntegrityPolicy::new(&Pyramid::new(sizes()));
        let tight = IntegrityPolicy::tightened(&Pyramid::new(sizes()), &default_stage1());
        assert!(tight.score_abs_bound() <= policy.score_abs_bound());
        for i in 0..4 {
            let img = SyntheticDataset::voc_like_val(4).sample(i).image;
            for scale in 0..2 {
                let out = sw.scale_candidates(&img, scale).unwrap();
                policy.validate_scale(scale, &out.candidates).unwrap();
                tight.validate_scale(scale, &out.candidates).unwrap();
            }
        }
    }

    #[test]
    fn every_corruption_style_is_caught() {
        let policy = IntegrityPolicy::new(&Pyramid::new(sizes()));
        let clean = Candidate { scale_idx: 0, x: 2, y: 3, score: 1000 };
        assert!(policy.validate_scale(0, &[clean]).is_ok());
        let styles = [
            Candidate { score: i32::MAX - 7, ..clean },
            Candidate { score: -(MAX_SCORE_ABS_BOUND + 1), ..clean },
            Candidate { x: u16::MAX - 3, ..clean },
            Candidate { y: u16::MAX, ..clean },
            Candidate { scale_idx: 1, ..clean },
        ];
        for bad in styles {
            assert!(policy.validate_scale(0, &[clean, bad]).is_err(), "{bad:?} slipped through");
        }
        // count cap: a 16x16 scale has a 9x9 score map → ceil(9/5)^2 = 4 blocks
        let flood = vec![clean; 5];
        assert_eq!(
            policy.validate_scale(0, &flood),
            Err(Violation::TooManyCandidates { scale_idx: 0, got: 5, cap: 4 })
        );
        assert!(matches!(
            policy.validate_scale(9, &[]),
            Err(Violation::ScaleOutOfRange { scale_idx: 9, n_scales: 2 })
        ));
    }

    #[test]
    fn injected_corruption_never_passes_validation() {
        use crate::backend::ProposalBackend;
        use crate::fault::{ChaosBackend, FaultPlan};
        let policy = IntegrityPolicy::new(&Pyramid::new(sizes()));
        for seed in 0..16u64 {
            let chaos = ChaosBackend::new(
                software(),
                FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(seed) },
            );
            let img = SyntheticDataset::voc_like_val(1).sample(0).image;
            for scale in 0..2 {
                let out = chaos.scale_candidates(&img, scale).unwrap();
                assert!(
                    policy.validate_scale(scale, &out.candidates).is_err(),
                    "seed {seed} scale {scale}: corruption passed validation"
                );
            }
        }
    }

    #[test]
    fn response_contract_checks_order_count_and_frame() {
        use crate::bing::BBox;
        let p = |score: f32| Proposal { bbox: BBox { x0: 0, y0: 0, x1: 9, y1: 9 }, score };
        let ok = vec![p(3.0), p(2.0), p(2.0), p(1.0)];
        assert!(IntegrityPolicy::validate_response(&ok, 4, 32, 32).is_ok());
        assert_eq!(
            IntegrityPolicy::validate_response(&ok, 3, 32, 32),
            Err(Violation::TooManyProposals { got: 4, top_k: 3 })
        );
        let unsorted = vec![p(1.0), p(2.0)];
        assert_eq!(
            IntegrityPolicy::validate_response(&unsorted, 4, 32, 32),
            Err(Violation::ScoresNotDescending { at: 1 })
        );
        let out = vec![Proposal { bbox: BBox { x0: 0, y0: 0, x1: 40, y1: 9 }, score: 1.0 }];
        assert!(matches!(
            IntegrityPolicy::validate_response(&out, 4, 32, 32),
            Err(Violation::BoxOutOfFrame { .. })
        ));
        assert!(IntegrityPolicy::validate_response(&[], 0, 32, 32).is_ok());
    }

    #[test]
    fn checksums_fingerprint_every_field() {
        let base = vec![Candidate { scale_idx: 0, x: 1, y: 2, score: 30 }];
        let h0 = checksum_candidates(&base);
        assert_eq!(h0, checksum_candidates(&base), "checksum must be deterministic");
        for mutant in [
            vec![Candidate { scale_idx: 1, ..base[0] }],
            vec![Candidate { x: 9, ..base[0] }],
            vec![Candidate { y: 9, ..base[0] }],
            vec![Candidate { score: 31, ..base[0] }],
            vec![],
        ] {
            assert_ne!(h0, checksum_candidates(&mutant), "{mutant:?} collided");
        }
        use crate::bing::BBox;
        let props = vec![Proposal { bbox: BBox { x0: 0, y0: 0, x1: 5, y1: 5 }, score: 1.5 }];
        let hp = checksum_proposals(&props);
        let mut shifted = props.clone();
        shifted[0].score = 1.5000001;
        assert_ne!(hp, checksum_proposals(&shifted), "f32 bit pattern must matter");
    }

    #[test]
    fn auditor_matches_clean_serving_and_flags_perturbations() {
        let sw = software();
        let metrics = Arc::new(ServeMetrics::default());
        let auditor = Auditor::new(
            sw.clone(),
            2,
            ScoreKernel::Reference,
            true,
            metrics.clone(),
        );
        assert!(auditor.should_audit(0));
        assert!(!auditor.should_audit(1));
        assert!(auditor.should_audit(2));
        let off = Auditor::new(sw.clone(), 0, ScoreKernel::Reference, true, metrics.clone());
        assert!(!off.should_audit(0), "rate 0 disables audits");

        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let served = sw.propose(&img, 16);
        assert!(auditor.audit(&img, 16, &served), "clean serving must pass the audit");
        assert_eq!(metrics.audits_run.get(), 1);
        assert_eq!(metrics.audit_mismatches.get(), 0);

        let mut tampered = served.clone();
        tampered[0].score += 0.25;
        assert!(!auditor.audit(&img, 16, &tampered));
        assert_eq!(metrics.audits_run.get(), 2);
        assert_eq!(metrics.audit_mismatches.get(), 1);
        // Reference is single-lane: a mismatch must NOT demote the fleet
        assert_eq!(metrics.kernel_demotions.get(), 0);
    }
}
