//! Scale pyramid: the preset resize ratios and the window→box mapping.

use super::WIN;

/// A bounding box in original-image pixel coordinates (inclusive).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BBox {
    pub x0: u32,
    pub y0: u32,
    pub x1: u32,
    pub y1: u32,
}

impl BBox {
    pub fn width(&self) -> u32 {
        self.x1 - self.x0 + 1
    }

    pub fn height(&self) -> u32 {
        self.y1 - self.y0 + 1
    }

    pub fn area(&self) -> u64 {
        self.width() as u64 * self.height() as u64
    }
}

/// The pyramid of preset resized sizes `(h, w)` and the geometry helpers.
#[derive(Debug, Clone)]
pub struct Pyramid {
    pub sizes: Vec<(usize, usize)>,
}

impl Pyramid {
    pub fn new(sizes: Vec<(usize, usize)>) -> Self {
        assert!(!sizes.is_empty(), "pyramid must have at least one scale");
        for &(h, w) in &sizes {
            assert!(h >= WIN && w >= WIN, "scale {h}x{w} smaller than the window");
        }
        Self { sizes }
    }

    /// Score-map shape `(oh, ow)` for scale `idx`.
    pub fn score_shape(&self, idx: usize) -> (usize, usize) {
        let (h, w) = self.sizes[idx];
        (h - WIN + 1, w - WIN + 1)
    }

    /// Total NMS blocks across all scales — an upper bound on candidates per
    /// image, used to size coordinator buffers.
    pub fn max_candidates(&self) -> usize {
        use crate::config::NMS_BLOCK;
        (0..self.sizes.len())
            .map(|i| {
                let (oh, ow) = self.score_shape(i);
                oh.div_ceil(NMS_BLOCK) * ow.div_ceil(NMS_BLOCK)
            })
            .sum()
    }
}

/// Map a window at score-map position `(x, y)` in scale `(sh, sw)` back to a
/// box in the original `(orig_w, orig_h)` image.
///
/// Pure integer math (floor for the origin, ceiling for the far edge) so the
/// mapping is platform-deterministic:
/// `x0 = x·W0/sw`, `x1 = min(⌈(x+8)·W0/sw⌉ − 1, W0−1)`, same for y.
pub fn window_to_box(
    x: u16,
    y: u16,
    scale: (usize, usize),
    orig_w: usize,
    orig_h: usize,
) -> BBox {
    let (sh, sw) = scale;
    let x0 = x as usize * orig_w / sw;
    let y0 = y as usize * orig_h / sh;
    let x1 = (((x as usize + WIN) * orig_w).div_ceil(sw) - 1).min(orig_w - 1);
    let y1 = (((y as usize + WIN) * orig_h).div_ceil(sh) - 1).min(orig_h - 1);
    BBox {
        x0: x0 as u32,
        y0: y0 as u32,
        x1: x1.max(x0) as u32,
        y1: y1.max(y0) as u32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_scale_maps_window_exactly() {
        // resized == original: the box is the window itself
        let b = window_to_box(3, 5, (32, 32), 32, 32);
        assert_eq!(b, BBox { x0: 3, y0: 5, x1: 10, y1: 12 });
    }

    #[test]
    fn half_scale_doubles_box() {
        let b = window_to_box(0, 0, (16, 16), 32, 32);
        assert_eq!(b, BBox { x0: 0, y0: 0, x1: 15, y1: 15 });
    }

    #[test]
    fn far_corner_stays_in_bounds() {
        // last window position: x = ow-1 = sw-8
        let b = window_to_box(8, 8, (16, 16), 100, 50);
        assert!(b.x1 <= 99 && b.y1 <= 49);
        assert_eq!(b.x1, 99);
        assert_eq!(b.y1, 49);
    }

    #[test]
    fn boxes_never_degenerate() {
        for &(sh, sw) in &[(16usize, 16usize), (16, 128), (128, 16)] {
            for y in [0u16, 4, (sh - 8) as u16] {
                for x in [0u16, 4, (sw - 8) as u16] {
                    let b = window_to_box(x, y, (sh, sw), 193, 97);
                    assert!(b.x1 >= b.x0 && b.y1 >= b.y0);
                    assert!(b.x1 < 193 && b.y1 < 97);
                }
            }
        }
    }

    #[test]
    fn score_shape_and_max_candidates() {
        let p = Pyramid::new(vec![(16, 16), (32, 64)]);
        assert_eq!(p.score_shape(0), (9, 9));
        assert_eq!(p.score_shape(1), (25, 57));
        // (2*2) + (5*12) = 64
        assert_eq!(p.max_candidates(), 64);
    }

    #[test]
    #[should_panic(expected = "smaller than the window")]
    fn rejects_tiny_scale() {
        let _ = Pyramid::new(vec![(4, 16)]);
    }
}
