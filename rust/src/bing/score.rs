//! SVM-I scoring: dense 8×8 sliding-window dot products over the gradient map.

use super::{Stage1Weights, WIN};
use crate::image::ImageGray;

/// A dense score map in the integer semantics (`i32` accumulators), with the
/// row-major layout the NMS/candidate stages expect.
///
/// `Default` is the empty 0×0 map — the starting state of a reusable output
/// buffer for the `*_into` scorers.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ScoreMap {
    pub w: usize,
    pub h: usize,
    pub data: Vec<i32>, // len == w * h
}

impl ScoreMap {
    #[inline]
    pub fn get(&self, x: usize, y: usize) -> i32 {
        self.data[y * self.w + x]
    }
}

/// Compute the stage-I score map: `s(y,x) = Σ_{dy,dx} G[y+dy, x+dx]·w[dy,dx]`.
///
/// Output shape `(h−7, w−7)`. Bit-exact twin of
/// `python/compile/kernels/ref.py::svm_window` (integer-valued f32 there,
/// i32 here — identical values by the representability argument in
/// `python/compile/common.py`).
pub fn score_map(g: &ImageGray, weights: &Stage1Weights) -> ScoreMap {
    let mut out = ScoreMap::default();
    score_map_into(g, weights, &mut out);
    out
}

/// [`score_map`] writing into a reusable output buffer (the scratch-arena
/// variant: steady-state serving re-scores without heap allocation).
pub fn score_map_into(g: &ImageGray, weights: &Stage1Weights, out: &mut ScoreMap) {
    assert!(g.w >= WIN && g.h >= WIN, "image smaller than the 8x8 window");
    let ow = g.w - WIN + 1;
    let oh = g.h - WIN + 1;
    out.w = ow;
    out.h = oh;
    out.data.clear();
    out.data.resize(ow * oh, 0);
    // Row-banded accumulation: for each window row dy, add the 1x8 partial
    // products into every affected output row. This is the same
    // "G_{1x8} rows compose G_{8x8}" decomposition the paper pipelines.
    for y in 0..oh {
        let out_row = &mut out.data[y * ow..(y + 1) * ow];
        for dy in 0..WIN {
            let g_row = &g.data[(y + dy) * g.w..(y + dy) * g.w + g.w];
            let w_row = &weights.w[dy];
            // windows(WIN) yields exactly `ow` windows; iterator zips elide
            // all bounds checks and let the 8-wide MAC vectorize
            // (perf-pass change #4, EXPERIMENTS.md §Perf).
            for (o, win) in out_row.iter_mut().zip(g_row.windows(WIN)) {
                let mut acc = 0i32;
                for (g8, w8) in win.iter().zip(w_row.iter()) {
                    acc += *g8 as i32 * *w8 as i32;
                }
                *o += acc;
            }
        }
    }
}

/// Stage-I scoring with arbitrary i32 weights — the *high-precision*
/// reference path used by the quantization ablation (Fig. 5): float-trained
/// weights are carried at 1/1024 resolution (`round(w·1024)`), which is
/// numerically indistinguishable from float scoring for ranking purposes,
/// while staying in the integer semantics.
pub fn score_map_i32(g: &ImageGray, weights: &[[i32; 8]; 8]) -> ScoreMap {
    let mut out = ScoreMap::default();
    score_map_i32_into(g, weights, &mut out);
    out
}

/// [`score_map_i32`] writing into a reusable output buffer.
pub fn score_map_i32_into(g: &ImageGray, weights: &[[i32; 8]; 8], out: &mut ScoreMap) {
    assert!(g.w >= WIN && g.h >= WIN, "image smaller than the 8x8 window");
    let ow = g.w - WIN + 1;
    let oh = g.h - WIN + 1;
    out.w = ow;
    out.h = oh;
    out.data.clear();
    out.data.resize(ow * oh, 0);
    for y in 0..oh {
        let out_row = &mut out.data[y * ow..(y + 1) * ow];
        for dy in 0..WIN {
            let g_row = &g.data[(y + dy) * g.w..(y + dy) * g.w + g.w];
            let w_row = &weights[dy];
            for (x, o) in out_row.iter_mut().enumerate() {
                let mut acc = 0i32;
                for dx in 0..WIN {
                    acc += g_row[x + dx] as i32 * w_row[dx];
                }
                *o += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::{default_stage1, gradient_map};
    use crate::image::ImageRgb;

    /// Straightforward quadruple-loop oracle for the oracle :-) — a
    /// deliberately naive implementation to pin the banded one.
    fn naive_score(g: &ImageGray, w: &Stage1Weights) -> ScoreMap {
        let ow = g.w - 7;
        let oh = g.h - 7;
        let mut data = vec![0i32; ow * oh];
        for y in 0..oh {
            for x in 0..ow {
                let mut acc = 0i32;
                for dy in 0..8 {
                    for dx in 0..8 {
                        acc += g.get(x + dx, y + dy) as i32 * w.w[dy][dx] as i32;
                    }
                }
                data[y * ow + x] = acc;
            }
        }
        ScoreMap { w: ow, h: oh, data }
    }

    #[test]
    fn matches_naive_on_structured_image() {
        let img = ImageRgb::from_fn(24, 20, |x, y| {
            if (8..16).contains(&x) && (6..14).contains(&y) {
                [220, 40, 90]
            } else {
                [((x * 13 + y * 7) % 256) as u8, 100, 50]
            }
        });
        let g = gradient_map(&img);
        let w = default_stage1();
        assert_eq!(score_map(&g, &w), naive_score(&g, &w));
    }

    #[test]
    fn output_shape() {
        let img = ImageRgb::new(16, 32);
        let s = score_map(&gradient_map(&img), &default_stage1());
        assert_eq!((s.w, s.h), (9, 25));
    }

    #[test]
    fn flat_image_scores_zero() {
        let img = ImageRgb::from_fn(16, 16, |_, _| [9, 9, 9]);
        let s = score_map(&gradient_map(&img), &default_stage1());
        assert!(s.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn score_bound_respected() {
        // |score| <= 64 * 255 * 12 (the f32-exactness bound)
        let img = ImageRgb::from_fn(32, 32, |x, y| {
            if (x + y) % 2 == 0 { [0, 0, 0] } else { [255, 255, 255] }
        });
        let s = score_map(&gradient_map(&img), &default_stage1());
        for &v in &s.data {
            assert!(v.abs() <= 64 * 255 * 12);
        }
    }

    #[test]
    #[should_panic(expected = "smaller than")]
    fn too_small_panics() {
        let img = ImageRgb::new(7, 16);
        let _ = score_map(&gradient_map(&img), &default_stage1());
    }
}
