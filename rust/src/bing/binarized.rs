//! The *binarized* scoring path that gives BING its name: approximate the
//! stage-I template with `Nw` binary basis vectors and the gradient with its
//! top `Ng` bits, so each 64-d window dot product becomes a handful of
//! popcounts on u64 words (Cheng et al. §3, "BING" ≈ binarized normed
//! gradients).
//!
//! This is the trick that lets the *CPU baseline* reach its published speed;
//! the FPGA datapath computes the exact dot product instead (DSP MACs are
//! cheap in hardware), which is why the accelerator and this module coexist.
//!
//! Two scorer implementations live here (EXPERIMENTS.md §Perf):
//!
//! * [`BinarizedScorer::score_map`] / [`BinarizedScorer::score_map_into`] —
//!   the incremental fast path. Gradient bits are packed **once** into
//!   per-bit-plane column streams, and the 8×8 window's plane words are
//!   maintained as the window slides (`word = word >> 8 | incoming_column`),
//!   the software analogue of the paper's line-buffer reuse. Per-pixel cost
//!   is O(ng·(nw+1)) popcounts instead of a 64-read repack.
//! * [`BinarizedScorer::score_map_reference`] — the original per-pixel
//!   repack, retained as the bit-exactness oracle for tests and for the
//!   before/after rows in `benches/hotpath.rs`.
//!
//! Both produce bit-identical maps: the fast path stores window words in a
//! *column-major* bit layout (bit `dx·8+dy` instead of `dy·8+dx`) so the
//! slide is two shifts, and applies the same permutation to the basis masks —
//! popcounts are invariant under a common bit permutation, and the integer
//! accumulation order is unchanged.

use super::{ScoreMap, Stage1Weights, WIN};
use crate::image::ImageGray;
use crate::simd::{self, ScoreKernel};

/// One binary basis vector: `b ∈ {−1, +1}^64` packed as the +1 positions.
#[derive(Debug, Clone, Copy)]
pub struct BinaryBasis {
    /// bit i set ⇔ b_i = +1 (row-major 8×8 layout, bit = dy*8+dx).
    pub plus: u64,
    /// coefficient β_j (kept in integer micro-units for determinism).
    pub beta_milli: i32,
}

/// Greedy binary decomposition `w ≈ Σ_j β_j·b_j` (Cheng et al., Alg. 1).
///
/// Returns `nw` basis vectors; the residual shrinks monotonically. β is
/// quantized to 1/1024 units so the scorer stays integer-only.
pub fn binarize_weights(w: &Stage1Weights, nw: usize) -> Vec<BinaryBasis> {
    let mut residual: Vec<f64> = w.flat().iter().map(|&v| v as f64).collect();
    let mut out = Vec::with_capacity(nw);
    for _ in 0..nw {
        let mut plus = 0u64;
        for (i, &r) in residual.iter().enumerate() {
            if r >= 0.0 {
                plus |= 1u64 << i;
            }
        }
        // β = <residual, b> / ||b||² = Σ|residual_i| / 64
        let beta: f64 = residual.iter().map(|r| r.abs()).sum::<f64>() / 64.0;
        let beta_milli = (beta * 1024.0).round() as i32;
        for (i, r) in residual.iter_mut().enumerate() {
            let b = if plus >> i & 1 == 1 { 1.0 } else { -1.0 };
            *r -= beta * b;
        }
        out.push(BinaryBasis { plus, beta_milli });
    }
    out
}

/// Transpose an 8×8 bit matrix between the row-major window layout
/// (bit = dy·8+dx) and the column-major one (bit = dx·8+dy).
fn transpose_bits(rm: u64) -> u64 {
    let mut cm = 0u64;
    for dy in 0..8 {
        for dx in 0..8 {
            if rm >> (dy * 8 + dx) & 1 == 1 {
                cm |= 1u64 << (dx * 8 + dy);
            }
        }
    }
    cm
}

/// Reusable packing buffers for [`BinarizedScorer::score_map_into`] — part of
/// the per-scale scratch arena ([`crate::baseline::ScaleScratch`]), so
/// steady-state serving re-scores without heap allocation.
#[derive(Debug, Default)]
pub struct BinarizedScratch {
    /// Column-major bit-plane streams: plane `k`, column `x` occupy
    /// `stride = ceil(h/8) + 1` bytes at `(k·w + x)·stride`; bit `j` of byte
    /// `b` is the plane bit of gradient row `8b + j`. The padding byte per
    /// column lets the scorer read 8 vertical bits as an unaligned u16
    /// without bounds branches. (Re-laid-out on every packing; only the
    /// allocation is reused.)
    cols: Vec<u8>,
    /// One output row's column bytes, contiguous per plane (plane `k` at
    /// `rowbuf[k·w ..]`) — the vector kernels' staging buffer: the window
    /// word of window `x` is then a plain unaligned u64 load at offset `x`,
    /// so 4 (AVX2) / 2 (NEON) adjacent windows are overlapping loads of the
    /// same cache lines.
    rowbuf: Vec<u8>,
}

/// Bitwise stage-I scorer: gradient approximated by its top `ng` bits,
/// weights by `nw` binary bases.
///
/// `score ≈ Σ_k 2^{7−k} Σ_j β_j · (2·popcount(B_kw ∧ b_j⁺) − 64 + …)` — the
/// standard BING identity `<b, x> = 2·popcount(x ∧ b⁺) − Σx` adapted to bit
/// planes; all integer arithmetic in milli-β units.
#[derive(Debug)]
pub struct BinarizedScorer {
    /// Bases in the row-major window layout (reference path).
    bases: Vec<BinaryBasis>,
    /// The same bases with `plus` transposed to column-major (fast path).
    bases_cm: Vec<BinaryBasis>,
    ng: usize,
}

impl BinarizedScorer {
    /// `nw` binary weight bases (paper/BING default 2), `ng` gradient bit
    /// planes (BING default 4).
    pub fn new(weights: &Stage1Weights, nw: usize, ng: usize) -> Self {
        assert!(ng >= 1 && ng <= 8);
        let bases = binarize_weights(weights, nw);
        let bases_cm = bases
            .iter()
            .map(|b| BinaryBasis { plus: transpose_bits(b.plus), beta_milli: b.beta_milli })
            .collect();
        Self { bases, bases_cm, ng }
    }

    /// Approximate score map (same shape contract as [`super::score_map`]).
    /// Scores are in the same scale as the exact map (milli-β rescaled back),
    /// so ranking quality is directly comparable.
    ///
    /// Allocating convenience over [`Self::score_map_into`]; bit-identical to
    /// [`Self::score_map_reference`].
    pub fn score_map(&self, g: &ImageGray) -> ScoreMap {
        let mut scratch = BinarizedScratch::default();
        let mut out = ScoreMap::default();
        self.score_map_into(g, &mut scratch, &mut out);
        out
    }

    /// Incremental scorer writing into reusable storage: packs the gradient's
    /// top `ng` bit planes into column streams once, then slides the 8×8
    /// window across each output row updating the per-plane u64 words with a
    /// shift + one incoming column byte per step.
    pub fn score_map_into(
        &self,
        g: &ImageGray,
        scratch: &mut BinarizedScratch,
        out: &mut ScoreMap,
    ) {
        let (ow, oh) = Self::out_shape(g, out);
        let ng = self.ng;
        let stride = self.pack_planes(g, scratch);

        // Score phase. `colbyte` reads the 8 vertical plane bits of rows
        // y..y+8 in column x (the padding byte makes base+1 always valid).
        let cols = &scratch.cols;
        let colbyte = |k: usize, x: usize, y: usize| -> u64 {
            let base = (k * g.w + x) * stride + (y >> 3);
            let b = cols[base] as u16 | (cols[base + 1] as u16) << 8;
            (b >> (y & 7)) as u64 & 0xff
        };
        let mut planes = [0u64; 8];
        for y in 0..oh {
            // Window word for x=0: eight column bytes, column dx in byte dx.
            for (k, plane) in planes.iter_mut().enumerate().take(ng) {
                let mut word = 0u64;
                for dx in 0..WIN {
                    word |= colbyte(k, dx, y) << (8 * dx);
                }
                *plane = word;
            }
            for x in 0..ow {
                if x > 0 {
                    // Slide right: drop column x−1, append column x+7.
                    for (k, plane) in planes.iter_mut().enumerate().take(ng) {
                        *plane = (*plane >> 8) | colbyte(k, x + WIN - 1, y) << 56;
                    }
                }
                let mut acc_milli = 0i64;
                for k in 0..ng {
                    let plane = planes[k];
                    let ones = plane.count_ones() as i64;
                    let mut plane_score = 0i64; // in milli-β units
                    for b in &self.bases_cm {
                        let pop = (plane & b.plus).count_ones() as i64;
                        // <b, plane_bits> where plane bit=1 contributes b_i
                        let dot = 2 * pop - ones;
                        plane_score += b.beta_milli as i64 * dot;
                    }
                    acc_milli += plane_score << (7 - k);
                }
                out.data[y * ow + x] = (acc_milli / 1024) as i32;
            }
        }
    }

    /// Kernel-dispatched scorer (the `--kernel` seam): same contract as
    /// [`Self::score_map_into`], with the score phase executed by the
    /// selected [`ScoreKernel`]. All kernels are bit-identical (asserted by
    /// the property tests in [`crate::simd`]); an unavailable vector kernel
    /// degrades to the SWAR path rather than failing.
    pub fn score_map_into_with(
        &self,
        g: &ImageGray,
        scratch: &mut BinarizedScratch,
        out: &mut ScoreMap,
        kernel: ScoreKernel,
    ) {
        match kernel {
            ScoreKernel::Reference => {
                let reference = self.score_map_reference(g);
                out.w = reference.w;
                out.h = reference.h;
                out.data.clear();
                out.data.extend_from_slice(&reference.data);
            }
            ScoreKernel::Swar => self.score_map_into(g, scratch, out),
            vector if !vector.is_available() => self.score_map_into(g, scratch, out),
            vector => self.score_map_vector(g, scratch, out, vector),
        }
    }

    /// Shared shape contract of every scoring path.
    fn out_shape(g: &ImageGray, out: &mut ScoreMap) -> (usize, usize) {
        assert!(g.w >= WIN && g.h >= WIN, "image smaller than the 8x8 window");
        let ow = g.w - WIN + 1;
        let oh = g.h - WIN + 1;
        out.w = ow;
        out.h = oh;
        out.data.clear();
        out.data.resize(ow * oh, 0);
        (ow, oh)
    }

    /// Pack phase shared by the SWAR and vector score phases: one pass over
    /// the gradient map filling the column bit-plane streams. Plane k holds
    /// bit (7−k) of each gradient value, so plane 0 is the most significant.
    /// Returns the per-column byte stride.
    fn pack_planes(&self, g: &ImageGray, scratch: &mut BinarizedScratch) -> usize {
        let ng = self.ng;
        let stride = g.h.div_ceil(8) + 1;
        scratch.cols.clear();
        scratch.cols.resize(ng * g.w * stride, 0);
        let cols = &mut scratch.cols;
        for y in 0..g.h {
            let (byte, bit) = (y >> 3, (y & 7) as u32);
            let row = &g.data[y * g.w..(y + 1) * g.w];
            for (x, &v) in row.iter().enumerate() {
                if v == 0 {
                    continue; // borders and flat regions skip all planes
                }
                for k in 0..ng {
                    if v >> (7 - k) & 1 == 1 {
                        cols[(k * g.w + x) * stride + byte] |= 1 << bit;
                    }
                }
            }
        }
        stride
    }

    /// Vector score phase: per output row, stage each plane's column bytes
    /// contiguously in `scratch.rowbuf` (so adjacent windows' plane words
    /// are overlapping unaligned u64 loads), then hand the row to the
    /// multi-window kernel in [`crate::simd`].
    fn score_map_vector(
        &self,
        g: &ImageGray,
        scratch: &mut BinarizedScratch,
        out: &mut ScoreMap,
        kernel: ScoreKernel,
    ) {
        let (ow, oh) = Self::out_shape(g, out);
        let ng = self.ng;
        let stride = self.pack_planes(g, scratch);

        let rw = g.w; // row stride: the last window's word ends at byte w−1
        let BinarizedScratch { cols, rowbuf } = scratch;
        rowbuf.clear();
        rowbuf.resize(ng * rw, 0);
        let colbyte = |k: usize, x: usize, y: usize| -> u8 {
            let base = (k * g.w + x) * stride + (y >> 3);
            let b = cols[base] as u16 | (cols[base + 1] as u16) << 8;
            (b >> (y & 7)) as u8
        };
        for y in 0..oh {
            for k in 0..ng {
                let plane_row = &mut rowbuf[k * rw..k * rw + g.w];
                for (x, byte) in plane_row.iter_mut().enumerate() {
                    *byte = colbyte(k, x, y);
                }
            }
            simd::score_row(
                kernel,
                &self.bases_cm,
                ng,
                rowbuf,
                rw,
                &mut out.data[y * ow..(y + 1) * ow],
            );
        }
    }

    /// The original scorer: re-reads and re-packs all 64 window bits per
    /// output pixel. Retained as the reference oracle the incremental path is
    /// asserted bit-identical against (property test + hotpath bench rows).
    pub fn score_map_reference(&self, g: &ImageGray) -> ScoreMap {
        assert!(g.w >= WIN && g.h >= WIN, "image smaller than the 8x8 window");
        let ow = g.w - WIN + 1;
        let oh = g.h - WIN + 1;
        let mut data = vec![0i32; ow * oh];

        for y in 0..oh {
            for x in 0..ow {
                // pack the window's bit-planes
                let mut planes = [0u64; 8];
                for dy in 0..WIN {
                    let row = &g.data[(y + dy) * g.w + x..(y + dy) * g.w + x + WIN];
                    for (dx, &v) in row.iter().enumerate() {
                        let bit = dy * 8 + dx;
                        for k in 0..self.ng {
                            if v >> (7 - k) & 1 == 1 {
                                planes[k] |= 1u64 << bit;
                            }
                        }
                    }
                }
                let mut acc_milli = 0i64;
                for k in 0..self.ng {
                    let plane = planes[k];
                    let ones = plane.count_ones() as i64;
                    let mut plane_score = 0i64; // in milli-β units
                    for b in &self.bases {
                        let pop = (plane & b.plus).count_ones() as i64;
                        let dot = 2 * pop - ones;
                        plane_score += b.beta_milli as i64 * dot;
                    }
                    acc_milli += plane_score << (7 - k);
                }
                data[y * ow + x] = (acc_milli / 1024) as i32;
            }
        }
        ScoreMap { w: ow, h: oh, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::{default_stage1, gradient_map, score_map};
    use crate::image::ImageRgb;

    fn structured_image(w: usize, h: usize) -> ImageRgb {
        ImageRgb::from_fn(w, h, |x, y| {
            if (w / 4..3 * w / 4).contains(&x) && (h / 4..3 * h / 4).contains(&y) {
                [230, 30, 60]
            } else {
                [((x * 5 + y * 3) % 128) as u8, 90, 90]
            }
        })
    }

    #[test]
    fn binarization_reduces_residual() {
        let w = default_stage1();
        let flat: Vec<f64> = w.flat().iter().map(|&v| v as f64).collect();
        let norm0: f64 = flat.iter().map(|v| v * v).sum();
        for nw in 1..=4 {
            let bases = binarize_weights(&w, nw);
            // reconstruct
            let mut recon = vec![0f64; 64];
            for b in &bases {
                for (i, r) in recon.iter_mut().enumerate() {
                    let s = if b.plus >> i & 1 == 1 { 1.0 } else { -1.0 };
                    *r += b.beta_milli as f64 / 1024.0 * s;
                }
            }
            let err: f64 = flat
                .iter()
                .zip(&recon)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(err < norm0, "nw={nw} did not reduce residual");
            if nw >= 3 {
                assert!(err / norm0 < 0.35, "nw={nw} residual too large: {}", err / norm0);
            }
        }
    }

    #[test]
    fn transpose_bits_is_an_involution_and_moves_corners() {
        // bit (dy=0, dx=7) must land at (dx=7, dy=0) = bit 56
        assert_eq!(transpose_bits(1 << 7), 1 << 56);
        assert_eq!(transpose_bits(1 << 56), 1 << 7);
        // diagonal bits are fixed points
        assert_eq!(transpose_bits(1 << 27), 1 << 27); // dy=3, dx=3
        for seed in 0..32u64 {
            let v = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15);
            assert_eq!(transpose_bits(transpose_bits(v)), v);
            assert_eq!(transpose_bits(v).count_ones(), v.count_ones());
        }
    }

    #[test]
    fn incremental_matches_reference_on_structured_image() {
        let img = structured_image(48, 40);
        let g = gradient_map(&img);
        let w = default_stage1();
        for (nw, ng) in [(1, 1), (2, 4), (3, 6), (4, 8)] {
            let scorer = BinarizedScorer::new(&w, nw, ng);
            assert_eq!(
                scorer.score_map(&g),
                scorer.score_map_reference(&g),
                "nw={nw} ng={ng} diverged"
            );
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        let scorer = BinarizedScorer::new(&default_stage1(), 3, 6);
        let mut scratch = BinarizedScratch::default();
        let mut out = ScoreMap::default();
        // big → small → big again: stale packed bits must never leak through
        for (w, h) in [(48usize, 40usize), (16, 24), (8, 8), (48, 40)] {
            let g = gradient_map(&structured_image(w, h));
            scorer.score_map_into(&g, &mut scratch, &mut out);
            assert_eq!(out, scorer.score_map_reference(&g), "dirty scratch at {w}x{h}");
        }
    }

    #[test]
    fn approximate_scores_correlate_with_exact() {
        let img = structured_image(48, 48);
        let g = gradient_map(&img);
        let w = default_stage1();
        let exact = score_map(&g, &w);
        let approx = BinarizedScorer::new(&w, 3, 6).score_map(&g);
        // Pearson correlation over the map
        let n = exact.data.len() as f64;
        let (mut sx, mut sy, mut sxx, mut syy, mut sxy) = (0f64, 0f64, 0f64, 0f64, 0f64);
        for (&a, &b) in exact.data.iter().zip(&approx.data) {
            let (a, b) = (a as f64, b as f64);
            sx += a;
            sy += b;
            sxx += a * a;
            syy += b * b;
            sxy += a * b;
        }
        let cov = sxy / n - sx / n * (sy / n);
        let va = sxx / n - (sx / n) * (sx / n);
        let vb = syy / n - (sy / n) * (sy / n);
        let corr = cov / (va.sqrt() * vb.sqrt()).max(1e-9);
        assert!(corr > 0.9, "correlation too low: {corr}");
    }

    #[test]
    fn same_shape_as_exact() {
        let img = ImageRgb::new(16, 24);
        let g = gradient_map(&img);
        let s = BinarizedScorer::new(&default_stage1(), 2, 4).score_map(&g);
        assert_eq!((s.w, s.h), (9, 17));
    }
}
