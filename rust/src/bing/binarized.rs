//! The *binarized* scoring path that gives BING its name: approximate the
//! stage-I template with `Nw` binary basis vectors and the gradient with its
//! top `Ng` bits, so each 64-d window dot product becomes a handful of
//! popcounts on u64 words (Cheng et al. §3, "BING" ≈ binarized normed
//! gradients).
//!
//! This is the trick that lets the *CPU baseline* reach its published speed;
//! the FPGA datapath computes the exact dot product instead (DSP MACs are
//! cheap in hardware), which is why the accelerator and this module coexist.

use super::{ScoreMap, Stage1Weights, WIN};
use crate::image::ImageGray;

/// One binary basis vector: `b ∈ {−1, +1}^64` packed as the +1 positions.
#[derive(Debug, Clone, Copy)]
pub struct BinaryBasis {
    /// bit i set ⇔ b_i = +1 (row-major 8×8 layout, bit = dy*8+dx).
    pub plus: u64,
    /// coefficient β_j (kept in integer micro-units for determinism).
    pub beta_milli: i32,
}

/// Greedy binary decomposition `w ≈ Σ_j β_j·b_j` (Cheng et al., Alg. 1).
///
/// Returns `nw` basis vectors; the residual shrinks monotonically. β is
/// quantized to 1/1024 units so the scorer stays integer-only.
pub fn binarize_weights(w: &Stage1Weights, nw: usize) -> Vec<BinaryBasis> {
    let mut residual: Vec<f64> = w.flat().iter().map(|&v| v as f64).collect();
    let mut out = Vec::with_capacity(nw);
    for _ in 0..nw {
        let mut plus = 0u64;
        for (i, &r) in residual.iter().enumerate() {
            if r >= 0.0 {
                plus |= 1u64 << i;
            }
        }
        // β = <residual, b> / ||b||² = Σ|residual_i| / 64
        let beta: f64 = residual.iter().map(|r| r.abs()).sum::<f64>() / 64.0;
        let beta_milli = (beta * 1024.0).round() as i32;
        for (i, r) in residual.iter_mut().enumerate() {
            let b = if plus >> i & 1 == 1 { 1.0 } else { -1.0 };
            *r -= beta * b;
        }
        out.push(BinaryBasis { plus, beta_milli });
    }
    out
}

/// Bitwise stage-I scorer: gradient approximated by its top `ng` bits,
/// weights by `nw` binary bases.
///
/// `score ≈ Σ_k 2^{7−k} Σ_j β_j · (2·popcount(B_kw ∧ b_j⁺) − 64 + …)` — the
/// standard BING identity `<b, x> = 2·popcount(x ∧ b⁺) − Σx` adapted to bit
/// planes; all integer arithmetic in milli-β units.
#[derive(Debug)]
pub struct BinarizedScorer {
    bases: Vec<BinaryBasis>,
    ng: usize,
}

impl BinarizedScorer {
    /// `nw` binary weight bases (paper/BING default 2), `ng` gradient bit
    /// planes (BING default 4).
    pub fn new(weights: &Stage1Weights, nw: usize, ng: usize) -> Self {
        assert!(ng >= 1 && ng <= 8);
        Self { bases: binarize_weights(weights, nw), ng }
    }

    /// Approximate score map (same shape contract as [`super::score_map`]).
    /// Scores are in the same scale as the exact map (milli-β rescaled back),
    /// so ranking quality is directly comparable.
    pub fn score_map(&self, g: &ImageGray) -> ScoreMap {
        assert!(g.w >= WIN && g.h >= WIN);
        let ow = g.w - WIN + 1;
        let oh = g.h - WIN + 1;
        let mut data = vec![0i32; ow * oh];

        // Per output row, maintain the 8x8 window's bit planes as u64 words,
        // updated incrementally as the window slides right — the software
        // analogue of the paper's line-buffer reuse.
        for y in 0..oh {
            for x in 0..ow {
                // pack the window's bit-planes
                let mut planes = [0u64; 8];
                for dy in 0..WIN {
                    let row = &g.data[(y + dy) * g.w + x..(y + dy) * g.w + x + WIN];
                    for (dx, &v) in row.iter().enumerate() {
                        let bit = dy * 8 + dx;
                        for k in 0..self.ng {
                            if v >> (7 - k) & 1 == 1 {
                                planes[k] |= 1u64 << bit;
                            }
                        }
                    }
                }
                let mut acc_milli = 0i64;
                for k in 0..self.ng {
                    let plane = planes[k];
                    let ones = plane.count_ones() as i64;
                    let mut plane_score = 0i64; // in milli-β units
                    for b in &self.bases {
                        let pop = (plane & b.plus).count_ones() as i64;
                        // <b, plane_bits> where plane bit=1 contributes b_i
                        let dot = 2 * pop - ones;
                        plane_score += b.beta_milli as i64 * dot;
                    }
                    acc_milli += plane_score << (7 - k);
                }
                data[y * ow + x] = (acc_milli / 1024) as i32;
            }
        }
        ScoreMap { w: ow, h: oh, data }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::{default_stage1, gradient_map, score_map};
    use crate::image::ImageRgb;

    #[test]
    fn binarization_reduces_residual() {
        let w = default_stage1();
        let flat: Vec<f64> = w.flat().iter().map(|&v| v as f64).collect();
        let norm0: f64 = flat.iter().map(|v| v * v).sum();
        for nw in 1..=4 {
            let bases = binarize_weights(&w, nw);
            // reconstruct
            let mut recon = vec![0f64; 64];
            for b in &bases {
                for (i, r) in recon.iter_mut().enumerate() {
                    let s = if b.plus >> i & 1 == 1 { 1.0 } else { -1.0 };
                    *r += b.beta_milli as f64 / 1024.0 * s;
                }
            }
            let err: f64 = flat
                .iter()
                .zip(&recon)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            assert!(err < norm0, "nw={nw} did not reduce residual");
            if nw >= 3 {
                assert!(err / norm0 < 0.35, "nw={nw} residual too large: {}", err / norm0);
            }
        }
    }

    #[test]
    fn approximate_scores_correlate_with_exact() {
        let img = ImageRgb::from_fn(48, 48, |x, y| {
            if (12..36).contains(&x) && (12..36).contains(&y) {
                [230, 30, 60]
            } else {
                [((x * 5 + y * 3) % 128) as u8, 90, 90]
            }
        });
        let g = gradient_map(&img);
        let w = default_stage1();
        let exact = score_map(&g, &w);
        let approx = BinarizedScorer::new(&w, 3, 6).score_map(&g);
        // Pearson correlation over the map
        let n = exact.data.len() as f64;
        let (mut sx, mut sy, mut sxx, mut syy, mut sxy) = (0f64, 0f64, 0f64, 0f64, 0f64);
        for (&a, &b) in exact.data.iter().zip(&approx.data) {
            let (a, b) = (a as f64, b as f64);
            sx += a;
            sy += b;
            sxx += a * a;
            syy += b * b;
            sxy += a * b;
        }
        let cov = sxy / n - sx / n * (sy / n);
        let va = sxx / n - (sx / n) * (sx / n);
        let vb = syy / n - (sy / n) * (sy / n);
        let corr = cov / (va.sqrt() * vb.sqrt()).max(1e-9);
        assert!(corr > 0.9, "correlation too low: {corr}");
    }

    #[test]
    fn same_shape_as_exact() {
        let img = ImageRgb::new(16, 24);
        let g = gradient_map(&img);
        let s = BinarizedScorer::new(&default_stage1(), 2, 4).score_map(&g);
        assert_eq!((s.w, s.h), (9, 17));
    }
}
