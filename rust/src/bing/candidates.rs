//! NMS-winner extraction — shared by the PJRT path (scores + mask tensors)
//! and the pure-rust paths (score map only), with a single tie-break policy
//! so every path emits the *same* candidate stream.

use super::ScoreMap;
use crate::config::{NEG_SENTINEL, NMS_BLOCK};

/// One NMS winner: window top-left (score-map coords) + raw score.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Winner {
    pub x: u16,
    pub y: u16,
    pub score: i32,
}

/// Winners straight from a score map: the paper's 5×5 block NMS (row max,
/// then column max), one winner per block, ties broken **row-major first**.
/// Blocks are the non-overlapping tiling anchored at (0,0); partial edge
/// blocks participate (the python side pads with `NEG_SENTINEL`, which can
/// never win a non-empty block).
pub fn winners_from_scores(s: &ScoreMap) -> Vec<Winner> {
    let mut out = Vec::new();
    winners_from_scores_into(s, &mut out);
    out
}

/// [`winners_from_scores`] writing into a reusable vector (cleared first) —
/// the scratch-arena variant used on the serving hot path.
pub fn winners_from_scores_into(s: &ScoreMap, out: &mut Vec<Winner>) {
    out.clear();
    out.reserve(s.w.div_ceil(NMS_BLOCK) * s.h.div_ceil(NMS_BLOCK));
    let mut by = 0;
    while by < s.h {
        let bh = NMS_BLOCK.min(s.h - by);
        let mut bx = 0;
        while bx < s.w {
            let bw = NMS_BLOCK.min(s.w - bx);
            let mut best = NEG_SENTINEL;
            let mut best_xy = (0usize, 0usize);
            for y in by..by + bh {
                let row = &s.data[y * s.w + bx..y * s.w + bx + bw];
                for (dx, &v) in row.iter().enumerate() {
                    if v > best {
                        best = v;
                        best_xy = (bx + dx, y);
                    }
                }
            }
            out.push(Winner { x: best_xy.0 as u16, y: best_xy.1 as u16, score: best });
            bx += NMS_BLOCK;
        }
        by += NMS_BLOCK;
    }
}

/// Winners from the HLO outputs: `scores` and the NMS `mask` (1.0 where the
/// cell equals its block max), both row-major f32 of shape `(oh, ow)`.
/// The mask may contain several 1s per block on ties; dedup row-major first —
/// identical policy to [`winners_from_scores`], asserted in tests.
pub fn winners_from_mask(scores: &[f32], mask: &[f32], oh: usize, ow: usize) -> Vec<Winner> {
    debug_assert_eq!(scores.len(), oh * ow);
    debug_assert_eq!(mask.len(), oh * ow);
    let nbx = ow.div_ceil(NMS_BLOCK);
    let nby = oh.div_ceil(NMS_BLOCK);
    let mut taken = vec![false; nbx * nby];
    let mut out = Vec::with_capacity(nbx * nby);
    for y in 0..oh {
        let block_row = y / NMS_BLOCK;
        for x in 0..ow {
            if mask[y * ow + x] != 1.0 {
                continue;
            }
            let b = block_row * nbx + x / NMS_BLOCK;
            if taken[b] {
                continue; // tie inside the block — keep the first row-major
            }
            taken[b] = true;
            out.push(Winner {
                x: x as u16,
                y: y as u16,
                // scores are integer-valued f32 (parity contract)
                score: scores[y * ow + x] as i32,
            });
        }
    }
    // Re-order to block-major (row-major over blocks) to match
    // winners_from_scores exactly.
    out.sort_by_key(|w| {
        (w.y as usize / NMS_BLOCK, w.x as usize / NMS_BLOCK)
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn map(w: usize, h: usize, f: impl Fn(usize, usize) -> i32) -> ScoreMap {
        let mut data = vec![0i32; w * h];
        for y in 0..h {
            for x in 0..w {
                data[y * w + x] = f(x, y);
            }
        }
        ScoreMap { w, h, data }
    }

    fn mask_from_scores(s: &ScoreMap) -> Vec<f32> {
        // reference mask: 1.0 where the cell equals its block max
        let mut m = vec![0f32; s.w * s.h];
        let mut by = 0;
        while by < s.h {
            let bh = NMS_BLOCK.min(s.h - by);
            let mut bx = 0;
            while bx < s.w {
                let bw = NMS_BLOCK.min(s.w - bx);
                let mut best = i32::MIN;
                for y in by..by + bh {
                    for x in bx..bx + bw {
                        best = best.max(s.get(x, y));
                    }
                }
                for y in by..by + bh {
                    for x in bx..bx + bw {
                        if s.get(x, y) == best {
                            m[y * s.w + x] = 1.0;
                        }
                    }
                }
                bx += NMS_BLOCK;
            }
            by += NMS_BLOCK;
        }
        m
    }

    #[test]
    fn one_winner_per_block() {
        let s = map(12, 7, |x, y| (x * 31 + y * 17) as i32 % 97);
        let ws = winners_from_scores(&s);
        // 12 → 3 block columns, 7 → 2 block rows
        assert_eq!(ws.len(), 6);
    }

    #[test]
    fn winner_is_block_max() {
        let s = map(10, 10, |x, y| ((x * 7919 + y * 104729) % 1000) as i32 - 500);
        for w in winners_from_scores(&s) {
            let bx = (w.x as usize / NMS_BLOCK) * NMS_BLOCK;
            let by = (w.y as usize / NMS_BLOCK) * NMS_BLOCK;
            for y in by..(by + NMS_BLOCK).min(10) {
                for x in bx..(bx + NMS_BLOCK).min(10) {
                    assert!(s.get(x, y) <= w.score);
                }
            }
        }
    }

    #[test]
    fn into_variant_clears_previous_contents() {
        let big = map(12, 7, |x, y| (x * 31 + y * 17) as i32 % 97);
        let small = map(4, 4, |x, y| (x + y) as i32);
        let mut out = Vec::new();
        winners_from_scores_into(&big, &mut out);
        winners_from_scores_into(&small, &mut out);
        assert_eq!(out, winners_from_scores(&small));
    }

    #[test]
    fn tie_break_is_row_major_first() {
        let s = map(5, 5, |_, _| 42); // all tied
        let ws = winners_from_scores(&s);
        assert_eq!(ws, vec![Winner { x: 0, y: 0, score: 42 }]);
    }

    #[test]
    fn mask_path_matches_score_path() {
        for seed in 0..5u64 {
            let s = map(13, 11, |x, y| {
                let v = x as u64 * 2654435761 + y as u64 * 40503 + seed * 97;
                ((v % 2048) as i32) - 1024
            });
            let scores_f: Vec<f32> = s.data.iter().map(|&v| v as f32).collect();
            let m = mask_from_scores(&s);
            let a = winners_from_scores(&s);
            let b = winners_from_mask(&scores_f, &m, s.h, s.w);
            assert_eq!(a, b, "paths diverged at seed {seed}");
        }
    }

    #[test]
    fn mask_path_with_ties_matches_too() {
        let s = map(10, 5, |x, _| (x < 5) as i32 * 7); // two blocks, each fully tied
        let scores_f: Vec<f32> = s.data.iter().map(|&v| v as f32).collect();
        let m = mask_from_scores(&s);
        assert_eq!(
            winners_from_mask(&scores_f, &m, s.h, s.w),
            winners_from_scores(&s)
        );
    }
}
