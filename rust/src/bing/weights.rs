//! Stage-I SVM weights: the 8×8 (= 64-d) linear template.

use std::path::Path;

use crate::util::json::{to_f64_vec, Json};

/// 8×8 stage-I weights in integer (i8-range) quantization.
///
/// Scores stay within `64 · 255 · max|w| < 2^24`, so f32 HLO arithmetic and
/// i32 rust arithmetic agree exactly (DESIGN.md §8).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stage1Weights {
    pub w: [[i8; 8]; 8],
}

impl Stage1Weights {
    /// Flattened row-wise 64-d view (the paper's feature layout).
    pub fn flat(&self) -> [i8; 64] {
        let mut out = [0i8; 64];
        for dy in 0..8 {
            for dx in 0..8 {
                out[dy * 8 + dx] = self.w[dy][dx];
            }
        }
        out
    }

    /// Quantize trained float weights to the i8 template: symmetric scaling
    /// so `max |w| → 12` (the default template's peak), round-to-nearest.
    pub fn quantize(float_w: &[[f64; 8]; 8]) -> Self {
        let peak = float_w
            .iter()
            .flatten()
            .fold(0f64, |m, &v| m.max(v.abs()))
            .max(1e-12);
        let scale = 12.0 / peak;
        let mut w = [[0i8; 8]; 8];
        for dy in 0..8 {
            for dx in 0..8 {
                w[dy][dx] = (float_w[dy][dx] * scale).round().clamp(-127.0, 127.0) as i8;
            }
        }
        Self { w }
    }

    /// Parse from the `stage1` field of `svm_weights.json`.
    pub fn from_json(j: &Json) -> Option<Self> {
        let rows = j.get("stage1")?.as_arr()?;
        if rows.len() != 8 {
            return None;
        }
        let mut w = [[0i8; 8]; 8];
        for (dy, row) in rows.iter().enumerate() {
            let vals = to_f64_vec(row)?;
            if vals.len() != 8 {
                return None;
            }
            for (dx, &v) in vals.iter().enumerate() {
                if v != v.round() || !(-127.0..=127.0).contains(&v) {
                    return None; // weights must be integral i8 (parity contract)
                }
                w[dy][dx] = v as i8;
            }
        }
        Some(Self { w })
    }

    /// Load from `artifacts/svm_weights.json`, falling back to the default
    /// template when absent — the same resolution order as `aot.py`, so the
    /// rust path and the baked HLO constants always agree.
    pub fn load_or_default(artifacts_dir: &Path) -> Self {
        let path = artifacts_dir.join("svm_weights.json");
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(doc) = Json::parse(&text) {
                if let Some(w) = Self::from_json(&doc) {
                    return w;
                }
            }
        }
        default_stage1()
    }
}

/// The deterministic center-surround template, bit-exact twin of
/// `python/compile/common.py::default_stage1_weights`:
/// `d = max(|2dy−7|, |2dx−7|)`, ring weights `{1:12, 3:6, 5:0, 7:−4}`.
pub fn default_stage1() -> Stage1Weights {
    let ring = |d: i32| -> i8 {
        match d {
            1 => 12,
            3 => 6,
            5 => 0,
            7 => -4,
            _ => unreachable!("d is max of two odd values in 1..=7"),
        }
    };
    let mut w = [[0i8; 8]; 8];
    for dy in 0..8i32 {
        for dx in 0..8i32 {
            let d = (2 * dy - 7).abs().max((2 * dx - 7).abs());
            w[dy as usize][dx as usize] = ring(d);
        }
    }
    Stage1Weights { w }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_template_mass_matches_python() {
        // python/tests/test_aot.py asserts sum == 8.0 for its twin
        let w = default_stage1();
        let sum: i32 = w.flat().iter().map(|&v| v as i32).sum();
        assert_eq!(sum, 8);
    }

    #[test]
    fn default_template_center_surround() {
        let w = default_stage1();
        assert_eq!(w.w[3][3], 12);
        assert_eq!(w.w[3][4], 12);
        assert_eq!(w.w[0][0], -4);
        assert_eq!(w.w[7][3], -4);
        assert_eq!(w.w[2][2], 6); // d = max(3, 3) → ring 6
        assert_eq!(w.w[1][2], 0); // d = max(5, 3) → ring 0
    }

    #[test]
    fn template_is_symmetric() {
        let w = default_stage1();
        for dy in 0..8 {
            for dx in 0..8 {
                assert_eq!(w.w[dy][dx], w.w[dx][dy]);
                assert_eq!(w.w[dy][dx], w.w[7 - dy][7 - dx]);
            }
        }
    }

    #[test]
    fn quantize_scales_peak_to_12() {
        let mut fw = [[0f64; 8]; 8];
        fw[3][3] = 0.5;
        fw[0][0] = -0.25;
        let q = Stage1Weights::quantize(&fw);
        assert_eq!(q.w[3][3], 12);
        assert_eq!(q.w[0][0], -6);
    }

    #[test]
    fn json_roundtrip_and_rejection() {
        let text = r#"{"stage1": [[1,2,3,4,5,6,7,8],[1,2,3,4,5,6,7,8],[1,2,3,4,5,6,7,8],
            [1,2,3,4,5,6,7,8],[1,2,3,4,5,6,7,8],[1,2,3,4,5,6,7,8],
            [1,2,3,4,5,6,7,8],[1,2,3,4,5,6,7,-8]]}"#;
        let j = Json::parse(text).unwrap();
        let w = Stage1Weights::from_json(&j).unwrap();
        assert_eq!(w.w[7][7], -8);
        // non-integral weights violate the parity contract
        let bad = Json::parse(r#"{"stage1": [[1.5,2,3,4,5,6,7,8]]}"#).unwrap();
        assert!(Stage1Weights::from_json(&bad).is_none());
    }

    #[test]
    fn load_or_default_falls_back() {
        let dir = std::env::temp_dir().join("bingflow-no-weights");
        std::fs::create_dir_all(&dir).unwrap();
        let w = Stage1Weights::load_or_default(&dir);
        assert_eq!(w, default_stage1());
    }
}
