//! The BING algorithm substrate (Cheng et al., CVPR'14) — the computation the
//! accelerator reproduces.
//!
//! Everything here follows the *quantized integer semantics* shared with the
//! python compile path (`python/compile/common.py`): pixels u8, gradients u8,
//! stage-I weights i8, scores i32. The HLO executables, the software baseline
//! and the dataflow simulator all call into (or are asserted equal to) these
//! functions — the parity anchor of the whole repo.

mod binarized;
mod candidates;
mod pyramid;
mod score;
mod weights;

pub use binarized::{binarize_weights, BinaryBasis, BinarizedScorer, BinarizedScratch};
pub use candidates::{winners_from_mask, winners_from_scores, winners_from_scores_into, Winner};
pub use pyramid::{window_to_box, BBox, Pyramid};
pub use score::{score_map, score_map_i32, score_map_i32_into, score_map_into, ScoreMap};
pub use weights::{default_stage1, Stage1Weights};

use crate::image::{ImageGray, ImageRgb};

/// Window size of the BING feature.
pub const WIN: usize = 8;

/// A per-scale candidate window (score-map coordinates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Candidate {
    /// Index into the pyramid's scale list.
    pub scale_idx: usize,
    /// Window top-left in the resized image (== score-map coords).
    pub x: u16,
    pub y: u16,
    /// Raw stage-I score (integer semantics).
    pub score: i32,
}

/// A final proposal in original-image coordinates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Proposal {
    pub bbox: BBox,
    /// Stage-II calibrated score.
    pub score: f32,
}

/// Normed-gradient map `G` of an RGB image (paper §3.3):
///
/// `D(Pa,Pb) = max_c |Pa(c) − Pb(c)|`,
/// `Ix(i,j) = D(P(i−1,j), P(i+1,j))`, `Iy(i,j) = D(P(i,j−1), P(i,j+1))`,
/// `G = min(Ix + Iy, 255)`; border pixels are 0 (missing neighbours).
///
/// Bit-exact twin of `python/compile/kernels/ref.py::calc_grad`.
pub fn gradient_map(img: &ImageRgb) -> ImageGray {
    let mut g = ImageGray::new(0, 0);
    gradient_map_into(img, &mut g);
    g
}

/// [`gradient_map`] writing into a reusable buffer (the scratch-arena
/// variant: the serving hot path recomputes gradients without allocating).
pub fn gradient_map_into(img: &ImageRgb, g: &mut ImageGray) {
    let (w, h) = (img.w, img.h);
    g.w = w;
    g.h = h;
    g.data.clear();
    g.data.resize(w * h, 0);
    if w < 3 || h < 3 {
        return; // too small for any interior pixel
    }
    let data = &img.data;
    let stride = w * 3;
    for y in 1..h - 1 {
        let row_above = (y - 1) * stride;
        let row_below = (y + 1) * stride;
        let row = y * stride;
        let out_row = y * w;
        for x in 1..w - 1 {
            let ix = chebyshev(data, row_above + x * 3, row_below + x * 3);
            let iy = chebyshev(data, row + (x - 1) * 3, row + (x + 1) * 3);
            g.data[out_row + x] = (ix + iy).min(255) as u8;
        }
    }
}

/// Recompute gradient rows `y0..y1` of `g` in place, assuming `g` already
/// holds a valid [`gradient_map`] of an image that differs from `img` only
/// in pixel rows `y0-1..y1+1` — the temporal incremental path
/// ([`crate::temporal`]) dilates its dirty-row intervals by ±1 before
/// calling, because gradient row `y` reads pixel rows `y−1..=y+1`.
///
/// Bit-identical to the corresponding rows of [`gradient_map_into`] by
/// construction: the per-pixel arithmetic is the same code, and rows 0 and
/// `h−1` (plus everything when `w < 3 || h < 3`) are written back to the
/// border zeros the full path produces.
pub fn gradient_rows_into(img: &ImageRgb, g: &mut ImageGray, y0: usize, y1: usize) {
    let (w, h) = (img.w, img.h);
    assert_eq!((g.w, g.h), (w, h), "gradient buffer shape must match the image");
    let y1 = y1.min(h);
    if y0 >= y1 {
        return;
    }
    let data = &img.data;
    let stride = w * 3;
    for y in y0..y1 {
        let out_row = y * w;
        g.data[out_row..out_row + w].fill(0);
        if y == 0 || y + 1 >= h || w < 3 || h < 3 {
            continue; // border row (or degenerate image): all zeros
        }
        let row_above = (y - 1) * stride;
        let row_below = (y + 1) * stride;
        let row = y * stride;
        for x in 1..w - 1 {
            let ix = chebyshev(data, row_above + x * 3, row_below + x * 3);
            let iy = chebyshev(data, row + (x - 1) * 3, row + (x + 1) * 3);
            g.data[out_row + x] = (ix + iy).min(255) as u8;
        }
    }
}

/// Chebyshev (max-channel) distance between two interleaved RGB pixels.
#[inline(always)]
fn chebyshev(data: &[u8], a: usize, b: usize) -> u16 {
    let d0 = data[a].abs_diff(data[b]) as u16;
    let d1 = data[a + 1].abs_diff(data[b + 1]) as u16;
    let d2 = data[a + 2].abs_diff(data[b + 2]) as u16;
    d0.max(d1).max(d2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::ImageRgb;

    #[test]
    fn gradient_of_flat_image_is_zero() {
        let img = ImageRgb::from_fn(16, 12, |_, _| [77, 12, 200]);
        let g = gradient_map(&img);
        assert!(g.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn border_is_zero() {
        let img = ImageRgb::from_fn(10, 10, |x, y| [(x * 25) as u8, (y * 25) as u8, 0]);
        let g = gradient_map(&img);
        for i in 0..10 {
            assert_eq!(g.get(i, 0), 0);
            assert_eq!(g.get(i, 9), 0);
            assert_eq!(g.get(0, i), 0);
            assert_eq!(g.get(9, i), 0);
        }
    }

    #[test]
    fn vertical_edge_detected_by_iy() {
        // columns 0..4 black, 5.. white → Iy spike at x in {4, 5}
        let img = ImageRgb::from_fn(12, 8, |x, _| if x < 5 { [0, 0, 0] } else { [255, 255, 255] });
        let g = gradient_map(&img);
        assert_eq!(g.get(4, 3), 255);
        assert_eq!(g.get(5, 3), 255);
        assert_eq!(g.get(2, 3), 0);
        assert_eq!(g.get(8, 3), 0);
    }

    #[test]
    fn clamped_at_255() {
        // period-4 XOR pattern: at (2,2) the i±1 neighbours differ by 255 in
        // both axes → Ix + Iy = 510 clamps to 255
        let img = ImageRgb::from_fn(8, 8, |x, y| {
            if (x % 4 < 2) ^ (y % 4 < 2) { [255, 255, 255] } else { [0, 0, 0] }
        });
        let g = gradient_map(&img);
        assert_eq!(g.get(2, 2), 255);
    }

    #[test]
    fn chebyshev_uses_max_channel() {
        let mut img = ImageRgb::new(3, 3);
        img.put(1, 0, [10, 0, 0]);
        img.put(1, 2, [0, 0, 90]); // vertical neighbours of (1,1): Ix = 90
        let g = gradient_map(&img);
        assert_eq!(g.get(1, 1), 90);
    }

    #[test]
    fn gradient_into_reuse_matches_fresh() {
        let a = ImageRgb::from_fn(16, 12, |x, y| [(x * 9) as u8, (y * 7) as u8, 30]);
        let b = ImageRgb::from_fn(7, 21, |x, y| [((x + y) * 11) as u8, 0, 200]);
        let mut g = ImageGray::new(0, 0);
        // shrink and regrow: stale pixels must never survive the reuse
        for img in [&a, &b, &a] {
            gradient_map_into(img, &mut g);
            assert_eq!(g, gradient_map(img));
        }
    }

    #[test]
    fn gradient_rows_match_full_recompute() {
        let img = ImageRgb::from_fn(20, 15, |x, y| {
            [((x * 13 + y * 7) % 256) as u8, (y * 9) as u8, ((x ^ y) * 5) as u8]
        });
        let full = gradient_map(&img);
        // scrub arbitrary row bands and rebuild them in place
        for (y0, y1) in [(0usize, 15usize), (3, 7), (0, 1), (14, 15), (5, 5), (10, 99)] {
            let mut g = full.clone();
            for y in y0..y1.min(15) {
                g.data[y * 20..(y + 1) * 20].fill(0xAA);
            }
            gradient_rows_into(&img, &mut g, y0, y1);
            assert_eq!(g, full, "rows {y0}..{y1} diverged");
        }
    }

    #[test]
    fn tiny_images_dont_panic() {
        for (w, h) in [(1, 1), (2, 5), (5, 2)] {
            let img = ImageRgb::new(w, h);
            let g = gradient_map(&img);
            assert!(g.data.iter().all(|&v| v == 0));
        }
    }
}
