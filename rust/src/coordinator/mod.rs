//! L3 coordinator — the serving layer, generic over the proposal backend.
//!
//! ```text
//!   submit(image) ──► admission gate (bounded slots, backpressure)
//!        │                     │ one task per (image, scale)
//!        │            shared process-wide worker pool
//!        │              ProposalBackend::scale_candidates
//!        │                ├─ SoftwareBing          (CPU pipeline, scratch arenas)
//!        │                ├─ EngineBackend         (resize → ScaleExecutor: mock/PJRT)
//!        │                └─ SimulatedAccelerator  (cycle-accurate stage graph,
//!        │                     │                    sim-cycle telemetry)
//!        └──◄ aggregator: when all scales of an image land →
//!             SVM stage-II calibration → bubble-pushing heap top-k →
//!             Response { proposals, latency }
//! ```
//!
//! `Coordinator<B: ProposalBackend + ?Sized>` drives any backend through
//! one generic code path — including `Coordinator<dyn ProposalBackend>`
//! for runtime selection (the CLI's `--backend engine|software|sim`). The
//! per-scale unit of work, the bounded admission queue, the shared
//! [`crate::util::pool`] worker pool and the aggregation logic are all
//! backend-independent; backends that model time (the simulator) surface
//! their cycle counts through [`ServeMetrics::sim_cycles`].
//!
//! The final ranking is [`crate::baseline::rank_and_select`], the exact
//! code the software baseline uses, so serving results are bit-identical
//! across backends given the parity contract (`tests/backend_parity.rs`).

mod scheduler;

pub use scheduler::TaskQueue;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::backend::{EngineBackend, ProposalBackend};
use crate::baseline::rank_and_select;
use crate::bing::{Candidate, Proposal, Pyramid};
use crate::config::ServingConfig;
use crate::image::ImageRgb;
use crate::runtime::ScaleExecutor;
use crate::svm::Stage2Calibration;
use crate::telemetry::ServeMetrics;
use crate::util::pool;

/// A completed response.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub proposals: Vec<Proposal>,
    pub latency: std::time::Duration,
}

/// One (image, scale) work item.
struct ScaleTask {
    scale_idx: usize,
    state: Arc<ImageState>,
}

/// Aggregation state for one in-flight image.
struct ImageState {
    id: u64,
    image: ImageRgb,
    started: Instant,
    remaining: Mutex<usize>,
    candidates: Mutex<Vec<Candidate>>,
    done_tx: Mutex<Option<mpsc::Sender<Response>>>,
}

/// Everything a worker needs to finish an image.
struct WorkerCtx<B: ?Sized> {
    stage2: Stage2Calibration,
    top_k: usize,
    metrics: Arc<ServeMetrics>,
    backend: Arc<B>,
}

/// Count of this coordinator's tasks on the pool; shutdown drains it to zero.
#[derive(Default)]
struct Inflight {
    count: Mutex<usize>,
    zero: Condvar,
}

impl Inflight {
    fn inc(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn dec(&self) {
        let mut c = self.count.lock().unwrap();
        *c -= 1;
        if *c == 0 {
            self.zero.notify_all();
        }
    }

    fn wait_zero(&self) {
        let mut c = self.count.lock().unwrap();
        while *c != 0 {
            c = self.zero.wait(c).unwrap();
        }
    }
}

/// The coordinator: admission gate + shared pool + aggregator, generic
/// over the [`ProposalBackend`] it serves (`dyn ProposalBackend` works —
/// the type parameter may be unsized).
pub struct Coordinator<B: ?Sized = dyn ProposalBackend> {
    /// Admission slots — one unit per scale task *waiting* on the pool
    /// (released when execution starts, exactly when the old dedicated
    /// workers popped their queue). Bounded at `queue_depth`, so producers
    /// feel the same backpressure, and the full-event counter carries over.
    slots: Arc<TaskQueue<()>>,
    ctx: Arc<WorkerCtx<B>>,
    inflight: Arc<Inflight>,
    closed: AtomicBool,
    pyramid: Pyramid,
    config: ServingConfig,
    pub metrics: Arc<ServeMetrics>,
    next_id: AtomicU64,
}

impl Coordinator<EngineBackend> {
    /// Build the serving layer against an engine (PJRT or mock) — the
    /// pre-backend-seam constructor, now sugar for
    /// [`Coordinator::with_backend`] over an [`EngineBackend`].
    pub fn new(
        engine: Arc<dyn ScaleExecutor>,
        pyramid: Pyramid,
        stage2: Stage2Calibration,
        config: ServingConfig,
    ) -> Self {
        Self::with_backend(Arc::new(EngineBackend::new(engine, pyramid)), stage2, config)
    }
}

impl<B: ProposalBackend + ?Sized + 'static> Coordinator<B> {
    /// Build the serving layer over any [`ProposalBackend`]. Grows the
    /// shared worker pool to at least the configured worker count.
    pub fn with_backend(
        backend: Arc<B>,
        stage2: Stage2Calibration,
        config: ServingConfig,
    ) -> Self {
        let pyramid = backend.pyramid().clone();
        assert_eq!(
            pyramid.sizes, stage2.sizes,
            "stage-II calibration must cover the pyramid"
        );
        pool::global().ensure_threads(config.workers.max(1));
        let metrics = Arc::new(ServeMetrics::default());
        let slots: Arc<TaskQueue<()>> = TaskQueue::new(config.queue_depth.max(1));
        let ctx = Arc::new(WorkerCtx {
            stage2,
            top_k: config.top_k,
            metrics: metrics.clone(),
            backend,
        });
        Self {
            slots,
            ctx,
            inflight: Arc::new(Inflight::default()),
            closed: AtomicBool::new(false),
            pyramid,
            config,
            metrics,
            next_id: AtomicU64::new(1),
        }
    }

    /// The backend this coordinator serves.
    pub fn backend(&self) -> &Arc<B> {
        &self.ctx.backend
    }

    /// Submit one image; returns a receiver for its response. Blocks when
    /// all admission slots are taken (backpressure).
    pub fn submit(&self, image: ImageRgb) -> mpsc::Receiver<Response> {
        assert!(
            !self.closed.load(Ordering::Acquire),
            "coordinator is shut down"
        );
        let (tx, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.metrics.requests.inc();
        let n_scales = self.pyramid.sizes.len();
        let state = Arc::new(ImageState {
            id,
            image,
            started: Instant::now(),
            remaining: Mutex::new(n_scales),
            candidates: Mutex::new(Vec::with_capacity(self.pyramid.max_candidates())),
            done_tx: Mutex::new(Some(tx)),
        });
        for scale_idx in 0..n_scales {
            let ok = self.slots.push(());
            assert!(ok, "coordinator shut down while submitting");
            self.inflight.inc();
            let task = ScaleTask { scale_idx, state: state.clone() };
            let ctx = self.ctx.clone();
            let slots = self.slots.clone();
            let inflight = self.inflight.clone();
            pool::global().execute(Box::new(move || {
                // Admission ends when execution begins — the old dedicated
                // workers popped the queue *before* running, so `queue_depth`
                // bounds queued (not executing) scale tasks, and a
                // queue_depth smaller than the worker count cannot throttle
                // execution concurrency.
                let _ = slots.pop();
                // a panicking scale must still decrement the inflight count,
                // or shutdown would wait forever
                let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_scale_task(&task, &ctx);
                }))
                .is_err();
                if panicked {
                    eprintln!("[coordinator] scale {scale_idx} task panicked");
                }
                inflight.dec();
            }));
        }
        rx
    }

    /// Submit a batch and wait for all responses (a dynamic batching round:
    /// up to `max_batch` images in flight together; their scales interleave
    /// over the worker pool).
    pub fn serve_batch(&self, images: Vec<ImageRgb>) -> Vec<Response> {
        let mut responses = Vec::with_capacity(images.len());
        for chunk in images.chunks(self.config.max_batch.max(1)) {
            let rxs: Vec<_> = chunk.iter().map(|img| self.submit(img.clone())).collect();
            for rx in rxs {
                responses.push(rx.recv().expect("worker pool died"));
            }
        }
        responses.sort_by_key(|r| r.id);
        responses
    }

    /// Graceful shutdown: refuse new submissions and drain in-flight scale
    /// tasks (runs on Drop too; consuming `self` just makes it explicit).
    pub fn shutdown(self) {
        drop(self);
    }

    /// Backpressure engagements observed by the admission gate.
    pub fn queue_full_events(&self) -> u64 {
        self.slots.full_events()
    }
}

impl<B: ?Sized> Drop for Coordinator<B> {
    fn drop(&mut self) {
        self.closed.store(true, Ordering::Release);
        // every submitted task releases its slot and decrements inflight on
        // the shared pool — wait for ours, leave the pool itself running
        self.inflight.wait_zero();
        self.slots.close();
    }
}

/// One (image, scale) unit: ask the backend for this scale's candidates
/// (software pipeline, engine executable or cycle simulation — the generic
/// seam), record telemetry, fold into the image's aggregate.
fn run_scale_task<B: ProposalBackend + ?Sized>(task: &ScaleTask, ctx: &WorkerCtx<B>) {
    let (h, w) = ctx.backend.pyramid().sizes[task.scale_idx];
    let t0 = Instant::now();
    let result = ctx.backend.scale_candidates(&task.state.image, task.scale_idx);
    let candidates = match result {
        Ok(out) => {
            ctx.metrics.exec_latency.record(t0.elapsed());
            ctx.metrics.scale_executions.inc();
            ctx.metrics.candidates_seen.add(out.candidates.len() as u64);
            if let Some(cycles) = out.sim_cycles {
                ctx.metrics.sim_cycles.add(cycles);
            }
            out.candidates
        }
        Err(e) => {
            // a serving system must not wedge on one bad scale: log and
            // complete the scale with no candidates
            eprintln!("[coordinator] scale {h}x{w} failed: {e:#}");
            Vec::new()
        }
    };
    complete_scale(task, candidates, ctx);
}

/// Record one finished scale; the last scale finalizes the image inline
/// (cheap: a few hundred candidates through the bubble heap).
fn complete_scale<B: ProposalBackend + ?Sized>(
    task: &ScaleTask,
    candidates: Vec<Candidate>,
    ctx: &WorkerCtx<B>,
) {
    let state = &task.state;
    state.candidates.lock().unwrap().extend(candidates);
    let mut remaining = state.remaining.lock().unwrap();
    *remaining -= 1;
    let done = *remaining == 0;
    drop(remaining);
    if done {
        if let Some(tx) = state.done_tx.lock().unwrap().take() {
            let cands = state.candidates.lock().unwrap();
            let proposals = rank_and_select(
                &cands,
                ctx.backend.pyramid(),
                &ctx.stage2,
                state.image.w,
                state.image.h,
                ctx.top_k,
            );
            drop(cands);
            ctx.metrics.e2e_latency.record(state.started.elapsed());
            ctx.metrics.images_done.inc();
            let _ = tx.send(Response {
                id: state.id,
                proposals,
                latency: state.started.elapsed(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;
    use crate::runtime::MockEngine;

    fn make(sizes: Vec<(usize, usize)>, cfg: ServingConfig) -> Coordinator<EngineBackend> {
        let engine = Arc::new(MockEngine::new(default_stage1(), sizes.clone()));
        Coordinator::new(
            engine,
            Pyramid::new(sizes.clone()),
            Stage2Calibration::identity(sizes),
            cfg,
        )
    }

    #[test]
    fn serves_one_image_matching_baseline() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes.clone(), ServingConfig { top_k: 50, ..Default::default() });
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = coord.submit(img.clone()).recv().unwrap();
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        );
        assert_eq!(resp.proposals, sw.propose(&img, 50));
        coord.shutdown();
    }

    #[test]
    fn batch_preserves_request_order() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig { max_batch: 4, ..Default::default() });
        let ds = SyntheticDataset::voc_like_val(6);
        let images: Vec<_> = ds.iter().map(|s| s.image).collect();
        let responses = coord.serve_batch(images);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64 + 1);
            assert!(!r.proposals.is_empty());
        }
        assert_eq!(coord.metrics.images_done.get(), 6);
        assert_eq!(coord.metrics.scale_executions.get(), 12);
        coord.shutdown();
    }

    #[test]
    fn concurrent_images_do_not_mix_candidates() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes.clone(), ServingConfig { workers: 8, ..Default::default() });
        let ds = SyntheticDataset::voc_like_val(4);
        let images: Vec<_> = ds.iter().map(|s| s.image).collect();
        let responses = coord.serve_batch(images.clone());
        // each response must equal the serial pipeline for its own image
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        );
        for (img, resp) in images.iter().zip(&responses) {
            assert_eq!(resp.proposals, sw.propose(img, 1000));
        }
        coord.shutdown();
    }

    #[test]
    fn tiny_queue_engages_backpressure_and_still_completes() {
        let sizes = vec![(16, 16), (32, 32), (64, 64), (128, 128)];
        let coord = make(
            sizes,
            ServingConfig { queue_depth: 2, workers: 2, ..Default::default() },
        );
        let ds = SyntheticDataset::voc_like_val(3);
        let responses = coord.serve_batch(ds.iter().map(|s| s.image).collect());
        assert_eq!(responses.len(), 3);
        coord.shutdown();
    }

    #[test]
    fn metrics_summary_is_populated() {
        let sizes = vec![(16, 16)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let _ = coord.submit(img).recv().unwrap();
        let summary = coord.metrics.summary();
        assert!(summary.contains("images=1"), "{summary}");
        coord.shutdown();
    }

    #[test]
    fn drop_waits_for_inflight_tasks() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let rx = coord.submit(img);
        drop(coord); // must drain the submitted scales, not orphan them
        let resp = rx.recv().expect("response still arrives after drop");
        assert!(!resp.proposals.is_empty());
    }

    // NOTE: dyn-dispatch serving over the simulator (Coordinator<dyn
    // ProposalBackend> + sim-cycle telemetry) is covered end to end in
    // tests/backend_parity.rs — not duplicated here.
}
