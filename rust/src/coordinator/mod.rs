//! L3 shard executor — one backend replica's serving engine, generic over
//! the proposal backend.
//!
//! ```text
//!   submit(image) ──► admission gate (bounded slots, backpressure,
//!        │            deadline-aware: a request never blocks past its
//!        │            own deadline; shutdown rolls partial images back)
//!        │                     │ one task per (image, scale)
//!        │            shared process-wide worker pool
//!        │              ProposalBackend::scale_candidates
//!        │                ├─ SoftwareBing          (CPU pipeline, scratch arenas)
//!        │                ├─ EngineBackend         (resize → ScaleExecutor: mock/PJRT)
//!        │                └─ SimulatedAccelerator  (cycle-accurate stage graph,
//!        │                     │                    sim-cycle telemetry)
//!        └──◄ aggregator: when all scales of an image land →
//!             SVM stage-II calibration → bubble-pushing heap top-k →
//!             Ok(ProposalResponse) — for a detect request, the cascade
//!             (greedy NMS → Platt confidence) runs on the same worker and
//!             yields Ok(DetectResponse) — or Err(ResponseError) for a
//!             cancelled, deadline-missed or worker-lost image
//! ```
//!
//! `Coordinator<B: ProposalBackend + ?Sized>` drives any backend through
//! one generic code path — including `Coordinator<dyn ProposalBackend>`
//! for runtime selection (the CLI's `--backend engine|software|sim`). It is
//! also the *shard executor* of the multi-replica serving stack: a
//! [`crate::serving::ServerRuntime`] owns N coordinators, each wrapping its
//! own backend replica behind its own bounded admission queue, wired
//! together through a shared [`ShardContext`] (one aggregated
//! [`ServeMetrics`] sink, one response-id space, a per-shard telemetry
//! lane).
//!
//! Request lifecycle: [`Coordinator::submit_request`] (or the `submit`
//! sugar) returns a [`RequestHandle`], [`Coordinator::submit_detect`] a
//! [`DetectHandle`] — or a typed [`SubmitError`] (no asserts, no blocking
//! past a deadline). Handles resolve to `Result<ServeResponse<_>,
//! ResponseError>` and support cooperative cancellation — a cancelled
//! image's remaining scale tasks become no-ops that still release their
//! admission slots. Internal channels never appear in public signatures;
//! the umbrella [`ServeError`] covers both phases for `?`-style callers.
//!
//! The final ranking is [`crate::baseline::rank_and_select_seeded`], the
//! exact code the software baseline uses (a video request seeds the heap
//! with the previous frame's winners, which never changes the selection),
//! so serving results are bit-identical across backends given the parity
//! contract (`tests/backend_parity.rs`) — and across shard counts and
//! routing policies, since every shard runs this same executor
//! (`tests/serving_soak.rs`).

mod error;
mod request;
mod scheduler;

pub use error::{ResponseError, ServeError, SubmitError};
pub use request::{
    DetectRequest, DetectResponse, Downgrade, ProposalRequest, ProposalResponse, Response,
    ServeResponse,
};
pub use scheduler::{PushOutcome, TaskQueue};

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::backend::{EngineBackend, ProposalBackend};
use crate::baseline::rank_and_select_seeded;
use crate::bing::{Candidate, Proposal, Pyramid};
use crate::config::ServingConfig;
use crate::detect::{run_cascade, run_cascade_lite, CascadeParams, Detection};
use crate::image::ImageRgb;
use crate::integrity::IntegrityPolicy;
use crate::runtime::ScaleExecutor;
use crate::svm::Stage2Calibration;
use crate::telemetry::ServeMetrics;
use crate::temporal::SessionStore;
use crate::util::pool;

/// Wiring a sharded runtime shares across its shard coordinators: one
/// aggregated metrics sink, one response-id space (ids stay unique and
/// monotone across shards), and this shard's telemetry lane.
pub struct ShardContext {
    pub metrics: Arc<ServeMetrics>,
    /// Response-id allocator; shared so ids never collide across shards.
    pub ids: Arc<AtomicU64>,
    /// Index of this coordinator's lane in `metrics` (None when unsharded).
    pub lane: Option<usize>,
}

impl ShardContext {
    /// Context for a standalone (unsharded) coordinator: fresh metrics,
    /// fresh id space, no lane.
    pub fn standalone() -> Self {
        Self {
            metrics: Arc::new(ServeMetrics::default()),
            ids: Arc::new(AtomicU64::new(1)),
            lane: None,
        }
    }
}

// Image abort causes (ImageState::aborted). First cause wins; ABORT_NONE
// means the image is still on the happy path.
const ABORT_NONE: u8 = 0;
const ABORT_CANCELLED: u8 = 1;
const ABORT_DEADLINE: u8 = 2;
const ABORT_WORKER_LOST: u8 = 3;
const ABORT_TRANSIENT: u8 = 4;
const ABORT_CORRUPT: u8 = 5;

/// One (image, scale) work item.
struct ScaleTask {
    scale_idx: usize,
    state: Arc<ImageState>,
}

/// What kind of finalization a request asked for. Resolved at submission —
/// per-request overrides are already folded into the params.
enum RequestMode {
    /// Stop at the proposal stage (stage-II calibration + top-k).
    Proposals,
    /// Run the full cascade (NMS + Platt confidence) after the proposals.
    Detect(CascadeParams),
}

/// Untyped finalization payload carried on the internal done channel; the
/// typed handles unwrap the variant their submit call guaranteed.
enum Payload {
    Proposals(Vec<Proposal>),
    Detections(Vec<Detection>),
}

struct RawResponse {
    id: u64,
    payload: Payload,
    latency: Duration,
    downgrade: Downgrade,
}

type DoneSender = mpsc::Sender<Result<RawResponse, ResponseError>>;
type DoneReceiver = mpsc::Receiver<Result<RawResponse, ResponseError>>;

/// Aggregation state for one in-flight image.
struct ImageState {
    id: u64,
    image: ImageRgb,
    started: Instant,
    deadline: Option<Instant>,
    /// Proposal-stage top-k for this request (per-request override or the
    /// serving config default).
    top_k: usize,
    mode: RequestMode,
    /// Video-session admission ticket (see [`crate::temporal`]): carries
    /// the canonical frame, the dirty-row runs and the heap-seeding
    /// priors. `None` for stateless requests.
    ticket: Option<crate::temporal::FrameTicket>,
    /// Brownout record for this request; carried through to the response
    /// and consulted by the finalizer (proposals-only cheap cascade).
    downgrade: Downgrade,
    /// First abort cause wins (CAS from ABORT_NONE); remaining scale tasks
    /// of an aborted image become no-ops.
    aborted: AtomicU8,
    remaining: Mutex<usize>,
    candidates: Mutex<Vec<Candidate>>,
    done_tx: Mutex<Option<DoneSender>>,
}

impl ImageState {
    fn abort(&self, cause: u8) {
        let _ = self.aborted.compare_exchange(
            ABORT_NONE,
            cause,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }

    fn abort_cause(&self) -> u8 {
        self.aborted.load(Ordering::Acquire)
    }

    fn past_deadline(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// Take the response sender even if a finalization panic poisoned its
/// mutex — the recovery path must reach the sender to surface
/// [`ResponseError::WorkerLost`] instead of leaving the caller hanging.
fn take_tx(state: &ImageState) -> Option<DoneSender> {
    match state.done_tx.lock() {
        Ok(mut tx) => tx.take(),
        Err(poisoned) => poisoned.into_inner().take(),
    }
}

/// A detached, cloneable cancellation handle for one in-flight request.
/// Unlike [`RequestHandle::cancel`] (which needs `&self` on the handle a
/// waiter is about to consume), a token can be held by another thread —
/// the resilient serving layer uses it to cancel the in-flight attempt
/// when a caller cancels mid-retry.
#[derive(Clone)]
pub struct CancelToken {
    state: Arc<ImageState>,
}

impl CancelToken {
    /// Cooperatively cancel the request this token belongs to. Best-effort
    /// and idempotent — an image that already finalized still resolves
    /// with its original outcome.
    pub fn cancel(&self) {
        self.state.abort(ABORT_CANCELLED);
    }

    /// Mark the request as past its deadline. The serving layer uses this
    /// when its bounded wait times out on an attempt that never resolved
    /// (e.g. a wedged worker): the eventual late completion — if the
    /// worker ever returns — then finalizes as a deadline miss into a
    /// dropped channel instead of pretending to be a healthy response.
    pub fn expire(&self) {
        self.state.abort(ABORT_DEADLINE);
    }
}

/// What the retry/hedge machinery in `serving` needs from an in-flight
/// handle, abstracted over the payload kind so one resilient code path
/// serves both [`RequestHandle`] and [`DetectHandle`].
pub trait ServeHandle: Sized + Send {
    type Item: Send;

    fn id(&self) -> u64;
    fn cancel_token(&self) -> CancelToken;
    /// Block until resolution.
    fn wait(self) -> Result<ServeResponse<Self::Item>, ResponseError>;
    /// Block until resolution or `until`, whichever comes first; on timeout
    /// the handle comes back so the caller can keep waiting (or hedge).
    fn wait_until(
        self,
        until: Instant,
    ) -> Result<Result<ServeResponse<Self::Item>, ResponseError>, Self>;
}

/// The shared body of `wait`/`wait_until`: unwrap the payload variant the
/// typed submit guaranteed.
fn resolve_raw<T>(
    msg: Result<Result<RawResponse, ResponseError>, mpsc::RecvError>,
    unwrap: impl FnOnce(Payload) -> Vec<T>,
) -> Result<ServeResponse<T>, ResponseError> {
    match msg {
        Ok(Ok(raw)) => Ok(ServeResponse {
            id: raw.id,
            items: unwrap(raw.payload),
            latency: raw.latency,
            downgrade: raw.downgrade,
        }),
        Ok(Err(e)) => Err(e),
        Err(_) => Err(ResponseError::WorkerLost),
    }
}

/// In-flight admitted proposal request: resolves to a
/// [`ProposalResponse`] (or a typed error), and supports cooperative
/// cancellation. The internal channel never appears in the signature.
pub struct RequestHandle {
    id: u64,
    rx: DoneReceiver,
    state: Arc<ImageState>,
}

impl RequestHandle {
    fn unwrap_payload(p: Payload) -> Vec<Proposal> {
        match p {
            Payload::Proposals(items) => items,
            // a proposal submit pins RequestMode::Proposals, and the
            // finalizer derives the payload from that mode
            Payload::Detections(_) => unreachable!("proposal handle got detections"),
        }
    }

    /// The response id this request will resolve with.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Cooperatively cancel: the image's remaining scale tasks become
    /// no-ops and the request resolves to `Err(Cancelled)`. Best-effort —
    /// an image that already finalized still resolves `Ok`.
    pub fn cancel(&self) {
        self.state.abort(ABORT_CANCELLED);
    }

    /// A detached cancellation handle (usable while another thread waits).
    pub fn cancel_token(&self) -> CancelToken {
        CancelToken { state: self.state.clone() }
    }

    /// Block until the request resolves. A worker whose panic escaped even
    /// the recovery path (the sender was dropped unsent) surfaces as
    /// [`ResponseError::WorkerLost`] rather than a caller-side panic.
    pub fn wait(self) -> Result<ProposalResponse, ResponseError> {
        resolve_raw(self.rx.recv(), Self::unwrap_payload)
    }

    /// Bounded wait: `Err(self)` hands the handle back on timeout.
    pub fn wait_until(
        self,
        until: Instant,
    ) -> Result<Result<ProposalResponse, ResponseError>, Self> {
        let budget = until.saturating_duration_since(Instant::now());
        match self.rx.recv_timeout(budget) {
            Ok(msg) => Ok(resolve_raw(Ok(msg), Self::unwrap_payload)),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(self),
            Err(mpsc::RecvTimeoutError::Disconnected) => Ok(Err(ResponseError::WorkerLost)),
        }
    }
}

impl ServeHandle for RequestHandle {
    type Item = Proposal;

    fn id(&self) -> u64 {
        RequestHandle::id(self)
    }

    fn cancel_token(&self) -> CancelToken {
        RequestHandle::cancel_token(self)
    }

    fn wait(self) -> Result<ProposalResponse, ResponseError> {
        RequestHandle::wait(self)
    }

    fn wait_until(
        self,
        until: Instant,
    ) -> Result<Result<ProposalResponse, ResponseError>, Self> {
        RequestHandle::wait_until(self, until)
    }
}

/// In-flight admitted detection request: resolves to a [`DetectResponse`]
/// (or a typed error). Same lifecycle as [`RequestHandle`] — the only
/// difference is the payload the finalizer builds.
pub struct DetectHandle {
    id: u64,
    rx: DoneReceiver,
    state: Arc<ImageState>,
}

impl DetectHandle {
    fn unwrap_payload(p: Payload) -> Vec<Detection> {
        match p {
            Payload::Detections(items) => items,
            Payload::Proposals(_) => unreachable!("detect handle got proposals"),
        }
    }

    /// The response id this request will resolve with.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Cooperatively cancel (see [`RequestHandle::cancel`]).
    pub fn cancel(&self) {
        self.state.abort(ABORT_CANCELLED);
    }

    /// A detached cancellation handle (usable while another thread waits).
    pub fn cancel_token(&self) -> CancelToken {
        CancelToken { state: self.state.clone() }
    }

    /// Block until the request resolves (see [`RequestHandle::wait`]).
    pub fn wait(self) -> Result<DetectResponse, ResponseError> {
        resolve_raw(self.rx.recv(), Self::unwrap_payload)
    }

    /// Bounded wait: `Err(self)` hands the handle back on timeout.
    pub fn wait_until(
        self,
        until: Instant,
    ) -> Result<Result<DetectResponse, ResponseError>, Self> {
        let budget = until.saturating_duration_since(Instant::now());
        match self.rx.recv_timeout(budget) {
            Ok(msg) => Ok(resolve_raw(Ok(msg), Self::unwrap_payload)),
            Err(mpsc::RecvTimeoutError::Timeout) => Err(self),
            Err(mpsc::RecvTimeoutError::Disconnected) => Ok(Err(ResponseError::WorkerLost)),
        }
    }
}

impl ServeHandle for DetectHandle {
    type Item = Detection;

    fn id(&self) -> u64 {
        DetectHandle::id(self)
    }

    fn cancel_token(&self) -> CancelToken {
        DetectHandle::cancel_token(self)
    }

    fn wait(self) -> Result<DetectResponse, ResponseError> {
        DetectHandle::wait(self)
    }

    fn wait_until(
        self,
        until: Instant,
    ) -> Result<Result<DetectResponse, ResponseError>, Self> {
        DetectHandle::wait_until(self, until)
    }
}

/// Everything a worker needs to finish an image.
struct WorkerCtx<B: ?Sized> {
    stage2: Stage2Calibration,
    top_k: usize,
    metrics: Arc<ServeMetrics>,
    /// Structural invariant validators (`integrity.validate`); `None`
    /// skips the checks entirely.
    integrity: Option<IntegrityPolicy>,
    /// This shard's video-session registry (frame caches + priors).
    sessions: Arc<SessionStore>,
    backend: Arc<B>,
}

/// Count of this coordinator's tasks on the pool; shutdown drains it to zero.
#[derive(Default)]
struct Inflight {
    count: Mutex<usize>,
    zero: Condvar,
}

impl Inflight {
    fn inc(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn dec(&self) {
        let mut c = self.count.lock().unwrap();
        *c -= 1;
        if *c == 0 {
            self.zero.notify_all();
        }
    }

    fn wait_zero(&self) {
        let mut c = self.count.lock().unwrap();
        while *c != 0 {
            c = self.zero.wait(c).unwrap();
        }
    }
}

/// The shard executor: admission gate + shared pool + aggregator, generic
/// over the [`ProposalBackend`] it serves (`dyn ProposalBackend` works —
/// the type parameter may be unsized).
pub struct Coordinator<B: ?Sized = dyn ProposalBackend> {
    /// Admission slots — one unit per scale task *waiting* on the pool
    /// (released when execution starts, exactly when the old dedicated
    /// workers popped their queue). Bounded at `queue_depth`, so producers
    /// feel the same backpressure, and the full-event counter carries over.
    slots: Arc<TaskQueue<()>>,
    ctx: Arc<WorkerCtx<B>>,
    inflight: Arc<Inflight>,
    closed: AtomicBool,
    pyramid: Pyramid,
    config: ServingConfig,
    pub metrics: Arc<ServeMetrics>,
    ids: Arc<AtomicU64>,
    /// Pool lane this shard submits scale tasks to. `None` for a
    /// standalone coordinator (tasks go to the shared injector); `Some`
    /// when part of a sharded runtime, so each shard keeps a home queue
    /// and idle workers steal from hot shards instead of head-of-line
    /// blocking behind them.
    lane: Option<usize>,
}

impl Coordinator<EngineBackend> {
    /// Build the serving layer against an engine (PJRT or mock) — the
    /// pre-backend-seam constructor, now sugar for
    /// [`Coordinator::with_backend`] over an [`EngineBackend`].
    pub fn new(
        engine: Arc<dyn ScaleExecutor>,
        pyramid: Pyramid,
        stage2: Stage2Calibration,
        config: ServingConfig,
    ) -> Self {
        Self::with_backend(Arc::new(EngineBackend::new(engine, pyramid)), stage2, config)
    }
}

impl<B: ProposalBackend + ?Sized + 'static> Coordinator<B> {
    /// Build a standalone serving layer over any [`ProposalBackend`] —
    /// [`Self::with_backend_shared`] with its own metrics and id space.
    pub fn with_backend(
        backend: Arc<B>,
        stage2: Stage2Calibration,
        config: ServingConfig,
    ) -> Self {
        Self::with_backend_shared(backend, stage2, config, ShardContext::standalone())
    }

    /// Build one shard executor over `backend`, wired into a runtime's
    /// shared metrics/id space via `shared`. Grows the shared worker pool
    /// to at least the configured worker count.
    pub fn with_backend_shared(
        backend: Arc<B>,
        stage2: Stage2Calibration,
        config: ServingConfig,
        shared: ShardContext,
    ) -> Self {
        let pyramid = backend.pyramid().clone();
        assert_eq!(
            pyramid.sizes, stage2.sizes,
            "stage-II calibration must cover the pyramid"
        );
        pool::global().ensure_threads(config.workers.max(1));
        let ShardContext { metrics, ids, lane } = shared;
        // the queue mirrors its full-events into the (possibly shared)
        // metrics counter — and, when this coordinator is a shard, the
        // lane's queue-depth gauge — under its own mutex: exact telemetry
        // with no extra lock traffic on the hot path
        let depth = lane
            .and_then(|i| metrics.shard(i))
            .map(|l| l.queue_depth.clone());
        let slots: Arc<TaskQueue<()>> = TaskQueue::with_sinks(
            config.queue_depth.max(1),
            metrics.queue_full_events.clone(),
            depth,
        );
        let ctx = Arc::new(WorkerCtx {
            stage2,
            top_k: config.top_k,
            metrics: metrics.clone(),
            integrity: config
                .integrity
                .validate
                .then(|| IntegrityPolicy::new(&pyramid)),
            sessions: Arc::new(SessionStore::new(config.temporal, pyramid.sizes.len())),
            backend,
        });
        Self {
            slots,
            ctx,
            inflight: Arc::new(Inflight::default()),
            closed: AtomicBool::new(false),
            pyramid,
            config,
            metrics,
            ids,
            lane,
        }
    }

    /// The backend this coordinator serves.
    pub fn backend(&self) -> &Arc<B> {
        &self.ctx.backend
    }

    /// Submit one image under the configured default deadline
    /// (`ServingConfig::deadline_ms`, if any). Blocks when all admission
    /// slots are taken (backpressure) — but never past the deadline.
    pub fn submit(&self, image: ImageRgb) -> Result<RequestHandle, SubmitError> {
        self.submit_request(ProposalRequest::new(image))
    }

    /// Submit one image with a per-request deadline override — sugar for
    /// [`Self::submit_request`] with only the deadline set.
    pub fn submit_deadline(
        &self,
        image: ImageRgb,
        deadline: Option<Instant>,
    ) -> Result<RequestHandle, SubmitError> {
        let mut req = ProposalRequest::new(image);
        req.deadline = deadline;
        self.submit_request(req)
    }

    /// Submit a typed proposal request. `None` options fall back to the
    /// serving config (deadline: `ServingConfig::deadline_ms` — the same
    /// contract as `ServerRuntime`, so the SLO holds whichever layer a
    /// caller submits through). Deadline-aware admission: an
    /// already-expired request is refused immediately, and a request that
    /// cannot clear the admission gate before its deadline is refused with
    /// any already-enqueued scale tasks rolled back to no-ops.
    pub fn submit_request(&self, req: ProposalRequest) -> Result<RequestHandle, SubmitError> {
        let ProposalRequest { image, top_k, deadline, scale_stride, session, downgrade } = req;
        let (id, rx, state) = self.submit_inner(
            image,
            deadline,
            top_k,
            RequestMode::Proposals,
            scale_stride,
            session,
            downgrade,
        )?;
        Ok(RequestHandle { id, rx, state })
    }

    /// Submit a typed detection request: the same admission, deadline and
    /// cancellation lifecycle as [`Self::submit_request`], but finalization
    /// runs the full cascade (proposals → greedy NMS → Platt confidence)
    /// and the handle resolves to a [`DetectResponse`]. Per-request cascade
    /// overrides fall back to `ServingConfig::cascade`.
    pub fn submit_detect(&self, req: DetectRequest) -> Result<DetectHandle, SubmitError> {
        let DetectRequest {
            image,
            deadline,
            top_k,
            nms_thresh,
            min_confidence,
            scale_stride,
            downgrade,
        } = req;
        let mut params = CascadeParams::from_config(&self.config.cascade);
        if let Some(t) = nms_thresh {
            params.nms_thresh = t;
        }
        if let Some(k) = top_k {
            params.top_k = k;
        }
        if let Some(c) = min_confidence {
            params.min_confidence = c;
        }
        let (id, rx, state) = self.submit_inner(
            image,
            deadline,
            None,
            RequestMode::Detect(params),
            scale_stride,
            None,
            downgrade,
        )?;
        Ok(DetectHandle { id, rx, state })
    }

    /// The shared admission path: resolve the deadline, allocate the image
    /// state, push one scale task per pyramid level through the bounded
    /// gate, fan out onto the shared pool.
    fn submit_inner(
        &self,
        image: ImageRgb,
        deadline: Option<Instant>,
        top_k: Option<usize>,
        mode: RequestMode,
        scale_stride: usize,
        session: Option<u64>,
        downgrade: Downgrade,
    ) -> Result<(u64, DoneReceiver, Arc<ImageState>), SubmitError> {
        let deadline = deadline.or_else(|| {
            self.config
                .deadline_ms
                .map(|ms| Instant::now() + Duration::from_millis(ms))
        });
        if self.closed.load(Ordering::Acquire) {
            self.metrics.rejected.inc();
            return Err(SubmitError::ShuttingDown);
        }
        if let Some(d) = deadline {
            if Instant::now() >= d {
                self.metrics.deadline_misses.inc();
                self.metrics.rejected.inc();
                return Err(SubmitError::DeadlineExceeded);
            }
        }
        let (tx, rx) = mpsc::channel();
        let id = self.ids.fetch_add(1, Ordering::Relaxed);
        // brownout (or the caller) may run only a strided subset of the
        // pyramid; scale 0 always runs so a response is never empty-by-
        // construction
        let n_scales = self.pyramid.sizes.len();
        let scales: Vec<usize> = (0..n_scales).step_by(scale_stride.max(1)).collect();
        // a video frame is admitted into its session here: the tile diff
        // runs once per request (before fan-out), so every scale worker
        // sees one consistent ticket
        let ticket = session.map(|sid| self.ctx.sessions.begin_frame(sid, &image, &self.metrics));
        let state = Arc::new(ImageState {
            id,
            image,
            started: Instant::now(),
            deadline,
            top_k: top_k.unwrap_or(self.ctx.top_k),
            mode,
            ticket,
            downgrade,
            aborted: AtomicU8::new(ABORT_NONE),
            remaining: Mutex::new(scales.len()),
            candidates: Mutex::new(Vec::with_capacity(self.pyramid.max_candidates())),
            done_tx: Mutex::new(Some(tx)),
        });
        for scale_idx in scales {
            let admitted = match deadline {
                Some(d) => self.slots.push_deadline((), d),
                None => {
                    if self.slots.push(()) {
                        PushOutcome::Pushed
                    } else {
                        PushOutcome::Closed
                    }
                }
            };
            match admitted {
                PushOutcome::Pushed => {}
                PushOutcome::Closed => {
                    return Err(self.roll_back(&state, SubmitError::ShuttingDown));
                }
                PushOutcome::TimedOut => {
                    self.metrics.deadline_misses.inc();
                    return Err(self.roll_back(&state, SubmitError::DeadlineExceeded));
                }
            }
            self.inflight.inc();
            let task = ScaleTask { scale_idx, state: state.clone() };
            let ctx = self.ctx.clone();
            let slots = self.slots.clone();
            let inflight = self.inflight.clone();
            let work: Box<dyn FnOnce() + Send> = Box::new(move || {
                // Admission ends when execution begins — the old dedicated
                // workers popped the queue *before* running, so `queue_depth`
                // bounds queued (not executing) scale tasks, and a
                // queue_depth smaller than the worker count cannot throttle
                // execution concurrency.
                let _ = slots.pop();
                // A panicking backend must neither kill the pool worker nor
                // strand the image: the loss is recorded and the scale still
                // completes (empty), so the image finalizes as WorkerLost.
                let candidates =
                    match catch_unwind(AssertUnwindSafe(|| compute_scale(&task, &ctx))) {
                        Ok(c) => c,
                        Err(_) => {
                            eprintln!("[coordinator] scale {scale_idx} task panicked");
                            task.state.abort(ABORT_WORKER_LOST);
                            Vec::new()
                        }
                    };
                // A panicking *finalization* (after the happy-path send
                // became impossible) still resolves the caller.
                if catch_unwind(AssertUnwindSafe(|| complete_scale(&task, candidates, &ctx)))
                    .is_err()
                {
                    eprintln!(
                        "[coordinator] image {} finalization panicked",
                        task.state.id
                    );
                    // count the loss even when the sender was already taken
                    // (a panic after take_tx still resolves the caller via
                    // the dropped sender → RecvError → WorkerLost)
                    ctx.metrics.worker_lost.inc();
                    if let Some(tx) = take_tx(&task.state) {
                        let _ = tx.send(Err(ResponseError::WorkerLost));
                    }
                }
                inflight.dec();
            });
            // Sharded coordinators enqueue on their home lane so the pool's
            // work-stealing can rebalance a hot shard onto idle siblings'
            // workers; standalone ones use the shared injector.
            match self.lane {
                Some(l) => pool::global().execute_on(l, work),
                None => pool::global().execute(work),
            }
        }
        self.metrics.requests.inc();
        Ok((id, rx, state))
    }

    /// Mid-image admission failure: mark the image aborted so its
    /// already-enqueued scale tasks become no-ops (they still release
    /// their slots and inflight bookkeeping), take the response sender so
    /// nothing ever fires on the dead channel, and hand the error back.
    fn roll_back(&self, state: &Arc<ImageState>, err: SubmitError) -> SubmitError {
        state.abort(if err == SubmitError::DeadlineExceeded {
            ABORT_DEADLINE
        } else {
            ABORT_CANCELLED
        });
        let _ = take_tx(state);
        self.metrics.rejected.inc();
        err
    }

    /// Submit a batch and wait for every result (a dynamic batching round:
    /// up to `max_batch` images in flight together; their scales interleave
    /// over the worker pool). Results come back in submission order; a
    /// refused submission surfaces as `Err(Rejected(_))` in its slot.
    pub fn serve_batch(
        &self,
        images: Vec<ImageRgb>,
    ) -> Vec<Result<ProposalResponse, ResponseError>> {
        serve_batch_with(images, self.config.max_batch, |img| self.submit(img), |h| h.wait())
    }

    /// [`Self::serve_batch`] through the full cascade: every image becomes
    /// a default [`DetectRequest`] and resolves to detections.
    pub fn detect_batch(
        &self,
        images: Vec<ImageRgb>,
    ) -> Vec<Result<DetectResponse, ResponseError>> {
        serve_batch_with(
            images,
            self.config.max_batch,
            |img| self.submit_detect(DetectRequest::new(img)),
            |h| h.wait(),
        )
    }

    /// Refuse all future submissions and wake any submitter blocked at the
    /// admission gate (their partial images roll back cleanly). In-flight
    /// scale tasks keep running; pair with [`Self::wait_idle`] to drain.
    /// Idempotent.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.slots.close();
    }

    /// Whether [`Self::close`] has run (submissions will be refused).
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Block until every scale task this coordinator enqueued has finished
    /// (the graceful-drain barrier; new submissions may still arrive unless
    /// [`Self::close`] was called or the router stopped sending).
    pub fn wait_idle(&self) {
        self.inflight.wait_zero();
    }

    /// Scale tasks currently waiting in the admission queue (not yet
    /// picked up by a pool worker).
    pub fn queued_tasks(&self) -> usize {
        self.slots.len()
    }

    /// Outstanding scale tasks — queued *or* executing (the `LeastLoaded`
    /// routing signal: admission tokens are released the moment execution
    /// starts, so the queue alone reads 0 under normal load).
    pub fn inflight_tasks(&self) -> usize {
        *self.inflight.count.lock().unwrap()
    }

    /// Graceful shutdown: refuse new submissions and drain in-flight scale
    /// tasks (runs on Drop too; consuming `self` just makes it explicit).
    pub fn shutdown(self) {
        drop(self);
    }

    /// Backpressure engagements observed by the admission gate.
    pub fn queue_full_events(&self) -> u64 {
        self.slots.full_events()
    }
}

impl<B: ?Sized> Drop for Coordinator<B> {
    fn drop(&mut self) {
        self.closed.store(true, Ordering::Release);
        // wake any submitter blocked at the gate (its image rolls back),
        // then wait for our tasks — every submitted task releases its slot
        // and decrements inflight on the shared pool, which stays running
        self.slots.close();
        self.inflight.wait_zero();
    }
}

/// The batching loop shared by the `serve_batch`/`detect_batch` entry
/// points on `Coordinator` and `serving::ServerRuntime`: chunk by
/// `max_batch`, submit the whole chunk, then wait it out in submission
/// order, surfacing refusals as `Err(Rejected(_))` in their slot. Generic
/// over the handle kind so both payloads share one loop.
pub(crate) fn serve_batch_with<H, T>(
    images: Vec<ImageRgb>,
    max_batch: usize,
    submit: impl Fn(ImageRgb) -> Result<H, SubmitError>,
    wait: impl Fn(H) -> Result<ServeResponse<T>, ResponseError>,
) -> Vec<Result<ServeResponse<T>, ResponseError>> {
    let max_batch = max_batch.max(1);
    let mut results = Vec::with_capacity(images.len());
    let mut images = images.into_iter();
    loop {
        // move each owned image straight into its submission — no per-image
        // pixel-buffer copy on the batch path
        let handles: Vec<_> = images.by_ref().take(max_batch).map(&submit).collect();
        if handles.is_empty() {
            break;
        }
        for handle in handles {
            results.push(match handle {
                Ok(h) => wait(h),
                Err(e) => Err(ResponseError::Rejected(e)),
            });
        }
    }
    results
}

/// One (image, scale) unit: ask the backend for this scale's candidates
/// (software pipeline, engine executable or cycle simulation — the generic
/// seam) and record telemetry. Aborted images (cancelled, expired, worker
/// lost, rolled back) skip the backend entirely — cooperative cancellation.
fn compute_scale<B: ProposalBackend + ?Sized>(
    task: &ScaleTask,
    ctx: &WorkerCtx<B>,
) -> Vec<Candidate> {
    let state = &task.state;
    if state.abort_cause() != ABORT_NONE {
        return Vec::new();
    }
    if state.past_deadline() {
        state.abort(ABORT_DEADLINE);
        return Vec::new();
    }
    let (h, w) = ctx.backend.pyramid().sizes[task.scale_idx];
    let t0 = Instant::now();
    // a session frame scores through the backend's per-session cache seam
    // (bit-identical to the stateless path; incremental when warm)
    let result = match &state.ticket {
        Some(ticket) => ctx.backend.scale_candidates_session(task.scale_idx, ticket),
        None => ctx.backend.scale_candidates(&state.image, task.scale_idx),
    };
    match result {
        Ok(out) => {
            ctx.metrics.exec_latency.record(t0.elapsed());
            ctx.metrics.scale_executions.inc();
            ctx.metrics.candidates_seen.add(out.candidates.len() as u64);
            if let Some(cycles) = out.sim_cycles {
                ctx.metrics.sim_cycles.add(cycles);
            }
            // Ring-1 SDC defense: a scale result violating a structural
            // invariant (window outside the score map, count beyond the
            // NMS cap, score beyond the weight-implied bound) aborts the
            // whole image as Corrupt — validated corruption must never
            // reach the ranking stage, let alone a caller. Corrupt is
            // retryable, so the resilient serving layer fails the request
            // over to another shard.
            if let Some(policy) = &ctx.integrity {
                if let Err(v) = policy.validate_scale(task.scale_idx, &out.candidates) {
                    eprintln!(
                        "[coordinator] image {} integrity violation: {v}",
                        state.id
                    );
                    ctx.metrics.integrity_violations.inc();
                    state.abort(ABORT_CORRUPT);
                    return Vec::new();
                }
            }
            out.candidates
        }
        Err(e) => {
            // A failed scale must fail the whole image: completing it with
            // an empty candidate set would return a *plausible but wrong*
            // proposal list (silently breaking bit-parity with the
            // fault-free run). Abort as Transient so the resilient serving
            // layer can re-submit to another shard.
            eprintln!("[coordinator] scale {h}x{w} failed: {e:#}");
            ctx.metrics.transient_errors.inc();
            state.abort(ABORT_TRANSIENT);
            Vec::new()
        }
    }
}

/// Record one finished scale; the last scale finalizes the image inline
/// (cheap: a few hundred candidates through the bubble heap) — as a
/// response on the happy path, or as the image's abort cause otherwise.
fn complete_scale<B: ProposalBackend + ?Sized>(
    task: &ScaleTask,
    candidates: Vec<Candidate>,
    ctx: &WorkerCtx<B>,
) {
    let state = &task.state;
    if !candidates.is_empty() {
        state.candidates.lock().unwrap().extend(candidates);
    }
    let done = {
        let mut remaining = state.remaining.lock().unwrap();
        *remaining -= 1;
        *remaining == 0
    };
    if !done {
        return;
    }
    // Completing after the deadline is still a miss — this final check
    // keeps the counter exact even when every per-task check raced ahead.
    if state.abort_cause() == ABORT_NONE && state.past_deadline() {
        state.abort(ABORT_DEADLINE);
    }
    let Some(tx) = take_tx(state) else { return };
    match state.abort_cause() {
        ABORT_CANCELLED => {
            ctx.metrics.cancellations.inc();
            let _ = tx.send(Err(ResponseError::Cancelled));
        }
        ABORT_DEADLINE => {
            ctx.metrics.deadline_misses.inc();
            let _ = tx.send(Err(ResponseError::DeadlineExceeded));
        }
        ABORT_WORKER_LOST => {
            ctx.metrics.worker_lost.inc();
            let _ = tx.send(Err(ResponseError::WorkerLost));
        }
        ABORT_TRANSIENT => {
            let _ = tx.send(Err(ResponseError::Transient));
        }
        ABORT_CORRUPT => {
            let _ = tx.send(Err(ResponseError::Corrupt));
        }
        _ => {
            // take the aggregate out from under its lock before the heavier
            // ranking runs — finalization must never panic while holding a
            // mutex the recovery path needs
            let cands = std::mem::take(&mut *state.candidates.lock().unwrap());
            // a video frame seeds the top-k heap with the previous frame's
            // winners (raising the eviction floor early — never changing
            // the selection) and records this frame's winners as the next
            // frame's priors
            let priors: &[(u16, u16, u16)] =
                state.ticket.as_ref().map_or(&[], |t| t.priors());
            let selection = rank_and_select_seeded(
                &cands,
                ctx.backend.pyramid(),
                &ctx.stage2,
                state.image.w,
                state.image.h,
                state.top_k,
                priors,
            );
            ctx.metrics.prior_hits.add(selection.prior_hits);
            if let Some(ticket) = &state.ticket {
                ticket.store_priors(&selection.winners);
            }
            let proposals = selection.proposals;
            // Ring-1, outer ring: the response-level contract (count ≤ k,
            // descending scores, boxes inside the frame). Catches ranking-
            // stage corruption the per-scale validators cannot see.
            if ctx.integrity.is_some() {
                if let Err(v) = IntegrityPolicy::validate_response(
                    &proposals,
                    state.top_k,
                    state.image.w,
                    state.image.h,
                ) {
                    eprintln!("[coordinator] image {} response integrity violation: {v}", state.id);
                    ctx.metrics.integrity_violations.inc();
                    let _ = tx.send(Err(ResponseError::Corrupt));
                    return;
                }
            }
            // a detect request runs the cascade here, on the same worker
            // that finalized the proposals — one request, one response;
            // a brownout-downgraded detect takes the proposals-only cheap
            // cascade (no NMS) instead
            let payload = match &state.mode {
                RequestMode::Proposals => Payload::Proposals(proposals),
                RequestMode::Detect(params) if state.downgrade.proposals_only => {
                    Payload::Detections(run_cascade_lite(&proposals, params))
                }
                RequestMode::Detect(params) => {
                    Payload::Detections(run_cascade(&proposals, params))
                }
            };
            let latency = state.started.elapsed();
            ctx.metrics.e2e_latency.record(latency);
            ctx.metrics.images_done.inc();
            let _ = tx.send(Ok(RawResponse {
                id: state.id,
                payload,
                latency,
                downgrade: state.downgrade,
            }));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;
    use crate::runtime::MockEngine;

    fn make(sizes: Vec<(usize, usize)>, cfg: ServingConfig) -> Coordinator<EngineBackend> {
        let engine = Arc::new(MockEngine::new(default_stage1(), sizes.clone()));
        Coordinator::new(
            engine,
            Pyramid::new(sizes.clone()),
            Stage2Calibration::identity(sizes),
            cfg,
        )
    }

    #[test]
    fn injected_corruption_resolves_as_corrupt_not_payload() {
        use crate::fault::{ChaosBackend, FaultPlan};
        let sizes = vec![(16, 16), (32, 32)];
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes.clone()),
            ScoringMode::Exact,
        );
        let plan = FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(11) };
        let chaos = Arc::new(ChaosBackend::new(Arc::new(sw), plan));
        let coord = Coordinator::with_backend(
            chaos.clone(),
            Stage2Calibration::identity(sizes),
            ServingConfig::default(),
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let err = coord.submit(img).unwrap().wait().unwrap_err();
        assert_eq!(err, ResponseError::Corrupt, "validated corruption must not reach the caller");
        assert!(coord.metrics.integrity_violations.get() >= 1);
        assert!(chaos.injected_corrupts.get() >= 1);
        coord.shutdown();
    }

    #[test]
    fn integrity_validation_can_be_disabled_by_config() {
        use crate::fault::{ChaosBackend, FaultPlan};
        let sizes = vec![(16, 16), (32, 32)];
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes.clone()),
            ScoringMode::Exact,
        );
        let plan = FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(11) };
        let chaos = Arc::new(ChaosBackend::new(Arc::new(sw), plan));
        let mut cfg = ServingConfig::default();
        cfg.integrity.validate = false;
        let coord =
            Coordinator::with_backend(chaos, Stage2Calibration::identity(sizes), cfg);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        // With the ring disabled the corrupted payload sails through — this
        // is exactly the escape the default-on policy exists to prevent.
        let resp = coord.submit(img).unwrap().wait();
        assert!(resp.is_ok(), "validation off ⇒ corruption is not intercepted");
        assert_eq!(coord.metrics.integrity_violations.get(), 0);
        coord.shutdown();
    }

    #[test]
    fn serves_one_image_matching_baseline() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes.clone(), ServingConfig { top_k: 50, ..Default::default() });
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = coord.submit(img.clone()).unwrap().wait().unwrap();
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        );
        assert_eq!(resp.items, sw.propose(&img, 50));
        coord.shutdown();
    }

    #[test]
    fn per_request_top_k_overrides_config() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig { top_k: 1000, ..Default::default() });
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = coord
            .submit_request(ProposalRequest::new(img).top_k(5))
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(resp.items.len(), 5);
        coord.shutdown();
    }

    #[test]
    fn detect_request_resolves_to_calibrated_detections() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let proposals = coord.submit(img.clone()).unwrap().wait().unwrap().items;
        let resp = coord
            .submit_detect(DetectRequest::new(img).top_k(8))
            .unwrap()
            .wait()
            .unwrap();
        assert!(!resp.items.is_empty());
        assert!(resp.items.len() <= 8);
        for d in &resp.items {
            assert!((0.0..=1.0).contains(&d.confidence));
            assert!(
                proposals.iter().any(|p| p.bbox == d.bbox && p.score == d.score),
                "every detection must come from the proposal pool"
            );
        }
        assert_eq!(coord.metrics.images_done.get(), 2);
        coord.shutdown();
    }

    #[test]
    fn batch_preserves_request_order() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig { max_batch: 4, ..Default::default() });
        let ds = SyntheticDataset::voc_like_val(6);
        let images: Vec<_> = ds.iter().map(|s| s.image).collect();
        let responses = coord.serve_batch(images);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            let r = r.as_ref().expect("all responses succeed");
            assert_eq!(r.id, i as u64 + 1);
            assert!(!r.items.is_empty());
        }
        assert_eq!(coord.metrics.images_done.get(), 6);
        assert_eq!(coord.metrics.scale_executions.get(), 12);
        coord.shutdown();
    }

    #[test]
    fn concurrent_images_do_not_mix_candidates() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes.clone(), ServingConfig { workers: 8, ..Default::default() });
        let ds = SyntheticDataset::voc_like_val(4);
        let images: Vec<_> = ds.iter().map(|s| s.image).collect();
        let responses = coord.serve_batch(images.clone());
        // each response must equal the serial pipeline for its own image
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        );
        for (img, resp) in images.iter().zip(&responses) {
            let resp = resp.as_ref().unwrap();
            assert_eq!(resp.items, sw.propose(img, 1000));
        }
        coord.shutdown();
    }

    #[test]
    fn tiny_queue_engages_backpressure_and_still_completes() {
        let sizes = vec![(16, 16), (32, 32), (64, 64), (128, 128)];
        let coord = make(
            sizes,
            ServingConfig { queue_depth: 2, workers: 2, ..Default::default() },
        );
        let ds = SyntheticDataset::voc_like_val(3);
        let responses = coord.serve_batch(ds.iter().map(|s| s.image).collect());
        assert_eq!(responses.len(), 3);
        assert!(responses.iter().all(|r| r.is_ok()));
        coord.shutdown();
    }

    #[test]
    fn metrics_summary_is_populated() {
        let sizes = vec![(16, 16)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let _ = coord.submit(img).unwrap().wait().unwrap();
        let summary = coord.metrics.summary();
        assert!(summary.contains("images=1"), "{summary}");
        assert!(summary.contains("deadline_miss=0"), "{summary}");
        coord.shutdown();
    }

    #[test]
    fn scale_stride_runs_a_subset_without_marking_a_downgrade() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = coord
            .submit_request(ProposalRequest::new(img).scale_stride(2))
            .unwrap()
            .wait()
            .unwrap();
        // scales 0 and 2 ran; scale 1 was skipped
        assert_eq!(coord.metrics.scale_executions.get(), 2);
        assert!(!resp.items.is_empty());
        // a *caller-requested* stride is full fidelity, not a brownout
        assert!(!resp.downgrade.any());
        coord.shutdown();
    }

    #[test]
    fn cancel_token_resolves_like_cancel() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let handle = coord.submit(img).unwrap();
        let token = handle.cancel_token();
        token.cancel();
        token.cancel(); // idempotent
        // best-effort: either the cancel landed first or the image already
        // finalized — both are legal resolutions, nothing hangs
        match handle.wait() {
            Ok(r) => assert!(!r.items.is_empty()),
            Err(e) => assert_eq!(e, ResponseError::Cancelled),
        }
        coord.shutdown();
    }

    #[test]
    fn wait_until_times_out_and_hands_the_handle_back() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let handle = coord.submit(img).unwrap();
        // an already-expired wait bound must come back immediately…
        let handle = match handle.wait_until(Instant::now()) {
            Err(h) => h,
            Ok(r) => {
                // …unless the response already landed, which is also fine
                assert!(!r.unwrap().items.is_empty());
                coord.shutdown();
                return;
            }
        };
        // …and a generous bound resolves normally
        let resp = handle
            .wait_until(Instant::now() + Duration::from_secs(30))
            .expect("resolves within bound")
            .expect("happy path");
        assert!(!resp.items.is_empty());
        coord.shutdown();
    }

    #[test]
    fn drop_waits_for_inflight_tasks() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let handle = coord.submit(img).unwrap();
        drop(coord); // must drain the submitted scales, not orphan them
        let resp = handle.wait().expect("response still arrives after drop");
        assert!(!resp.items.is_empty());
    }

    #[test]
    fn closed_coordinator_rejects_instead_of_asserting() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig::default());
        coord.close();
        coord.close(); // idempotent
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        assert_eq!(coord.submit(img).unwrap_err(), SubmitError::ShuttingDown);
        assert_eq!(coord.metrics.rejected.get(), 1);
        assert_eq!(coord.metrics.requests.get(), 0, "a refused submit is not a request");
        coord.shutdown();
    }

    #[test]
    fn expired_deadline_is_refused_at_admission() {
        let sizes = vec![(16, 16)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let past = Instant::now() - Duration::from_millis(5);
        assert_eq!(
            coord.submit_deadline(img, Some(past)).unwrap_err(),
            SubmitError::DeadlineExceeded
        );
        assert_eq!(coord.metrics.deadline_misses.get(), 1);
        assert_eq!(coord.metrics.rejected.get(), 1);
        coord.shutdown();
    }

    // NOTE: dyn-dispatch serving over the simulator (Coordinator<dyn
    // ProposalBackend> + sim-cycle telemetry) is covered end to end in
    // tests/backend_parity.rs; the poisoned-backend, cancellation and
    // in-flight deadline lifecycles in tests/integration_coordinator.rs;
    // the sharded router in src/serving/ and tests/serving_soak.rs.
}
