//! L3 coordinator — the serving layer around the per-scale executables.
//!
//! ```text
//!   submit(image) ──► router (bounded queue, backpressure)
//!        │                     │ one task per (image, scale)
//!        │            worker pool (N threads)
//!        │              resize → ScaleExecutor::execute → winners
//!        │                     │
//!        └──◄ aggregator: when all scales of an image land →
//!             SVM stage-II calibration → bubble-pushing heap top-k →
//!             Response { proposals, latency }
//! ```
//!
//! Resizing lives here (it is the paper's resize module, L3's job — the
//! executables take the already-resized image), and Python never runs on
//! this path. The final ranking is [`crate::baseline::rank_and_select`], the
//! exact code the software baseline uses, so serving results are
//! bit-identical to the reference pipeline given the same engine outputs.

mod scheduler;

pub use scheduler::TaskQueue;

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::baseline::rank_and_select;
use crate::bing::{winners_from_mask, Candidate, Proposal, Pyramid};
use crate::config::ServingConfig;
use crate::image::ImageRgb;
use crate::runtime::ScaleExecutor;
use crate::svm::Stage2Calibration;
use crate::telemetry::ServeMetrics;

/// A completed response.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub proposals: Vec<Proposal>,
    pub latency: std::time::Duration,
}

/// One (image, scale) work item.
struct ScaleTask {
    scale_idx: usize,
    state: Arc<ImageState>,
}

/// Aggregation state for one in-flight image.
struct ImageState {
    id: u64,
    image: ImageRgb,
    started: Instant,
    remaining: Mutex<usize>,
    candidates: Mutex<Vec<Candidate>>,
    done_tx: Mutex<Option<mpsc::Sender<Response>>>,
}

/// Everything a worker needs to finish an image.
struct WorkerCtx {
    engine: Arc<dyn ScaleExecutor>,
    pyramid: Pyramid,
    stage2: Stage2Calibration,
    top_k: usize,
    metrics: Arc<ServeMetrics>,
}

/// The coordinator: router + worker pool + aggregator.
pub struct Coordinator {
    queue: Arc<TaskQueue<ScaleTask>>,
    workers: Vec<JoinHandle<()>>,
    pyramid: Pyramid,
    config: ServingConfig,
    pub metrics: Arc<ServeMetrics>,
    next_id: std::sync::atomic::AtomicU64,
}

impl Coordinator {
    /// Spawn the worker pool against an engine (PJRT or mock).
    pub fn new(
        engine: Arc<dyn ScaleExecutor>,
        pyramid: Pyramid,
        stage2: Stage2Calibration,
        config: ServingConfig,
    ) -> Self {
        assert_eq!(
            engine.sizes(),
            &pyramid.sizes[..],
            "engine pyramid must match coordinator pyramid"
        );
        assert_eq!(
            pyramid.sizes, stage2.sizes,
            "stage-II calibration must cover the pyramid"
        );
        let metrics = Arc::new(ServeMetrics::default());
        let queue: Arc<TaskQueue<ScaleTask>> = TaskQueue::new(config.queue_depth.max(1));
        let ctx = Arc::new(WorkerCtx {
            engine,
            pyramid: pyramid.clone(),
            stage2,
            top_k: config.top_k,
            metrics: metrics.clone(),
        });
        let mut workers = Vec::with_capacity(config.workers.max(1));
        for _ in 0..config.workers.max(1) {
            let queue = queue.clone();
            let ctx = ctx.clone();
            workers.push(std::thread::spawn(move || worker_loop(queue, ctx)));
        }
        Self {
            queue,
            workers,
            pyramid,
            config,
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit one image; returns a receiver for its response. Blocks when
    /// the task queue is full (backpressure).
    pub fn submit(&self, image: ImageRgb) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.metrics.requests.inc();
        let n_scales = self.pyramid.sizes.len();
        let state = Arc::new(ImageState {
            id,
            image,
            started: Instant::now(),
            remaining: Mutex::new(n_scales),
            candidates: Mutex::new(Vec::with_capacity(self.pyramid.max_candidates())),
            done_tx: Mutex::new(Some(tx)),
        });
        for scale_idx in 0..n_scales {
            let ok = self
                .queue
                .push(ScaleTask { scale_idx, state: state.clone() });
            assert!(ok, "coordinator queue closed while submitting");
        }
        rx
    }

    /// Submit a batch and wait for all responses (a dynamic batching round:
    /// up to `max_batch` images in flight together; their scales interleave
    /// over the worker pool).
    pub fn serve_batch(&self, images: Vec<ImageRgb>) -> Vec<Response> {
        let mut responses = Vec::with_capacity(images.len());
        for chunk in images.chunks(self.config.max_batch.max(1)) {
            let rxs: Vec<_> = chunk.iter().map(|img| self.submit(img.clone())).collect();
            for rx in rxs {
                responses.push(rx.recv().expect("worker pool died"));
            }
        }
        responses.sort_by_key(|r| r.id);
        responses
    }

    /// Graceful shutdown: drain and join workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Backpressure engagements observed by the router.
    pub fn queue_full_events(&self) -> u64 {
        self.queue.full_events()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(queue: Arc<TaskQueue<ScaleTask>>, ctx: Arc<WorkerCtx>) {
    while let Some(task) = queue.pop() {
        let (h, w) = ctx.pyramid.sizes[task.scale_idx];
        let t0 = Instant::now();
        // resize module (L3's job), then the AOT executable
        let resized = task.state.image.resize_nearest(w, h);
        let candidates = match ctx.engine.execute(task.scale_idx, &resized) {
            Ok(out) => {
                ctx.metrics.exec_latency.record(t0.elapsed());
                ctx.metrics.scale_executions.inc();
                let winners = winners_from_mask(&out.scores, &out.mask, out.oh, out.ow);
                ctx.metrics.candidates_seen.add(winners.len() as u64);
                winners
                    .into_iter()
                    .map(|win| Candidate {
                        scale_idx: task.scale_idx,
                        x: win.x,
                        y: win.y,
                        score: win.score,
                    })
                    .collect()
            }
            Err(e) => {
                // a serving system must not wedge on one bad scale: log and
                // complete the scale with no candidates
                eprintln!("[coordinator] scale {h}x{w} failed: {e:#}");
                Vec::new()
            }
        };
        complete_scale(&task, candidates, &ctx);
    }
}

/// Record one finished scale; the last scale finalizes the image inline
/// (cheap: a few hundred candidates through the bubble heap).
fn complete_scale(task: &ScaleTask, candidates: Vec<Candidate>, ctx: &WorkerCtx) {
    let state = &task.state;
    state.candidates.lock().unwrap().extend(candidates);
    let mut remaining = state.remaining.lock().unwrap();
    *remaining -= 1;
    let done = *remaining == 0;
    drop(remaining);
    if done {
        if let Some(tx) = state.done_tx.lock().unwrap().take() {
            let cands = state.candidates.lock().unwrap();
            let proposals = rank_and_select(
                &cands,
                &ctx.pyramid,
                &ctx.stage2,
                state.image.w,
                state.image.h,
                ctx.top_k,
            );
            drop(cands);
            ctx.metrics.e2e_latency.record(state.started.elapsed());
            ctx.metrics.images_done.inc();
            let _ = tx.send(Response {
                id: state.id,
                proposals,
                latency: state.started.elapsed(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;
    use crate::runtime::MockEngine;

    fn make(sizes: Vec<(usize, usize)>, cfg: ServingConfig) -> Coordinator {
        let engine = Arc::new(MockEngine::new(default_stage1(), sizes.clone()));
        Coordinator::new(
            engine,
            Pyramid::new(sizes.clone()),
            Stage2Calibration::identity(sizes),
            cfg,
        )
    }

    #[test]
    fn serves_one_image_matching_baseline() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes.clone(), ServingConfig { top_k: 50, ..Default::default() });
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = coord.submit(img.clone()).recv().unwrap();
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        );
        assert_eq!(resp.proposals, sw.propose(&img, 50));
        coord.shutdown();
    }

    #[test]
    fn batch_preserves_request_order() {
        let sizes = vec![(16, 16), (32, 32)];
        let coord = make(sizes, ServingConfig { max_batch: 4, ..Default::default() });
        let ds = SyntheticDataset::voc_like_val(6);
        let images: Vec<_> = ds.iter().map(|s| s.image).collect();
        let responses = coord.serve_batch(images);
        assert_eq!(responses.len(), 6);
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.id, i as u64 + 1);
            assert!(!r.proposals.is_empty());
        }
        assert_eq!(coord.metrics.images_done.get(), 6);
        assert_eq!(coord.metrics.scale_executions.get(), 12);
        coord.shutdown();
    }

    #[test]
    fn concurrent_images_do_not_mix_candidates() {
        let sizes = vec![(16, 16), (32, 32), (64, 64)];
        let coord = make(sizes.clone(), ServingConfig { workers: 8, ..Default::default() });
        let ds = SyntheticDataset::voc_like_val(4);
        let images: Vec<_> = ds.iter().map(|s| s.image).collect();
        let responses = coord.serve_batch(images.clone());
        // each response must equal the serial pipeline for its own image
        let sw = SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        );
        for (img, resp) in images.iter().zip(&responses) {
            assert_eq!(resp.proposals, sw.propose(img, 1000));
        }
        coord.shutdown();
    }

    #[test]
    fn tiny_queue_engages_backpressure_and_still_completes() {
        let sizes = vec![(16, 16), (32, 32), (64, 64), (128, 128)];
        let coord = make(
            sizes,
            ServingConfig { queue_depth: 2, workers: 2, ..Default::default() },
        );
        let ds = SyntheticDataset::voc_like_val(3);
        let responses = coord.serve_batch(ds.iter().map(|s| s.image).collect());
        assert_eq!(responses.len(), 3);
        coord.shutdown();
    }

    #[test]
    fn metrics_summary_is_populated() {
        let sizes = vec![(16, 16)];
        let coord = make(sizes, ServingConfig::default());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let _ = coord.submit(img).recv().unwrap();
        let summary = coord.metrics.summary();
        assert!(summary.contains("images=1"), "{summary}");
        coord.shutdown();
    }
}
