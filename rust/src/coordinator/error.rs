//! The serving error surface, consolidated in one place.
//!
//! Three layers, all `std::error::Error + Display`, none leaking internal
//! channel types:
//!
//! * [`SubmitError`] — refusals at the admission gate (the request never
//!   entered the system);
//! * [`ResponseError`] — admitted requests that resolved without a payload;
//! * [`ServeError`] — the umbrella for callers who `?` across both phases
//!   (`From` impls on each side).

/// Why a submission was refused at the admission gate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The coordinator (or its runtime) is shutting down; any scale tasks
    /// already enqueued for this image were rolled back to no-ops.
    ShuttingDown,
    /// The request's deadline expired before it could be admitted.
    DeadlineExceeded,
    /// No shard accepts new work (every shard is draining).
    Unroutable,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::ShuttingDown => write!(f, "serving is shutting down"),
            SubmitError::DeadlineExceeded => {
                write!(f, "deadline expired before the request was admitted")
            }
            SubmitError::Unroutable => write!(f, "no shard accepts new work (all draining)"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an admitted request resolved without a payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResponseError {
    /// The worker or finalization for this image panicked (or its channel
    /// was dropped); the serving loop survived and surfaced the loss.
    WorkerLost,
    /// The request was cancelled via its handle's `cancel`.
    Cancelled,
    /// The request missed its deadline (cooperatively expired in flight or
    /// detected at completion).
    DeadlineExceeded,
    /// A backend returned a transient `Err` for one of the request's scale
    /// tasks. The whole request aborts (a partial scale set would silently
    /// break bit-parity) and is safe to retry on another shard.
    Transient,
    /// The integrity validators caught a structural invariant violation in
    /// this request's output (silent data corruption at the backend seam).
    /// The whole request aborts — corrupted data must never reach a caller
    /// — and, like `Transient`, it is safe to retry on another shard whose
    /// hardware is presumably not flipping bits.
    Corrupt,
    /// The submission itself was refused (batch slots and the resilient
    /// `ServerRuntime::serve` family fold admission refusals in here so
    /// one error type covers the whole request).
    Rejected(SubmitError),
}

impl ResponseError {
    /// Whether re-submitting the same request (ideally to a different
    /// shard) can plausibly succeed. Drives `serving::RetryPolicy`.
    pub fn retryable(&self) -> bool {
        matches!(
            self,
            ResponseError::WorkerLost | ResponseError::Transient | ResponseError::Corrupt
        )
    }
}

impl std::fmt::Display for ResponseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResponseError::WorkerLost => write!(f, "worker lost (panic during serving)"),
            ResponseError::Cancelled => write!(f, "request cancelled"),
            ResponseError::DeadlineExceeded => write!(f, "request missed its deadline"),
            ResponseError::Transient => {
                write!(f, "transient backend failure (safe to retry)")
            }
            ResponseError::Corrupt => {
                write!(f, "output failed integrity validation (corruption contained)")
            }
            ResponseError::Rejected(e) => write!(f, "rejected at submission: {e}"),
        }
    }
}

impl std::error::Error for ResponseError {}

/// The one-type error surface: everything a request through the serving
/// stack (proposals or detections) can fail with. `From` impls let a caller
/// write `runtime.submit(img)?.wait()?` inside a
/// `Result<_, ServeError>` function without matching on the phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeError {
    /// Refused at the admission gate.
    Submit(SubmitError),
    /// Admitted but resolved without a payload.
    Response(ResponseError),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Submit(e) => write!(f, "submit: {e}"),
            ServeError::Response(e) => write!(f, "response: {e}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Submit(e) => Some(e),
            ServeError::Response(e) => Some(e),
        }
    }
}

impl From<SubmitError> for ServeError {
    fn from(e: SubmitError) -> Self {
        ServeError::Submit(e)
    }
}

impl From<ResponseError> for ServeError {
    fn from(e: ResponseError) -> Self {
        ServeError::Response(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn umbrella_wraps_both_phases() {
        fn roundtrip(r: Result<(), SubmitError>) -> Result<(), ServeError> {
            r?;
            Ok(())
        }
        assert_eq!(
            roundtrip(Err(SubmitError::Unroutable)),
            Err(ServeError::Submit(SubmitError::Unroutable))
        );
        let e: ServeError = ResponseError::Cancelled.into();
        assert_eq!(e, ServeError::Response(ResponseError::Cancelled));
    }

    #[test]
    fn only_lost_workers_transients_and_corruption_are_retryable() {
        assert!(ResponseError::WorkerLost.retryable());
        assert!(ResponseError::Transient.retryable());
        assert!(ResponseError::Corrupt.retryable());
        assert!(!ResponseError::Cancelled.retryable());
        assert!(!ResponseError::DeadlineExceeded.retryable());
        assert!(!ResponseError::Rejected(SubmitError::Unroutable).retryable());
    }

    #[test]
    fn displays_are_human_readable_and_sourced() {
        use std::error::Error;
        let e = ServeError::Response(ResponseError::Rejected(SubmitError::ShuttingDown));
        assert_eq!(
            e.to_string(),
            "response: rejected at submission: serving is shutting down"
        );
        assert!(e.source().is_some());
    }
}
