//! The typed request/response vocabulary of the redesigned serving API.
//!
//! Requests are built with a consuming builder (`ProposalRequest::new(img)
//! .top_k(200).deadline_in(ms)`); responses are one generic
//! [`ServeResponse<T>`] over the payload kind — [`ProposalResponse`] for the
//! proposal stage, [`DetectResponse`] for the full cascade. The legacy
//! [`Response`] name stays as an alias for `ProposalResponse` (migration
//! note: the payload field is now `items`, not `proposals`).

use std::time::{Duration, Instant};

use crate::bing::Proposal;
use crate::detect::Detection;
use crate::image::ImageRgb;

/// What the runtime took away from a request to keep serving it under
/// pressure. Attached to every [`ServeResponse`] so callers can tell a
/// full-fidelity answer from a brownout-degraded one.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Downgrade {
    /// `top_k` was capped below what the request/config asked for.
    pub top_k_capped: bool,
    /// Only a strided subset of the scale pyramid ran.
    pub reduced_scales: bool,
    /// Detect request served through the proposals-only cheap cascade
    /// (no NMS; proposals mapped straight to calibrated detections).
    pub proposals_only: bool,
}

impl Downgrade {
    /// Whether any degradation was applied (false ⇒ bit-parity with a
    /// fault-free, pressure-free run is guaranteed).
    pub fn any(&self) -> bool {
        self.top_k_capped || self.reduced_scales || self.proposals_only
    }
}

/// A proposal-stage request: one image plus per-request options. `None`
/// options fall back to the serving config.
#[derive(Debug)]
pub struct ProposalRequest {
    pub(crate) image: ImageRgb,
    pub(crate) top_k: Option<usize>,
    pub(crate) deadline: Option<Instant>,
    pub(crate) scale_stride: usize,
    /// Video-session id (see [`crate::temporal`]): frames of one session
    /// share a dirty-tile frame cache and prior-seeded ranking. `None` =
    /// the stateless single-image path.
    pub(crate) session: Option<u64>,
    /// Set by the brownout controller, never by callers: records what was
    /// shed so the response can carry it back.
    pub(crate) downgrade: Downgrade,
}

impl ProposalRequest {
    pub fn new(image: ImageRgb) -> Self {
        Self {
            image,
            top_k: None,
            deadline: None,
            scale_stride: 1,
            session: None,
            downgrade: Downgrade::default(),
        }
    }

    /// Mark this request as frame of video session `id` — consecutive
    /// frames of one session are scored incrementally against the
    /// session's cached previous frame (bit-identical to full recompute)
    /// and, under the `session` route policy, pinned to one shard.
    pub fn session(mut self, id: u64) -> Self {
        self.session = Some(id);
        self
    }

    /// Override the number of proposals returned (default:
    /// `ServingConfig::top_k`).
    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = Some(k);
        self
    }

    /// Run only every `s`-th pyramid scale (1 = all scales, the default).
    /// Cuts work roughly by `1/s` at a recall cost; the brownout
    /// controller uses the same knob under overload.
    pub fn scale_stride(mut self, s: usize) -> Self {
        assert!(s >= 1, "scale_stride must be >= 1");
        self.scale_stride = s;
        self
    }

    /// Absolute per-request deadline (default: `ServingConfig::deadline_ms`).
    pub fn deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Relative per-request deadline, measured from now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }
}

/// A detection request: one image through the full cascade (proposals →
/// NMS → Platt confidence). `None` options fall back to
/// `ServingConfig::cascade` / `deadline_ms`.
#[derive(Debug)]
pub struct DetectRequest {
    pub(crate) image: ImageRgb,
    pub(crate) deadline: Option<Instant>,
    /// Max *detections* returned (the proposal pool stays at the serving
    /// config's `top_k`).
    pub(crate) top_k: Option<usize>,
    pub(crate) nms_thresh: Option<f32>,
    pub(crate) min_confidence: Option<f32>,
    pub(crate) scale_stride: usize,
    pub(crate) downgrade: Downgrade,
}

impl DetectRequest {
    pub fn new(image: ImageRgb) -> Self {
        Self {
            image,
            deadline: None,
            top_k: None,
            nms_thresh: None,
            min_confidence: None,
            scale_stride: 1,
            downgrade: Downgrade::default(),
        }
    }

    /// Run only every `s`-th pyramid scale (1 = all scales, the default).
    pub fn scale_stride(mut self, s: usize) -> Self {
        assert!(s >= 1, "scale_stride must be >= 1");
        self.scale_stride = s;
        self
    }

    /// Override the maximum detections returned (default:
    /// `CascadeConfig::top_k`).
    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = Some(k);
        self
    }

    /// Override the greedy-NMS IoU threshold (default:
    /// `CascadeConfig::nms_thresh`). Must be in `[0, 1]`.
    pub fn nms_thresh(mut self, t: f32) -> Self {
        assert!((0.0..=1.0).contains(&t), "nms_thresh is an IoU ratio");
        self.nms_thresh = Some(t);
        self
    }

    /// Override the confidence floor (default:
    /// `CascadeConfig::min_confidence`).
    pub fn min_confidence(mut self, c: f32) -> Self {
        self.min_confidence = Some(c);
        self
    }

    /// Absolute per-request deadline (default: `ServingConfig::deadline_ms`).
    pub fn deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Relative per-request deadline, measured from now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }
}

/// A completed response, generic over the payload kind.
#[derive(Debug)]
pub struct ServeResponse<T> {
    /// Unique, monotone across shards.
    pub id: u64,
    /// The payload: proposals or detections, best first.
    pub items: Vec<T>,
    /// Submission-to-finalization latency.
    pub latency: Duration,
    /// What, if anything, the brownout controller shed from this request
    /// (`Downgrade::default()` ⇒ full fidelity).
    pub downgrade: Downgrade,
}

/// Proposal-stage response.
pub type ProposalResponse = ServeResponse<Proposal>;

/// Full-cascade response.
pub type DetectResponse = ServeResponse<Detection>;

/// Legacy name for [`ProposalResponse`] (pre-cascade API). The payload
/// field moved from `proposals` to the generic `items`.
pub type Response = ProposalResponse;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDataset;

    #[test]
    fn builders_accumulate_options() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let req = ProposalRequest::new(img.clone()).top_k(77).deadline_in(Duration::from_secs(5));
        assert_eq!(req.top_k, Some(77));
        assert!(req.deadline.unwrap() > Instant::now());
        assert_eq!(req.session, None, "stateless unless opted in");
        let vid = ProposalRequest::new(img.clone()).session(9);
        assert_eq!(vid.session, Some(9));

        let det = DetectRequest::new(img).top_k(10).nms_thresh(0.3).min_confidence(0.25);
        assert_eq!(det.top_k, Some(10));
        assert_eq!(det.nms_thresh, Some(0.3));
        assert_eq!(det.min_confidence, Some(0.25));
        assert_eq!(det.deadline, None);
        assert_eq!(det.scale_stride, 1);
        assert!(!det.downgrade.any());
    }

    #[test]
    fn downgrade_any_tracks_every_flag() {
        assert!(!Downgrade::default().any());
        assert!(Downgrade { top_k_capped: true, ..Default::default() }.any());
        assert!(Downgrade { reduced_scales: true, ..Default::default() }.any());
        assert!(Downgrade { proposals_only: true, ..Default::default() }.any());
    }

    #[test]
    #[should_panic(expected = "scale_stride")]
    fn zero_scale_stride_is_refused() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let _ = ProposalRequest::new(img).scale_stride(0);
    }

    #[test]
    #[should_panic(expected = "IoU ratio")]
    fn nms_thresh_must_be_a_ratio() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let _ = DetectRequest::new(img).nms_thresh(1.5);
    }
}
