//! The typed request/response vocabulary of the redesigned serving API.
//!
//! Requests are built with a consuming builder (`ProposalRequest::new(img)
//! .top_k(200).deadline_in(ms)`); responses are one generic
//! [`ServeResponse<T>`] over the payload kind — [`ProposalResponse`] for the
//! proposal stage, [`DetectResponse`] for the full cascade. The legacy
//! [`Response`] name stays as an alias for `ProposalResponse` (migration
//! note: the payload field is now `items`, not `proposals`).

use std::time::{Duration, Instant};

use crate::bing::Proposal;
use crate::detect::Detection;
use crate::image::ImageRgb;

/// A proposal-stage request: one image plus per-request options. `None`
/// options fall back to the serving config.
#[derive(Debug)]
pub struct ProposalRequest {
    pub(crate) image: ImageRgb,
    pub(crate) top_k: Option<usize>,
    pub(crate) deadline: Option<Instant>,
}

impl ProposalRequest {
    pub fn new(image: ImageRgb) -> Self {
        Self { image, top_k: None, deadline: None }
    }

    /// Override the number of proposals returned (default:
    /// `ServingConfig::top_k`).
    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = Some(k);
        self
    }

    /// Absolute per-request deadline (default: `ServingConfig::deadline_ms`).
    pub fn deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Relative per-request deadline, measured from now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }
}

/// A detection request: one image through the full cascade (proposals →
/// NMS → Platt confidence). `None` options fall back to
/// `ServingConfig::cascade` / `deadline_ms`.
#[derive(Debug)]
pub struct DetectRequest {
    pub(crate) image: ImageRgb,
    pub(crate) deadline: Option<Instant>,
    /// Max *detections* returned (the proposal pool stays at the serving
    /// config's `top_k`).
    pub(crate) top_k: Option<usize>,
    pub(crate) nms_thresh: Option<f32>,
    pub(crate) min_confidence: Option<f32>,
}

impl DetectRequest {
    pub fn new(image: ImageRgb) -> Self {
        Self {
            image,
            deadline: None,
            top_k: None,
            nms_thresh: None,
            min_confidence: None,
        }
    }

    /// Override the maximum detections returned (default:
    /// `CascadeConfig::top_k`).
    pub fn top_k(mut self, k: usize) -> Self {
        self.top_k = Some(k);
        self
    }

    /// Override the greedy-NMS IoU threshold (default:
    /// `CascadeConfig::nms_thresh`). Must be in `[0, 1]`.
    pub fn nms_thresh(mut self, t: f32) -> Self {
        assert!((0.0..=1.0).contains(&t), "nms_thresh is an IoU ratio");
        self.nms_thresh = Some(t);
        self
    }

    /// Override the confidence floor (default:
    /// `CascadeConfig::min_confidence`).
    pub fn min_confidence(mut self, c: f32) -> Self {
        self.min_confidence = Some(c);
        self
    }

    /// Absolute per-request deadline (default: `ServingConfig::deadline_ms`).
    pub fn deadline(mut self, at: Instant) -> Self {
        self.deadline = Some(at);
        self
    }

    /// Relative per-request deadline, measured from now.
    pub fn deadline_in(self, budget: Duration) -> Self {
        self.deadline(Instant::now() + budget)
    }
}

/// A completed response, generic over the payload kind.
#[derive(Debug)]
pub struct ServeResponse<T> {
    /// Unique, monotone across shards.
    pub id: u64,
    /// The payload: proposals or detections, best first.
    pub items: Vec<T>,
    /// Submission-to-finalization latency.
    pub latency: Duration,
}

/// Proposal-stage response.
pub type ProposalResponse = ServeResponse<Proposal>;

/// Full-cascade response.
pub type DetectResponse = ServeResponse<Detection>;

/// Legacy name for [`ProposalResponse`] (pre-cascade API). The payload
/// field moved from `proposals` to the generic `items`.
pub type Response = ProposalResponse;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticDataset;

    #[test]
    fn builders_accumulate_options() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let req = ProposalRequest::new(img.clone()).top_k(77).deadline_in(Duration::from_secs(5));
        assert_eq!(req.top_k, Some(77));
        assert!(req.deadline.unwrap() > Instant::now());

        let det = DetectRequest::new(img).top_k(10).nms_thresh(0.3).min_confidence(0.25);
        assert_eq!(det.top_k, Some(10));
        assert_eq!(det.nms_thresh, Some(0.3));
        assert_eq!(det.min_confidence, Some(0.25));
        assert_eq!(det.deadline, None);
    }

    #[test]
    #[should_panic(expected = "IoU ratio")]
    fn nms_thresh_must_be_a_ratio() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let _ = DetectRequest::new(img).nms_thresh(1.5);
    }
}
