//! Work-queue scheduler: bounded task queue with backpressure + worker pool.
//!
//! The unit of work is one (image, scale) execution — the same granularity
//! the FPGA time-multiplexes scales through its pipelines. A bounded queue
//! provides backpressure to the router (`submit` blocks when the system is
//! saturated), and a condvar-based pool replaces tokio in this offline
//! environment.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A closed, bounded MPMC queue.
pub struct TaskQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    closed: AtomicBool,
}

struct QueueState<T> {
    q: VecDeque<T>,
    /// producer-side blocking events (the backpressure signal)
    pub full_events: u64,
}

impl<T> TaskQueue<T> {
    pub fn new(cap: usize) -> Arc<Self> {
        assert!(cap > 0);
        Arc::new(Self {
            inner: Mutex::new(QueueState { q: VecDeque::with_capacity(cap), full_events: 0 }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
            closed: AtomicBool::new(false),
        })
    }

    /// Blocking push; returns false if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        if st.q.len() >= self.cap {
            st.full_events += 1;
        }
        while st.q.len() >= self.cap {
            if self.closed.load(Ordering::Acquire) {
                return false;
            }
            st = self.not_full.wait(st).unwrap();
        }
        if self.closed.load(Ordering::Acquire) {
            return false;
        }
        st.q.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Blocking pop; returns None when the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(v) = st.q.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(v);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: producers fail, consumers drain then get None.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Times a producer found the queue full (backpressure engagements).
    pub fn full_events(&self) -> u64 {
        self.inner.lock().unwrap().full_events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order_single_thread() {
        let q = TaskQueue::new(4);
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_unblocks_consumers() {
        let q: Arc<TaskQueue<u32>> = TaskQueue::new(2);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn backpressure_blocks_then_releases() {
        let q = TaskQueue::new(1);
        q.push(10);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(20));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(10));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(20));
        assert!(q.full_events() >= 1);
    }

    #[test]
    fn mpmc_transfers_everything_exactly_once() {
        let q: Arc<TaskQueue<u64>> = TaskQueue::new(8);
        let total = 1000u64;
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let q = q.clone();
            producers.push(thread::spawn(move || {
                for i in 0..total / 4 {
                    q.push(p * 1_000_000 + i);
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total as usize, "lost or duplicated items");
    }

    #[test]
    fn closed_queue_rejects_push() {
        let q = TaskQueue::new(1);
        q.close();
        assert!(!q.push(5));
    }
}
