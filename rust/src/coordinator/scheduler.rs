//! Work-queue scheduler: bounded task queue with backpressure + worker pool.
//!
//! The unit of work is one (image, scale) execution — the same granularity
//! the FPGA time-multiplexes scales through its pipelines. A bounded queue
//! provides backpressure to the router (`submit` blocks when the system is
//! saturated), and a condvar-based pool replaces tokio in this offline
//! environment.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::telemetry::{Counter, Gauge};

/// Outcome of a deadline-bounded [`TaskQueue::push_deadline`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// The item was enqueued.
    Pushed,
    /// The queue was closed before the item could be enqueued.
    Closed,
    /// The deadline expired while waiting for a free slot.
    TimedOut,
}

/// A closed, bounded MPMC queue.
pub struct TaskQueue<T> {
    inner: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    closed: AtomicBool,
    /// Optional shared telemetry counter mirroring `full_events` — the
    /// coordinator wires its `ServeMetrics::queue_full_events` here so the
    /// reported backpressure number is exact (counted under the queue
    /// mutex), not sampled, and aggregates across shards.
    sink: Option<Arc<Counter>>,
    /// Optional queue-depth gauge (a sharded coordinator's telemetry
    /// lane), updated under the queue mutex on every push/pop — exact and
    /// free of extra lock acquisitions.
    depth: Option<Arc<Gauge>>,
}

struct QueueState<T> {
    q: VecDeque<T>,
    /// producer-side blocking events (the backpressure signal)
    pub full_events: u64,
}

impl<T> TaskQueue<T> {
    pub fn new(cap: usize) -> Arc<Self> {
        Self::build(cap, None, None)
    }

    /// A queue wired into serving telemetry: every full-event increments
    /// `sink` and (when given) every push/pop publishes the queue depth to
    /// `depth` — both under the queue mutex, so the numbers are exact.
    pub fn with_sinks(
        cap: usize,
        sink: Arc<Counter>,
        depth: Option<Arc<Gauge>>,
    ) -> Arc<Self> {
        Self::build(cap, Some(sink), depth)
    }

    fn build(cap: usize, sink: Option<Arc<Counter>>, depth: Option<Arc<Gauge>>) -> Arc<Self> {
        assert!(cap > 0);
        Arc::new(Self {
            inner: Mutex::new(QueueState { q: VecDeque::with_capacity(cap), full_events: 0 }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap,
            closed: AtomicBool::new(false),
            sink,
            depth,
        })
    }

    /// Record one producer-found-the-queue-full event (exact: callers hold
    /// the queue mutex via `st`).
    fn note_full(&self, st: &mut QueueState<T>) {
        st.full_events += 1;
        if let Some(sink) = &self.sink {
            sink.inc();
        }
    }

    /// Publish the current depth to the gauge (callers hold the mutex).
    fn note_depth(&self, st: &QueueState<T>) {
        if let Some(depth) = &self.depth {
            depth.set(st.q.len() as u64);
        }
    }

    /// Blocking push; returns false if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.lock().unwrap();
        if st.q.len() >= self.cap {
            self.note_full(&mut st);
        }
        while st.q.len() >= self.cap {
            if self.closed.load(Ordering::Acquire) {
                return false;
            }
            st = self.not_full.wait(st).unwrap();
        }
        if self.closed.load(Ordering::Acquire) {
            return false;
        }
        st.q.push_back(item);
        self.note_depth(&st);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Deadline-bounded blocking push: waits for a free slot only until
    /// `deadline` — the admission half of deadline-aware serving. A request
    /// whose deadline passes while the gate is saturated is turned away
    /// instead of blocking past its own budget.
    pub fn push_deadline(&self, item: T, deadline: Instant) -> PushOutcome {
        let mut st = self.inner.lock().unwrap();
        if st.q.len() >= self.cap {
            self.note_full(&mut st);
        }
        while st.q.len() >= self.cap {
            if self.closed.load(Ordering::Acquire) {
                return PushOutcome::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                // baton passing: this thread may have consumed a not_full
                // wakeup it is now abandoning — re-notify so another blocked
                // producer gets the freed slot instead of hanging
                drop(st);
                self.not_full.notify_one();
                return PushOutcome::TimedOut;
            }
            let (guard, _timeout) = self.not_full.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        if self.closed.load(Ordering::Acquire) {
            return PushOutcome::Closed;
        }
        st.q.push_back(item);
        self.note_depth(&st);
        drop(st);
        self.not_empty.notify_one();
        PushOutcome::Pushed
    }

    /// Blocking pop; returns None when the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.inner.lock().unwrap();
        loop {
            if let Some(v) = st.q.pop_front() {
                self.note_depth(&st);
                drop(st);
                self.not_full.notify_one();
                return Some(v);
            }
            if self.closed.load(Ordering::Acquire) {
                return None;
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Close the queue: producers fail, consumers drain then get None.
    pub fn close(&self) {
        // set the flag while holding the queue mutex: a waiter is then
        // either before its closed-check (sees true) or already parked in
        // wait (caught by the notify below) — never between the two, where
        // an unlocked store+notify could slip past it and strand it forever
        let guard = self.inner.lock().unwrap();
        self.closed.store(true, Ordering::Release);
        drop(guard);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Times a producer found the queue full (backpressure engagements).
    pub fn full_events(&self) -> u64 {
        self.inner.lock().unwrap().full_events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order_single_thread() {
        let q = TaskQueue::new(4);
        q.push(1);
        q.push(2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_unblocks_consumers() {
        let q: Arc<TaskQueue<u32>> = TaskQueue::new(2);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop());
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn backpressure_blocks_then_releases() {
        let q = TaskQueue::new(1);
        q.push(10);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.push(20));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(10));
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(20));
        assert!(q.full_events() >= 1);
    }

    #[test]
    fn mpmc_transfers_everything_exactly_once() {
        let q: Arc<TaskQueue<u64>> = TaskQueue::new(8);
        let total = 1000u64;
        let mut producers = Vec::new();
        for p in 0..4u64 {
            let q = q.clone();
            producers.push(thread::spawn(move || {
                for i in 0..total / 4 {
                    q.push(p * 1_000_000 + i);
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let q = q.clone();
            consumers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = q.pop() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total as usize, "lost or duplicated items");
    }

    #[test]
    fn closed_queue_rejects_push() {
        let q = TaskQueue::new(1);
        q.close();
        assert!(!q.push(5));
    }

    #[test]
    fn push_deadline_succeeds_with_room() {
        let q = TaskQueue::new(2);
        let d = Instant::now() + Duration::from_millis(50);
        assert_eq!(q.push_deadline(1, d), PushOutcome::Pushed);
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn push_deadline_times_out_on_a_full_queue() {
        let q = TaskQueue::new(1);
        q.push(1);
        let t0 = Instant::now();
        let out = q.push_deadline(2, t0 + Duration::from_millis(20));
        assert_eq!(out, PushOutcome::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(20), "returned early");
        assert!(q.full_events() >= 1);
        // the stuck item never entered the queue
        assert_eq!(q.pop(), Some(1));
        assert!(q.is_empty());
    }

    #[test]
    fn push_deadline_reports_closed_over_timeout() {
        let q: Arc<TaskQueue<u32>> = TaskQueue::new(1);
        q.push(1);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            q2.push_deadline(2, Instant::now() + Duration::from_secs(5))
        });
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), PushOutcome::Closed);
    }
}
