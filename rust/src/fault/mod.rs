//! Deterministic fault injection for the serving stack.
//!
//! The chaos harness every robustness test and `benches/chaos_bench.rs`
//! drive: a seeded [`FaultPlan`] decides, per scale task, whether to
//! inject a panic (→ the coordinator's `catch_unwind` containment →
//! `ResponseError::WorkerLost`), a transient `Err` (→
//! `ResponseError::Transient`, the retryable abort), or extra latency —
//! and [`ChaosBackend`] applies those decisions in front of any inner
//! [`ProposalBackend`].
//!
//! Determinism contract: a fault decision is a pure function of
//! `(seed, scale_idx, n)` where `n` is the per-scale call ordinal. Thread
//! interleaving does not change *which* calls fault (only which request a
//! faulting call belongs to), and — critically for retry testing — a
//! retried scale task is a *new* call with a new ordinal, so it re-rolls
//! rather than deterministically failing forever. The whole fault schedule
//! reproduces from the seed.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::backend::{ProposalBackend, ScaleCandidates};
use crate::bing::Pyramid;
use crate::config::ResilienceConfig;
use crate::image::ImageRgb;
use crate::telemetry::Counter;
use crate::util::Rng;

/// What the plan injects into one scale-task execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectedFault {
    /// Delegate to the inner backend untouched.
    None,
    /// Panic inside `scale_candidates` (exercises worker-loss containment).
    Panic,
    /// Return a transient `Err` (exercises the typed retryable path).
    Transient,
    /// Sleep before delegating (exercises deadline and hedge paths).
    Latency(Duration),
}

/// A seeded, deterministic fault schedule. Probabilities are disjoint
/// bands of one uniform draw per decision, so
/// `panic_p + transient_p + latency_p` must stay ≤ 1.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    pub panic_p: f64,
    pub transient_p: f64,
    pub latency_p: f64,
    pub latency: Duration,
}

impl FaultPlan {
    /// A plan with the `ResilienceConfig` default fault rates.
    pub fn seeded(seed: u64) -> Self {
        Self::from_config(seed, &ResilienceConfig::default())
    }

    /// Build from the `resilience.chaos_*` knobs (the CLI path).
    pub fn from_config(seed: u64, cfg: &ResilienceConfig) -> Self {
        let plan = Self {
            seed,
            panic_p: cfg.chaos_panic_p,
            transient_p: cfg.chaos_transient_p,
            latency_p: cfg.chaos_latency_p,
            latency: Duration::from_millis(cfg.chaos_latency_ms),
        };
        assert!(
            plan.panic_p + plan.transient_p + plan.latency_p <= 1.0 + 1e-9,
            "fault probabilities must sum to <= 1"
        );
        plan
    }

    /// The deterministic decision for the `n`-th call on `scale_idx`.
    /// One fresh SplitMix64-seeded generator per decision keyed on
    /// `(seed, scale_idx, n)` — no shared RNG state, so concurrency cannot
    /// perturb the schedule.
    pub fn decide(&self, scale_idx: usize, n: u64) -> InjectedFault {
        let key = self
            .seed
            .wrapping_add((scale_idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(n.wrapping_mul(0xD1B5_4A32_D192_ED03));
        let roll = Rng::seed_from_u64(key).f64();
        if roll < self.panic_p {
            InjectedFault::Panic
        } else if roll < self.panic_p + self.transient_p {
            InjectedFault::Transient
        } else if roll < self.panic_p + self.transient_p + self.latency_p {
            InjectedFault::Latency(self.latency)
        } else {
            InjectedFault::None
        }
    }
}

/// A [`ProposalBackend`] decorator that injects the plan's faults in front
/// of any inner backend — the same wrapper works over `SoftwareBing`, the
/// engine, the simulator, or `dyn ProposalBackend` (the CLI path).
///
/// `set_enabled(false)` ends the fault window at runtime; recovery tests
/// use it to let a quarantined shard's probes succeed.
pub struct ChaosBackend<B: ?Sized> {
    plan: FaultPlan,
    enabled: AtomicBool,
    /// Per-scale call ordinals — the `n` fed to [`FaultPlan::decide`].
    calls: Vec<AtomicU64>,
    /// Injection tallies (for exact accounting in tests and the bench).
    pub injected_panics: Counter,
    pub injected_transients: Counter,
    pub injected_latencies: Counter,
    inner: Arc<B>,
}

impl<B: ProposalBackend + ?Sized> ChaosBackend<B> {
    pub fn new(inner: Arc<B>, plan: FaultPlan) -> Self {
        let n_scales = inner.pyramid().sizes.len();
        Self {
            plan,
            enabled: AtomicBool::new(true),
            calls: (0..n_scales).map(|_| AtomicU64::new(0)).collect(),
            injected_panics: Counter::default(),
            injected_transients: Counter::default(),
            injected_latencies: Counter::default(),
            inner,
        }
    }

    /// Open/close the fault window (injection on by default).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Arc<B> {
        &self.inner
    }

    /// Total faults injected so far (panics + transients + latencies).
    pub fn injected_total(&self) -> u64 {
        self.injected_panics.get()
            + self.injected_transients.get()
            + self.injected_latencies.get()
    }
}

impl<B: ProposalBackend + ?Sized> ProposalBackend for ChaosBackend<B> {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn pyramid(&self) -> &Pyramid {
        self.inner.pyramid()
    }

    fn scale_candidates(&self, img: &ImageRgb, scale_idx: usize) -> Result<ScaleCandidates> {
        if self.is_enabled() {
            let n = self.calls[scale_idx].fetch_add(1, Ordering::Relaxed);
            match self.plan.decide(scale_idx, n) {
                InjectedFault::None => {}
                InjectedFault::Panic => {
                    self.injected_panics.inc();
                    panic!("chaos: injected panic (scale {scale_idx}, call {n})");
                }
                InjectedFault::Transient => {
                    self.injected_transients.inc();
                    return Err(anyhow!(
                        "chaos: injected transient failure (scale {scale_idx}, call {n})"
                    ));
                }
                InjectedFault::Latency(d) => {
                    self.injected_latencies.inc();
                    std::thread::sleep(d);
                }
            }
        }
        self.inner.scale_candidates(img, scale_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;
    use crate::svm::Stage2Calibration;

    fn software() -> Arc<SoftwareBing> {
        let sizes = vec![(16, 16), (32, 32)];
        Arc::new(SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        ))
    }

    fn heavy_plan(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            panic_p: 0.2,
            transient_p: 0.3,
            latency_p: 0.2,
            latency: Duration::from_micros(100),
        }
    }

    #[test]
    fn decisions_are_deterministic_in_seed_scale_and_ordinal() {
        let plan = heavy_plan(42);
        for scale in 0..4 {
            for n in 0..64 {
                assert_eq!(plan.decide(scale, n), plan.decide(scale, n));
            }
        }
        // a different seed produces a different schedule somewhere
        let other = heavy_plan(43);
        let differs = (0..64).any(|n| plan.decide(0, n) != other.decide(0, n));
        assert!(differs, "seeds 42 and 43 produced identical schedules");
    }

    #[test]
    fn band_rates_approach_the_configured_probabilities() {
        let plan = heavy_plan(7);
        let n = 4000;
        let mut counts = [0usize; 4];
        for i in 0..n {
            match plan.decide(0, i) {
                InjectedFault::None => counts[0] += 1,
                InjectedFault::Panic => counts[1] += 1,
                InjectedFault::Transient => counts[2] += 1,
                InjectedFault::Latency(_) => counts[3] += 1,
            }
        }
        let rate = |c: usize| c as f64 / n as f64;
        assert!((rate(counts[1]) - 0.2).abs() < 0.05, "panic rate {}", rate(counts[1]));
        assert!((rate(counts[2]) - 0.3).abs() < 0.05, "transient rate {}", rate(counts[2]));
        assert!((rate(counts[3]) - 0.2).abs() < 0.05, "latency rate {}", rate(counts[3]));
    }

    #[test]
    fn zero_rate_plan_is_transparent_and_bit_identical() {
        let inner = software();
        let plan = FaultPlan {
            seed: 1,
            panic_p: 0.0,
            transient_p: 0.0,
            latency_p: 0.0,
            latency: Duration::ZERO,
        };
        let chaos = ChaosBackend::new(inner.clone(), plan);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        for scale in 0..2 {
            let a = chaos.scale_candidates(&img, scale).unwrap();
            let b = inner.scale_candidates(&img, scale).unwrap();
            assert_eq!(a.candidates, b.candidates);
        }
        assert_eq!(chaos.injected_total(), 0);
    }

    #[test]
    fn disabled_chaos_injects_nothing_even_at_rate_one() {
        let chaos = ChaosBackend::new(
            software(),
            FaultPlan {
                seed: 3,
                panic_p: 1.0,
                transient_p: 0.0,
                latency_p: 0.0,
                latency: Duration::ZERO,
            },
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        chaos.set_enabled(false);
        assert!(chaos.scale_candidates(&img, 0).is_ok());
        chaos.set_enabled(true);
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = chaos.scale_candidates(&img, 0);
        }));
        assert!(hit.is_err(), "re-enabled chaos at rate 1.0 must panic");
        assert_eq!(chaos.injected_panics.get(), 1);
    }

    #[test]
    fn transient_faults_surface_as_errors_with_tally() {
        let chaos = ChaosBackend::new(
            software(),
            FaultPlan {
                seed: 5,
                panic_p: 0.0,
                transient_p: 1.0,
                latency_p: 0.0,
                latency: Duration::ZERO,
            },
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        for _ in 0..3 {
            assert!(chaos.scale_candidates(&img, 1).is_err());
        }
        assert_eq!(chaos.injected_transients.get(), 3);
        assert_eq!(chaos.name(), "chaos");
        assert_eq!(chaos.pyramid().sizes, chaos.inner().pyramid().sizes);
    }
}
