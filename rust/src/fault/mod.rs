//! Deterministic fault injection for the serving stack.
//!
//! The chaos harness every robustness test and `benches/chaos_bench.rs`
//! drive: a seeded [`FaultPlan`] decides, per scale task, whether to
//! inject a panic (→ the coordinator's `catch_unwind` containment →
//! `ResponseError::WorkerLost`), a transient `Err` (→
//! `ResponseError::Transient`, the retryable abort), extra latency, a
//! *silent corruption* of the scale's candidates (→ caught by the
//! `integrity` validators → `ResponseError::Corrupt`), or a *hang* (a
//! sleep far past any deadline, modeling a wedged worker rather than a
//! slow one → contained by the pool's stall reaper) — and
//! [`ChaosBackend`] applies those decisions in front of any inner
//! [`ProposalBackend`].
//!
//! Determinism contract: a fault decision is a pure function of
//! `(seed, scale_idx, n)` where `n` is the per-scale call ordinal. Thread
//! interleaving does not change *which* calls fault (only which request a
//! faulting call belongs to), and — critically for retry testing — a
//! retried scale task is a *new* call with a new ordinal, so it re-rolls
//! rather than deterministically failing forever. The whole fault schedule
//! reproduces from the seed.
//!
//! Corruption contract: every corruption style violates a structural
//! invariant checked by [`crate::integrity::IntegrityPolicy::validate_scale`]
//! (a score beyond the weight-implied bound, or a window coordinate beyond
//! the scale's score-map dims). The chaos layer exercises the *defense*,
//! so an injected corruption is always detectable — undetectable SDC is
//! the golden-probe auditor's department, not the injector's.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::backend::{ProposalBackend, ScaleCandidates};
use crate::bing::{Candidate, Pyramid};
use crate::config::ResilienceConfig;
use crate::image::ImageRgb;
use crate::telemetry::Counter;
use crate::util::Rng;

/// What the plan injects into one scale-task execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectedFault {
    /// Delegate to the inner backend untouched.
    None,
    /// Panic inside `scale_candidates` (exercises worker-loss containment).
    Panic,
    /// Return a transient `Err` (exercises the typed retryable path).
    Transient,
    /// Sleep before delegating (exercises deadline and hedge paths).
    Latency(Duration),
    /// Delegate, then deterministically perturb the result's scores/boxes
    /// (exercises the integrity validators and golden-probe audits).
    Corrupt,
    /// Sleep far past any plausible deadline before delegating
    /// (exercises wedged-worker detection and replacement).
    Hang(Duration),
}

/// A seeded, deterministic fault schedule. Probabilities are disjoint
/// bands of one uniform draw per decision, so
/// `panic_p + transient_p + latency_p + corrupt_p + hang_p` must stay ≤ 1
/// (checked by [`FaultPlan::validate`]).
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    pub panic_p: f64,
    pub transient_p: f64,
    pub latency_p: f64,
    pub latency: Duration,
    pub corrupt_p: f64,
    pub hang_p: f64,
    pub hang: Duration,
}

impl FaultPlan {
    /// A plan with the `ResilienceConfig` default fault rates.
    pub fn seeded(seed: u64) -> Self {
        Self::from_config(seed, &ResilienceConfig::default())
    }

    /// A plan that injects nothing — the base for test literals that turn
    /// exactly one band on (`FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(7) }`).
    pub fn zero(seed: u64) -> Self {
        Self {
            seed,
            panic_p: 0.0,
            transient_p: 0.0,
            latency_p: 0.0,
            latency: Duration::ZERO,
            corrupt_p: 0.0,
            hang_p: 0.0,
            hang: Duration::ZERO,
        }
    }

    /// Build from the `resilience.chaos_*` knobs (the CLI path).
    pub fn from_config(seed: u64, cfg: &ResilienceConfig) -> Self {
        let plan = Self {
            seed,
            panic_p: cfg.chaos_panic_p,
            transient_p: cfg.chaos_transient_p,
            latency_p: cfg.chaos_latency_p,
            latency: Duration::from_millis(cfg.chaos_latency_ms),
            corrupt_p: cfg.chaos_corrupt_p,
            hang_p: cfg.chaos_hang_p,
            hang: Duration::from_millis(cfg.chaos_hang_ms),
        };
        if let Err(e) = plan.validate() {
            panic!("invalid fault plan from config: {e}");
        }
        plan
    }

    /// Check the band invariants: every probability in `[0, 1]` and the
    /// bands disjoint (sum ≤ 1). Struct-literal construction skips
    /// `from_config`, so [`ChaosBackend::new`] calls this too — a plan
    /// cannot reach the injection path unvalidated.
    pub fn validate(&self) -> std::result::Result<(), String> {
        for (name, p) in [
            ("panic_p", self.panic_p),
            ("transient_p", self.transient_p),
            ("latency_p", self.latency_p),
            ("corrupt_p", self.corrupt_p),
            ("hang_p", self.hang_p),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("{name} must be within [0, 1], got {p}"));
            }
        }
        let sum = self.panic_p + self.transient_p + self.latency_p + self.corrupt_p + self.hang_p;
        if sum > 1.0 + 1e-9 {
            return Err(format!("fault probabilities must sum to <= 1, got {sum}"));
        }
        Ok(())
    }

    /// The deterministic decision for the `n`-th call on `scale_idx`.
    /// One fresh SplitMix64-seeded generator per decision keyed on
    /// `(seed, scale_idx, n)` — no shared RNG state, so concurrency cannot
    /// perturb the schedule.
    pub fn decide(&self, scale_idx: usize, n: u64) -> InjectedFault {
        debug_assert!(self.validate().is_ok(), "{:?}", self.validate());
        let roll = Rng::seed_from_u64(Self::key(self.seed, scale_idx, n)).f64();
        let mut edge = self.panic_p;
        if roll < edge {
            return InjectedFault::Panic;
        }
        edge += self.transient_p;
        if roll < edge {
            return InjectedFault::Transient;
        }
        edge += self.latency_p;
        if roll < edge {
            return InjectedFault::Latency(self.latency);
        }
        edge += self.corrupt_p;
        if roll < edge {
            return InjectedFault::Corrupt;
        }
        edge += self.hang_p;
        if roll < edge {
            return InjectedFault::Hang(self.hang);
        }
        InjectedFault::None
    }

    fn key(seed: u64, scale_idx: usize, n: u64) -> u64 {
        seed.wrapping_add((scale_idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(n.wrapping_mul(0xD1B5_4A32_D192_ED03))
    }
}

/// Decorrelation constant for the corruption style sub-stream (so the
/// style draw does not reuse the band draw's generator state).
const CORRUPT_STREAM: u64 = 0xC0DE_D00D_FEED_FACE;

/// Deterministically perturb one scale's output so that it *always*
/// violates a structural invariant (see the module docs' corruption
/// contract). Keyed on the same `(seed, scale_idx, n)` as the band
/// decision, via a decorrelated sub-stream.
fn corrupt_scale(out: &mut ScaleCandidates, scale_idx: usize, key: u64) {
    let mut rng = Rng::seed_from_u64(key ^ CORRUPT_STREAM);
    if out.candidates.is_empty() {
        // fabricate a candidate no backend could have produced
        out.candidates.push(Candidate {
            scale_idx,
            x: u16::MAX,
            y: u16::MAX,
            score: i32::MAX,
        });
        return;
    }
    let i = (rng.next_u64() as usize) % out.candidates.len();
    let c = &mut out.candidates[i];
    match rng.next_u64() % 3 {
        // a score no weight vector can reach (bound is < 2^23)
        0 => c.score = i32::MAX - (rng.next_u64() % 1024) as i32,
        // a column far beyond any score map's width
        1 => c.x = u16::MAX - (rng.next_u64() % 64) as u16,
        // a row far beyond any score map's height
        _ => c.y = u16::MAX - (rng.next_u64() % 64) as u16,
    }
}

/// A [`ProposalBackend`] decorator that injects the plan's faults in front
/// of any inner backend — the same wrapper works over `SoftwareBing`, the
/// engine, the simulator, or `dyn ProposalBackend` (the CLI path).
///
/// `set_enabled(false)` ends the fault window at runtime; recovery tests
/// use it to let a quarantined shard's probes succeed.
pub struct ChaosBackend<B: ?Sized> {
    plan: FaultPlan,
    enabled: AtomicBool,
    /// Per-scale call ordinals — the `n` fed to [`FaultPlan::decide`].
    calls: Vec<AtomicU64>,
    /// Injection tallies (for exact accounting in tests and the bench).
    pub injected_panics: Counter,
    pub injected_transients: Counter,
    pub injected_latencies: Counter,
    pub injected_corrupts: Counter,
    pub injected_hangs: Counter,
    inner: Arc<B>,
}

impl<B: ProposalBackend + ?Sized> ChaosBackend<B> {
    pub fn new(inner: Arc<B>, plan: FaultPlan) -> Self {
        if let Err(e) = plan.validate() {
            panic!("invalid fault plan: {e}");
        }
        let n_scales = inner.pyramid().sizes.len();
        Self {
            plan,
            enabled: AtomicBool::new(true),
            calls: (0..n_scales).map(|_| AtomicU64::new(0)).collect(),
            injected_panics: Counter::default(),
            injected_transients: Counter::default(),
            injected_latencies: Counter::default(),
            injected_corrupts: Counter::default(),
            injected_hangs: Counter::default(),
            inner,
        }
    }

    /// Open/close the fault window (injection on by default).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Acquire)
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Arc<B> {
        &self.inner
    }

    /// Total faults injected so far (all bands).
    pub fn injected_total(&self) -> u64 {
        self.injected_panics.get()
            + self.injected_transients.get()
            + self.injected_latencies.get()
            + self.injected_corrupts.get()
            + self.injected_hangs.get()
    }
}

impl<B: ProposalBackend + ?Sized> ProposalBackend for ChaosBackend<B> {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn pyramid(&self) -> &Pyramid {
        self.inner.pyramid()
    }

    fn scale_candidates(&self, img: &ImageRgb, scale_idx: usize) -> Result<ScaleCandidates> {
        if self.is_enabled() {
            // A bad index here is a caller bug, not chaos — keep its panic
            // message clearly distinguishable from an injected one.
            let ordinal = self.calls.get(scale_idx).unwrap_or_else(|| {
                panic!(
                    "ChaosBackend: scale_idx {scale_idx} out of range for a \
                     {}-scale pyramid (caller bug, not an injected fault)",
                    self.calls.len()
                )
            });
            let n = ordinal.fetch_add(1, Ordering::Relaxed);
            match self.plan.decide(scale_idx, n) {
                InjectedFault::None => {}
                InjectedFault::Panic => {
                    self.injected_panics.inc();
                    panic!("chaos: injected panic (scale {scale_idx}, call {n})");
                }
                InjectedFault::Transient => {
                    self.injected_transients.inc();
                    return Err(anyhow!(
                        "chaos: injected transient failure (scale {scale_idx}, call {n})"
                    ));
                }
                InjectedFault::Latency(d) => {
                    self.injected_latencies.inc();
                    std::thread::sleep(d);
                }
                InjectedFault::Corrupt => {
                    self.injected_corrupts.inc();
                    let mut out = self.inner.scale_candidates(img, scale_idx)?;
                    let key = FaultPlan::key(self.plan.seed, scale_idx, n);
                    corrupt_scale(&mut out, scale_idx, key);
                    return Ok(out);
                }
                InjectedFault::Hang(d) => {
                    self.injected_hangs.inc();
                    std::thread::sleep(d);
                }
            }
        }
        self.inner.scale_candidates(img, scale_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::default_stage1;
    use crate::data::SyntheticDataset;
    use crate::svm::Stage2Calibration;

    fn software() -> Arc<SoftwareBing> {
        let sizes = vec![(16, 16), (32, 32)];
        Arc::new(SoftwareBing::new(
            Pyramid::new(sizes.clone()),
            default_stage1(),
            Stage2Calibration::identity(sizes),
            ScoringMode::Exact,
        ))
    }

    fn heavy_plan(seed: u64) -> FaultPlan {
        FaultPlan {
            panic_p: 0.2,
            transient_p: 0.3,
            latency_p: 0.2,
            latency: Duration::from_micros(100),
            ..FaultPlan::zero(seed)
        }
    }

    #[test]
    fn decisions_are_deterministic_in_seed_scale_and_ordinal() {
        let plan = heavy_plan(42);
        for scale in 0..4 {
            for n in 0..64 {
                assert_eq!(plan.decide(scale, n), plan.decide(scale, n));
            }
        }
        // a different seed produces a different schedule somewhere
        let other = heavy_plan(43);
        let differs = (0..64).any(|n| plan.decide(0, n) != other.decide(0, n));
        assert!(differs, "seeds 42 and 43 produced identical schedules");
    }

    #[test]
    fn band_rates_approach_the_configured_probabilities() {
        let plan = FaultPlan {
            panic_p: 0.2,
            transient_p: 0.2,
            latency_p: 0.2,
            latency: Duration::from_micros(100),
            corrupt_p: 0.15,
            hang_p: 0.15,
            hang: Duration::from_micros(100),
            ..FaultPlan::zero(7)
        };
        let n = 4000;
        let mut counts = [0usize; 6];
        for i in 0..n {
            match plan.decide(0, i) {
                InjectedFault::None => counts[0] += 1,
                InjectedFault::Panic => counts[1] += 1,
                InjectedFault::Transient => counts[2] += 1,
                InjectedFault::Latency(_) => counts[3] += 1,
                InjectedFault::Corrupt => counts[4] += 1,
                InjectedFault::Hang(_) => counts[5] += 1,
            }
        }
        let rate = |c: usize| c as f64 / n as f64;
        assert!((rate(counts[1]) - 0.2).abs() < 0.05, "panic rate {}", rate(counts[1]));
        assert!((rate(counts[2]) - 0.2).abs() < 0.05, "transient rate {}", rate(counts[2]));
        assert!((rate(counts[3]) - 0.2).abs() < 0.05, "latency rate {}", rate(counts[3]));
        assert!((rate(counts[4]) - 0.15).abs() < 0.05, "corrupt rate {}", rate(counts[4]));
        assert!((rate(counts[5]) - 0.15).abs() < 0.05, "hang rate {}", rate(counts[5]));
    }

    #[test]
    fn zero_rate_plan_is_transparent_and_bit_identical() {
        let inner = software();
        let chaos = ChaosBackend::new(inner.clone(), FaultPlan::zero(1));
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        for scale in 0..2 {
            let a = chaos.scale_candidates(&img, scale).unwrap();
            let b = inner.scale_candidates(&img, scale).unwrap();
            assert_eq!(a.candidates, b.candidates);
        }
        assert_eq!(chaos.injected_total(), 0);
    }

    #[test]
    fn disabled_chaos_injects_nothing_even_at_rate_one() {
        let chaos = ChaosBackend::new(
            software(),
            FaultPlan { panic_p: 1.0, ..FaultPlan::zero(3) },
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        chaos.set_enabled(false);
        assert!(chaos.scale_candidates(&img, 0).is_ok());
        chaos.set_enabled(true);
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = chaos.scale_candidates(&img, 0);
        }));
        assert!(hit.is_err(), "re-enabled chaos at rate 1.0 must panic");
        assert_eq!(chaos.injected_panics.get(), 1);
    }

    #[test]
    fn transient_faults_surface_as_errors_with_tally() {
        let chaos = ChaosBackend::new(
            software(),
            FaultPlan { transient_p: 1.0, ..FaultPlan::zero(5) },
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        for _ in 0..3 {
            assert!(chaos.scale_candidates(&img, 1).is_err());
        }
        assert_eq!(chaos.injected_transients.get(), 3);
        assert_eq!(chaos.name(), "chaos");
        assert_eq!(chaos.pyramid().sizes, chaos.inner().pyramid().sizes);
    }

    #[test]
    fn validate_rejects_overfull_and_out_of_range_bands() {
        let mut plan = FaultPlan::zero(1);
        assert!(plan.validate().is_ok());
        plan.panic_p = 0.5;
        plan.corrupt_p = 0.4;
        plan.hang_p = 0.3;
        assert!(plan.validate().is_err(), "sum 1.2 must be rejected");
        let mut neg = FaultPlan::zero(1);
        neg.transient_p = -0.1;
        assert!(neg.validate().is_err(), "negative probability must be rejected");
        let mut over = FaultPlan::zero(1);
        over.hang_p = 1.5;
        assert!(over.validate().is_err(), "probability > 1 must be rejected");
    }

    #[test]
    #[should_panic(expected = "invalid fault plan")]
    fn chaos_backend_rejects_unvalidated_literal_plans() {
        let _ = ChaosBackend::new(
            software(),
            FaultPlan { panic_p: 0.9, transient_p: 0.9, ..FaultPlan::zero(1) },
        );
    }

    #[test]
    #[should_panic(expected = "caller bug, not an injected fault")]
    fn out_of_range_scale_idx_is_distinguishable_from_chaos() {
        let chaos = ChaosBackend::new(software(), FaultPlan::zero(2));
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let _ = chaos.scale_candidates(&img, 99);
    }

    #[test]
    fn corruption_is_deterministic_and_structurally_detectable() {
        let inner = software();
        let make = || {
            ChaosBackend::new(inner.clone(), FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(11) })
        };
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let a = make().scale_candidates(&img, 0).unwrap();
        let b = make().scale_candidates(&img, 0).unwrap();
        assert_eq!(a.candidates, b.candidates, "same seed+ordinal must corrupt identically");
        let clean = inner.scale_candidates(&img, 0).unwrap();
        assert_ne!(a.candidates, clean.candidates, "corruption must change the output");
        // the corruption contract: some candidate violates a structural bound
        let detectable = a.candidates.iter().any(|c| {
            c.score > crate::integrity::MAX_SCORE_ABS_BOUND
                || c.x >= 32_000
                || c.y >= 32_000
        });
        assert!(detectable, "corruption must violate a structural invariant: {:?}", a.candidates);
    }

    #[test]
    fn corrupt_and_hang_bands_tally() {
        let chaos = ChaosBackend::new(
            software(),
            FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(13) },
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        assert!(chaos.scale_candidates(&img, 0).is_ok());
        assert_eq!(chaos.injected_corrupts.get(), 1);
        assert_eq!(chaos.injected_total(), 1);

        let hangs = ChaosBackend::new(
            software(),
            FaultPlan {
                hang_p: 1.0,
                hang: Duration::from_millis(5),
                ..FaultPlan::zero(17)
            },
        );
        let t0 = std::time::Instant::now();
        assert!(hangs.scale_candidates(&img, 0).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(5), "hang must actually block");
        assert_eq!(hangs.injected_hangs.get(), 1);
    }
}
