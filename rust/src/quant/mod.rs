//! Fixed-point quantization substrate.
//!
//! The paper: "a carefully quantization strategy is adopted to specify
//! various bit-width for different data storage purpose." This module makes
//! those bit-widths explicit, provides saturating fixed-point ops for the
//! dataflow simulator's datapaths, and quantifies the error the strategy
//! introduces (the source of the 97.63% → 94.72% DR gap the paper reports).

/// A signed fixed-point format: `int_bits` integer bits (excluding sign) and
/// `frac_bits` fractional bits, stored in an i64 carrier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedFormat {
    pub int_bits: u32,
    pub frac_bits: u32,
}

impl FixedFormat {
    pub const fn new(int_bits: u32, frac_bits: u32) -> Self {
        assert!(int_bits + frac_bits <= 62);
        Self { int_bits, frac_bits }
    }

    /// Total storage width including sign — what the resource model charges
    /// per register/BRAM entry.
    pub const fn width(&self) -> u32 {
        1 + self.int_bits + self.frac_bits
    }

    pub fn max_raw(&self) -> i64 {
        (1i64 << (self.int_bits + self.frac_bits)) - 1
    }

    pub fn min_raw(&self) -> i64 {
        -(1i64 << (self.int_bits + self.frac_bits))
    }

    /// Quantize a float: scale, round-to-nearest-even-free (half away from
    /// zero, like Vivado HLS AP_RND), saturate (AP_SAT).
    pub fn quantize(&self, v: f64) -> Fixed {
        let scaled = v * (1i64 << self.frac_bits) as f64;
        let rounded = if scaled >= 0.0 { (scaled + 0.5).floor() } else { (scaled - 0.5).ceil() };
        let raw = (rounded as i64).clamp(self.min_raw(), self.max_raw());
        Fixed { raw, fmt: *self }
    }

    pub fn from_raw(&self, raw: i64) -> Fixed {
        Fixed { raw: raw.clamp(self.min_raw(), self.max_raw()), fmt: *self }
    }
}

/// A fixed-point value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fixed {
    pub raw: i64,
    pub fmt: FixedFormat,
}

impl Fixed {
    pub fn to_f64(&self) -> f64 {
        self.raw as f64 / (1i64 << self.fmt.frac_bits) as f64
    }

    /// Saturating add (same format required — datapaths are format-stable).
    pub fn sat_add(&self, other: &Fixed) -> Fixed {
        assert_eq!(self.fmt, other.fmt, "format mismatch in datapath");
        self.fmt.from_raw(self.raw.saturating_add(other.raw))
    }

    /// Saturating multiply with result renormalized into `out` format.
    pub fn sat_mul(&self, other: &Fixed, out: FixedFormat) -> Fixed {
        let prod = self.raw as i128 * other.raw as i128;
        let shift = self.fmt.frac_bits + other.fmt.frac_bits - out.frac_bits;
        let shifted = (prod >> shift) as i64;
        out.from_raw(shifted)
    }
}

/// The paper-calibrated bit-width plan for every signal in the accelerator —
/// consumed by `dataflow::resource` to charge BRAM/FF bits and by the quant
/// error analysis.
#[derive(Debug, Clone)]
pub struct QuantPlan {
    /// input pixels (u8, unsigned — carried as 0 int bits of sign headroom)
    pub pixel: FixedFormat,
    /// gradient values (0..255, clamped)
    pub gradient: FixedFormat,
    /// stage-I weights (i8 template)
    pub weight: FixedFormat,
    /// score accumulators (|s| ≤ 64·255·12 < 2^18)
    pub score: FixedFormat,
    /// stage-II calibrated scores (fractional)
    pub calibrated: FixedFormat,
}

impl Default for QuantPlan {
    fn default() -> Self {
        Self {
            pixel: FixedFormat::new(8, 0),
            gradient: FixedFormat::new(8, 0),
            weight: FixedFormat::new(7, 0),
            score: FixedFormat::new(18, 0),
            calibrated: FixedFormat::new(18, 8),
        }
    }
}

impl QuantPlan {
    /// Verify the plan admits the full dynamic range of the integer
    /// semantics — a misconfigured plan must fail fast, not wrap silently.
    pub fn validate(&self) -> Result<(), String> {
        if self.pixel.max_raw() < 255 {
            return Err("pixel format cannot hold 255".into());
        }
        if self.gradient.max_raw() < 255 {
            return Err("gradient format cannot hold 255".into());
        }
        if self.weight.max_raw() < 127 {
            return Err("weight format cannot hold i8".into());
        }
        let max_score = 64i64 * 255 * 12;
        if self.score.max_raw() < max_score {
            return Err(format!("score format cannot hold {max_score}"));
        }
        Ok(())
    }

    /// Worst-case stage-II rounding error of the calibrated format.
    pub fn calibration_lsb(&self) -> f64 {
        1.0 / (1i64 << self.calibrated.frac_bits) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_exact_integers() {
        let fmt = FixedFormat::new(8, 0);
        for v in [-255.0, -1.0, 0.0, 7.0, 255.0] {
            assert_eq!(fmt.quantize(v).to_f64(), v);
        }
    }

    #[test]
    fn quantize_saturates() {
        let fmt = FixedFormat::new(4, 0); // range [-16, 15]
        assert_eq!(fmt.quantize(100.0).raw, 15);
        assert_eq!(fmt.quantize(-100.0).raw, -16);
    }

    #[test]
    fn quantize_rounds_half_away_from_zero() {
        let fmt = FixedFormat::new(4, 2); // LSB = 0.25
        assert_eq!(fmt.quantize(0.125).to_f64(), 0.25); // half → away from zero
        assert_eq!(fmt.quantize(-0.125).to_f64(), -0.25);
        assert_eq!(fmt.quantize(0.3).to_f64(), 0.25);
        assert_eq!(fmt.quantize(-0.3).to_f64(), -0.25);
        assert_eq!(fmt.quantize(0.375).to_f64(), 0.5); // raw 1.5 → 2
    }

    #[test]
    fn sat_add_saturates_at_rails() {
        let fmt = FixedFormat::new(3, 0); // [-8, 7]
        let a = fmt.from_raw(7);
        assert_eq!(a.sat_add(&a).raw, 7);
        let b = fmt.from_raw(-8);
        assert_eq!(b.sat_add(&b).raw, -8);
    }

    #[test]
    fn sat_mul_renormalizes() {
        let f8 = FixedFormat::new(7, 8);
        let out = FixedFormat::new(15, 8);
        let a = f8.quantize(1.5);
        let b = f8.quantize(2.0);
        assert_eq!(a.sat_mul(&b, out).to_f64(), 3.0);
    }

    #[test]
    fn default_plan_is_valid_and_tight() {
        let plan = QuantPlan::default();
        plan.validate().unwrap();
        // score width is the minimum that holds the worst case
        assert!(FixedFormat::new(17, 0).max_raw() < 64 * 255 * 12);
        assert_eq!(plan.score.width(), 19);
    }

    #[test]
    fn undersized_plan_rejected() {
        let mut plan = QuantPlan::default();
        plan.score = FixedFormat::new(10, 0);
        assert!(plan.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "format mismatch")]
    fn mixed_format_add_panics() {
        let a = FixedFormat::new(4, 0).from_raw(1);
        let b = FixedFormat::new(5, 0).from_raw(1);
        let _ = a.sat_add(&b);
    }
}
