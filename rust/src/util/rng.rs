//! Deterministic PRNG substrate (offline environment — no `rand` crate).
//!
//! xoshiro256** (Blackman & Vigna) seeded through SplitMix64: fast,
//! well-distributed, and — crucially for this repo — *platform-stable*:
//! every stochastic component (dataset generation, SGD shuffling, bench
//! workloads) reproduces bit-exactly from a u64 seed.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (recommended by the xoshiro authors; avoids the
    /// all-zero state and decorrelates nearby seeds).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` via Lemire's multiply-shift with rejection
    /// (unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform u32 in `[lo, hi]` (inclusive — matches placement math).
    #[inline]
    pub fn range_u32_inclusive(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi, "empty range {lo}..={hi}");
        lo + self.below((hi - lo) as u64 + 1) as u32
    }

    /// Uniform i32 in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_i32_inclusive(&mut self, lo: i32, hi: i32) -> i32 {
        assert!(lo <= hi, "empty range {lo}..={hi}");
        lo + self.below((hi - lo) as u64 + 1) as i64 as i32
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool_p(&mut self, p: f64) -> bool {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < p
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_stays_in_range_and_covers() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 7];
        for _ in 0..500 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "not all residues hit");
    }

    #[test]
    fn ranges_inclusive_and_exclusive() {
        let mut r = Rng::seed_from_u64(9);
        for _ in 0..200 {
            let u = r.range_usize(3, 10);
            assert!((3..10).contains(&u));
            let i = r.range_i32_inclusive(-5, 5);
            assert!((-5..=5).contains(&i));
            let w = r.range_u32_inclusive(7, 7);
            assert_eq!(w, 7);
        }
    }

    #[test]
    fn bool_p_rate_plausible() {
        let mut r = Rng::seed_from_u64(17);
        let hits = (0..10_000).filter(|_| r.bool_p(0.25)).count();
        assert!((2_000..3_000).contains(&hits), "p=0.25 gave {hits}/10000");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "shuffle was identity");
    }

    #[test]
    #[should_panic(expected = "below(0)")]
    fn below_zero_panics() {
        Rng::seed_from_u64(0).below(0);
    }
}
