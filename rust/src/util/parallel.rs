//! Ordered fork-join over an index range, executed on the persistent
//! process-wide [`super::pool::WorkerPool`] (offline environment — no rayon).
//!
//! Until PR 2 this spawned (and joined) fresh OS threads on every call; the
//! pool keeps thread creation off the serving hot path and lets worker
//! threads retain their scratch arenas between requests.

/// Map `f` over `0..n` using up to `threads` concurrent workers (the caller
/// plus `threads − 1` pool helpers); results come back in index order. `f`
/// must be `Sync` (it is shared by reference).
///
/// Work is distributed by atomic work-stealing over indices, so uneven
/// per-item cost (e.g. pyramid scales of very different sizes) balances
/// automatically — the same reason the paper gives each kernel pipeline its
/// own stream rather than a static split.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    super::pool::global().scope_map(n, threads - 1, f)
}

/// Default worker count: the machine's parallelism, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // items 0..8 are expensive, rest cheap — must still complete & order
        let out = parallel_map(64, 4, |i| {
            if i < 8 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_result() {
        let serial: Vec<u64> = (0..200).map(|i| (i as u64).wrapping_mul(2654435761)).collect();
        let par = parallel_map(200, 7, |i| (i as u64).wrapping_mul(2654435761));
        assert_eq!(par, serial);
    }

    #[test]
    fn repeated_calls_reuse_the_pool() {
        // spawn-per-call would make this test markedly slower; mostly we
        // assert correctness under rapid reuse of the shared pool
        for round in 0..50u64 {
            let out = parallel_map(16, 4, move |i| round * 100 + i as u64);
            assert_eq!(out, (0..16).map(|i| round * 100 + i).collect::<Vec<_>>());
        }
    }
}
