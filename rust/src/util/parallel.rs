//! Thread-pool substrate (offline environment — no rayon): scoped
//! fork-join over an index range, preserving output order.

/// Map `f` over `0..n` using up to `threads` OS threads; results come back
/// in index order. `f` must be `Sync` (it is shared by reference).
///
/// Work is distributed by atomic work-stealing over indices, so uneven
/// per-item cost (e.g. pyramid scales of very different sizes) balances
/// automatically — the same reason the paper gives each kernel pipeline its
/// own stream rather than a static split.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    use std::sync::atomic::{AtomicUsize, Ordering};
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots: Vec<SendPtr<Option<T>>> =
        out.iter_mut().map(|s| SendPtr(s as *mut Option<T>)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            let next = &next;
            let f = &f;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let value = f(i);
                // SAFETY: each index i is claimed exactly once (fetch_add),
                // so no two threads write the same slot; the scope outlives
                // all writes and `out` is not read until the scope ends.
                let slot = slots[i].0;
                unsafe { *slot = Some(value) };
            });
        }
    });
    out.into_iter().map(|v| v.expect("worker missed a slot")).collect()
}

/// Pointer wrapper asserting cross-thread transfer is safe (see SAFETY above).
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Default worker count: the machine's parallelism, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn uneven_work_balances() {
        // items 0..8 are expensive, rest cheap — must still complete & order
        let out = parallel_map(64, 4, |i| {
            if i < 8 {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            i
        });
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn matches_serial_result() {
        let serial: Vec<u64> = (0..200).map(|i| (i as u64).wrapping_mul(2654435761)).collect();
        let par = parallel_map(200, 7, |i| (i as u64).wrapping_mul(2654435761));
        assert_eq!(par, serial);
    }
}
