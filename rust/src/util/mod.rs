//! Small shared utilities built in-tree for the offline environment:
//! a dependency-free JSON subset (weight files), a deterministic PRNG
//! (xoshiro256**) and a persistent worker pool with a fork-join helper
//! ([`WorkerPool::scope_map`] — the deprecated spawn-per-call
//! `parallel_map` shim it superseded is gone).

pub mod json;
pub mod pool;
pub mod rng;

pub use pool::{default_threads, PoolStats, WorkerPool};
pub use rng::Rng;

/// Deterministic RNG from a u64 seed — every stochastic component in the
/// crate (dataset generation, SVM init, benchmarks) goes through this so
/// experiments are exactly reproducible.
pub fn rng(seed: u64) -> Rng {
    Rng::seed_from_u64(seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = rng(7);
        let mut b = rng(7);
        for _ in 0..16 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let mut a = rng(1);
        let mut b = rng(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
