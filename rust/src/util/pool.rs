//! Persistent worker pool — the process-wide execution substrate behind
//! the baseline's per-scale fan-out and the coordinator's scale tasks.
//!
//! The pre-PR-2 `parallel_map` shim spawned (and joined) fresh OS threads
//! on every call, which put thread creation on the serving hot path; it has
//! since been deleted. This pool spawns its workers once; callers either
//!
//! * fan out a scoped index map with [`WorkerPool::scope_map`] (fork-join:
//!   the caller participates in the work and blocks until every index is
//!   done, so the closure may borrow from the caller's stack), or
//! * hand off a detached `'static` task with [`WorkerPool::execute`]
//!   (fire-and-forget: the coordinator's per-(image, scale) units).
//!
//! Worker threads are reused across calls, which also makes the thread-local
//! scratch arenas ([`crate::baseline::with_scale_scratch`]) persistent —
//! steady-state serving touches pre-grown buffers only.
//!
//! Since PR 8 the pool is **lane-aware and affinity-pinned** (the serving
//! half of the ROADMAP "raw speed" item):
//!
//! * Each worker is pinned to core `index % ncpus` at spawn (raw
//!   `sched_setaffinity` on Linux — the crate stays dependency-free; a
//!   failed or unsupported pin is recorded, not fatal), so a worker's
//!   thread-local scratch arenas stay cache-warm on one core across
//!   requests instead of migrating.
//! * [`WorkerPool::execute_on`] enqueues into a per-lane queue (serving
//!   gives each shard its own lane). A worker prefers its home lane
//!   (`worker % lanes`), then the shared injector, then **steals** from
//!   sibling lanes — a hot shard borrows idle siblings' threads instead of
//!   queueing behind its own. Steal and pin counts are exported through
//!   [`WorkerPool::stats`] into the serving telemetry.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A detached unit of work.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// Hard ceiling on pool size; [`WorkerPool::ensure_threads`] clamps to it.
const MAX_WORKERS: usize = 32;

/// Hard ceiling on lane count ([`WorkerPool::execute_on`] wraps modulo the
/// lane count, so more shards than lanes just share).
const MAX_LANES: usize = 64;

struct PoolState {
    /// The shared injector queue ([`WorkerPool::execute`]): lane-less work,
    /// served after a worker's home lane and before stealing.
    tasks: VecDeque<Task>,
    /// Per-lane queues ([`WorkerPool::execute_on`]); grown by
    /// [`WorkerPool::ensure_lanes`], never shrunk.
    lanes: Vec<VecDeque<Task>>,
    /// workers spawned so far (monotonic until shutdown)
    workers: usize,
    /// Per-worker (by wid) start time of the task currently executing;
    /// `None` while idle. [`WorkerPool::reap_wedged`] reads these to find
    /// workers stuck far past any deadline.
    busy: Vec<Option<std::time::Instant>>,
    /// Per-worker abandonment flags: a reaped worker finishes (or stays
    /// stuck in) its current task and then exits instead of looping; its
    /// replacement runs under a fresh wid. One-way per worker.
    abandoned: Vec<bool>,
    shutdown: bool,
}

impl PoolState {
    /// Next task for worker `wid`: home lane → injector → steal (scanning
    /// siblings from the home lane outward, so contention spreads).
    fn take(&mut self, wid: usize, steals: &AtomicU64) -> Option<Task> {
        let nl = self.lanes.len();
        if nl > 0 {
            if let Some(t) = self.lanes[wid % nl].pop_front() {
                return Some(t);
            }
        }
        if let Some(t) = self.tasks.pop_front() {
            return Some(t);
        }
        for off in 1..nl {
            if let Some(t) = self.lanes[(wid + off) % nl].pop_front() {
                steals.fetch_add(1, Ordering::Relaxed);
                return Some(t);
            }
        }
        None
    }

    fn is_drained(&self) -> bool {
        self.tasks.is_empty() && self.lanes.iter().all(|l| l.is_empty())
    }
}

struct PoolShared {
    state: Mutex<PoolState>,
    available: Condvar,
    /// Tasks a worker took from a lane other than its home lane.
    steals: AtomicU64,
    /// Workers whose affinity pin succeeded.
    pinned: AtomicUsize,
    /// Workers abandoned by [`WorkerPool::reap_wedged`] (hang containment).
    wedged: AtomicU64,
}

/// A point-in-time snapshot of the pool's scheduling counters, surfaced in
/// `ServeMetrics::summary()` and `BENCH_serving.json`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker threads spawned.
    pub workers: usize,
    /// Workers successfully pinned to a core (0 on non-Linux, or when the
    /// platform rejects `sched_setaffinity` — e.g. restricted sandboxes).
    pub pinned: usize,
    /// Per-lane queues created so far.
    pub lanes: usize,
    /// Cross-lane steals since pool creation.
    pub steals: u64,
    /// Workers reaped as wedged (stuck in one task past a stall bound) and
    /// replaced since pool creation.
    pub wedged: u64,
}

/// Pin the calling thread to `core` (modulo the CPU count). Linux-only: the
/// crate links glibc already, so the raw syscall wrapper costs no
/// dependency. Returns whether the pin took effect.
#[cfg(target_os = "linux")]
fn pin_to_core(core: usize) -> bool {
    // glibc's cpu_set_t is 1024 bits; sized as u64 words here.
    const CPU_SET_WORDS: usize = 16;
    extern "C" {
        // int sched_setaffinity(pid_t pid, size_t cpusetsize, const cpu_set_t *mask)
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cpu = core % ncpu.min(CPU_SET_WORDS * 64);
    let mut mask = [0u64; CPU_SET_WORDS];
    mask[cpu / 64] = 1u64 << (cpu % 64);
    // SAFETY: pid 0 targets the calling thread; the mask outlives the call
    // and its length is passed explicitly.
    unsafe { sched_setaffinity(0, CPU_SET_WORDS * 8, mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn pin_to_core(_core: usize) -> bool {
    false
}

/// Process-wide affinity-pinning switch (config key `pool.pin`, default on).
/// Checked at worker spawn, so flip it before the first pool use.
static PIN_WORKERS: AtomicBool = AtomicBool::new(true);

/// Enable/disable core pinning for workers spawned *after* this call.
pub fn set_pinning(enabled: bool) {
    PIN_WORKERS.store(enabled, Ordering::Relaxed);
}

/// A persistent pool of worker threads draining a shared FIFO task queue.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Join handles, taken on Drop. Lock order: `shared.state` before this.
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// The shared process-wide pool (created on first use, never torn down —
/// worker threads die with the process). `SoftwareBing` and `Coordinator`
/// both schedule onto this instance.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(default_threads()))
}

/// Default worker count: the machine's parallelism, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (at least one).
    pub fn new(threads: usize) -> Self {
        let pool = Self {
            shared: Arc::new(PoolShared {
                state: Mutex::new(PoolState {
                    tasks: VecDeque::new(),
                    lanes: Vec::new(),
                    workers: 0,
                    busy: Vec::new(),
                    abandoned: Vec::new(),
                    shutdown: false,
                }),
                available: Condvar::new(),
                steals: AtomicU64::new(0),
                pinned: AtomicUsize::new(0),
                wedged: AtomicU64::new(0),
            }),
            handles: Mutex::new(Vec::new()),
        };
        pool.ensure_threads(threads.max(1));
        pool
    }

    /// Grow the pool to at least `n` workers (clamped to [`MAX_WORKERS`]).
    /// Never shrinks; serving layers call this with their configured worker
    /// count so capacity matches the largest requested deployment.
    pub fn ensure_threads(&self, n: usize) {
        let n = n.clamp(1, MAX_WORKERS);
        let mut st = self.shared.state.lock().unwrap();
        while st.workers < n && !st.shutdown {
            self.spawn_worker(&mut st);
        }
    }

    /// Spawn one worker under the state lock (shared by [`Self::ensure_threads`]
    /// growth and [`Self::reap_wedged`] replacement — replacements get fresh
    /// wids; an abandoned wid's slots stay behind, inert).
    fn spawn_worker(&self, st: &mut PoolState) {
        let wid = st.workers;
        st.workers += 1;
        st.busy.push(None);
        st.abandoned.push(false);
        let shared = self.shared.clone();
        let pin = PIN_WORKERS.load(Ordering::Relaxed);
        let handle = std::thread::Builder::new()
            .name(format!("bingflow-pool-{wid}"))
            .spawn(move || {
                if pin && pin_to_core(wid) {
                    shared.pinned.fetch_add(1, Ordering::Relaxed);
                }
                worker_loop(&shared, wid)
            })
            .expect("spawning pool worker");
        self.handles.lock().unwrap().push(handle);
    }

    /// Hang containment: abandon every worker stuck in one task for at
    /// least `stall` and spawn a replacement for each, so pool capacity
    /// survives a wedged backend call (an injected `InjectedFault::Hang`,
    /// a driver stuck in an ioctl, an accelerator that stopped answering).
    /// Returns how many workers were reaped.
    ///
    /// The abandoned worker is not killed — Rust threads can't be — it
    /// finishes (or stays stuck in) its current task and then exits
    /// instead of taking more work. A false positive (slow but alive
    /// task) is therefore harmless: the task still completes and delivers;
    /// the pool just runs one extra thread until it does.
    pub fn reap_wedged(&self, stall: std::time::Duration) -> usize {
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return 0;
        }
        let mut reaped = 0;
        for wid in 0..st.busy.len() {
            if st.abandoned[wid] {
                continue;
            }
            if let Some(t0) = st.busy[wid] {
                if t0.elapsed() >= stall {
                    st.abandoned[wid] = true;
                    reaped += 1;
                }
            }
        }
        for _ in 0..reaped {
            self.spawn_worker(&mut st);
        }
        if reaped > 0 {
            self.shared.wedged.fetch_add(reaped as u64, Ordering::Relaxed);
            eprintln!(
                "[pool] reaped {reaped} wedged worker(s) (stalled ≥ {stall:?}); \
                 replacements spawned"
            );
        }
        reaped
    }

    /// Grow the per-lane queue set to at least `n` lanes (clamped to
    /// [`MAX_LANES`]; never shrinks). Serving calls this with its shard
    /// count so each shard owns a lane.
    pub fn ensure_lanes(&self, n: usize) {
        let n = n.min(MAX_LANES);
        let mut st = self.shared.state.lock().unwrap();
        while st.lanes.len() < n {
            st.lanes.push(VecDeque::new());
        }
    }

    /// Current worker count.
    pub fn threads(&self) -> usize {
        self.shared.state.lock().unwrap().workers
    }

    /// Scheduling counters for telemetry.
    pub fn stats(&self) -> PoolStats {
        let st = self.shared.state.lock().unwrap();
        PoolStats {
            workers: st.workers,
            pinned: self.shared.pinned.load(Ordering::Relaxed),
            lanes: st.lanes.len(),
            steals: self.shared.steals.load(Ordering::Relaxed),
            wedged: self.shared.wedged.load(Ordering::Relaxed),
        }
    }

    /// Enqueue a detached task; some pool worker will run it. Panics if the
    /// pool is shut down (the global pool never is).
    pub fn execute(&self, task: Task) {
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(!st.shutdown, "worker pool is shut down");
            st.tasks.push_back(task);
        }
        self.shared.available.notify_one();
    }

    /// Enqueue a detached task into lane `lane % lanes` — its home workers
    /// drain it first; everyone else steals it when idle. Falls back to the
    /// injector queue while no lanes exist.
    pub fn execute_on(&self, lane: usize, task: Task) {
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(!st.shutdown, "worker pool is shut down");
            match st.lanes.len() {
                0 => st.tasks.push_back(task),
                nl => st.lanes[lane % nl].push_back(task),
            }
        }
        self.shared.available.notify_one();
    }

    /// Map `f` over `0..n` with up to `max_helpers` pool workers assisting;
    /// results come back in index order. The caller thread participates in
    /// the work and does not return until all indices are complete, which is
    /// what makes borrowing `f`'s environment sound.
    ///
    /// Indices are claimed by atomic work-stealing, so uneven per-item cost
    /// (pyramid scales of very different sizes) balances automatically.
    pub fn scope_map<T, F>(&self, n: usize, max_helpers: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        if n <= 1 || max_helpers == 0 {
            for (i, slot) in out.iter_mut().enumerate() {
                *slot = Some(f(i));
            }
            return out.into_iter().map(|v| v.expect("serial slot")).collect();
        }

        let job = Arc::new(JobState {
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            finished: Condvar::new(),
        });
        let slots: Vec<SendPtr<Option<T>>> =
            out.iter_mut().map(|s| SendPtr(s as *mut Option<T>)).collect();

        // Helpers capture the caller's state as raw pointers only (no
        // references), so a stale task popped after this call returns holds
        // nothing but dangling *pointers* it will never dereference.
        let fp = SendConstPtr(&f as *const F);
        let sp = SendConstPtr(slots.as_ptr());
        let helpers = max_helpers.min(n - 1).min(MAX_WORKERS);
        for _ in 0..helpers {
            let job = job.clone();
            let task: Box<dyn FnOnce() + Send + '_> =
                Box::new(move || drive(&job, n, fp, sp));
            // SAFETY: erasing the closure's lifetime (a pointer cast that
            // changes only the trait object's lifetime) is sound because the
            // closure touches caller memory strictly through `drive`, which
            // materializes references only after claiming an index `< n` —
            // and this function blocks below until every index is complete,
            // so claimed indices imply the borrowed state is still alive. A
            // helper invoked after that point observes `next >= n` and
            // touches only the Arc'd JobState it owns.
            let task: Task = unsafe {
                Box::from_raw(Box::into_raw(task) as *mut (dyn FnOnce() + Send + 'static))
            };
            self.execute(task);
        }

        // The caller is a full participant — even a saturated pool cannot
        // stall a scoped map (no helper ever *must* run for completion).
        drive(&job, n, fp, sp);

        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.finished.wait(done).unwrap();
        }
        drop(done);
        // Per-item panics are deferred (unwinding mid-job would free the
        // slot storage under concurrent helpers) and re-raised here.
        assert!(!job.panicked.load(Ordering::Acquire), "scope_map task panicked");
        out.into_iter().map(|v| v.expect("pool missed a slot")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.available.notify_all();
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &PoolShared, wid: usize) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.abandoned[wid] {
                    return; // reaped; the replacement carries the load now
                }
                if let Some(t) = st.take(wid, &shared.steals) {
                    st.busy[wid] = Some(std::time::Instant::now());
                    break t;
                }
                if st.shutdown {
                    debug_assert!(st.is_drained());
                    return; // queues drained: workers exit only when idle
                }
                st = shared.available.wait(st).unwrap();
            }
        };
        // One bad task must not kill a (process-shared) worker thread.
        if catch_unwind(AssertUnwindSafe(task)).is_err() {
            eprintln!("[pool] worker task panicked");
        }
        let mut st = shared.state.lock().unwrap();
        st.busy[wid] = None;
        if st.abandoned[wid] {
            // Reaped mid-task: the (possibly very late) task above still ran
            // to completion and delivered its result; only the thread retires.
            return;
        }
    }
}

/// Scoped-map progress shared between the caller and its helpers.
struct JobState {
    /// next index to claim
    next: AtomicUsize,
    /// indices not yet completed
    pending: AtomicUsize,
    /// some item panicked; re-raised by the caller after the job drains
    panicked: AtomicBool,
    done: Mutex<bool>,
    finished: Condvar,
}

/// Steal indices until the job is exhausted, writing each result into its
/// slot; whoever completes the final index flips `done`. Item panics are
/// recorded rather than unwound: unwinding out of the caller's own `drive`
/// would drop the slot storage while helpers still write to it.
///
/// Takes the closure and slot array as raw pointers and materializes
/// references only *after* claiming an index: a claimed `i < n` means
/// `pending > 0`, so the `scope_map` caller is still blocked and the
/// pointed-to state is alive. A stale invocation (after the job drained)
/// never forms a reference at all.
fn drive<T, F>(
    job: &JobState,
    n: usize,
    f: SendConstPtr<F>,
    slots: SendConstPtr<SendPtr<Option<T>>>,
) where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        // SAFETY: index i is claimed exactly once (fetch_add), so no two
        // threads write the same slot; the claim proves the job is not
        // complete, so the caller still keeps `f` and the slots alive.
        let (f_ref, slot) = unsafe { (&*f.0, *slots.0.add(i)) };
        match catch_unwind(AssertUnwindSafe(|| f_ref(i))) {
            // SAFETY: see above — exclusive claim on slot i, storage alive.
            Ok(value) => unsafe { *slot.0 = Some(value) },
            Err(_) => job.panicked.store(true, Ordering::Release),
        }
        // AcqRel chains every slot write into the final decrement, so the
        // thread that observes 0 (and the caller, via the mutex) sees them.
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = job.done.lock().unwrap();
            *done = true;
            job.finished.notify_all();
        }
    }
}

/// Mutable-pointer wrapper asserting cross-thread transfer is safe (see
/// SAFETY in [`drive`]).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Const-pointer sibling of [`SendPtr`] for the closure and slot array.
struct SendConstPtr<T>(*const T);

impl<T> Clone for SendConstPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendConstPtr<T> {}
unsafe impl<T> Sync for SendConstPtr<T> {}
unsafe impl<T> Send for SendConstPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn execute_runs_detached_tasks() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..64 {
            let c = counter.clone();
            pool.execute(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            }));
        }
        drop(pool); // Drop drains the queue before joining
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn scope_map_preserves_order() {
        let pool = WorkerPool::new(4);
        let out = pool.scope_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_borrows_caller_state() {
        let pool = WorkerPool::new(3);
        let base: Vec<u64> = (0..50).map(|i| i * 3).collect();
        let out = pool.scope_map(base.len(), 3, |i| base[i] + 1);
        assert_eq!(out, (0..50).map(|i| i * 3 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty_and_single() {
        let pool = WorkerPool::new(2);
        assert!(pool.scope_map(0, 4, |i| i).is_empty());
        assert_eq!(pool.scope_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn concurrent_scope_maps_do_not_interfere() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for t in 0..6u64 {
            let pool = pool.clone();
            joins.push(std::thread::spawn(move || {
                let out = pool.scope_map(40, 4, |i| t * 1000 + i as u64);
                assert_eq!(out, (0..40).map(|i| t * 1000 + i).collect::<Vec<_>>());
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn scope_map_survives_saturated_pool() {
        // Fill the single worker with slow detached tasks: the caller must
        // still complete the scoped map on its own.
        let pool = WorkerPool::new(1);
        for _ in 0..4 {
            pool.execute(Box::new(|| std::thread::sleep(Duration::from_millis(30))));
        }
        let out = pool.scope_map(16, 1, |i| i);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_propagates_item_panic_without_hanging() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_map(8, 2, |i| {
                if i == 3 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(result.is_err(), "item panic must surface to the caller");
        // the pool (and its workers) must stay healthy afterwards
        assert_eq!(pool.scope_map(4, 2, |i| i), vec![0, 1, 2, 3]);
    }

    #[test]
    fn ensure_threads_grows_but_never_shrinks() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.threads(), 2);
        pool.ensure_threads(5);
        assert_eq!(pool.threads(), 5);
        pool.ensure_threads(1);
        assert_eq!(pool.threads(), 5);
    }

    #[test]
    fn execute_on_without_lanes_degrades_to_injector() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..16 {
            let c = counter.clone();
            let task: Task = Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
            pool.execute_on(i, task);
        }
        drop(pool);
        assert_eq!(counter.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn lanes_drain_and_idle_workers_steal_from_hot_lanes() {
        // One worker (home lane 0), work enqueued only on lane 1: every
        // completed task is necessarily a cross-lane steal.
        let pool = WorkerPool::new(1);
        pool.ensure_lanes(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let c = counter.clone();
            let task: Task = Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
            pool.execute_on(1, task);
        }
        let stats = loop {
            let s = pool.stats();
            if counter.load(Ordering::Relaxed) == 8 {
                break s;
            }
            std::thread::sleep(Duration::from_millis(1));
        };
        assert_eq!(stats.lanes, 2);
        assert!(
            pool.stats().steals >= 8,
            "every off-home task must count as a steal: {:?}",
            pool.stats()
        );
    }

    #[test]
    fn home_lane_work_is_not_a_steal() {
        // One worker whose home lane is 0 (0 % 1 == 0), single lane: no
        // cross-lane traffic exists, so the steal counter must stay zero.
        let pool = WorkerPool::new(1);
        pool.ensure_lanes(1);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..8 {
            let c = counter.clone();
            let task: Task = Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
            pool.execute_on(0, task);
        }
        while counter.load(Ordering::Relaxed) != 8 {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.stats().steals, 0);
    }

    #[test]
    fn ensure_lanes_grows_but_never_shrinks() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.stats().lanes, 0);
        pool.ensure_lanes(4);
        assert_eq!(pool.stats().lanes, 4);
        pool.ensure_lanes(2);
        assert_eq!(pool.stats().lanes, 4);
    }

    #[test]
    fn stats_report_plausible_pinning() {
        // Pin success depends on the platform/sandbox; the invariant is
        // only that pinned workers never exceed spawned workers.
        let pool = WorkerPool::new(3);
        let stats = pool.stats();
        assert_eq!(stats.workers, 3);
        assert!(stats.pinned <= stats.workers, "{stats:?}");
    }

    #[test]
    fn lanes_preserve_scope_map_and_detached_mix() {
        // Scoped maps (injector) and lane tasks interleave without loss.
        let pool = Arc::new(WorkerPool::new(3));
        pool.ensure_lanes(3);
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..32 {
            let c = counter.clone();
            let task: Task = Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
            pool.execute_on(i % 3, task);
        }
        let out = pool.scope_map(64, 3, |i| i * 2);
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
        while counter.load(Ordering::Relaxed) != 32 {
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn reap_replaces_wedged_worker_and_work_continues() {
        let pool = WorkerPool::new(1);
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let entered = Arc::new(AtomicU64::new(0));
        let e = entered.clone();
        pool.execute(Box::new(move || {
            e.fetch_add(1, Ordering::Relaxed);
            let _ = rx.recv(); // wedged until the test releases it
        }));
        while entered.load(Ordering::Relaxed) == 0 {
            std::thread::sleep(Duration::from_millis(1));
        }
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(pool.reap_wedged(Duration::from_millis(1)), 1);
        assert_eq!(pool.stats().wedged, 1);
        // the replacement worker keeps the pool serving while the original
        // stays stuck — the wedge is contained, not merely observed
        let done = Arc::new(AtomicU64::new(0));
        let d = done.clone();
        pool.execute(Box::new(move || {
            d.fetch_add(1, Ordering::Relaxed);
        }));
        let t0 = std::time::Instant::now();
        while done.load(Ordering::Relaxed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(10), "replacement worker never ran");
            std::thread::sleep(Duration::from_millis(1));
        }
        // an already-abandoned worker is never reaped twice, and freshly
        // busy/idle workers don't qualify under a generous stall bound
        assert_eq!(pool.reap_wedged(Duration::from_secs(60)), 0);
        assert_eq!(pool.stats().wedged, 1);
        tx.send(()).unwrap(); // unwedge so Drop can join every thread
    }

    #[test]
    fn reap_spares_idle_and_fast_workers() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.reap_wedged(Duration::from_millis(1)), 0, "idle pool has no wedges");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..16 {
            let c = counter.clone();
            pool.execute(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            }));
        }
        while counter.load(Ordering::Relaxed) != 16 {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.reap_wedged(Duration::from_secs(60)), 0);
        assert_eq!(pool.stats().wedged, 0);
    }

    #[test]
    fn global_pool_is_shared_and_alive() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1);
        assert_eq!(global().scope_map(8, 4, |i| i), (0..8).collect::<Vec<_>>());
    }
}
