//! Persistent worker pool — the process-wide execution substrate behind
//! the baseline's per-scale fan-out and the coordinator's scale tasks.
//!
//! The pre-PR-2 `parallel_map` shim spawned (and joined) fresh OS threads
//! on every call, which put thread creation on the serving hot path; it has
//! since been deleted. This pool spawns its workers once; callers either
//!
//! * fan out a scoped index map with [`WorkerPool::scope_map`] (fork-join:
//!   the caller participates in the work and blocks until every index is
//!   done, so the closure may borrow from the caller's stack), or
//! * hand off a detached `'static` task with [`WorkerPool::execute`]
//!   (fire-and-forget: the coordinator's per-(image, scale) units).
//!
//! Worker threads are reused across calls, which also makes the thread-local
//! scratch arenas ([`crate::baseline::with_scale_scratch`]) persistent —
//! steady-state serving touches pre-grown buffers only.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A detached unit of work.
pub type Task = Box<dyn FnOnce() + Send + 'static>;

/// Hard ceiling on pool size; [`WorkerPool::ensure_threads`] clamps to it.
const MAX_WORKERS: usize = 32;

struct PoolState {
    tasks: VecDeque<Task>,
    /// workers spawned so far (monotonic until shutdown)
    workers: usize,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    available: Condvar,
}

/// A persistent pool of worker threads draining a shared FIFO task queue.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    /// Join handles, taken on Drop. Lock order: `shared.state` before this.
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

/// The shared process-wide pool (created on first use, never torn down —
/// worker threads die with the process). `SoftwareBing` and `Coordinator`
/// both schedule onto this instance.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(default_threads()))
}

/// Default worker count: the machine's parallelism, capped.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(16)
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (at least one).
    pub fn new(threads: usize) -> Self {
        let pool = Self {
            shared: Arc::new(PoolShared {
                state: Mutex::new(PoolState {
                    tasks: VecDeque::new(),
                    workers: 0,
                    shutdown: false,
                }),
                available: Condvar::new(),
            }),
            handles: Mutex::new(Vec::new()),
        };
        pool.ensure_threads(threads.max(1));
        pool
    }

    /// Grow the pool to at least `n` workers (clamped to [`MAX_WORKERS`]).
    /// Never shrinks; serving layers call this with their configured worker
    /// count so capacity matches the largest requested deployment.
    pub fn ensure_threads(&self, n: usize) {
        let n = n.clamp(1, MAX_WORKERS);
        let mut st = self.shared.state.lock().unwrap();
        while st.workers < n && !st.shutdown {
            st.workers += 1;
            let shared = self.shared.clone();
            let handle = std::thread::Builder::new()
                .name("bingflow-pool".into())
                .spawn(move || worker_loop(&shared))
                .expect("spawning pool worker");
            self.handles.lock().unwrap().push(handle);
        }
    }

    /// Current worker count.
    pub fn threads(&self) -> usize {
        self.shared.state.lock().unwrap().workers
    }

    /// Enqueue a detached task; some pool worker will run it. Panics if the
    /// pool is shut down (the global pool never is).
    pub fn execute(&self, task: Task) {
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(!st.shutdown, "worker pool is shut down");
            st.tasks.push_back(task);
        }
        self.shared.available.notify_one();
    }

    /// Map `f` over `0..n` with up to `max_helpers` pool workers assisting;
    /// results come back in index order. The caller thread participates in
    /// the work and does not return until all indices are complete, which is
    /// what makes borrowing `f`'s environment sound.
    ///
    /// Indices are claimed by atomic work-stealing, so uneven per-item cost
    /// (pyramid scales of very different sizes) balances automatically.
    pub fn scope_map<T, F>(&self, n: usize, max_helpers: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        if n <= 1 || max_helpers == 0 {
            for (i, slot) in out.iter_mut().enumerate() {
                *slot = Some(f(i));
            }
            return out.into_iter().map(|v| v.expect("serial slot")).collect();
        }

        let job = Arc::new(JobState {
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
            done: Mutex::new(false),
            finished: Condvar::new(),
        });
        let slots: Vec<SendPtr<Option<T>>> =
            out.iter_mut().map(|s| SendPtr(s as *mut Option<T>)).collect();

        // Helpers capture the caller's state as raw pointers only (no
        // references), so a stale task popped after this call returns holds
        // nothing but dangling *pointers* it will never dereference.
        let fp = SendConstPtr(&f as *const F);
        let sp = SendConstPtr(slots.as_ptr());
        let helpers = max_helpers.min(n - 1).min(MAX_WORKERS);
        for _ in 0..helpers {
            let job = job.clone();
            let task: Box<dyn FnOnce() + Send + '_> =
                Box::new(move || drive(&job, n, fp, sp));
            // SAFETY: erasing the closure's lifetime (a pointer cast that
            // changes only the trait object's lifetime) is sound because the
            // closure touches caller memory strictly through `drive`, which
            // materializes references only after claiming an index `< n` —
            // and this function blocks below until every index is complete,
            // so claimed indices imply the borrowed state is still alive. A
            // helper invoked after that point observes `next >= n` and
            // touches only the Arc'd JobState it owns.
            let task: Task = unsafe {
                Box::from_raw(Box::into_raw(task) as *mut (dyn FnOnce() + Send + 'static))
            };
            self.execute(task);
        }

        // The caller is a full participant — even a saturated pool cannot
        // stall a scoped map (no helper ever *must* run for completion).
        drive(&job, n, fp, sp);

        let mut done = job.done.lock().unwrap();
        while !*done {
            done = job.finished.wait(done).unwrap();
        }
        drop(done);
        // Per-item panics are deferred (unwinding mid-job would free the
        // slot storage under concurrent helpers) and re-raised here.
        assert!(!job.panicked.load(Ordering::Acquire), "scope_map task panicked");
        out.into_iter().map(|v| v.expect("pool missed a slot")).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.available.notify_all();
        for handle in self.handles.lock().unwrap().drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let task = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(t) = st.tasks.pop_front() {
                    break t;
                }
                if st.shutdown {
                    return; // queue drained: workers exit only when idle
                }
                st = shared.available.wait(st).unwrap();
            }
        };
        // One bad task must not kill a (process-shared) worker thread.
        if catch_unwind(AssertUnwindSafe(task)).is_err() {
            eprintln!("[pool] worker task panicked");
        }
    }
}

/// Scoped-map progress shared between the caller and its helpers.
struct JobState {
    /// next index to claim
    next: AtomicUsize,
    /// indices not yet completed
    pending: AtomicUsize,
    /// some item panicked; re-raised by the caller after the job drains
    panicked: AtomicBool,
    done: Mutex<bool>,
    finished: Condvar,
}

/// Steal indices until the job is exhausted, writing each result into its
/// slot; whoever completes the final index flips `done`. Item panics are
/// recorded rather than unwound: unwinding out of the caller's own `drive`
/// would drop the slot storage while helpers still write to it.
///
/// Takes the closure and slot array as raw pointers and materializes
/// references only *after* claiming an index: a claimed `i < n` means
/// `pending > 0`, so the `scope_map` caller is still blocked and the
/// pointed-to state is alive. A stale invocation (after the job drained)
/// never forms a reference at all.
fn drive<T, F>(
    job: &JobState,
    n: usize,
    f: SendConstPtr<F>,
    slots: SendConstPtr<SendPtr<Option<T>>>,
) where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= n {
            break;
        }
        // SAFETY: index i is claimed exactly once (fetch_add), so no two
        // threads write the same slot; the claim proves the job is not
        // complete, so the caller still keeps `f` and the slots alive.
        let (f_ref, slot) = unsafe { (&*f.0, *slots.0.add(i)) };
        match catch_unwind(AssertUnwindSafe(|| f_ref(i))) {
            // SAFETY: see above — exclusive claim on slot i, storage alive.
            Ok(value) => unsafe { *slot.0 = Some(value) },
            Err(_) => job.panicked.store(true, Ordering::Release),
        }
        // AcqRel chains every slot write into the final decrement, so the
        // thread that observes 0 (and the caller, via the mutex) sees them.
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = job.done.lock().unwrap();
            *done = true;
            job.finished.notify_all();
        }
    }
}

/// Mutable-pointer wrapper asserting cross-thread transfer is safe (see
/// SAFETY in [`drive`]).
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

/// Const-pointer sibling of [`SendPtr`] for the closure and slot array.
struct SendConstPtr<T>(*const T);

impl<T> Clone for SendConstPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendConstPtr<T> {}
unsafe impl<T> Sync for SendConstPtr<T> {}
unsafe impl<T> Send for SendConstPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn execute_runs_detached_tasks() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..64 {
            let c = counter.clone();
            pool.execute(Box::new(move || {
                c.fetch_add(1, Ordering::Relaxed);
            }));
        }
        drop(pool); // Drop drains the queue before joining
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn scope_map_preserves_order() {
        let pool = WorkerPool::new(4);
        let out = pool.scope_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_borrows_caller_state() {
        let pool = WorkerPool::new(3);
        let base: Vec<u64> = (0..50).map(|i| i * 3).collect();
        let out = pool.scope_map(base.len(), 3, |i| base[i] + 1);
        assert_eq!(out, (0..50).map(|i| i * 3 + 1).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_empty_and_single() {
        let pool = WorkerPool::new(2);
        assert!(pool.scope_map(0, 4, |i| i).is_empty());
        assert_eq!(pool.scope_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn concurrent_scope_maps_do_not_interfere() {
        let pool = Arc::new(WorkerPool::new(4));
        let mut joins = Vec::new();
        for t in 0..6u64 {
            let pool = pool.clone();
            joins.push(std::thread::spawn(move || {
                let out = pool.scope_map(40, 4, |i| t * 1000 + i as u64);
                assert_eq!(out, (0..40).map(|i| t * 1000 + i).collect::<Vec<_>>());
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn scope_map_survives_saturated_pool() {
        // Fill the single worker with slow detached tasks: the caller must
        // still complete the scoped map on its own.
        let pool = WorkerPool::new(1);
        for _ in 0..4 {
            pool.execute(Box::new(|| std::thread::sleep(Duration::from_millis(30))));
        }
        let out = pool.scope_map(16, 1, |i| i);
        assert_eq!(out, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn scope_map_propagates_item_panic_without_hanging() {
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_map(8, 2, |i| {
                if i == 3 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(result.is_err(), "item panic must surface to the caller");
        // the pool (and its workers) must stay healthy afterwards
        assert_eq!(pool.scope_map(4, 2, |i| i), vec![0, 1, 2, 3]);
    }

    #[test]
    fn ensure_threads_grows_but_never_shrinks() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.threads(), 2);
        pool.ensure_threads(5);
        assert_eq!(pool.threads(), 5);
        pool.ensure_threads(1);
        assert_eq!(pool.threads(), 5);
    }

    #[test]
    fn global_pool_is_shared_and_alive() {
        let a = global() as *const WorkerPool;
        let b = global() as *const WorkerPool;
        assert_eq!(a, b);
        assert!(global().threads() >= 1);
        assert_eq!(global().scope_map(8, 4, |i| i), (0..8).collect::<Vec<_>>());
    }
}
