//! Minimal JSON reader/writer for the weight files exchanged with the
//! python compile path (`artifacts/svm_weights.json`).
//!
//! Supports the subset we emit: objects, arrays, numbers (f64), strings
//! (no escapes beyond `\"`, `\\`, `\n`, `\t`), booleans, null. Not a general
//! JSON library — a substrate with exactly the surface the project needs,
//! fully tested below.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value (numbers are f64, as in JSON itself).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj()?.get(key)
    }

    /// Serialize compactly (deterministic: object keys are sorted by BTreeMap).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        _ => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (whole input must be consumed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(JsonError::Trailing(pos));
        }
        Ok(value)
    }
}

/// Parse errors with byte offsets for debuggability.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JsonError {
    Eof,
    Unexpected(usize, u8),
    BadNumber(usize),
    BadEscape(usize),
    Trailing(usize),
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JsonError::Eof => write!(f, "json: unexpected end of input"),
            JsonError::Unexpected(p, b) => {
                write!(f, "json: unexpected byte {:?} at offset {p}", *b as char)
            }
            JsonError::BadNumber(p) => write!(f, "json: bad number at offset {p}"),
            JsonError::BadEscape(p) => write!(f, "json: bad escape at offset {p}"),
            JsonError::Trailing(p) => write!(f, "json: trailing garbage at offset {p}"),
        }
    }
}

impl std::error::Error for JsonError {}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else {
        return Err(JsonError::Eof);
    };
    match c {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, b"true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, b"false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, b"null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        other => Err(JsonError::Unexpected(*pos, other)),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &[u8], v: Json) -> Result<Json, JsonError> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(JsonError::Unexpected(*pos, b[*pos]))
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    debug_assert_eq!(b[*pos], b'{');
    *pos += 1;
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            return Err(b.get(*pos).map_or(JsonError::Eof, |&c| {
                JsonError::Unexpected(*pos, c)
            }));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(b.get(*pos).map_or(JsonError::Eof, |&c| {
                JsonError::Unexpected(*pos, c)
            }));
        }
        *pos += 1;
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            Some(&c) => return Err(JsonError::Unexpected(*pos, c)),
            None => return Err(JsonError::Eof),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    debug_assert_eq!(b[*pos], b'[');
    *pos += 1;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            Some(&c) => return Err(JsonError::Unexpected(*pos, c)),
            None => return Err(JsonError::Eof),
        }
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or(JsonError::BadNumber(start))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut s = String::new();
    loop {
        let Some(&c) = b.get(*pos) else {
            return Err(JsonError::Eof);
        };
        *pos += 1;
        match c {
            b'"' => return Ok(s),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    return Err(JsonError::Eof);
                };
                *pos += 1;
                match e {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'/' => s.push('/'),
                    _ => return Err(JsonError::BadEscape(*pos - 1)),
                }
            }
            _ => {
                // re-decode multi-byte utf8 by finding the char boundary
                let tail = &b[*pos - 1..];
                let ch_len = utf8_len(c);
                if ch_len == 1 {
                    s.push(c as char);
                } else {
                    let chunk = std::str::from_utf8(&tail[..ch_len])
                        .map_err(|_| JsonError::Unexpected(*pos - 1, c))?;
                    s.push_str(chunk);
                    *pos += ch_len - 1;
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Convenience: build `Json::Arr` of numbers.
pub fn num_array<I: IntoIterator<Item = f64>>(items: I) -> Json {
    Json::Arr(items.into_iter().map(Json::Num).collect())
}

/// Convenience: read an array of f64.
pub fn to_f64_vec(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(Json::as_f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_weights_shape() {
        let mut obj = BTreeMap::new();
        obj.insert(
            "stage1".to_string(),
            Json::Arr(vec![num_array([1.0, -2.0]), num_array([3.5, 0.0])]),
        );
        obj.insert("note".to_string(), Json::Str("hi \"there\"\n".to_string()));
        let doc = Json::Obj(obj);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn parses_python_json_output() {
        let text = r#"{"stage1": [[12, 6], [0, -4.5]], "ok": true, "n": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        let s1 = v.get("stage1").unwrap().as_arr().unwrap();
        assert_eq!(to_f64_vec(&s1[1]), Some(vec![0.0, -4.5]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(12.0).to_string(), "12");
        assert_eq!(Json::Num(-4.0).to_string(), "-4");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"héllo → ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → ok"));
    }

    #[test]
    fn nested_structures() {
        let v = Json::parse(r#"{"a": {"b": [1, [2, {"c": 3}]]}}"#).unwrap();
        let inner = v.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(inner[0].as_f64(), Some(1.0));
    }
}
