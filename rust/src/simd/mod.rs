//! Explicit-SIMD stage-I scoring kernels with runtime dispatch — the
//! software analogue of the paper's "multiple windows per cycle" kernel
//! array (ROADMAP "raw speed" item).
//!
//! The binarized scorer's column byte-streams ([`crate::bing::BinarizedScratch`])
//! are already the layout a vector unit wants: for one output row, the
//! per-plane window words of *adjacent* windows are overlapping 8-byte
//! strings of the same contiguous column-byte row. So a vector register
//! holding 4 (AVX2) or 2 (NEON) consecutive window words advances 4/2
//! windows per load, and the per-basis `2·popcount(plane ∧ b⁺) − Σx` dot
//! products run lane-parallel:
//!
//! * **AVX2** ([`ScoreKernel::Avx2`]) — 4 windows per `__m256i`; popcounts
//!   via the nibble-LUT `pshufb` + `psadbw` reduction (no AVX-512 needed).
//! * **NEON** ([`ScoreKernel::Neon`]) — 2 windows per `uint64x2_t`;
//!   popcounts via `vcnt` + pairwise-widening adds, dot products via the
//!   `vmull_s32` widening multiply.
//! * **SWAR** ([`ScoreKernel::Swar`]) — the PR-2 incremental scalar path,
//!   the universal fallback; and [`ScoreKernel::Reference`], the per-pixel
//!   repack oracle.
//!
//! Every path is **bit-identical**: all kernels evaluate the same i64
//! accumulation `acc += (Σ_j β_j·dot_j) << (7−k)` then `acc / 1024`, and the
//! property tests in this module (plus the hotpath bench) assert equality
//! against [`crate::bing::BinarizedScorer::score_map_reference`] on every
//! available path. Dispatch is decided once (at backend construction or via
//! the `--kernel` CLI override), not per window.

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::bing::BinaryBasis;

/// Fleet-wide, one-way kernel demotion latch (SDC defense, PR 9).
///
/// When a golden-probe audit catches a vector kernel producing output that
/// diverges from [`ScoreKernel::Reference`] — silent data corruption that
/// passed every structural check — the auditor latches this flag and every
/// subsequent [`score_row`] dispatch in the process degrades multi-lane
/// kernels to [`ScoreKernel::Swar`]. One bad lane is evidence the vector
/// unit (or its microcode) can't be trusted; correctness beats the ~lanes×
/// speedup. The latch is deliberately one-way: flapping back onto a kernel
/// that corrupted data once is never worth it within one process lifetime.
///
/// All kernels are bit-identical on correct hardware, so latching is
/// semantics-preserving — it only changes which instructions produce the
/// same numbers.
static DEMOTED: AtomicBool = AtomicBool::new(false);

/// Latch the fleet-wide demotion. Returns `true` only for the call that
/// actually flipped the latch (callers count `kernel_demotions` exactly
/// once per process, however many audits subsequently mismatch).
pub fn demote_to_swar() -> bool {
    DEMOTED.compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst).is_ok()
}

/// Whether the demotion latch has fired (telemetry, summaries, dispatch).
pub fn demoted() -> bool {
    DEMOTED.load(Ordering::SeqCst)
}

/// The kernel dispatch will actually run for `kernel` right now: `Swar`
/// for multi-lane kernels after demotion, `kernel` itself otherwise.
pub fn effective_kernel(kernel: ScoreKernel) -> ScoreKernel {
    if kernel.lanes() > 1 && demoted() {
        ScoreKernel::Swar
    } else {
        kernel
    }
}

/// Test-only undo so the process-global latch can't poison unrelated tests.
/// Tests that touch the latch serialize on [`DEMOTION_TEST_LOCK`].
#[cfg(test)]
pub fn reset_demotion() {
    DEMOTED.store(false, Ordering::SeqCst);
}

/// Serializes every test that reads or writes the demotion latch (it is
/// process-global state and `cargo test` runs threads in parallel).
#[cfg(test)]
pub static DEMOTION_TEST_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// One concrete scoring implementation. Resolved from a [`KernelChoice`] at
/// construction time; `Swar` is always available, vector kernels only where
/// the CPU reports the feature at runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScoreKernel {
    /// Per-pixel repack oracle (`score_map_reference`) — debug/bench only.
    Reference,
    /// Incremental scalar path: one u64 window word per plane, maintained
    /// across the slide (PR 2). The universal fallback.
    Swar,
    /// 4 windows per instruction on x86-64 with AVX2.
    Avx2,
    /// 2 windows per instruction on aarch64 (NEON is baseline there).
    Neon,
}

impl ScoreKernel {
    /// Can this kernel execute on the running CPU?
    pub fn is_available(self) -> bool {
        match self {
            ScoreKernel::Reference | ScoreKernel::Swar => true,
            ScoreKernel::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    is_x86_feature_detected!("avx2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            ScoreKernel::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Best available kernel on this host: AVX2 > NEON > SWAR.
    pub fn detect() -> Self {
        if ScoreKernel::Avx2.is_available() {
            ScoreKernel::Avx2
        } else if ScoreKernel::Neon.is_available() {
            ScoreKernel::Neon
        } else {
            ScoreKernel::Swar
        }
    }

    /// Short display name (CLI flag value, bench row label, telemetry).
    pub fn name(self) -> &'static str {
        match self {
            ScoreKernel::Reference => "reference",
            ScoreKernel::Swar => "swar",
            ScoreKernel::Avx2 => "avx2",
            ScoreKernel::Neon => "neon",
        }
    }

    /// How many windows one kernel iteration scores (bench bookkeeping).
    pub fn lanes(self) -> usize {
        match self {
            ScoreKernel::Reference | ScoreKernel::Swar => 1,
            ScoreKernel::Avx2 => 4,
            ScoreKernel::Neon => 2,
        }
    }
}

impl fmt::Display for ScoreKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The user-facing kernel selection (`--kernel auto|swar|avx2|neon`, config
/// key `scoring.kernel`): either pick the best available at startup or force
/// one specific path (forcing an *unavailable* vector path degrades to SWAR
/// with identical outputs — never a crash).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    /// Runtime dispatch: `is_x86_feature_detected!("avx2")`, NEON on
    /// aarch64, SWAR otherwise.
    #[default]
    Auto,
    Fixed(ScoreKernel),
}

impl KernelChoice {
    /// Resolve to a concrete, available kernel.
    pub fn resolve(self) -> ScoreKernel {
        match self {
            KernelChoice::Auto => ScoreKernel::detect(),
            KernelChoice::Fixed(k) if k.is_available() => k,
            KernelChoice::Fixed(_) => ScoreKernel::Swar,
        }
    }
}

impl FromStr for KernelChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(KernelChoice::Auto),
            "reference" | "ref" => Ok(KernelChoice::Fixed(ScoreKernel::Reference)),
            "swar" | "scalar" => Ok(KernelChoice::Fixed(ScoreKernel::Swar)),
            "avx2" => Ok(KernelChoice::Fixed(ScoreKernel::Avx2)),
            "neon" => Ok(KernelChoice::Fixed(ScoreKernel::Neon)),
            other => Err(format!(
                "unknown kernel `{other}` (expected auto|reference|swar|avx2|neon)"
            )),
        }
    }
}

impl fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelChoice::Auto => f.write_str("auto"),
            KernelChoice::Fixed(k) => f.write_str(k.name()),
        }
    }
}

/// Score one output row of windows. `rowbuf` holds, for each of the `ng` bit
/// planes, the contiguous column bytes of this row (plane `k` at
/// `rowbuf[k·rw ..]`, column `x` at byte offset `x`); the window word of
/// window `x` in plane `k` is the little-endian u64 at `rowbuf[k·rw + x]`.
/// `out_row.len()` windows are scored.
///
/// The caller guarantees `kernel.is_available()`; an unavailable vector
/// kernel (cross-arch match arm elision) falls through to the scalar loop,
/// which is bit-identical anyway.
pub(crate) fn score_row(
    kernel: ScoreKernel,
    bases_cm: &[BinaryBasis],
    ng: usize,
    rowbuf: &[u8],
    rw: usize,
    out_row: &mut [i32],
) {
    // SDC defense: after an audit-latched demotion, multi-lane kernels
    // dispatch to the scalar path (bit-identical output, trusted ALU).
    let kernel = effective_kernel(kernel);
    match kernel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `is_available()` checked at dispatch resolution; the
        // target_feature fn is only reached when the CPU has AVX2.
        ScoreKernel::Avx2 => unsafe { score_row_avx2(bases_cm, ng, rowbuf, rw, out_row) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline feature of aarch64.
        ScoreKernel::Neon => unsafe { score_row_neon(bases_cm, ng, rowbuf, rw, out_row) },
        _ => score_row_scalar(bases_cm, ng, rowbuf, rw, out_row),
    }
}

/// The shared scalar window: identical i64 arithmetic to
/// `BinarizedScorer::score_map_into`'s inner loop (and to every vector lane)
/// — used for the remainder windows of the vector paths and as the whole
/// loop when no vector unit exists.
#[inline]
fn score_window_scalar(
    bases_cm: &[BinaryBasis],
    ng: usize,
    rowbuf: &[u8],
    rw: usize,
    x: usize,
) -> i32 {
    let mut acc_milli = 0i64;
    for k in 0..ng {
        let plane = load_word(rowbuf, k * rw + x);
        let ones = plane.count_ones() as i64;
        let mut plane_score = 0i64; // in milli-β units
        for b in bases_cm {
            let pop = (plane & b.plus).count_ones() as i64;
            let dot = 2 * pop - ones;
            plane_score += b.beta_milli as i64 * dot;
        }
        acc_milli += plane_score << (7 - k);
    }
    (acc_milli / 1024) as i32
}

fn score_row_scalar(
    bases_cm: &[BinaryBasis],
    ng: usize,
    rowbuf: &[u8],
    rw: usize,
    out_row: &mut [i32],
) {
    for (x, out) in out_row.iter_mut().enumerate() {
        *out = score_window_scalar(bases_cm, ng, rowbuf, rw, x);
    }
}

/// Unaligned little-endian u64 read: the window word whose byte `dx` is the
/// column byte of column `x + dx`.
#[inline]
fn load_word(rowbuf: &[u8], offset: usize) -> u64 {
    u64::from_le_bytes(rowbuf[offset..offset + 8].try_into().expect("8-byte window word"))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn score_row_avx2(
    bases_cm: &[BinaryBasis],
    ng: usize,
    rowbuf: &[u8],
    rw: usize,
    out_row: &mut [i32],
) {
    use std::arch::x86_64::*;

    /// Per-64-bit-lane popcount without AVX-512: nibble LUT via `pshufb`,
    /// byte sums reduced per lane by `psadbw` against zero (Mula's method).
    #[inline]
    unsafe fn popcnt_epi64(v: __m256i) -> __m256i {
        // SAFETY: caller (an avx2 target_feature fn) guarantees AVX2.
        unsafe {
            #[rustfmt::skip]
            let lut = _mm256_setr_epi8(
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
                0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
            );
            let low = _mm256_set1_epi8(0x0f);
            let lo = _mm256_and_si256(v, low);
            let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
            let per_byte =
                _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi));
            _mm256_sad_epu8(per_byte, _mm256_setzero_si256())
        }
    }

    let ow = out_row.len();
    let mut x = 0;
    // SAFETY (whole block): all loads stay in bounds — window x reads bytes
    // [k·rw + x, k·rw + x + 8) and the caller sizes rowbuf rows to hold the
    // last window's word; lane l of a group reads window x + l with
    // x + 3 < ow. AVX2 intrinsics are safe per the target_feature contract.
    unsafe {
        while x + 4 <= ow {
            let mut acc = _mm256_setzero_si256();
            for k in 0..ng {
                let base = k * rw + x;
                // lanes 0..4 = window words of windows x..x+4 (overlapping
                // unaligned loads of the contiguous column-byte row)
                let plane = _mm256_set_epi64x(
                    load_word(rowbuf, base + 3) as i64,
                    load_word(rowbuf, base + 2) as i64,
                    load_word(rowbuf, base + 1) as i64,
                    load_word(rowbuf, base) as i64,
                );
                let ones = popcnt_epi64(plane);
                let mut plane_score = _mm256_setzero_si256();
                for b in bases_cm {
                    let mask = _mm256_set1_epi64x(b.plus as i64);
                    let pop = popcnt_epi64(_mm256_and_si256(plane, mask));
                    // dot = 2·pop − ones ∈ [−64, 64]: exact in the low 32
                    // bits, so the widening 32×32→64 signed multiply below
                    // is exact i64 arithmetic — bit-identical to the scalar.
                    let dot = _mm256_sub_epi64(_mm256_add_epi64(pop, pop), ones);
                    let beta = _mm256_set1_epi64x(b.beta_milli as i64);
                    plane_score = _mm256_add_epi64(plane_score, _mm256_mul_epi32(dot, beta));
                }
                let shift = _mm_cvtsi32_si128((7 - k) as i32);
                acc = _mm256_add_epi64(acc, _mm256_sll_epi64(plane_score, shift));
            }
            let mut lanes = [0i64; 4];
            _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, acc);
            for (l, &milli) in lanes.iter().enumerate() {
                out_row[x + l] = (milli / 1024) as i32;
            }
            x += 4;
        }
    }
    // remainder windows (< 4): identical scalar math
    for (i, out) in out_row.iter_mut().enumerate().skip(x) {
        *out = score_window_scalar(bases_cm, ng, rowbuf, rw, i);
    }
}

#[cfg(target_arch = "aarch64")]
unsafe fn score_row_neon(
    bases_cm: &[BinaryBasis],
    ng: usize,
    rowbuf: &[u8],
    rw: usize,
    out_row: &mut [i32],
) {
    use std::arch::aarch64::*;

    /// Per-64-bit-lane popcount: per-byte `vcnt`, then three pairwise
    /// widening adds (u8→u16→u32→u64).
    #[inline]
    unsafe fn popcnt_u64x2(v: uint64x2_t) -> uint64x2_t {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(vreinterpretq_u8_u64(v))))) }
    }

    let ow = out_row.len();
    let mut x = 0;
    // SAFETY: same bounds argument as the AVX2 path (lane l reads window
    // x + l with x + 1 < ow); NEON intrinsics are baseline on aarch64.
    unsafe {
        while x + 2 <= ow {
            let mut acc = vdupq_n_s64(0);
            for k in 0..ng {
                let base = k * rw + x;
                let plane = vcombine_u64(
                    vcreate_u64(load_word(rowbuf, base)),
                    vcreate_u64(load_word(rowbuf, base + 1)),
                );
                let ones = vreinterpretq_s64_u64(popcnt_u64x2(plane));
                let mut plane_score = vdupq_n_s64(0);
                for b in bases_cm {
                    let mask = vdupq_n_u64(b.plus);
                    let pop = popcnt_u64x2(vandq_u64(plane, mask));
                    // dot = 2·pop − ones fits i32, so narrowing then the
                    // widening vmull_s32 multiply is exact i64 arithmetic.
                    let dot =
                        vsubq_s64(vreinterpretq_s64_u64(vshlq_n_u64::<1>(pop)), ones);
                    let dot32 = vmovn_s64(dot);
                    let prod = vmull_s32(dot32, vdup_n_s32(b.beta_milli));
                    plane_score = vaddq_s64(plane_score, prod);
                }
                acc = vaddq_s64(acc, vshlq_s64(plane_score, vdupq_n_s64((7 - k) as i64)));
            }
            out_row[x] = (vgetq_lane_s64::<0>(acc) / 1024) as i32;
            out_row[x + 1] = (vgetq_lane_s64::<1>(acc) / 1024) as i32;
            x += 2;
        }
    }
    for (i, out) in out_row.iter_mut().enumerate().skip(x) {
        *out = score_window_scalar(bases_cm, ng, rowbuf, rw, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bing::{default_stage1, gradient_map, BinarizedScorer, BinarizedScratch, ScoreMap};
    use crate::image::{ImageGray, ImageRgb};
    use crate::util::rng;

    const ALL: [ScoreKernel; 4] = [
        ScoreKernel::Reference,
        ScoreKernel::Swar,
        ScoreKernel::Avx2,
        ScoreKernel::Neon,
    ];

    #[test]
    fn detect_returns_an_available_kernel() {
        let k = ScoreKernel::detect();
        assert!(k.is_available(), "detected kernel {k} must be available");
        assert_ne!(k, ScoreKernel::Reference, "auto must never pick the oracle");
    }

    #[test]
    fn swar_is_always_available() {
        assert!(ScoreKernel::Swar.is_available());
        assert!(ScoreKernel::Reference.is_available());
    }

    #[test]
    fn choice_parsing_round_trips() {
        for s in ["auto", "reference", "swar", "avx2", "neon"] {
            let c: KernelChoice = s.parse().unwrap();
            assert_eq!(c.to_string(), s, "Display must round-trip FromStr");
        }
        assert_eq!("SCALAR".parse::<KernelChoice>(), Ok(KernelChoice::Fixed(ScoreKernel::Swar)));
        assert!("sse9".parse::<KernelChoice>().is_err());
    }

    #[test]
    fn forcing_an_unavailable_kernel_degrades_to_swar() {
        for k in ALL {
            let resolved = KernelChoice::Fixed(k).resolve();
            if k.is_available() {
                assert_eq!(resolved, k);
            } else {
                assert_eq!(resolved, ScoreKernel::Swar);
            }
        }
    }

    /// Random gradient maps with realistic sparsity (borders and flat
    /// regions are zero in real gradient maps — exercise the skip path).
    fn random_gradient(seed: u64, w: usize, h: usize) -> ImageGray {
        let mut r = rng(seed);
        let mut g = ImageGray::new(w, h);
        for v in g.data.iter_mut() {
            let roll = r.next_u64();
            *v = if roll % 4 == 0 { 0 } else { (roll >> 8) as u8 };
        }
        g
    }

    /// The dispatch-matrix oracle: every kernel (available paths natively,
    /// unavailable ones via their documented SWAR degradation) must be
    /// bit-identical to `score_map_reference` on random inputs across the
    /// (nw, ng) grid — the property-test contract of the ISSUE.
    #[test]
    fn prop_all_kernels_match_reference_bitwise() {
        let weights = default_stage1();
        for seed in 0..6u64 {
            let (w, h) = (8 + (seed as usize * 7) % 57, 8 + (seed as usize * 11) % 41);
            let g = random_gradient(seed, w, h);
            for (nw, ng) in [(1usize, 1usize), (2, 4), (3, 6), (4, 8)] {
                let scorer = BinarizedScorer::new(&weights, nw, ng);
                let want = scorer.score_map_reference(&g);
                for k in ALL {
                    let mut scratch = BinarizedScratch::default();
                    let mut got = ScoreMap::default();
                    scorer.score_map_into_with(&g, &mut scratch, &mut got, k);
                    assert_eq!(
                        got, want,
                        "kernel {k} != reference (seed {seed}, nw={nw}, ng={ng}, {w}x{h})"
                    );
                }
            }
        }
    }

    /// Forced-fallback coverage: on a vector-capable host the scalar paths
    /// must stay exercised and exact — `--kernel swar` is a correctness
    /// escape hatch, not a stale code path.
    #[test]
    fn forced_swar_matches_native_kernel_on_structured_image() {
        let img = ImageRgb::from_fn(40, 32, |x, y| {
            [((x * 13 + y * 29) % 251) as u8, (x % 17 * 15) as u8, (y % 13 * 19) as u8]
        });
        let g = gradient_map(&img);
        let scorer = BinarizedScorer::new(&default_stage1(), 2, 4);
        let native = ScoreKernel::detect();
        let mut scratch = BinarizedScratch::default();
        let (mut a, mut b) = (ScoreMap::default(), ScoreMap::default());
        scorer.score_map_into_with(&g, &mut scratch, &mut a, native);
        scorer.score_map_into_with(&g, &mut scratch, &mut b, ScoreKernel::Swar);
        assert_eq!(a, b, "forced SWAR diverged from the native kernel {native}");
    }

    /// Shape edge cases: minimum window, single row/column of output, and
    /// widths that leave every possible vector remainder (ow mod 4 ∈ 0..4).
    #[test]
    fn vector_remainders_and_minimum_shapes() {
        let scorer = BinarizedScorer::new(&default_stage1(), 3, 6);
        for (w, h) in [(8usize, 8usize), (9, 8), (10, 9), (11, 8), (12, 10), (15, 8), (8, 40)] {
            let g = random_gradient((w * 31 + h) as u64, w, h);
            let want = scorer.score_map_reference(&g);
            for k in ALL {
                let mut scratch = BinarizedScratch::default();
                let mut got = ScoreMap::default();
                scorer.score_map_into_with(&g, &mut scratch, &mut got, k);
                assert_eq!(got, want, "kernel {k} diverged at {w}x{h}");
            }
        }
    }

    #[test]
    fn lanes_are_consistent_with_the_kernel() {
        assert_eq!(ScoreKernel::Swar.lanes(), 1);
        assert!(ScoreKernel::Avx2.lanes() == 4 && ScoreKernel::Neon.lanes() == 2);
    }

    #[test]
    fn demotion_latch_is_one_way_and_scalar_safe() {
        let _guard = DEMOTION_TEST_LOCK.lock().unwrap();
        reset_demotion();
        assert!(!demoted());
        assert_eq!(effective_kernel(ScoreKernel::Avx2), ScoreKernel::Avx2);
        assert_eq!(effective_kernel(ScoreKernel::Swar), ScoreKernel::Swar);
        assert!(demote_to_swar(), "first latch reports the flip");
        assert!(demoted());
        assert!(!demote_to_swar(), "second latch is a no-op");
        // multi-lane kernels degrade; single-lane paths are untouched
        assert_eq!(effective_kernel(ScoreKernel::Avx2), ScoreKernel::Swar);
        assert_eq!(effective_kernel(ScoreKernel::Neon), ScoreKernel::Swar);
        assert_eq!(effective_kernel(ScoreKernel::Swar), ScoreKernel::Swar);
        assert_eq!(effective_kernel(ScoreKernel::Reference), ScoreKernel::Reference);
        // demoted dispatch still produces bit-identical score maps
        let g = random_gradient(99, 24, 16);
        let scorer = BinarizedScorer::new(&default_stage1(), 2, 4);
        let want = scorer.score_map_reference(&g);
        for k in ALL {
            let mut scratch = BinarizedScratch::default();
            let mut got = ScoreMap::default();
            scorer.score_map_into_with(&g, &mut scratch, &mut got, k);
            assert_eq!(got, want, "kernel {k} diverged under demotion");
        }
        reset_demotion();
    }
}
