//! One-`use` surface for serving callers: `use bingflow::prelude::*;`
//! brings in the runtime, the request/response/error vocabulary, the
//! backend constructors and the cascade types — everything the README
//! quickstart and the examples need.
//!
//! (The ISSUE names this `pallas::prelude`; the crate is `bingflow`, so it
//! lives at `bingflow::prelude`.)

pub use crate::backend::{EngineBackend, ProposalBackend, ScaleCandidates, SimulatedAccelerator};
pub use crate::baseline::{ScoringMode, SoftwareBing};
pub use crate::bing::{default_stage1, BBox, Candidate, Proposal, Pyramid, Stage1Weights};
pub use crate::config::{
    AcceleratorConfig, CascadeConfig, Config, ResilienceConfig, RoutePolicyKind, ServingConfig,
};
pub use crate::coordinator::{
    CancelToken, Coordinator, DetectHandle, DetectRequest, DetectResponse, Downgrade,
    ProposalRequest, ProposalResponse, RequestHandle, Response, ResponseError, ServeError,
    ServeHandle, ServeResponse, ShardContext, SubmitError,
};
pub use crate::data::SyntheticDataset;
pub use crate::detect::{
    run_cascade, run_cascade_lite, CascadeDetector, CascadeParams, Detection, DetectionBackend,
};
pub use crate::fault::{ChaosBackend, FaultPlan, InjectedFault};
pub use crate::image::ImageRgb;
pub use crate::runtime::{default_engine, MockEngine, ScaleExecutor};
pub use crate::serving::{
    make_policy, BrownoutController, ResilienceToken, RetryPolicy, RoutePolicy, ServerRuntime,
    Shard, ShardHealth, ShardSupervisor,
};
pub use crate::svm::{PlattScaling, Stage2Calibration, WeightBundle};
