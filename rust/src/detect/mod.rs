//! The detection cascade: proposals as a *component*, detections as the
//! *product*.
//!
//! ```text
//!   ProposalBackend::scale_candidates      (software | engine | sim —
//!        │   every scale                    bit-identical candidates)
//!        ▼
//!   baseline::rank_and_select              stage-II SVM calibration +
//!        │   top-k proposals               bubble-heap top-k (the exact
//!        ▼                                 served proposal stage)
//!   nms::greedy_nms_topk                   class-agnostic box dedup
//!        ▼
//!   svm::PlattScaling::confidence          margin → objectness probability
//!        ▼
//!   Vec<Detection>                         (bbox, score, confidence)
//! ```
//!
//! The downstream-detector literature assumes a proposals→classifier
//! contract (Faster R-CNN's RPN feeds a detector); [`DetectionBackend`] is
//! that contract one trait level above [`ProposalBackend`]. The served path
//! (`ServerRuntime::submit_detect` → per-shard coordinator) runs exactly
//! [`run_cascade`] after the proposal stage, so the direct
//! [`CascadeDetector`] and the served cascade agree box for box — and the
//! proposal stage underneath keeps its bit-parity contract across all three
//! backends (`tests/detect_cascade.rs`).

use std::sync::Arc;

use anyhow::Result;

use crate::backend::ProposalBackend;
use crate::baseline::rank_and_select;
use crate::bing::{BBox, Candidate, Proposal};
use crate::config::CascadeConfig;
use crate::image::ImageRgb;
use crate::nms::greedy_nms_topk;
use crate::svm::{PlattScaling, Stage2Calibration};

/// A calibrated detection: the cascade's unit of output.
#[derive(Debug, Clone, PartialEq)]
pub struct Detection {
    /// Box in original-image coordinates (inclusive corners).
    pub bbox: BBox,
    /// Stage-II calibrated proposal score (comparable across scales).
    pub score: f32,
    /// Platt-calibrated class-agnostic objectness in `[0, 1]`.
    pub confidence: f32,
}

/// Resolved cascade parameters for one request: the [`CascadeConfig`]
/// defaults with any per-request overrides already folded in.
#[derive(Debug, Clone, PartialEq)]
pub struct CascadeParams {
    /// Greedy-NMS IoU threshold.
    pub nms_thresh: f32,
    /// Maximum detections returned.
    pub top_k: usize,
    /// Minimum confidence kept.
    pub min_confidence: f32,
    /// Confidence head.
    pub platt: PlattScaling,
}

impl CascadeParams {
    pub fn from_config(cfg: &CascadeConfig) -> Self {
        Self {
            nms_thresh: cfg.nms_thresh,
            top_k: cfg.top_k,
            min_confidence: cfg.min_confidence,
            platt: PlattScaling::new(cfg.platt_a, cfg.platt_b),
        }
    }
}

impl Default for CascadeParams {
    fn default() -> Self {
        Self::from_config(&CascadeConfig::default())
    }
}

/// The post-proposal half of the cascade: ranked proposals → greedy NMS →
/// Platt confidence → confidence floor → top-k detections. Pure and
/// deterministic — the served path and [`CascadeDetector`] both call this,
/// which is what makes direct/served parity a structural property rather
/// than a test-only coincidence.
pub fn run_cascade(proposals: &[Proposal], params: &CascadeParams) -> Vec<Detection> {
    let boxes: Vec<(BBox, f32)> = proposals.iter().map(|p| (p.bbox, p.score)).collect();
    greedy_nms_topk(boxes, params.nms_thresh, params.top_k)
        .into_iter()
        .map(|(bbox, score)| Detection {
            bbox,
            score,
            confidence: params.platt.confidence(score),
        })
        .filter(|d| d.confidence >= params.min_confidence)
        .collect()
}

/// The brownout cheap cascade: skip NMS entirely and map the ranked
/// proposals straight to calibrated detections (confidence floor and top-k
/// still apply). Roughly O(k) instead of O(k²) — the load-shedding
/// fallback when the serving tier downgrades a detect request to
/// proposals-only. Responses served through this path carry
/// `Downgrade::proposals_only` so callers can tell.
pub fn run_cascade_lite(proposals: &[Proposal], params: &CascadeParams) -> Vec<Detection> {
    proposals
        .iter()
        .take(params.top_k)
        .map(|p| Detection {
            bbox: p.bbox,
            score: p.score,
            confidence: params.platt.confidence(p.score),
        })
        .filter(|d| d.confidence >= params.min_confidence)
        .collect()
}

/// A detector the serving stack (or a caller) can run end to end: one image
/// in, calibrated detections out. One trait level above
/// [`ProposalBackend`] — implementations own the whole cascade.
pub trait DetectionBackend: Send + Sync {
    /// Short name for logs and telemetry.
    fn name(&self) -> &'static str;

    /// Detect with this backend's configured cascade parameters.
    fn detect(&self, img: &ImageRgb) -> Result<Vec<Detection>>;

    /// Detect with explicit per-call cascade parameters.
    fn detect_with(&self, img: &ImageRgb, params: &CascadeParams) -> Result<Vec<Detection>>;
}

/// The reference cascade over any [`ProposalBackend`]: runs every pyramid
/// scale serially on the calling thread, ranks through the *same*
/// `rank_and_select` the coordinator uses, then [`run_cascade`]. This is the
/// direct (unserved) path — the oracle the served cascade is tested against.
pub struct CascadeDetector<B: ?Sized = dyn ProposalBackend> {
    backend: Arc<B>,
    stage2: Stage2Calibration,
    params: CascadeParams,
    /// Proposal-pool size fed into NMS (the serving layer's `top_k`).
    top_k_proposals: usize,
}

impl<B: ProposalBackend + ?Sized> CascadeDetector<B> {
    pub fn new(
        backend: Arc<B>,
        stage2: Stage2Calibration,
        params: CascadeParams,
        top_k_proposals: usize,
    ) -> Self {
        assert_eq!(
            backend.pyramid().sizes,
            stage2.sizes,
            "stage-II calibration must cover the pyramid"
        );
        Self { backend, stage2, params, top_k_proposals }
    }

    /// The wrapped proposal backend.
    pub fn backend(&self) -> &Arc<B> {
        &self.backend
    }

    /// The configured default cascade parameters.
    pub fn params(&self) -> &CascadeParams {
        &self.params
    }

    /// The proposal stage alone (for parity checks against the served path).
    pub fn propose(&self, img: &ImageRgb) -> Result<Vec<Proposal>> {
        let mut cands: Vec<Candidate> = Vec::new();
        for scale_idx in 0..self.backend.pyramid().sizes.len() {
            cands.extend(self.backend.scale_candidates(img, scale_idx)?.candidates);
        }
        Ok(rank_and_select(
            &cands,
            self.backend.pyramid(),
            &self.stage2,
            img.w,
            img.h,
            self.top_k_proposals,
        ))
    }
}

impl<B: ProposalBackend + ?Sized> DetectionBackend for CascadeDetector<B> {
    fn name(&self) -> &'static str {
        self.backend.name()
    }

    fn detect(&self, img: &ImageRgb) -> Result<Vec<Detection>> {
        self.detect_with(img, &self.params)
    }

    fn detect_with(&self, img: &ImageRgb, params: &CascadeParams) -> Result<Vec<Detection>> {
        Ok(run_cascade(&self.propose(img)?, params))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::{default_stage1, Pyramid};
    use crate::data::SyntheticDataset;

    fn sizes() -> Vec<(usize, usize)> {
        vec![(16, 16), (32, 32)]
    }

    fn detector() -> CascadeDetector<SoftwareBing> {
        CascadeDetector::new(
            Arc::new(SoftwareBing::new(
                Pyramid::new(sizes()),
                default_stage1(),
                Stage2Calibration::identity(sizes()),
                ScoringMode::Exact,
            )),
            Stage2Calibration::identity(sizes()),
            CascadeParams::default(),
            200,
        )
    }

    fn bb(x0: u32, y0: u32, x1: u32, y1: u32) -> BBox {
        BBox { x0, y0, x1, y1 }
    }

    #[test]
    fn cascade_detections_come_from_the_proposal_pool() {
        let det = detector();
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let proposals = det.propose(&img).unwrap();
        let detections = det.detect(&img).unwrap();
        assert!(!detections.is_empty());
        assert!(detections.len() <= proposals.len());
        for d in &detections {
            assert!(
                proposals.iter().any(|p| p.bbox == d.bbox && p.score == d.score),
                "detection not traceable to a proposal: {d:?}"
            );
            assert!((0.0..=1.0).contains(&d.confidence));
        }
    }

    #[test]
    fn run_cascade_caps_at_top_k_and_floors_confidence() {
        let proposals: Vec<Proposal> = (0..10)
            .map(|i| {
                let o = i as u32 * 20; // disjoint boxes: NMS keeps all
                Proposal { bbox: bb(o, 0, o + 9, 9), score: 5.0 - i as f32 }
            })
            .collect();
        let params = CascadeParams { top_k: 4, ..Default::default() };
        let capped = run_cascade(&proposals, &params);
        assert_eq!(capped.len(), 4);
        assert_eq!(capped[0].score, 5.0, "highest score first");

        // identity platt: score 5 → σ(5) ≈ 0.993, score -4 → σ(-4) ≈ 0.018
        let params = CascadeParams { min_confidence: 0.5, ..Default::default() };
        let floored = run_cascade(&proposals, &params);
        assert!(floored.iter().all(|d| d.confidence >= 0.5));
        assert!(floored.len() < proposals.len(), "the floor must drop the negatives");
    }

    #[test]
    fn lite_cascade_skips_nms_but_keeps_cap_and_floor() {
        // two heavily-overlapping boxes: full cascade dedups, lite keeps both
        let proposals = vec![
            Proposal { bbox: bb(0, 0, 20, 20), score: 4.0 },
            Proposal { bbox: bb(1, 1, 21, 21), score: 3.5 },
            Proposal { bbox: bb(100, 100, 120, 120), score: -9.0 },
        ];
        let params = CascadeParams { min_confidence: 0.5, ..Default::default() };
        let full = run_cascade(&proposals, &params);
        let lite = run_cascade_lite(&proposals, &params);
        assert_eq!(full.len(), 1, "NMS collapses the overlap: {full:?}");
        assert_eq!(lite.len(), 2, "lite keeps both overlaps: {lite:?}");
        assert!(lite.iter().all(|d| d.confidence >= 0.5), "floor still applies");
        let capped =
            run_cascade_lite(&proposals, &CascadeParams { top_k: 1, ..Default::default() });
        assert_eq!(capped.len(), 1, "cap still applies");
        // on either path, every detection traces back to a proposal with
        // identical score → the confidence head agrees too
        assert_eq!(full[0], lite[0]);
    }

    #[test]
    fn confidence_is_monotone_in_score() {
        let det = detector();
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let dets = det.detect(&img).unwrap();
        for pair in dets.windows(2) {
            assert!(pair[0].score >= pair[1].score, "detections sorted by score");
            assert!(pair[0].confidence >= pair[1].confidence);
        }
    }

    #[test]
    fn params_resolve_from_config() {
        let cfg = CascadeConfig { nms_thresh: 0.3, top_k: 7, ..Default::default() };
        let p = CascadeParams::from_config(&cfg);
        assert_eq!(p.nms_thresh, 0.3);
        assert_eq!(p.top_k, 7);
        assert_eq!(p.platt, PlattScaling::identity());
    }
}
