//! Image substrate: RGB/grayscale buffers, PPM/PGM I/O, resizing.
//!
//! The resize functions here are the *functional* reference for the paper's
//! resizing module; the cycle-level streaming version (ping-pong cache,
//! 4-block rotation fetch) lives in [`crate::dataflow::resizer`] and is
//! asserted pixel-identical to [`ImageRgb::resize_nearest`].

mod io;
mod resize;

pub use io::{read_ppm, write_pgm, write_ppm, ImageIoError};

/// An 8-bit RGB image in row-major interleaved layout (`[r g b r g b ...]`).
///
/// `Default` is the empty 0×0 image — the starting state of a reusable
/// buffer for the `*_into` operations.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ImageRgb {
    pub w: usize,
    pub h: usize,
    pub data: Vec<u8>, // len == w * h * 3
}

/// An 8-bit single-channel image (gradient maps, masks).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ImageGray {
    pub w: usize,
    pub h: usize,
    pub data: Vec<u8>, // len == w * h
}

impl ImageRgb {
    /// Allocate a black image.
    pub fn new(w: usize, h: usize) -> Self {
        Self { w, h, data: vec![0; w * h * 3] }
    }

    /// Build from a fill function `(x, y) -> [r, g, b]`.
    pub fn from_fn(w: usize, h: usize, mut f: impl FnMut(usize, usize) -> [u8; 3]) -> Self {
        let mut img = Self::new(w, h);
        for y in 0..h {
            for x in 0..w {
                img.put(x, y, f(x, y));
            }
        }
        img
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> [u8; 3] {
        debug_assert!(x < self.w && y < self.h);
        let i = (y * self.w + x) * 3;
        [self.data[i], self.data[i + 1], self.data[i + 2]]
    }

    #[inline]
    pub fn put(&mut self, x: usize, y: usize, px: [u8; 3]) {
        debug_assert!(x < self.w && y < self.h);
        let i = (y * self.w + x) * 3;
        self.data[i] = px[0];
        self.data[i + 1] = px[1];
        self.data[i + 2] = px[2];
    }

    /// Nearest-neighbour resize — the hardware-faithful variant: the FPGA
    /// resizer fetches source pixels by index arithmetic, no interpolation
    /// (matches the paper's HLS design and [11]'s approach).
    pub fn resize_nearest(&self, nw: usize, nh: usize) -> ImageRgb {
        resize::nearest(self, nw, nh)
    }

    /// [`Self::resize_nearest`] writing into a reusable buffer (cleared and
    /// resized as needed) — the allocation-free serving-path variant.
    pub fn resize_nearest_into(&self, nw: usize, nh: usize, out: &mut ImageRgb) {
        resize::nearest_into(self, nw, nh, out)
    }

    /// Bilinear resize — software-quality variant for the CPU baseline
    /// comparisons and dataset tooling.
    pub fn resize_bilinear(&self, nw: usize, nh: usize) -> ImageRgb {
        resize::bilinear(self, nw, nh)
    }
}

impl ImageGray {
    pub fn new(w: usize, h: usize) -> Self {
        Self { w, h, data: vec![0; w * h] }
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> u8 {
        self.data[y * self.w + x]
    }

    #[inline]
    pub fn put(&mut self, x: usize, y: usize, v: u8) {
        self.data[y * self.w + x] = v;
    }
}

/// The source-index map used by nearest-neighbour resizing:
/// `src = floor(dst * src_len / dst_len)`, clamped. Public because the
/// dataflow resizer must use the *identical* mapping to stay pixel-exact.
#[inline]
pub fn nearest_index(dst: usize, src_len: usize, dst_len: usize) -> usize {
    ((dst * src_len) / dst_len).min(src_len - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut img = ImageRgb::new(4, 3);
        img.put(2, 1, [10, 20, 30]);
        assert_eq!(img.get(2, 1), [10, 20, 30]);
        assert_eq!(img.get(0, 0), [0, 0, 0]);
    }

    #[test]
    fn from_fn_layout() {
        let img = ImageRgb::from_fn(3, 2, |x, y| [x as u8, y as u8, 7]);
        assert_eq!(img.get(2, 1), [2, 1, 7]);
        assert_eq!(img.data.len(), 3 * 2 * 3);
    }

    #[test]
    fn nearest_index_endpoints() {
        assert_eq!(nearest_index(0, 100, 10), 0);
        assert_eq!(nearest_index(9, 100, 10), 90);
        assert_eq!(nearest_index(9, 10, 10), 9);
        // never out of range even when upsampling
        assert_eq!(nearest_index(9, 3, 10), 2);
    }

    #[test]
    fn identity_resize_is_identity() {
        let img = ImageRgb::from_fn(8, 8, |x, y| [(x * 16) as u8, (y * 16) as u8, 0]);
        assert_eq!(img.resize_nearest(8, 8), img);
    }

    #[test]
    fn downsample_by_two_picks_even_pixels() {
        let img = ImageRgb::from_fn(8, 8, |x, y| [(x * 10) as u8, (y * 10) as u8, 0]);
        let half = img.resize_nearest(4, 4);
        for y in 0..4 {
            for x in 0..4 {
                assert_eq!(half.get(x, y), img.get(x * 2, y * 2));
            }
        }
    }

    #[test]
    fn bilinear_constant_image_stays_constant() {
        let img = ImageRgb::from_fn(10, 10, |_, _| [123, 45, 200]);
        let out = img.resize_bilinear(7, 13);
        for y in 0..13 {
            for x in 0..7 {
                assert_eq!(out.get(x, y), [123, 45, 200]);
            }
        }
    }
}
