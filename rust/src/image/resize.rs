//! Resizing implementations (functional reference for the resizing module).

use super::{nearest_index, ImageRgb};

/// Nearest-neighbour resize using [`nearest_index`] — the exact mapping the
/// streaming resizer in `dataflow::resizer` reproduces cycle by cycle.
pub fn nearest(src: &ImageRgb, nw: usize, nh: usize) -> ImageRgb {
    assert!(nw > 0 && nh > 0, "resize target must be non-empty");
    let mut out = ImageRgb::new(nw, nh);
    // Precompute the column map once (the FPGA stores this as a small ROM).
    let col_map: Vec<usize> = (0..nw).map(|x| nearest_index(x, src.w, nw)).collect();
    for y in 0..nh {
        let sy = nearest_index(y, src.h, nh);
        let src_row = &src.data[sy * src.w * 3..(sy + 1) * src.w * 3];
        let dst_row = &mut out.data[y * nw * 3..(y + 1) * nw * 3];
        for (x, &sx) in col_map.iter().enumerate() {
            dst_row[x * 3..x * 3 + 3].copy_from_slice(&src_row[sx * 3..sx * 3 + 3]);
        }
    }
    out
}

/// Bilinear resize with fixed rounding (used by dataset tooling and the
/// software-quality baseline; NOT part of the parity contract).
pub fn bilinear(src: &ImageRgb, nw: usize, nh: usize) -> ImageRgb {
    assert!(nw > 0 && nh > 0, "resize target must be non-empty");
    let mut out = ImageRgb::new(nw, nh);
    let fx = src.w as f32 / nw as f32;
    let fy = src.h as f32 / nh as f32;
    for y in 0..nh {
        let sy = ((y as f32 + 0.5) * fy - 0.5).max(0.0);
        let y0 = sy as usize;
        let y1 = (y0 + 1).min(src.h - 1);
        let wy = sy - y0 as f32;
        for x in 0..nw {
            let sx = ((x as f32 + 0.5) * fx - 0.5).max(0.0);
            let x0 = sx as usize;
            let x1 = (x0 + 1).min(src.w - 1);
            let wx = sx - x0 as f32;
            let mut px = [0u8; 3];
            for c in 0..3 {
                let p00 = src.get(x0, y0)[c] as f32;
                let p01 = src.get(x1, y0)[c] as f32;
                let p10 = src.get(x0, y1)[c] as f32;
                let p11 = src.get(x1, y1)[c] as f32;
                let top = p00 + (p01 - p00) * wx;
                let bot = p10 + (p11 - p10) * wx;
                px[c] = (top + (bot - top) * wy).round().clamp(0.0, 255.0) as u8;
            }
            out.put(x, y, px);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_matches_per_pixel_definition() {
        let img = ImageRgb::from_fn(13, 9, |x, y| [(x * 7 % 256) as u8, (y * 11 % 256) as u8, 3]);
        let out = nearest(&img, 5, 4);
        for y in 0..4 {
            for x in 0..5 {
                let sx = nearest_index(x, 13, 5);
                let sy = nearest_index(y, 9, 4);
                assert_eq!(out.get(x, y), img.get(sx, sy));
            }
        }
    }

    #[test]
    fn bilinear_preserves_corners_on_upsample() {
        let img = ImageRgb::from_fn(2, 2, |x, y| [(x * 255) as u8, (y * 255) as u8, 0]);
        let out = bilinear(&img, 8, 8);
        assert_eq!(out.get(0, 0)[0], 0);
        assert_eq!(out.get(7, 7)[1], 255);
    }

    #[test]
    #[should_panic(expected = "non-empty")]
    fn zero_target_panics() {
        let img = ImageRgb::new(4, 4);
        let _ = nearest(&img, 0, 4);
    }
}
