//! Binary PPM (P6) / PGM (P5) I/O — enough to exchange images with any
//! standard tool (ImageMagick, OpenCV) without an image-crate dependency.

use std::fmt;
use std::io::{Read, Write};
use std::path::Path;

use super::{ImageGray, ImageRgb};

/// I/O and format errors for the netpbm loaders.
#[derive(Debug)]
pub enum ImageIoError {
    Io(std::io::Error),
    Format(String),
}

impl fmt::Display for ImageIoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ImageIoError::Io(e) => write!(f, "image io: {e}"),
            ImageIoError::Format(m) => write!(f, "image format: {m}"),
        }
    }
}

impl std::error::Error for ImageIoError {}

impl From<std::io::Error> for ImageIoError {
    fn from(e: std::io::Error) -> Self {
        ImageIoError::Io(e)
    }
}

fn format_err(msg: impl Into<String>) -> ImageIoError {
    ImageIoError::Format(msg.into())
}

/// Read one whitespace/comment-delimited ASCII token from a PNM header.
fn next_token(bytes: &[u8], pos: &mut usize) -> Result<String, ImageIoError> {
    // skip whitespace and `#` comments
    loop {
        while *pos < bytes.len() && bytes[*pos].is_ascii_whitespace() {
            *pos += 1;
        }
        if *pos < bytes.len() && bytes[*pos] == b'#' {
            while *pos < bytes.len() && bytes[*pos] != b'\n' {
                *pos += 1;
            }
        } else {
            break;
        }
    }
    let start = *pos;
    while *pos < bytes.len() && !bytes[*pos].is_ascii_whitespace() {
        *pos += 1;
    }
    if start == *pos {
        return Err(format_err("unexpected end of header"));
    }
    Ok(String::from_utf8_lossy(&bytes[start..*pos]).into_owned())
}

/// Load a binary PPM (P6, maxval 255).
pub fn read_ppm(path: &Path) -> Result<ImageRgb, ImageIoError> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut bytes)?;
    let mut pos = 0usize;
    let magic = next_token(&bytes, &mut pos)?;
    if magic != "P6" {
        return Err(format_err(format!("expected P6, got {magic}")));
    }
    let w: usize = next_token(&bytes, &mut pos)?
        .parse()
        .map_err(|_| format_err("bad width"))?;
    let h: usize = next_token(&bytes, &mut pos)?
        .parse()
        .map_err(|_| format_err("bad height"))?;
    let maxval: usize = next_token(&bytes, &mut pos)?
        .parse()
        .map_err(|_| format_err("bad maxval"))?;
    if maxval != 255 {
        return Err(format_err(format!("unsupported maxval {maxval}")));
    }
    pos += 1; // single whitespace after maxval
    let need = w * h * 3;
    if bytes.len() < pos + need {
        return Err(format_err("truncated pixel data"));
    }
    Ok(ImageRgb { w, h, data: bytes[pos..pos + need].to_vec() })
}

/// Write a binary PPM (P6).
pub fn write_ppm(path: &Path, img: &ImageRgb) -> Result<(), ImageIoError> {
    let mut f = std::fs::File::create(path)?;
    write!(f, "P6\n{} {}\n255\n", img.w, img.h)?;
    f.write_all(&img.data)?;
    Ok(())
}

/// Write a binary PGM (P5) — used to dump gradient maps for inspection.
pub fn write_pgm(path: &Path, img: &ImageGray) -> Result<(), ImageIoError> {
    let mut f = std::fs::File::create(path)?;
    write!(f, "P5\n{} {}\n255\n", img.w, img.h)?;
    f.write_all(&img.data)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bingflow-image-io");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn ppm_roundtrip() {
        let img = ImageRgb::from_fn(5, 3, |x, y| [x as u8, y as u8, (x + y) as u8]);
        let path = tmp("roundtrip.ppm");
        write_ppm(&path, &img).unwrap();
        let back = read_ppm(&path).unwrap();
        assert_eq!(back, img);
    }

    #[test]
    fn ppm_with_comment_header() {
        let path = tmp("comment.ppm");
        let mut payload = b"P6\n# a comment\n2 1\n255\n".to_vec();
        payload.extend_from_slice(&[1, 2, 3, 4, 5, 6]);
        std::fs::write(&path, payload).unwrap();
        let img = read_ppm(&path).unwrap();
        assert_eq!((img.w, img.h), (2, 1));
        assert_eq!(img.get(1, 0), [4, 5, 6]);
    }

    #[test]
    fn rejects_wrong_magic_and_truncation() {
        let path = tmp("bad.ppm");
        std::fs::write(&path, b"P5\n2 2\n255\n....").unwrap();
        assert!(read_ppm(&path).is_err());
        let path2 = tmp("trunc.ppm");
        std::fs::write(&path2, b"P6\n4 4\n255\nxx").unwrap();
        assert!(read_ppm(&path2).is_err());
    }

    #[test]
    fn pgm_writes_header() {
        let g = ImageGray { w: 3, h: 2, data: vec![0, 64, 128, 192, 255, 7] };
        let path = tmp("g.pgm");
        write_pgm(&path, &g).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(bytes.starts_with(b"P5\n3 2\n255\n"));
        assert_eq!(&bytes[bytes.len() - 6..], &[0, 64, 128, 192, 255, 7]);
    }
}
