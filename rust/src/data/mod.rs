//! Dataset substrate: annotations + the synthetic VOC2007 stand-in.
//!
//! VOC2007 is not available in this environment (repro band 0 → data gate),
//! so quality experiments (Fig. 5: DR / MABO vs #WIN) run on procedurally
//! generated scenes with exact ground-truth boxes — see [`synthetic`] and
//! DESIGN.md §2 for why the substitution preserves the measured behaviour
//! (DR/MABO are geometric functions of proposals × GT boxes; the SVM is
//! trained the same way BING's stage-I is).

pub mod synthetic;

pub use synthetic::{SceneConfig, SyntheticDataset, SyntheticVideo};

use crate::image::ImageRgb;

/// An axis-aligned ground-truth box, inclusive pixel coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GtBox {
    pub x0: u32,
    pub y0: u32,
    pub x1: u32,
    pub y1: u32,
}

impl GtBox {
    pub fn new(x0: u32, y0: u32, x1: u32, y1: u32) -> Self {
        debug_assert!(x0 <= x1 && y0 <= y1);
        Self { x0, y0, x1, y1 }
    }

    pub fn width(&self) -> u32 {
        self.x1 - self.x0 + 1
    }

    pub fn height(&self) -> u32 {
        self.y1 - self.y0 + 1
    }

    pub fn area(&self) -> u64 {
        self.width() as u64 * self.height() as u64
    }
}

/// One annotated sample.
#[derive(Debug, Clone)]
pub struct Sample {
    pub image: ImageRgb,
    pub boxes: Vec<GtBox>,
    /// Stable id (seed-derived) for reproducible reporting.
    pub id: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gtbox_geometry() {
        let b = GtBox::new(10, 20, 19, 39);
        assert_eq!(b.width(), 10);
        assert_eq!(b.height(), 20);
        assert_eq!(b.area(), 200);
    }
}
