//! Procedural VOC-like scene generator.
//!
//! Each scene is a textured low-contrast background with 1..=max_objects
//! salient objects (rectangles, ellipses, triangles — solid or textured)
//! whose boundaries carry the closed-gradient signal BING keys on. Placement
//! rejects heavy overlap so ground truth stays unambiguous. Fully
//! deterministic from the dataset seed: sample `i` of seed `s` is identical
//! across runs and platforms (ChaCha8 + integer-only placement logic).

use super::{GtBox, Sample};
use crate::image::ImageRgb;
use crate::util::{rng, Rng};

/// Shape classes the generator draws.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Shape {
    Rect,
    Ellipse,
    Triangle,
}

/// Scene-generation parameters.
#[derive(Debug, Clone)]
pub struct SceneConfig {
    pub width: usize,
    pub height: usize,
    pub max_objects: usize,
    /// Minimum object side as a fraction of the image side (per-mille).
    pub min_side_pm: u32,
    /// Maximum object side as a fraction of the image side (per-mille).
    pub max_side_pm: u32,
    /// Background texture amplitude (0 = flat background).
    pub bg_noise: u8,
}

impl Default for SceneConfig {
    fn default() -> Self {
        Self {
            width: 192,
            height: 192,
            max_objects: 4,
            min_side_pm: 120,  // 12% of the side
            max_side_pm: 550,  // 55% of the side
            bg_noise: 14,
        }
    }
}

/// A deterministic, indexable synthetic dataset.
#[derive(Debug, Clone)]
pub struct SyntheticDataset {
    pub config: SceneConfig,
    pub seed: u64,
    pub len: usize,
}

impl SyntheticDataset {
    pub fn new(config: SceneConfig, seed: u64, len: usize) -> Self {
        Self { config, seed, len }
    }

    /// The canonical evaluation split used by EXPERIMENTS.md (seed 2007,
    /// mirroring the VOC year; 64 images of 192×192 by default).
    pub fn voc_like_val(len: usize) -> Self {
        Self::new(SceneConfig::default(), 2007, len)
    }

    /// Training split (distinct seed so train/val never overlap).
    pub fn voc_like_train(len: usize) -> Self {
        Self::new(SceneConfig::default(), 7002, len)
    }

    /// Generate sample `index` (stateless — samples can be generated in any
    /// order or in parallel).
    pub fn sample(&self, index: usize) -> Sample {
        assert!(index < self.len, "sample index out of range");
        let mut r = rng(self.seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let cfg = &self.config;
        let mut image = background(&mut r, cfg);
        let n_objects = r.range_usize(1, cfg.max_objects + 1);
        let mut boxes: Vec<GtBox> = Vec::with_capacity(n_objects);
        for _ in 0..n_objects {
            // rejection-sample a placement that doesn't swallow existing GT
            for _attempt in 0..24 {
                let Some(gt) = try_place(&mut r, cfg, &boxes) else {
                    continue;
                };
                draw_object(&mut r, &mut image, gt);
                boxes.push(gt);
                break;
            }
        }
        Sample { image, boxes, id: self.seed.wrapping_mul(1_000_003) + index as u64 }
    }

    pub fn iter(&self) -> impl Iterator<Item = Sample> + '_ {
        (0..self.len).map(|i| self.sample(i))
    }
}

/// A temporally coherent synthetic video clip: one static scene whose
/// objects drift by at most `jitter` pixels per axis from frame to frame.
/// The background and the object set (count, shapes, colors, textures,
/// sizes) never change — only positions do — so consecutive frames differ
/// in a handful of object-sized patches. That is exactly the workload the
/// dirty-tile incremental path in [`crate::temporal`] exploits, and the
/// trace-replay benchmark drives through the serving runtime.
#[derive(Debug, Clone)]
pub struct SyntheticVideo {
    pub config: SceneConfig,
    pub seed: u64,
    /// Maximum per-axis object displacement per frame, in pixels.
    /// `0` = a perfectly static clip (every frame bit-identical).
    pub jitter: u32,
}

impl SyntheticVideo {
    pub fn new(config: SceneConfig, seed: u64, jitter: u32) -> Self {
        Self { config, seed, jitter }
    }

    /// The canonical clip for the video benchmarks: the default VOC-like
    /// scene with per-frame object drift.
    pub fn voc_like(seed: u64, jitter: u32) -> Self {
        Self::new(SceneConfig::default(), seed, jitter)
    }

    /// Frame `index`, stateless and deterministic. Three independent rng
    /// streams keep the clip coherent: the *scene* stream (derived from
    /// the video seed alone) fixes the background and the object
    /// placements identically in every frame; the *drift* stream (seed ⊕
    /// frame index) jitters each object's position; each object's *paint*
    /// stream (seed ⊕ object index) draws it the same way wherever it
    /// landed. Shifts preserve box size, so a moved object repaints the
    /// same pixel count — its texture stays frame-stable too.
    pub fn frame(&self, index: u64) -> ImageRgb {
        let cfg = &self.config;
        let mut scene = rng(self.seed ^ 0xB5AD_4ECE_DA1C_E2A9);
        let mut image = background(&mut scene, cfg);
        let n_objects = scene.range_usize(1, cfg.max_objects + 1);
        let mut boxes: Vec<GtBox> = Vec::with_capacity(n_objects);
        for _ in 0..n_objects {
            for _attempt in 0..24 {
                let Some(gt) = try_place(&mut scene, cfg, &boxes) else {
                    continue;
                };
                boxes.push(gt);
                break;
            }
        }
        let mut drift = rng(
            self.seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x94D0_49BB_1331_11EB,
        );
        for (i, gt) in boxes.iter().enumerate() {
            let moved = if self.jitter == 0 {
                *gt
            } else {
                let j = self.jitter as i32;
                let dx = drift.range_i32_inclusive(-j, j) as i64;
                let dy = drift.range_i32_inclusive(-j, j) as i64;
                shift_box(*gt, dx, dy, cfg.width, cfg.height)
            };
            let mut paint =
                rng(self.seed ^ (i as u64 + 1).wrapping_mul(0xA076_1D64_78BD_642F));
            draw_object(&mut paint, &mut image, moved);
        }
        image
    }
}

/// Translate a GT box by `(dx, dy)`, clamped so it keeps the 1-pixel
/// border margin `try_place` guarantees. Size is preserved exactly: an
/// object pushed against a border slides along it instead of shrinking.
fn shift_box(gt: GtBox, dx: i64, dy: i64, w: usize, h: usize) -> GtBox {
    let span_x = gt.x1 - gt.x0;
    let span_y = gt.y1 - gt.y0;
    let max_x0 = (w as i64 - 2 - span_x as i64).max(1);
    let max_y0 = (h as i64 - 2 - span_y as i64).max(1);
    let x0 = (gt.x0 as i64 + dx).clamp(1, max_x0) as u32;
    let y0 = (gt.y0 as i64 + dy).clamp(1, max_y0) as u32;
    GtBox::new(x0, y0, x0 + span_x, y0 + span_y)
}

/// Low-contrast textured background: two-tone vertical ramp + value noise.
fn background(r: &mut Rng, cfg: &SceneConfig) -> ImageRgb {
    let base: [i32; 3] = [
        r.range_i32_inclusive(70, 149),
        r.range_i32_inclusive(70, 149),
        r.range_i32_inclusive(70, 149),
    ];
    let ramp: i32 = r.range_i32_inclusive(-30, 29);
    let noise = cfg.bg_noise as i32;
    let h = cfg.height as i32;
    let mut img = ImageRgb::new(cfg.width, cfg.height);
    for y in 0..cfg.height {
        let row_shift = ramp * y as i32 / h.max(1);
        for x in 0..cfg.width {
            let mut px = [0u8; 3];
            for c in 0..3 {
                let n: i32 = if noise > 0 { r.range_i32_inclusive(-noise, noise) } else { 0 };
                px[c] = (base[c] + row_shift + n).clamp(0, 255) as u8;
            }
            img.put(x, y, px);
        }
    }
    img
}

/// Try to place a new GT box that overlaps existing ones by < 30% IoU-ish
/// (cheap intersection-over-min-area test; exact IoU lives in metrics/).
fn try_place(r: &mut Rng, cfg: &SceneConfig, existing: &[GtBox]) -> Option<GtBox> {
    let side_w = cfg.width as u32;
    let side_h = cfg.height as u32;
    let min_w = (side_w * cfg.min_side_pm / 1000).max(8);
    let max_w = (side_w * cfg.max_side_pm / 1000).max(min_w + 1);
    let min_h = (side_h * cfg.min_side_pm / 1000).max(8);
    let max_h = (side_h * cfg.max_side_pm / 1000).max(min_h + 1);
    let bw = r.range_u32_inclusive(min_w, max_w);
    let bh = r.range_u32_inclusive(min_h, max_h);
    if bw + 2 >= side_w || bh + 2 >= side_h {
        return None;
    }
    let x0 = r.range_u32_inclusive(1, side_w - bw - 2);
    let y0 = r.range_u32_inclusive(1, side_h - bh - 2);
    let cand = GtBox::new(x0, y0, x0 + bw - 1, y0 + bh - 1);
    for b in existing {
        let ix = overlap_1d(cand.x0, cand.x1, b.x0, b.x1);
        let iy = overlap_1d(cand.y0, cand.y1, b.y0, b.y1);
        let inter = ix as u64 * iy as u64;
        if inter * 10 > cand.area().min(b.area()) * 3 {
            return None; // > 30% of the smaller box covered
        }
    }
    Some(cand)
}

fn overlap_1d(a0: u32, a1: u32, b0: u32, b1: u32) -> u32 {
    let lo = a0.max(b0);
    let hi = a1.min(b1);
    if hi >= lo {
        hi - lo + 1
    } else {
        0
    }
}

/// One contrasting color channel: pushed away from the background midtones.
fn object_channel(r: &mut Rng) -> u8 {
    if r.bool_p(0.5) {
        r.range_u32_inclusive(180, 255) as u8
    } else {
        r.range_u32_inclusive(0, 50) as u8
    }
}

/// Paint an object inside its GT box with a contrasting color (optionally
/// textured), so the box boundary is a closed gradient contour.
fn draw_object(r: &mut Rng, img: &mut ImageRgb, gt: GtBox) {
    let shape = match r.below(3) {
        0 => Shape::Rect,
        1 => Shape::Ellipse,
        _ => Shape::Triangle,
    };
    // contrasting palette: push channels away from background midtones
    let color: [u8; 3] = [
        object_channel(r),
        object_channel(r),
        object_channel(r),
    ];
    let textured = r.bool_p(0.35);
    let tex_amp: i32 = if textured { r.range_i32_inclusive(8, 27) } else { 0 };
    let (cx, cy) = (
        (gt.x0 + gt.x1) as f32 / 2.0,
        (gt.y0 + gt.y1) as f32 / 2.0,
    );
    let (rx, ry) = (
        (gt.x1 - gt.x0) as f32 / 2.0,
        (gt.y1 - gt.y0) as f32 / 2.0,
    );
    for y in gt.y0..=gt.y1 {
        for x in gt.x0..=gt.x1 {
            let inside = match shape {
                Shape::Rect => true,
                Shape::Ellipse => {
                    let dx = (x as f32 - cx) / rx.max(0.5);
                    let dy = (y as f32 - cy) / ry.max(0.5);
                    dx * dx + dy * dy <= 1.0
                }
                Shape::Triangle => {
                    // upright triangle: width shrinks linearly toward the top
                    let t = (y - gt.y0) as f32 / (gt.y1 - gt.y0).max(1) as f32;
                    let half = rx * t;
                    (x as f32 - cx).abs() <= half
                }
            };
            if inside {
                let mut px = color;
                if tex_amp > 0 {
                    for c in &mut px {
                        let n: i32 = r.range_i32_inclusive(-tex_amp, tex_amp);
                        *c = (*c as i32 + n).clamp(0, 255) as u8;
                    }
                }
                img.put(x as usize, y as usize, px);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed_and_index() {
        let ds = SyntheticDataset::voc_like_val(4);
        let a = ds.sample(2);
        let b = ds.sample(2);
        assert_eq!(a.image, b.image);
        assert_eq!(a.boxes, b.boxes);
    }

    #[test]
    fn different_indices_differ() {
        let ds = SyntheticDataset::voc_like_val(4);
        assert_ne!(ds.sample(0).image, ds.sample(1).image);
    }

    #[test]
    fn every_sample_has_ground_truth() {
        let ds = SyntheticDataset::voc_like_val(8);
        for s in ds.iter() {
            assert!(!s.boxes.is_empty(), "sample {} lost all objects", s.id);
            for b in &s.boxes {
                assert!((b.x1 as usize) < s.image.w);
                assert!((b.y1 as usize) < s.image.h);
                assert!(b.width() >= 8 && b.height() >= 8);
            }
        }
    }

    #[test]
    fn boxes_do_not_heavily_overlap() {
        let ds = SyntheticDataset::voc_like_val(8);
        for s in ds.iter() {
            for (i, a) in s.boxes.iter().enumerate() {
                for b in &s.boxes[i + 1..] {
                    let ix = overlap_1d(a.x0, a.x1, b.x0, b.x1) as u64;
                    let iy = overlap_1d(a.y0, a.y1, b.y0, b.y1) as u64;
                    assert!(ix * iy * 10 <= a.area().min(b.area()) * 3);
                }
            }
        }
    }

    #[test]
    fn objects_are_salient_against_background() {
        // the object boundary must carry real gradient energy
        let ds = SyntheticDataset::voc_like_val(4);
        let s = ds.sample(0);
        let g = crate::bing::gradient_map(&s.image);
        let b = s.boxes[0];
        let mut boundary_energy = 0u64;
        for x in b.x0..=b.x1 {
            boundary_energy += g.get(x as usize, b.y0 as usize) as u64;
            boundary_energy += g.get(x as usize, b.y1 as usize) as u64;
        }
        let per_pixel = boundary_energy / (2 * b.width() as u64);
        assert!(per_pixel > 10, "boundary too faint: {per_pixel}");
    }

    #[test]
    fn train_val_disjoint_seeds() {
        let t = SyntheticDataset::voc_like_train(2).sample(0);
        let v = SyntheticDataset::voc_like_val(2).sample(0);
        assert_ne!(t.image, v.image);
    }

    #[test]
    fn video_frames_are_deterministic_and_zero_jitter_is_static() {
        let v = SyntheticVideo::voc_like(11, 3);
        assert_eq!(v.frame(4), v.frame(4), "frame generation must be stateless");
        let still = SyntheticVideo::voc_like(11, 0);
        assert_eq!(still.frame(0), still.frame(9), "zero jitter must freeze the clip");
    }

    #[test]
    fn jittered_frames_stay_temporally_coherent() {
        let v = SyntheticVideo::voc_like(5, 2);
        let a = v.frame(0);
        let b = v.frame(1);
        assert_ne!(a, b, "jitter must move something");
        let changed = a.data.iter().zip(&b.data).filter(|(x, y)| x != y).count();
        let frac = changed as f64 / a.data.len() as f64;
        assert!(
            frac < 0.5,
            "consecutive frames must share most pixels, {frac:.2} changed"
        );
    }

    #[test]
    fn shift_box_clamps_at_borders_and_preserves_size() {
        let g = GtBox::new(5, 5, 20, 30);
        let s = shift_box(g, -100, 100, 64, 64);
        assert_eq!((s.width(), s.height()), (g.width(), g.height()));
        assert_eq!(s.x0, 1, "left clamp keeps the placement margin");
        assert!(s.y1 <= 62, "bottom clamp keeps the placement margin: {}", s.y1);
        // no displacement, no change
        assert_eq!(shift_box(g, 0, 0, 64, 64), g);
    }
}
