//! Routing policies: how the request router picks a backend shard.
//!
//! A policy sees a small immutable [`RouteRequest`] plus a load
//! [`ShardSnapshot`] per shard and returns a shard index — it never holds
//! locks or blocks, so routing stays off the serving hot path's critical
//! section. Draining shards must not be picked; a policy that cannot place
//! the request anywhere returns `None` and the runtime refuses the
//! submission as [`crate::coordinator::SubmitError::Unroutable`].
//!
//! Three built-ins cover the paper's scale-out space:
//!
//! * [`RoundRobin`] — uniform spraying; the baseline distributor in front
//!   of replicated pipelines (PipeCNN's work-item dispatch).
//! * [`LeastLoaded`] — join-the-shortest-queue by *outstanding scale
//!   tasks* (queued or executing), the inflight count each shard already
//!   tracks.
//! * [`ScaleAffinity`] — the paper's multi-pipeline split: large frames
//!   are pinned to a dedicated shard group so the long-running big-scale
//!   work cannot convoy small frames behind it.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Immutable facts about one request the router may key on. Policies that
/// need arrival-order state (rotation cursors, token buckets) keep their
/// own atomics, as the built-ins do.
#[derive(Debug, Clone, Copy)]
pub struct RouteRequest {
    /// Original image width in pixels.
    pub image_w: usize,
    /// Original image height in pixels.
    pub image_h: usize,
}

impl RouteRequest {
    /// Image area — the size signal `ScaleAffinity` keys on.
    pub fn area(&self) -> usize {
        self.image_w * self.image_h
    }
}

/// Snapshot of one shard's load at routing time.
#[derive(Debug, Clone, Copy)]
pub struct ShardSnapshot {
    /// Outstanding scale tasks on the shard — queued at admission *or*
    /// executing. (Admission tokens are released the moment execution
    /// starts, so a queued-only count would read 0 under normal load and
    /// blind every load-aware policy.)
    pub load: usize,
    /// The shard is draining — it must not receive new requests.
    pub draining: bool,
}

/// A shard-selection strategy. Implementations must be `Send + Sync`
/// (routing happens concurrently from every submitting thread).
pub trait RoutePolicy: Send + Sync {
    /// Short name for logs, config and benchmark rows.
    fn name(&self) -> &'static str;

    /// Pick a shard index for `req`, or `None` when no shard accepts work.
    /// Must never return an index `>= shards.len()` or a draining shard.
    fn route(&self, req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize>;

    /// Whether this policy reads [`ShardSnapshot::load`]. When `false`
    /// (the default) the runtime skips the per-shard inflight-count lock
    /// acquisitions and passes `load = 0` — load-oblivious policies keep
    /// the submit hot path lock-free apart from their own atomics.
    fn needs_load(&self) -> bool {
        false
    }
}

/// Starting at `ctr`'s next value, pick the first non-draining shard in
/// `[lo, hi)` walking circularly — the shared round-robin scan.
fn scan(lo: usize, hi: usize, ctr: &AtomicUsize, shards: &[ShardSnapshot]) -> Option<usize> {
    let len = hi.saturating_sub(lo);
    if len == 0 {
        return None;
    }
    let start = ctr.fetch_add(1, Ordering::Relaxed);
    (0..len)
        .map(|k| lo + (start + k) % len)
        .find(|&i| !shards[i].draining)
}

/// Uniform spraying over the non-draining shards.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&self, _req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize> {
        scan(0, shards.len(), &self.next, shards)
    }
}

/// Join-the-shortest-queue by outstanding (queued + executing) scale
/// tasks; ties break toward the lowest shard index (deterministic under
/// equal load).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least"
    }

    fn route(&self, _req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize> {
        shards
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.draining)
            .min_by_key(|(i, s)| (s.load, *i))
            .map(|(i, _)| i)
    }

    fn needs_load(&self) -> bool {
        true
    }
}

/// The paper's multi-pipeline split as a routing policy: the upper half of
/// the shard array is dedicated to large frames (`area >= large_area`),
/// the lower half to small ones, round-robin inside each group. With a
/// single shard (or when the preferred group is fully draining) requests
/// fall back to the other group, so affinity degrades to round-robin
/// rather than refusing work.
#[derive(Debug)]
pub struct ScaleAffinity {
    /// Images at least this many pixels route to the large-frame group.
    pub large_area: usize,
    next_small: AtomicUsize,
    next_large: AtomicUsize,
}

impl ScaleAffinity {
    /// Default split point: the 192×192 synthetic VOC-like frame — the
    /// canonical eval image lands in the large group, anything scaled
    /// below it in the small group.
    pub const DEFAULT_LARGE_AREA: usize = 192 * 192;

    pub fn new(large_area: usize) -> Self {
        Self {
            large_area,
            next_small: AtomicUsize::new(0),
            next_large: AtomicUsize::new(0),
        }
    }
}

impl Default for ScaleAffinity {
    fn default() -> Self {
        Self::new(Self::DEFAULT_LARGE_AREA)
    }
}

impl RoutePolicy for ScaleAffinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn route(&self, req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize> {
        let n = shards.len();
        if n == 0 {
            return None;
        }
        // small group: [0, split); large group: [split, n). n=1 → no large
        // group, everything routes through the small scan.
        let split = n - n / 2;
        let is_large = n > 1 && req.area() >= self.large_area;
        let (primary, fallback) = if is_large {
            ((split, n, &self.next_large), (0, split, &self.next_small))
        } else {
            ((0, split, &self.next_small), (split, n, &self.next_large))
        };
        scan(primary.0, primary.1, primary.2, shards)
            .or_else(|| scan(fallback.0, fallback.1, fallback.2, shards))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snaps(load: &[usize], draining: &[bool]) -> Vec<ShardSnapshot> {
        load.iter()
            .zip(draining)
            .map(|(&q, &d)| ShardSnapshot { load: q, draining: d })
            .collect()
    }

    fn req(side: usize) -> RouteRequest {
        RouteRequest { image_w: side, image_h: side }
    }

    #[test]
    fn round_robin_cycles_and_skips_draining() {
        let p = RoundRobin::new();
        let s = snaps(&[0, 0, 0], &[false, false, false]);
        let picks: Vec<_> = (0..6).map(|_| p.route(&req(192), &s).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);

        let s = snaps(&[0, 0, 0], &[false, true, false]);
        for _ in 0..8 {
            assert_ne!(p.route(&req(192), &s), Some(1), "routed to a draining shard");
        }
        let all_drained = snaps(&[0, 0], &[true, true]);
        assert_eq!(p.route(&req(192), &all_drained), None);
    }

    #[test]
    fn only_least_loaded_requests_load_snapshots() {
        assert!(LeastLoaded.needs_load());
        assert!(!RoundRobin::new().needs_load());
        assert!(!ScaleAffinity::default().needs_load());
    }

    #[test]
    fn least_loaded_picks_shortest_queue() {
        let p = LeastLoaded;
        let s = snaps(&[3, 0, 2], &[false, false, false]);
        assert_eq!(p.route(&req(192), &s), Some(1));
        // draining minimum is skipped for the next-best shard
        let s = snaps(&[3, 0, 2], &[false, true, false]);
        assert_eq!(p.route(&req(192), &s), Some(2));
        // deterministic tie-break toward the lowest index
        let s = snaps(&[1, 1, 1], &[false, false, false]);
        assert_eq!(p.route(&req(192), &s), Some(0));
    }

    #[test]
    fn affinity_partitions_by_image_area() {
        let p = ScaleAffinity::default();
        let s = snaps(&[0; 4], &[false; 4]);
        // 4 shards: small group {0,1}, large group {2,3}
        for _ in 0..6 {
            let small = p.route(&req(96), &s).unwrap();
            assert!(small < 2, "small frame left its group: {small}");
            let large = p.route(&req(256), &s).unwrap();
            assert!(large >= 2, "large frame left its group: {large}");
        }
    }

    #[test]
    fn affinity_falls_back_when_its_group_drains() {
        let p = ScaleAffinity::default();
        // large group {2,3} fully draining → large frames spill to {0,1}
        let s = snaps(&[0; 4], &[false, false, true, true]);
        for _ in 0..4 {
            let pick = p.route(&req(256), &s).unwrap();
            assert!(pick < 2, "fallback left the healthy group: {pick}");
        }
        // everything draining → unroutable
        let s = snaps(&[0; 4], &[true; 4]);
        assert_eq!(p.route(&req(256), &s), None);
    }

    #[test]
    fn affinity_single_shard_serves_everything() {
        let p = ScaleAffinity::default();
        let s = snaps(&[0], &[false]);
        assert_eq!(p.route(&req(96), &s), Some(0));
        assert_eq!(p.route(&req(512), &s), Some(0));
    }
}
