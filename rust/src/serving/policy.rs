//! Routing policies: how the request router picks a backend shard.
//!
//! A policy sees a small immutable [`RouteRequest`] plus a load
//! [`ShardSnapshot`] per shard and returns a shard index — it never holds
//! locks or blocks, so routing stays off the serving hot path's critical
//! section. Draining shards must not be picked; a policy that cannot place
//! the request anywhere returns `None` and the runtime refuses the
//! submission as [`crate::coordinator::SubmitError::Unroutable`].
//!
//! Four built-ins cover the paper's scale-out space:
//!
//! * [`RoundRobin`] — uniform spraying; the baseline distributor in front
//!   of replicated pipelines (PipeCNN's work-item dispatch).
//! * [`LeastLoaded`] — join-the-shortest-queue by *outstanding scale
//!   tasks* (queued or executing), the inflight count each shard already
//!   tracks.
//! * [`ScaleAffinity`] — the paper's multi-pipeline split: large frames
//!   are pinned to a dedicated shard group so the long-running big-scale
//!   work cannot convoy small frames behind it.
//! * [`SessionAffinity`] — video serving: frames of one session are pinned
//!   to one shard so that shard's [`crate::temporal`] frame cache stays
//!   warm; re-pins (shard drained under a live session) invalidate the
//!   cache and are counted.
//!
//! Policies that want to report routing anomalies (fallbacks, cache
//! invalidations) receive the runtime's metrics sink once through
//! [`RoutePolicy::attach_metrics`] and keep it in a `OnceLock` — routing
//! itself stays lock-free apart from the policies' own state.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::telemetry::ServeMetrics;

/// Immutable facts about one request the router may key on. Policies that
/// need arrival-order state (rotation cursors, token buckets) keep their
/// own atomics, as the built-ins do.
#[derive(Debug, Clone, Copy)]
pub struct RouteRequest {
    /// Original image width in pixels.
    pub image_w: usize,
    /// Original image height in pixels.
    pub image_h: usize,
    /// Video-session id, when the request opted in — the signal
    /// [`SessionAffinity`] keys on. `None` for stateless requests.
    pub session: Option<u64>,
}

impl RouteRequest {
    /// Image area — the size signal `ScaleAffinity` keys on.
    pub fn area(&self) -> usize {
        self.image_w * self.image_h
    }
}

/// Snapshot of one shard's load at routing time.
#[derive(Debug, Clone, Copy)]
pub struct ShardSnapshot {
    /// Outstanding scale tasks on the shard — queued at admission *or*
    /// executing. (Admission tokens are released the moment execution
    /// starts, so a queued-only count would read 0 under normal load and
    /// blind every load-aware policy.)
    pub load: usize,
    /// The shard is draining — it must not receive new requests.
    pub draining: bool,
}

/// A shard-selection strategy. Implementations must be `Send + Sync`
/// (routing happens concurrently from every submitting thread).
pub trait RoutePolicy: Send + Sync {
    /// Short name for logs, config and benchmark rows.
    fn name(&self) -> &'static str;

    /// Pick a shard index for `req`, or `None` when no shard accepts work.
    /// Must never return an index `>= shards.len()` or a draining shard.
    fn route(&self, req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize>;

    /// Whether this policy reads [`ShardSnapshot::load`]. When `false`
    /// (the default) the runtime skips the per-shard inflight-count lock
    /// acquisitions and passes `load = 0` — load-oblivious policies keep
    /// the submit hot path lock-free apart from their own atomics.
    fn needs_load(&self) -> bool {
        false
    }

    /// Called once by the runtime at construction so policies can report
    /// routing anomalies ([`ServeMetrics::route_fallbacks`],
    /// [`ServeMetrics::cache_invalidations`]). The default ignores it —
    /// metrics-oblivious policies need no state.
    fn attach_metrics(&self, _metrics: &Arc<ServeMetrics>) {}
}

/// Starting at `ctr`'s next value, pick the first non-draining shard in
/// `[lo, hi)` walking circularly — the shared round-robin scan.
fn scan(lo: usize, hi: usize, ctr: &AtomicUsize, shards: &[ShardSnapshot]) -> Option<usize> {
    let len = hi.saturating_sub(lo);
    if len == 0 {
        return None;
    }
    let start = ctr.fetch_add(1, Ordering::Relaxed);
    (0..len)
        .map(|k| lo + (start + k) % len)
        .find(|&i| !shards[i].draining)
}

/// Uniform spraying over the non-draining shards.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: AtomicUsize,
}

impl RoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RoutePolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&self, _req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize> {
        scan(0, shards.len(), &self.next, shards)
    }
}

/// Join-the-shortest-queue by outstanding (queued + executing) scale
/// tasks; ties break toward the lowest shard index (deterministic under
/// equal load).
#[derive(Debug, Default)]
pub struct LeastLoaded;

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least"
    }

    fn route(&self, _req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize> {
        shards
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.draining)
            .min_by_key(|(i, s)| (s.load, *i))
            .map(|(i, _)| i)
    }

    fn needs_load(&self) -> bool {
        true
    }
}

/// The paper's multi-pipeline split as a routing policy: the upper half of
/// the shard array is dedicated to large frames (`area >= large_area`),
/// the lower half to small ones, round-robin inside each group. With a
/// single shard everything routes through the small-group scan; when the
/// preferred group is fully draining the request spills to the *lowest*
/// non-draining shard of the other group (deterministic, not rotor-based,
/// so a spill burst during a drain lands on one predictable shard) and
/// [`ServeMetrics::route_fallbacks`] is incremented — the fallback used to
/// be silent, which hid mid-drain affinity violations from operators.
#[derive(Debug)]
pub struct ScaleAffinity {
    /// Images at least this many pixels route to the large-frame group.
    pub large_area: usize,
    next_small: AtomicUsize,
    next_large: AtomicUsize,
    metrics: OnceLock<Arc<ServeMetrics>>,
}

impl ScaleAffinity {
    /// Default split point: the 192×192 synthetic VOC-like frame — the
    /// canonical eval image lands in the large group, anything scaled
    /// below it in the small group.
    pub const DEFAULT_LARGE_AREA: usize = 192 * 192;

    pub fn new(large_area: usize) -> Self {
        Self {
            large_area,
            next_small: AtomicUsize::new(0),
            next_large: AtomicUsize::new(0),
            metrics: OnceLock::new(),
        }
    }
}

impl Default for ScaleAffinity {
    fn default() -> Self {
        Self::new(Self::DEFAULT_LARGE_AREA)
    }
}

impl RoutePolicy for ScaleAffinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn route(&self, req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize> {
        let n = shards.len();
        if n == 0 {
            return None;
        }
        // small group: [0, split); large group: [split, n). n=1 → no large
        // group, everything routes through the small scan.
        let split = n - n / 2;
        let is_large = n > 1 && req.area() >= self.large_area;
        let (primary, fallback) = if is_large {
            ((split, n, &self.next_large), (0, split))
        } else {
            ((0, split, &self.next_small), (split, n))
        };
        scan(primary.0, primary.1, primary.2, shards).or_else(|| {
            // Whole preferred group draining: spill deterministically to
            // the lowest healthy shard of the other group and say so.
            let spill = (fallback.0..fallback.1).find(|&i| !shards[i].draining)?;
            if let Some(m) = self.metrics.get() {
                m.route_fallbacks.add(1);
            }
            Some(spill)
        })
    }

    fn attach_metrics(&self, metrics: &Arc<ServeMetrics>) {
        let _ = self.metrics.set(Arc::clone(metrics));
    }
}

/// Pin every frame of a video session to one shard so that shard's
/// per-session frame cache ([`crate::temporal::SessionStore`]) keeps
/// seeing consecutive frames — the incremental dirty-tile path only pays
/// off when a session's frames land where its previous frame is cached.
///
/// * First frame of a session pins it to its home shard `sid % n` (stable
///   across runs, spreads sessions uniformly without coordination).
/// * If the pinned shard is draining, the session re-pins to the first
///   non-draining shard walking circularly from the stale pin — then keeps
///   that pin. Each re-pin is one [`ServeMetrics::route_fallbacks`] *and*
///   one [`ServeMetrics::cache_invalidations`]: the new shard has no frame
///   history for the session, so its next frame is a full recompute.
/// * Sessionless requests round-robin over the healthy shards; they carry
///   no cache to protect.
#[derive(Debug, Default)]
pub struct SessionAffinity {
    pins: Mutex<HashMap<u64, usize>>,
    next: AtomicUsize,
    metrics: OnceLock<Arc<ServeMetrics>>,
}

impl SessionAffinity {
    pub fn new() -> Self {
        Self::default()
    }
}

impl RoutePolicy for SessionAffinity {
    fn name(&self) -> &'static str {
        "session"
    }

    fn route(&self, req: &RouteRequest, shards: &[ShardSnapshot]) -> Option<usize> {
        let n = shards.len();
        if n == 0 {
            return None;
        }
        let Some(sid) = req.session else {
            return scan(0, n, &self.next, shards);
        };
        let mut pins = self.pins.lock().unwrap();
        let home = (sid % n as u64) as usize;
        let current = *pins.get(&sid).unwrap_or(&home);
        if current < n && !shards[current].draining {
            pins.insert(sid, current);
            return Some(current);
        }
        // Pinned shard drained (or the fleet shrank): deterministic re-pin
        // walking circularly from just past the stale pin, so consecutive
        // re-pinned sessions don't all pile onto shard 0.
        let new_pin = (1..=n).map(|k| (current + k) % n).find(|&i| !shards[i].draining)?;
        pins.insert(sid, new_pin);
        if let Some(m) = self.metrics.get() {
            m.route_fallbacks.add(1);
            m.cache_invalidations.add(1);
        }
        Some(new_pin)
    }

    fn attach_metrics(&self, metrics: &Arc<ServeMetrics>) {
        let _ = self.metrics.set(Arc::clone(metrics));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snaps(load: &[usize], draining: &[bool]) -> Vec<ShardSnapshot> {
        load.iter()
            .zip(draining)
            .map(|(&q, &d)| ShardSnapshot { load: q, draining: d })
            .collect()
    }

    fn req(side: usize) -> RouteRequest {
        RouteRequest { image_w: side, image_h: side, session: None }
    }

    fn video_req(sid: u64) -> RouteRequest {
        RouteRequest { image_w: 96, image_h: 96, session: Some(sid) }
    }

    #[test]
    fn round_robin_cycles_and_skips_draining() {
        let p = RoundRobin::new();
        let s = snaps(&[0, 0, 0], &[false, false, false]);
        let picks: Vec<_> = (0..6).map(|_| p.route(&req(192), &s).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);

        let s = snaps(&[0, 0, 0], &[false, true, false]);
        for _ in 0..8 {
            assert_ne!(p.route(&req(192), &s), Some(1), "routed to a draining shard");
        }
        let all_drained = snaps(&[0, 0], &[true, true]);
        assert_eq!(p.route(&req(192), &all_drained), None);
    }

    #[test]
    fn only_least_loaded_requests_load_snapshots() {
        assert!(LeastLoaded.needs_load());
        assert!(!RoundRobin::new().needs_load());
        assert!(!ScaleAffinity::default().needs_load());
    }

    #[test]
    fn least_loaded_picks_shortest_queue() {
        let p = LeastLoaded;
        let s = snaps(&[3, 0, 2], &[false, false, false]);
        assert_eq!(p.route(&req(192), &s), Some(1));
        // draining minimum is skipped for the next-best shard
        let s = snaps(&[3, 0, 2], &[false, true, false]);
        assert_eq!(p.route(&req(192), &s), Some(2));
        // deterministic tie-break toward the lowest index
        let s = snaps(&[1, 1, 1], &[false, false, false]);
        assert_eq!(p.route(&req(192), &s), Some(0));
    }

    #[test]
    fn affinity_partitions_by_image_area() {
        let p = ScaleAffinity::default();
        let s = snaps(&[0; 4], &[false; 4]);
        // 4 shards: small group {0,1}, large group {2,3}
        for _ in 0..6 {
            let small = p.route(&req(96), &s).unwrap();
            assert!(small < 2, "small frame left its group: {small}");
            let large = p.route(&req(256), &s).unwrap();
            assert!(large >= 2, "large frame left its group: {large}");
        }
    }

    #[test]
    fn affinity_falls_back_when_its_group_drains() {
        let p = ScaleAffinity::default();
        let m = Arc::new(ServeMetrics::default());
        p.attach_metrics(&m);
        // large group {2,3} fully draining → large frames spill to the
        // lowest healthy shard of {0,1}, deterministically, and each
        // spill is counted.
        let s = snaps(&[0; 4], &[false, false, true, true]);
        for _ in 0..4 {
            assert_eq!(p.route(&req(256), &s), Some(0), "spill must be deterministic");
        }
        assert_eq!(m.route_fallbacks.get(), 4, "every cross-group spill is counted");
        // everything draining → unroutable, not another fallback
        let s = snaps(&[0; 4], &[true; 4]);
        assert_eq!(p.route(&req(256), &s), None);
        assert_eq!(m.route_fallbacks.get(), 4);
    }

    #[test]
    fn affinity_without_metrics_still_falls_back() {
        // attach_metrics never called (standalone policy use): the spill
        // still routes, it just can't report.
        let p = ScaleAffinity::default();
        let s = snaps(&[0; 4], &[false, false, true, true]);
        assert_eq!(p.route(&req(256), &s), Some(0));
    }

    #[test]
    fn session_affinity_pins_each_session_to_its_home_shard() {
        let p = SessionAffinity::new();
        let s = snaps(&[0; 3], &[false; 3]);
        for sid in 0..6u64 {
            let home = (sid % 3) as usize;
            for _ in 0..4 {
                assert_eq!(p.route(&video_req(sid), &s), Some(home), "session {sid} moved");
            }
        }
        assert!(!p.needs_load(), "pinning never reads load snapshots");
    }

    #[test]
    fn session_affinity_repins_once_on_drain_and_counts_the_invalidation() {
        let p = SessionAffinity::new();
        let m = Arc::new(ServeMetrics::default());
        p.attach_metrics(&m);
        let healthy = snaps(&[0; 3], &[false; 3]);
        assert_eq!(p.route(&video_req(1), &healthy), Some(1));

        // Shard 1 drains mid-session: the session re-pins to the next
        // healthy shard after its stale pin (2), exactly once.
        let draining = snaps(&[0; 3], &[false, true, false]);
        for _ in 0..5 {
            assert_eq!(p.route(&video_req(1), &draining), Some(2));
        }
        assert_eq!(m.route_fallbacks.get(), 1, "one drain, one re-pin");
        assert_eq!(m.cache_invalidations.get(), 1, "one re-pin, one cold cache");

        // The shard comes back: the pin sticks (no flap, no second
        // invalidation) — the cache now lives on shard 2.
        assert_eq!(p.route(&video_req(1), &healthy), Some(2));
        assert_eq!(m.cache_invalidations.get(), 1);
    }

    #[test]
    fn session_affinity_round_robins_sessionless_requests() {
        let p = SessionAffinity::new();
        let s = snaps(&[0; 3], &[false; 3]);
        let picks: Vec<_> = (0..6).map(|_| p.route(&req(96), &s).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        let all_drained = snaps(&[0; 2], &[true; 2]);
        assert_eq!(p.route(&video_req(0), &all_drained), None);
        assert_eq!(p.route(&req(96), &all_drained), None);
    }

    #[test]
    fn affinity_single_shard_serves_everything() {
        let p = ScaleAffinity::default();
        let s = snaps(&[0], &[false]);
        assert_eq!(p.route(&req(96), &s), Some(0));
        assert_eq!(p.route(&req(512), &s), Some(0));
    }
}
