//! Per-shard health supervision: the circuit breaker of the self-healing
//! runtime.
//!
//! ```text
//!            failures ≥ degrade_failures        failures ≥ quarantine_failures
//!   Healthy ───────────────────────► Degraded ─────────────────────► Quarantined
//!      ▲                                │  (same window)                  │
//!      │ window clears                  └────────────────────────────────┤
//!      │                                                                 │ cooldown
//!      │        probe_successes consecutive Ok              half-open    ▼
//!      └──────────────────────────────────────────────── Recovering ◄────┘
//!                                      (one probe failure re-quarantines)
//! ```
//!
//! The supervisor judges each shard over a sliding window of request
//! outcomes (worker-lost / transient / deadline-miss = failure). A
//! quarantined shard is masked out of routing — the same mechanism the
//! drain flag uses, so [`crate::serving::RoutePolicy`] implementations
//! need no changes — until its cooldown elapses; it then half-opens into
//! `Recovering`, where routed requests act as probes: enough consecutive
//! successes restore it, one failure re-trips the breaker.
//!
//! State transitions are lazy (checked on the routing and outcome paths) —
//! no background thread to shut down or leak.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::ResilienceConfig;
use crate::telemetry::ServeMetrics;

/// One shard's position in the health state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardHealth {
    /// In rotation, failure rate under the degrade threshold.
    Healthy,
    /// In rotation, elevated failures — the early-warning state.
    Degraded,
    /// Breaker tripped: masked out of routing until the cooldown elapses.
    Quarantined,
    /// Half-open: back in rotation, but being judged probe-by-probe.
    Recovering,
}

impl ShardHealth {
    /// Encoding for the per-shard telemetry gauge
    /// (`telemetry::health_letter` renders it).
    pub fn as_gauge(self) -> u64 {
        match self {
            ShardHealth::Healthy => 0,
            ShardHealth::Degraded => 1,
            ShardHealth::Quarantined => 2,
            ShardHealth::Recovering => 3,
        }
    }
}

struct ShardState {
    health: ShardHealth,
    /// Sliding window of recent outcomes (`true` = failure).
    window: VecDeque<bool>,
    /// Failures currently inside the window (kept incrementally).
    failures: usize,
    quarantined_at: Option<Instant>,
    /// Consecutive probe successes while `Recovering`.
    probe_ok: usize,
}

impl ShardState {
    fn new() -> Self {
        Self {
            health: ShardHealth::Healthy,
            window: VecDeque::new(),
            failures: 0,
            quarantined_at: None,
            probe_ok: 0,
        }
    }
}

/// The fleet's health bookkeeping: one state machine per shard, shared
/// metrics for trip/restore counts and the per-shard health gauge.
pub struct ShardSupervisor {
    window: usize,
    degrade_failures: usize,
    quarantine_failures: usize,
    cooldown: Duration,
    probe_successes: usize,
    states: Vec<Mutex<ShardState>>,
    metrics: Arc<ServeMetrics>,
}

impl ShardSupervisor {
    pub fn new(n_shards: usize, cfg: &ResilienceConfig, metrics: Arc<ServeMetrics>) -> Self {
        Self {
            window: cfg.supervisor_window.max(1),
            degrade_failures: cfg.degrade_failures,
            quarantine_failures: cfg.quarantine_failures.max(1),
            cooldown: Duration::from_millis(cfg.quarantine_cooldown_ms),
            probe_successes: cfg.probe_successes.max(1),
            states: (0..n_shards).map(|_| Mutex::new(ShardState::new())).collect(),
            metrics,
        }
    }

    fn set_health(&self, idx: usize, st: &mut ShardState, health: ShardHealth) {
        st.health = health;
        if let Some(lane) = self.metrics.shard(idx) {
            lane.health.set(health.as_gauge());
        }
    }

    /// Routing-time mask: may requests land on shard `idx` right now?
    /// Also performs the lazy `Quarantined → Recovering` transition once
    /// the cooldown has elapsed (half-open: probe traffic allowed).
    pub fn admits(&self, idx: usize) -> bool {
        let mut st = self.states[idx].lock().unwrap();
        match st.health {
            ShardHealth::Healthy | ShardHealth::Degraded | ShardHealth::Recovering => true,
            ShardHealth::Quarantined => {
                let expired = st
                    .quarantined_at
                    .is_some_and(|t| t.elapsed() >= self.cooldown);
                if expired {
                    st.probe_ok = 0;
                    self.set_health(idx, &mut st, ShardHealth::Recovering);
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Record one request outcome served by shard `idx` (`failure` =
    /// worker-lost / transient / deadline-miss; cancellations are neutral
    /// and should not be recorded at all).
    pub fn record(&self, idx: usize, failure: bool) {
        let mut st = self.states[idx].lock().unwrap();
        match st.health {
            ShardHealth::Recovering => {
                if failure {
                    // one bad probe re-trips the breaker for a fresh cooldown
                    st.quarantined_at = Some(Instant::now());
                    st.probe_ok = 0;
                    self.metrics.shards_quarantined.inc();
                    self.set_health(idx, &mut st, ShardHealth::Quarantined);
                } else {
                    st.probe_ok += 1;
                    if st.probe_ok >= self.probe_successes {
                        st.window.clear();
                        st.failures = 0;
                        st.quarantined_at = None;
                        self.metrics.shards_restored.inc();
                        self.set_health(idx, &mut st, ShardHealth::Healthy);
                    }
                }
            }
            ShardHealth::Quarantined => {
                // an in-flight request from before the trip resolving late:
                // the breaker has already acted, nothing to learn here
            }
            ShardHealth::Healthy | ShardHealth::Degraded => {
                st.window.push_back(failure);
                st.failures += failure as usize;
                if st.window.len() > self.window {
                    st.failures -= st.window.pop_front().unwrap() as usize;
                }
                if st.failures >= self.quarantine_failures {
                    st.quarantined_at = Some(Instant::now());
                    st.window.clear();
                    st.failures = 0;
                    self.metrics.shards_quarantined.inc();
                    self.set_health(idx, &mut st, ShardHealth::Quarantined);
                } else if st.failures >= self.degrade_failures {
                    self.set_health(idx, &mut st, ShardHealth::Degraded);
                } else if st.health == ShardHealth::Degraded {
                    self.set_health(idx, &mut st, ShardHealth::Healthy);
                }
            }
        }
    }

    /// Record one outcome with `weight` (≥ 1): a heavily-weighted failure
    /// fills the sliding window `weight` ordinary failures' worth, so a
    /// shard emitting *corrupted* output trips the breaker much faster
    /// than one merely crashing — SDC is evidence of broken hardware, not
    /// bad luck. Implemented as repeated [`Self::record`] calls, which
    /// keeps every transition edge-exact: once the first iteration trips
    /// the breaker the rest land in the `Quarantined` arm and are ignored
    /// (no double trips), and a `Recovering` failure re-trips on the first
    /// iteration exactly as an unweighted one would.
    pub fn record_weighted(&self, idx: usize, failure: bool, weight: usize) {
        for _ in 0..weight.max(1) {
            self.record(idx, failure);
        }
    }

    /// Current health of shard `idx`.
    pub fn health(&self, idx: usize) -> ShardHealth {
        self.states[idx].lock().unwrap().health
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn supervisor(n: usize) -> (ShardSupervisor, Arc<ServeMetrics>) {
        let metrics = Arc::new(ServeMetrics::default());
        metrics.install_shards(n);
        let cfg = ResilienceConfig {
            supervisor_window: 8,
            degrade_failures: 2,
            quarantine_failures: 4,
            quarantine_cooldown_ms: 20,
            probe_successes: 2,
            ..Default::default()
        };
        (ShardSupervisor::new(n, &cfg, metrics.clone()), metrics)
    }

    #[test]
    fn failures_walk_healthy_degraded_quarantined() {
        let (sup, metrics) = supervisor(1);
        assert_eq!(sup.health(0), ShardHealth::Healthy);
        sup.record(0, true);
        assert_eq!(sup.health(0), ShardHealth::Healthy, "one failure is noise");
        sup.record(0, true);
        assert_eq!(sup.health(0), ShardHealth::Degraded);
        assert_eq!(metrics.shard(0).unwrap().health.get(), 1);
        sup.record(0, true);
        sup.record(0, true);
        assert_eq!(sup.health(0), ShardHealth::Quarantined);
        assert!(!sup.admits(0), "quarantined shard must be masked");
        assert_eq!(metrics.shards_quarantined.get(), 1);
        assert_eq!(metrics.shard(0).unwrap().health.get(), 2);
    }

    #[test]
    fn successes_clear_a_degraded_shard() {
        let (sup, _) = supervisor(1);
        sup.record(0, true);
        sup.record(0, true);
        assert_eq!(sup.health(0), ShardHealth::Degraded);
        // successes push the failures out of the window
        for _ in 0..8 {
            sup.record(0, false);
        }
        assert_eq!(sup.health(0), ShardHealth::Healthy);
    }

    #[test]
    fn cooldown_half_opens_then_probes_restore() {
        let (sup, metrics) = supervisor(1);
        for _ in 0..4 {
            sup.record(0, true);
        }
        assert_eq!(sup.health(0), ShardHealth::Quarantined);
        assert!(!sup.admits(0));
        std::thread::sleep(Duration::from_millis(25));
        assert!(sup.admits(0), "cooldown elapsed: half-open");
        assert_eq!(sup.health(0), ShardHealth::Recovering);
        sup.record(0, false);
        assert_eq!(sup.health(0), ShardHealth::Recovering, "needs 2 probes");
        sup.record(0, false);
        assert_eq!(sup.health(0), ShardHealth::Healthy);
        assert_eq!(metrics.shards_restored.get(), 1);
        assert_eq!(metrics.shard(0).unwrap().health.get(), 0);
    }

    #[test]
    fn one_bad_probe_re_trips_the_breaker() {
        let (sup, metrics) = supervisor(1);
        for _ in 0..4 {
            sup.record(0, true);
        }
        std::thread::sleep(Duration::from_millis(25));
        assert!(sup.admits(0));
        sup.record(0, false);
        sup.record(0, true); // probe failure
        assert_eq!(sup.health(0), ShardHealth::Quarantined);
        assert_eq!(metrics.shards_quarantined.get(), 2, "the re-trip counts");
        assert!(!sup.admits(0), "fresh cooldown started");
    }

    #[test]
    fn shards_are_judged_independently() {
        let (sup, _) = supervisor(2);
        for _ in 0..4 {
            sup.record(1, true);
        }
        assert_eq!(sup.health(0), ShardHealth::Healthy);
        assert_eq!(sup.health(1), ShardHealth::Quarantined);
        assert!(sup.admits(0));
        assert!(!sup.admits(1));
    }

    #[test]
    fn weighted_failures_trip_the_breaker_faster_and_exactly_once() {
        let (sup, metrics) = supervisor(1);
        // one corruption outcome at weight 4 = the whole quarantine budget
        sup.record_weighted(0, true, 4);
        assert_eq!(sup.health(0), ShardHealth::Quarantined);
        assert_eq!(metrics.shards_quarantined.get(), 1, "a single weighted record trips once");
        // weighted successes are just repeated successes
        std::thread::sleep(Duration::from_millis(25));
        assert!(sup.admits(0));
        sup.record_weighted(0, false, 2);
        assert_eq!(sup.health(0), ShardHealth::Healthy);
        assert_eq!(metrics.shards_restored.get(), 1);
    }

    /// Satellite 3: the quarantine → half-open boundary under racing
    /// `admits` and `record` callers. The invariants: the trip and the
    /// restore are each counted exactly once per cycle, and no interleaving
    /// regresses a shard backwards (e.g. a late `record` resurrecting a
    /// quarantined shard without probes).
    #[test]
    fn concurrent_admits_and_records_cross_the_boundary_exactly_once() {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Barrier;
        let (sup, metrics) = supervisor(1);
        let sup = Arc::new(sup);
        for _ in 0..4 {
            sup.record(0, true);
        }
        assert_eq!(sup.health(0), ShardHealth::Quarantined);
        assert_eq!(metrics.shards_quarantined.get(), 1);
        std::thread::sleep(Duration::from_millis(25)); // cooldown elapsed
        // Many threads race the lazy half-open transition in `admits` while
        // others hammer successful probe outcomes through `record`.
        let barrier = Arc::new(Barrier::new(8));
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for t in 0..8 {
            let sup = sup.clone();
            let barrier = barrier.clone();
            let stop = stop.clone();
            handles.push(std::thread::spawn(move || {
                barrier.wait();
                for _ in 0..200 {
                    if stop.load(Ordering::Relaxed) {
                        break;
                    }
                    if t % 2 == 0 {
                        // routing path: admits() may half-open the shard
                        let _ = sup.admits(0);
                    } else {
                        // probe path: only record when the shard is taking
                        // traffic, as the serving loop would
                        if sup.admits(0) {
                            sup.record(0, false);
                        }
                    }
                    if sup.health(0) == ShardHealth::Healthy {
                        stop.store(true, Ordering::Relaxed);
                    }
                    std::thread::yield_now();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            sup.health(0),
            ShardHealth::Healthy,
            "enough successful probes must restore the shard"
        );
        assert_eq!(metrics.shards_quarantined.get(), 1, "no phantom re-trips from racing probes");
        assert_eq!(metrics.shards_restored.get(), 1, "the restore must count exactly once");
        // post-restore traffic keeps it healthy — no stale Recovering state
        sup.record(0, false);
        assert_eq!(sup.health(0), ShardHealth::Healthy);
    }

    /// Satellite 3, failure flavor: racing probe *failures* at the boundary
    /// re-trip the breaker exactly once per half-open cycle, never restore.
    /// A generous 300 ms cooldown makes "the racing threads finish inside
    /// one cooldown" robust even on an oversubscribed CI box.
    #[test]
    fn racing_failed_probes_re_trip_exactly_once_per_cycle() {
        let metrics = Arc::new(ServeMetrics::default());
        metrics.install_shards(1);
        let cfg = ResilienceConfig {
            supervisor_window: 8,
            degrade_failures: 2,
            quarantine_failures: 4,
            quarantine_cooldown_ms: 300,
            probe_successes: 2,
            ..Default::default()
        };
        let sup = Arc::new(ShardSupervisor::new(1, &cfg, metrics.clone()));
        for _ in 0..4 {
            sup.record(0, true);
        }
        std::thread::sleep(Duration::from_millis(310));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let sup = sup.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    if sup.admits(0) {
                        sup.record(0, true);
                    }
                    std::thread::yield_now();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(sup.health(0), ShardHealth::Quarantined);
        assert_eq!(metrics.shards_restored.get(), 0, "failed probes must never restore");
        // Each re-trip requires a fresh half-open, which requires a fresh
        // 300 ms cooldown to elapse — the yield loops above finish well
        // inside one cooldown, so exactly one re-trip is possible.
        assert_eq!(
            metrics.shards_quarantined.get(),
            2,
            "one original trip + exactly one re-trip at the boundary"
        );
    }

    #[test]
    fn late_outcomes_during_quarantine_are_ignored() {
        let (sup, metrics) = supervisor(1);
        for _ in 0..4 {
            sup.record(0, true);
        }
        // stragglers from before the trip must not double-count or extend
        sup.record(0, true);
        sup.record(0, false);
        assert_eq!(sup.health(0), ShardHealth::Quarantined);
        assert_eq!(metrics.shards_quarantined.get(), 1);
    }
}
