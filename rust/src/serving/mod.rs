//! The sharded serving runtime: request router + replicated backend shards.
//!
//! ```text
//!                         ServerRuntime
//!   submit(image) ──► RoutePolicy (rr | least | affinity)
//!        │                │ pick one non-draining shard
//!        │     ┌──────────┼──────────────┐
//!        ▼     ▼          ▼              ▼
//!      Shard 0          Shard 1   ...  Shard N-1      (replicated pipelines,
//!      Coordinator      Coordinator    Coordinator     the paper's scale-out)
//!      · own bounded    · own bounded  · own bounded
//!        TaskQueue        TaskQueue      TaskQueue
//!      · ProposalBackend replica (software / engine / sim)
//!        └───────────── shared worker pool ────────────┘
//!                │ shared ServeMetrics (per-shard lanes) + shared id space
//!                ▼
//!      Result<ServeResponse<_>, ResponseError> — deadline-aware,
//!      cancellable; proposals (`submit*`) or detections (`detect*`,
//!      the full cascade: stage-II SVM → greedy NMS → Platt confidence)
//! ```
//!
//! The paper's headline claim is *scalability*: throughput grows by
//! replicating whole pipelines behind a work distributor. This module is
//! that claim at the serving layer — each [`Shard`] wraps one
//! [`ProposalBackend`] replica behind its own bounded admission queue
//! ([`crate::coordinator::Coordinator`] is the per-shard executor), and a
//! pluggable [`RoutePolicy`] decides which replica each request lands on.
//! Proposals stay bit-identical to `baseline::rank_and_select` for every
//! (policy, shard count, backend) combination, because every shard runs the
//! same executor over the same parity-contract backends
//! (`tests/serving_soak.rs`).
//!
//! Shards drain gracefully: [`ServerRuntime::drain_shard`] steers the
//! router away, waits for the shard's in-flight scale tasks, and leaves the
//! shard reusable ([`ServerRuntime::resume_shard`]) — rolling restarts
//! without dropping a single response.

mod policy;

pub use policy::{LeastLoaded, RoundRobin, RoutePolicy, RouteRequest, ScaleAffinity, ShardSnapshot};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::backend::ProposalBackend;
use crate::config::{RoutePolicyKind, ServingConfig};
use crate::coordinator::{
    serve_batch_with, Coordinator, DetectHandle, DetectRequest, DetectResponse, ProposalRequest,
    ProposalResponse, RequestHandle, ResponseError, ShardContext, SubmitError,
};
use crate::image::ImageRgb;
use crate::svm::Stage2Calibration;
use crate::telemetry::ServeMetrics;
use crate::util::pool;

/// Instantiate the policy a [`RoutePolicyKind`] names (all built-ins with
/// their default parameters; use [`ServerRuntime::with_policy`] to plug a
/// custom or tuned implementation).
pub fn make_policy(kind: RoutePolicyKind) -> Box<dyn RoutePolicy> {
    match kind {
        RoutePolicyKind::RoundRobin => Box::new(RoundRobin::new()),
        RoutePolicyKind::LeastLoaded => Box::new(LeastLoaded),
        RoutePolicyKind::ScaleAffinity => Box::new(ScaleAffinity::default()),
    }
}

/// One backend replica behind its own admission queue.
pub struct Shard<B: ?Sized> {
    id: usize,
    coordinator: Coordinator<B>,
    draining: AtomicBool,
    /// Admission gate closing the route→admit window against a concurrent
    /// drain: submits hold the read side across the draining re-check and
    /// the shard admission; `drain_shard` flips `draining` under the write
    /// side, so once the flip lands no straddling submit can still be on
    /// its way in — `wait_idle` then really is the end of the shard's work.
    gate: RwLock<()>,
}

impl<B: ProposalBackend + ?Sized + 'static> Shard<B> {
    /// This shard's index in the runtime.
    pub fn id(&self) -> usize {
        self.id
    }

    /// The shard's executor (for direct inspection: backend, metrics,
    /// backpressure counters).
    pub fn coordinator(&self) -> &Coordinator<B> {
        &self.coordinator
    }

    /// Whether the router is currently steering around this shard.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Routing snapshot. `with_load = false` skips the inflight-count
    /// lock (the load signal) for policies that never read it.
    fn snapshot(&self, with_load: bool) -> ShardSnapshot {
        ShardSnapshot {
            load: if with_load { self.coordinator.inflight_tasks() } else { 0 },
            draining: self.is_draining(),
        }
    }
}

/// The multi-shard serving runtime: N replicated shard executors behind a
/// routing policy, sharing one metrics sink and one response-id space.
pub struct ServerRuntime<B: ?Sized = dyn ProposalBackend> {
    shards: Vec<Shard<B>>,
    policy: Box<dyn RoutePolicy>,
    pub metrics: Arc<ServeMetrics>,
    config: ServingConfig,
}

impl<B: ProposalBackend + ?Sized + 'static> ServerRuntime<B> {
    /// Build `config.shards` replicas over one shared backend instance
    /// (backends are `Send + Sync` and stateless per-call, so replicas can
    /// share the weights/executables rather than duplicating them).
    pub fn new(backend: Arc<B>, stage2: Stage2Calibration, config: ServingConfig) -> Self {
        let n = config.shards.max(1);
        let backends = (0..n).map(|_| backend.clone()).collect();
        Self::from_backends(backends, stage2, config)
    }

    /// Build one shard per backend in `backends` (the heterogeneous-fleet
    /// seam: software shards next to engine shards, different pool sizes,
    /// canary replicas). `config.shards` is ignored in favour of
    /// `backends.len()`.
    pub fn from_backends(
        backends: Vec<Arc<B>>,
        stage2: Stage2Calibration,
        config: ServingConfig,
    ) -> Self {
        Self::with_policy(backends, stage2, config.clone(), make_policy(config.policy))
    }

    /// [`Self::from_backends`] with an explicit policy instance.
    pub fn with_policy(
        backends: Vec<Arc<B>>,
        stage2: Stage2Calibration,
        config: ServingConfig,
        policy: Box<dyn RoutePolicy>,
    ) -> Self {
        assert!(!backends.is_empty(), "a runtime needs at least one shard");
        let metrics = Arc::new(ServeMetrics::default());
        metrics.install_shards(backends.len());
        let ids = Arc::new(AtomicU64::new(1));
        // the pool is the process-wide substrate: size it for the whole
        // fleet (clamped internally), not a single shard's slice
        pool::global().ensure_threads(config.workers.max(1) * backends.len());
        let shards = backends
            .into_iter()
            .enumerate()
            .map(|(id, backend)| Shard {
                id,
                coordinator: Coordinator::with_backend_shared(
                    backend,
                    stage2.clone(),
                    config.clone(),
                    ShardContext {
                        metrics: metrics.clone(),
                        ids: ids.clone(),
                        lane: Some(id),
                    },
                ),
                draining: AtomicBool::new(false),
                gate: RwLock::new(()),
            })
            .collect();
        Self { shards, policy, metrics, config }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Access one shard (panics on a bad index, like slice indexing).
    pub fn shard(&self, idx: usize) -> &Shard<B> {
        &self.shards[idx]
    }

    /// The active routing policy's name ("rr", "least", "affinity", …).
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Route and submit one image under the configured default deadline.
    pub fn submit(&self, image: ImageRgb) -> Result<RequestHandle, SubmitError> {
        self.submit_deadline(image, None)
    }

    /// Route and submit with an explicit deadline override (`None` falls
    /// back to `ServingConfig::deadline_ms`, applied by the shard — the
    /// same contract as `Coordinator::submit_deadline`).
    pub fn submit_deadline(
        &self,
        image: ImageRgb,
        deadline: Option<Instant>,
    ) -> Result<RequestHandle, SubmitError> {
        let mut req = ProposalRequest::new(image);
        if let Some(d) = deadline {
            req = req.deadline(d);
        }
        self.submit_request(req)
    }

    /// Route and submit a typed proposal request (per-request top-k and
    /// deadline ride along to the shard executor).
    pub fn submit_request(&self, req: ProposalRequest) -> Result<RequestHandle, SubmitError> {
        let (w, h) = (req.image.w, req.image.h);
        self.route_submit(w, h, move |coord| coord.submit_request(req))
    }

    /// Route and submit one image through the full detection cascade with
    /// the configured cascade defaults.
    pub fn detect(&self, image: ImageRgb) -> Result<DetectHandle, SubmitError> {
        self.submit_detect(DetectRequest::new(image))
    }

    /// Route and submit a typed detection request: one request in, one
    /// [`DetectResponse`] out — proposals, stage-II calibration, NMS and
    /// Platt confidence all happen shard-side.
    pub fn submit_detect(&self, req: DetectRequest) -> Result<DetectHandle, SubmitError> {
        let (w, h) = (req.image.w, req.image.h);
        self.route_submit(w, h, move |coord| coord.submit_detect(req))
    }

    /// The routing loop shared by every submit flavour: pick a shard, hold
    /// its admission gate across the draining re-check, hand the request to
    /// its coordinator. Generic over the handle kind.
    fn route_submit<H>(
        &self,
        image_w: usize,
        image_h: usize,
        submit: impl FnOnce(&Coordinator<B>) -> Result<H, SubmitError>,
    ) -> Result<H, SubmitError> {
        let req = RouteRequest { image_w, image_h };
        let with_load = self.policy.needs_load();
        // Re-route loop: an attempt fails only when the picked shard raced
        // with a drain flip; the shard is then excluded from this request's
        // next routing pass (so a deterministic policy like LeastLoaded
        // moves on instead of re-picking it), which bounds the loop at one
        // attempt per shard.
        let mut submit = Some(submit);
        let mut excluded = vec![false; self.shards.len()];
        for _ in 0..self.shards.len() {
            let snapshots: Vec<ShardSnapshot> = self
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut snap = s.snapshot(with_load);
                    snap.draining |= excluded[i];
                    snap
                })
                .collect();
            let idx = match self.policy.route(&req, &snapshots) {
                Some(i) if i < self.shards.len() && !snapshots[i].draining => i,
                // a policy that ignored the draining flag picked a draining
                // shard: exclude it and re-route instead of refusing while
                // healthy shards sit idle
                Some(i) if i < self.shards.len() => {
                    excluded[i] = true;
                    continue;
                }
                // out-of-range pick (misbehaving custom policy) or no shard
                // left: a refusal, not a panic on the serving path
                _ => break,
            };
            let shard = &self.shards[idx];
            // try_read, not read: a blocked acquisition means a drain flip
            // is pending on this shard (its writer queued behind an
            // in-flight admission) — steer away instead of parking a
            // possibly-deadlined submit behind the writer
            let Ok(admit) = shard.gate.try_read() else {
                excluded[idx] = true;
                continue;
            };
            if shard.is_draining() || shard.coordinator.is_closed() {
                // lost the race with a drain flip, or the shard's executor
                // was closed directly — re-route elsewhere. (Direct close()
                // is best-effort: unlike drain_shard it takes no gate, so a
                // submit that loses the exact race still surfaces a
                // retryable ShuttingDown below. Prefer drain_shard for
                // client-invisible maintenance.)
                drop(admit);
                excluded[idx] = true;
                continue;
            }
            let submit = submit.take().expect("one admission per request");
            let result = submit(&shard.coordinator);
            drop(admit);
            // count the image as routed only once the shard actually
            // admitted it — refusals must not inflate the lane totals
            if result.is_ok() {
                if let Some(lane) = self.metrics.shard(idx) {
                    lane.images.inc();
                }
            }
            return result;
        }
        self.metrics.rejected.inc();
        Err(SubmitError::Unroutable)
    }

    /// Submit a batch and wait for every result, `max_batch` images in
    /// flight together, results in submission order (refusals surface as
    /// `Err(Rejected(_))` in their slot).
    pub fn serve_batch(
        &self,
        images: Vec<ImageRgb>,
    ) -> Vec<Result<ProposalResponse, ResponseError>> {
        serve_batch_with(images, self.config.max_batch, |img| self.submit(img), |h| h.wait())
    }

    /// [`Self::serve_batch`] through the full cascade: every image becomes
    /// a default [`DetectRequest`] and resolves to detections.
    pub fn detect_batch(
        &self,
        images: Vec<ImageRgb>,
    ) -> Vec<Result<DetectResponse, ResponseError>> {
        serve_batch_with(images, self.config.max_batch, |img| self.detect(img), |h| h.wait())
    }

    /// Gracefully drain one shard: steer the router away, then block until
    /// the shard's in-flight scale tasks finish. The flag flips under the
    /// shard's admission write-gate, so a submit that snapshotted the shard
    /// as healthy either lands before the flip (and is awaited below) or
    /// re-checks, sees the flag and re-routes — when this returns, the
    /// shard holds no work and can receive none. The shard stays usable —
    /// [`Self::resume_shard`] puts it back in rotation (rolling restarts).
    pub fn drain_shard(&self, idx: usize) {
        let shard = &self.shards[idx];
        {
            let _gate = shard.gate.write().unwrap();
            shard.draining.store(true, Ordering::Release);
        }
        shard.coordinator.wait_idle();
    }

    /// Put a drained shard back in the routing rotation.
    pub fn resume_shard(&self, idx: usize) {
        self.shards[idx].draining.store(false, Ordering::Release);
    }

    /// Block until every shard is idle (no queued or executing scale
    /// tasks). New submissions may still arrive afterwards.
    pub fn wait_idle(&self) {
        for shard in &self.shards {
            shard.coordinator.wait_idle();
        }
    }

    /// Backpressure engagements over all shard admission gates (the shared
    /// metrics counter every shard queue feeds exactly, under its mutex).
    pub fn queue_full_events(&self) -> u64 {
        self.metrics.queue_full_events.get()
    }

    /// One-line fleet summary (the shared metrics sink, including the
    /// per-shard lane rollup).
    pub fn summary(&self) -> String {
        self.metrics.summary()
    }

    /// Graceful shutdown: each shard refuses new work and drains (runs on
    /// Drop too; consuming `self` just makes it explicit).
    pub fn shutdown(self) {
        drop(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::{default_stage1, Pyramid};
    use crate::data::SyntheticDataset;

    fn sizes() -> Vec<(usize, usize)> {
        vec![(16, 16), (32, 32)]
    }

    fn software() -> Arc<SoftwareBing> {
        Arc::new(SoftwareBing::new(
            Pyramid::new(sizes()),
            default_stage1(),
            Stage2Calibration::identity(sizes()),
            ScoringMode::Exact,
        ))
    }

    fn runtime(shards: usize, policy: RoutePolicyKind) -> ServerRuntime<SoftwareBing> {
        ServerRuntime::new(
            software(),
            Stage2Calibration::identity(sizes()),
            ServingConfig { shards, policy, top_k: 60, workers: 2, ..Default::default() },
        )
    }

    #[test]
    fn make_policy_names_match_config_spellings() {
        // the bench labels rows with RoutePolicyKind::name() while logs use
        // the trait impl's name() — they must never drift apart
        for kind in [
            RoutePolicyKind::RoundRobin,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::ScaleAffinity,
        ] {
            assert_eq!(make_policy(kind).name(), kind.name());
        }
    }

    #[test]
    fn every_policy_and_shard_count_matches_the_baseline() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let want = software().propose(&img, 60);
        for policy in [
            RoutePolicyKind::RoundRobin,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::ScaleAffinity,
        ] {
            for shards in [1usize, 2, 3] {
                let rt = runtime(shards, policy);
                assert_eq!(rt.shards(), shards);
                let resp = rt.submit(img.clone()).unwrap().wait().unwrap();
                assert_eq!(
                    resp.items, want,
                    "policy {policy:?} x {shards} shards diverged from the baseline"
                );
                rt.shutdown();
            }
        }
    }

    #[test]
    fn round_robin_spreads_images_across_lanes() {
        let rt = runtime(3, RoutePolicyKind::RoundRobin);
        let ds = SyntheticDataset::voc_like_val(6);
        let results = rt.serve_batch(ds.iter().map(|s| s.image).collect());
        assert!(results.iter().all(|r| r.is_ok()));
        for i in 0..3 {
            assert_eq!(
                rt.metrics.shard(i).unwrap().images.get(),
                2,
                "rr must balance 6 images over 3 shards"
            );
        }
        // shared id space: ids unique and in submission order
        let ids: Vec<u64> = results.iter().map(|r| r.as_ref().unwrap().id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5, 6]);
        assert!(rt.summary().contains("shard2["), "{}", rt.summary());
        rt.shutdown();
    }

    #[test]
    fn draining_shard_receives_no_new_images_and_resumes() {
        let rt = runtime(2, RoutePolicyKind::RoundRobin);
        let ds = SyntheticDataset::voc_like_val(5);
        rt.drain_shard(1);
        assert!(rt.shard(1).is_draining());
        let results = rt.serve_batch(ds.iter().map(|s| s.image).collect());
        assert!(results.iter().all(|r| r.is_ok()), "drain must not drop work");
        assert_eq!(rt.metrics.shard(1).unwrap().images.get(), 0);
        assert_eq!(rt.metrics.shard(0).unwrap().images.get(), 5);

        rt.resume_shard(1);
        let more = rt.serve_batch(ds.iter().map(|s| s.image).collect());
        assert!(more.iter().all(|r| r.is_ok()));
        assert!(
            rt.metrics.shard(1).unwrap().images.get() > 0,
            "resumed shard never came back into rotation"
        );
        rt.shutdown();
    }

    #[test]
    fn all_shards_draining_is_unroutable() {
        let rt = runtime(2, RoutePolicyKind::LeastLoaded);
        rt.drain_shard(0);
        rt.drain_shard(1);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        assert_eq!(rt.submit(img).unwrap_err(), SubmitError::Unroutable);
        assert_eq!(rt.metrics.rejected.get(), 1);
        rt.shutdown();
    }

    #[test]
    fn served_detections_match_the_direct_cascade() {
        use crate::detect::{CascadeDetector, CascadeParams, DetectionBackend};
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let cfg = ServingConfig { shards: 2, top_k: 60, workers: 2, ..Default::default() };
        let oracle = CascadeDetector::new(
            software(),
            Stage2Calibration::identity(sizes()),
            CascadeParams::from_config(&cfg.cascade),
            cfg.top_k,
        );
        let want = oracle.detect(&img).unwrap();
        let rt = ServerRuntime::new(software(), Stage2Calibration::identity(sizes()), cfg);
        let resp = rt.detect(img).unwrap().wait().unwrap();
        assert_eq!(resp.items, want, "served cascade diverged from the direct path");
        rt.shutdown();
    }

    #[test]
    fn per_request_cascade_overrides_apply() {
        let rt = runtime(1, RoutePolicyKind::RoundRobin);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let full = rt.detect(img.clone()).unwrap().wait().unwrap();
        let capped = rt
            .submit_detect(DetectRequest::new(img).top_k(3))
            .unwrap()
            .wait()
            .unwrap();
        assert!(capped.items.len() <= 3);
        assert!(full.items.len() >= capped.items.len());
        // greedy keeps are decided in score order: the cap is a prefix
        assert_eq!(capped.items[..], full.items[..capped.items.len()]);
        rt.shutdown();
    }

    #[test]
    fn heterogeneous_backends_one_per_shard() {
        // from_backends: distinct replica instances, still one id space
        let rt: ServerRuntime<SoftwareBing> = ServerRuntime::from_backends(
            vec![software(), software()],
            Stage2Calibration::identity(sizes()),
            ServingConfig { top_k: 40, ..Default::default() },
        );
        assert_eq!(rt.shards(), 2);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let a = rt.submit(img.clone()).unwrap().wait().unwrap();
        let b = rt.submit(img).unwrap().wait().unwrap();
        assert_eq!(a.items, b.items);
        assert_ne!(a.id, b.id);
        rt.shutdown();
    }
}
