//! The sharded serving runtime: request router + replicated backend shards,
//! now self-healing.
//!
//! ```text
//!                         ServerRuntime
//!   serve(req) ──► brownout? (shed: top-k cap / stride / lite cascade)
//!        │
//!        ▼
//!   RoutePolicy (rr | least | affinity | session) ◄── health mask (ShardSupervisor:
//!        │ pick one admitted shard            quarantined shards routed
//!        │                                    around, like draining ones)
//!        ▼
//!      Shard i  ── outcome ──► supervisor.record(i, ok/fail)
//!        │                          Healthy→Degraded→Quarantined→Recovering
//!        ▼
//!   Err(WorkerLost | Transient)? ──► RetryPolicy: re-submit to an untried
//!                                    shard within the deadline budget
//!                                    (+ optional hedged duplicate)
//! ```
//!
//! The paper's headline claim is *scalability*: throughput grows by
//! replicating whole pipelines behind a work distributor. This module is
//! that claim at the serving layer — each [`Shard`] wraps one
//! [`ProposalBackend`] replica behind its own bounded admission queue
//! ([`crate::coordinator::Coordinator`] is the per-shard executor), and a
//! pluggable [`RoutePolicy`] decides which replica each request lands on.
//! Proposals stay bit-identical to `baseline::rank_and_select` for every
//! (policy, shard count, backend) combination, because every shard runs the
//! same executor over the same parity-contract backends
//! (`tests/serving_soak.rs`).
//!
//! On top of routing, three resilience layers (all configured by
//! `resilience.*` keys, all neutral by default):
//!
//! * **[`ShardSupervisor`]** — a per-shard circuit breaker judging request
//!   outcomes over a sliding window; quarantined shards are masked out of
//!   routing exactly like draining ones (policies need no changes), then
//!   half-open after a cooldown and are restored by successful probes. If
//!   every shard trips at once the mask fails open: a fully-quarantined
//!   fleet keeps serving rather than going dark.
//! * **[`RetryPolicy`]** — [`ServerRuntime::serve`]-family calls re-submit
//!   retryable failures (`WorkerLost`, `Transient`) to a shard the request
//!   has not tried yet, with linear backoff capped by the remaining
//!   deadline budget, plus an optional hedged duplicate when the primary
//!   attempt is slow. Successful paths stay bit-identical: a retry re-runs
//!   the same deterministic computation, it never changes it.
//! * **[`BrownoutController`]** — under queue-depth or deadline-miss
//!   pressure, requests are degraded (top-k cap, scale stride, proposals-
//!   only cascade) instead of rejected; every response carries a
//!   [`crate::coordinator::Downgrade`] record of what was shed.
//!
//! Shards drain gracefully: [`ServerRuntime::drain_shard`] steers the
//! router away, waits for the shard's in-flight scale tasks, and leaves the
//! shard reusable ([`ServerRuntime::resume_shard`]) — rolling restarts
//! without dropping a single response.

mod policy;
mod resilience;
mod supervisor;

pub use policy::{
    LeastLoaded, RoundRobin, RoutePolicy, RouteRequest, ScaleAffinity, SessionAffinity,
    ShardSnapshot,
};
pub use resilience::{BrownoutController, ResilienceToken, RetryPolicy};
pub use supervisor::{ShardHealth, ShardSupervisor};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use crate::backend::ProposalBackend;
use crate::baseline::SoftwareBing;
use crate::config::{RoutePolicyKind, ServingConfig};
use crate::coordinator::{
    Coordinator, DetectHandle, DetectRequest, DetectResponse, ProposalRequest, ProposalResponse,
    RequestHandle, ResponseError, ServeHandle, ServeResponse, ShardContext, SubmitError,
};
use crate::image::ImageRgb;
use crate::integrity::Auditor;
use crate::simd::ScoreKernel;
use crate::svm::Stage2Calibration;
use crate::telemetry::ServeMetrics;
use crate::util::pool;

/// Supervisor weight of one corruption outcome (a validated structural
/// violation or a golden-probe audit mismatch): corrupted output is
/// evidence of broken hardware, not bad luck, so it fills the breaker
/// window [`CORRUPT_WEIGHT`]× faster than a crash or transient failure.
pub const CORRUPT_WEIGHT: usize = 4;

/// Instantiate the policy a [`RoutePolicyKind`] names (all built-ins with
/// their default parameters; use [`ServerRuntime::with_policy`] to plug a
/// custom or tuned implementation).
pub fn make_policy(kind: RoutePolicyKind) -> Box<dyn RoutePolicy> {
    match kind {
        RoutePolicyKind::RoundRobin => Box::new(RoundRobin::new()),
        RoutePolicyKind::LeastLoaded => Box::new(LeastLoaded),
        RoutePolicyKind::ScaleAffinity => Box::new(ScaleAffinity::default()),
        RoutePolicyKind::SessionAffinity => Box::new(SessionAffinity::new()),
    }
}

/// One backend replica behind its own admission queue.
pub struct Shard<B: ?Sized> {
    id: usize,
    coordinator: Coordinator<B>,
    draining: AtomicBool,
    /// Admission gate closing the route→admit window against a concurrent
    /// drain: submits hold the read side across the draining re-check and
    /// the shard admission; `drain_shard` flips `draining` under the write
    /// side, so once the flip lands no straddling submit can still be on
    /// its way in — `wait_idle` then really is the end of the shard's work.
    gate: RwLock<()>,
}

impl<B: ProposalBackend + ?Sized + 'static> Shard<B> {
    /// This shard's index in the runtime.
    pub fn id(&self) -> usize {
        self.id
    }

    /// The shard's executor (for direct inspection: backend, metrics,
    /// backpressure counters).
    pub fn coordinator(&self) -> &Coordinator<B> {
        &self.coordinator
    }

    /// Whether the router is currently steering around this shard.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    /// Routing snapshot. `with_load = false` skips the inflight-count
    /// lock (the load signal) for policies that never read it.
    fn snapshot(&self, with_load: bool) -> ShardSnapshot {
        ShardSnapshot {
            load: if with_load { self.coordinator.inflight_tasks() } else { 0 },
            draining: self.is_draining(),
        }
    }
}

/// The multi-shard serving runtime: N replicated shard executors behind a
/// routing policy, sharing one metrics sink and one response-id space.
pub struct ServerRuntime<B: ?Sized = dyn ProposalBackend> {
    shards: Vec<Shard<B>>,
    policy: Box<dyn RoutePolicy>,
    supervisor: ShardSupervisor,
    retry: RetryPolicy,
    brownout: Option<BrownoutController>,
    /// Ring-2 SDC defense: the golden-probe auditor, installed by
    /// [`Self::install_auditor`] (needs a concrete fault-free oracle, which
    /// a generic runtime cannot build from an arbitrary backend).
    auditor: Option<Auditor>,
    /// Admission ordinal for the deterministic audit sampler.
    audit_ordinal: AtomicU64,
    pub metrics: Arc<ServeMetrics>,
    config: ServingConfig,
}

impl<B: ProposalBackend + ?Sized + 'static> ServerRuntime<B> {
    /// Build `config.shards` replicas over one shared backend instance
    /// (backends are `Send + Sync` and stateless per-call, so replicas can
    /// share the weights/executables rather than duplicating them).
    pub fn new(backend: Arc<B>, stage2: Stage2Calibration, config: ServingConfig) -> Self {
        let n = config.shards.max(1);
        let backends = (0..n).map(|_| backend.clone()).collect();
        Self::from_backends(backends, stage2, config)
    }

    /// Build one shard per backend in `backends` (the heterogeneous-fleet
    /// seam: software shards next to engine shards, different pool sizes,
    /// canary replicas). `config.shards` is ignored in favour of
    /// `backends.len()`.
    pub fn from_backends(
        backends: Vec<Arc<B>>,
        stage2: Stage2Calibration,
        config: ServingConfig,
    ) -> Self {
        Self::with_policy(backends, stage2, config.clone(), make_policy(config.policy))
    }

    /// [`Self::from_backends`] with an explicit policy instance.
    pub fn with_policy(
        backends: Vec<Arc<B>>,
        stage2: Stage2Calibration,
        config: ServingConfig,
        policy: Box<dyn RoutePolicy>,
    ) -> Self {
        assert!(!backends.is_empty(), "a runtime needs at least one shard");
        let metrics = Arc::new(ServeMetrics::default());
        metrics.install_shards(backends.len());
        // policies that report routing anomalies (affinity spills, session
        // re-pins) get the fleet sink exactly once, before any routing
        policy.attach_metrics(&metrics);
        let supervisor = ShardSupervisor::new(backends.len(), &config.resilience, metrics.clone());
        let retry = RetryPolicy::from_config(&config.resilience);
        let brownout =
            config.resilience.brownout.then(|| BrownoutController::new(&config.resilience));
        let ids = Arc::new(AtomicU64::new(1));
        // the pool is the process-wide substrate: size it for the whole
        // fleet (clamped internally), not a single shard's slice — and one
        // lane per shard, so each shard has a home queue that idle workers
        // steal from when their own shard goes quiet
        pool::global().ensure_threads(config.workers.max(1) * backends.len());
        pool::global().ensure_lanes(backends.len());
        let shards = backends
            .into_iter()
            .enumerate()
            .map(|(id, backend)| Shard {
                id,
                coordinator: Coordinator::with_backend_shared(
                    backend,
                    stage2.clone(),
                    config.clone(),
                    ShardContext {
                        metrics: metrics.clone(),
                        ids: ids.clone(),
                        lane: Some(id),
                    },
                ),
                draining: AtomicBool::new(false),
                gate: RwLock::new(()),
            })
            .collect();
        Self {
            shards,
            policy,
            supervisor,
            retry,
            brownout,
            auditor: None,
            audit_ordinal: AtomicU64::new(0),
            metrics,
            config,
        }
    }

    /// Install the golden-probe auditor (ring 2 of the SDC defense): a
    /// fault-free [`SoftwareBing`] oracle that re-executes a deterministic
    /// 1-in-`integrity.audit_rate` sample of served proposal requests
    /// through [`ScoreKernel::Reference`] and compares bitwise.
    /// `production_kernel` is the kernel the serving backends score with —
    /// a mismatch implicates it, and (under `integrity.demote_on_mismatch`)
    /// latches the fleet-wide SWAR demotion when it is multi-lane SIMD.
    /// A zero `integrity.audit_rate` leaves every request unaudited.
    pub fn install_auditor(&mut self, oracle: Arc<SoftwareBing>, production_kernel: ScoreKernel) {
        self.auditor = Some(Auditor::new(
            oracle,
            self.config.integrity.audit_rate,
            production_kernel,
            self.config.integrity.demote_on_mismatch,
            self.metrics.clone(),
        ));
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Access one shard (panics on a bad index, like slice indexing).
    pub fn shard(&self, idx: usize) -> &Shard<B> {
        &self.shards[idx]
    }

    /// The active routing policy's name ("rr", "least", "affinity", …).
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// The shard supervisor (health state machine + breaker bookkeeping).
    pub fn supervisor(&self) -> &ShardSupervisor {
        &self.supervisor
    }

    /// Current health of shard `idx` (panics on a bad index).
    pub fn shard_health(&self, idx: usize) -> ShardHealth {
        self.supervisor.health(idx)
    }

    /// The active retry/hedge policy.
    pub fn retry_policy(&self) -> &RetryPolicy {
        &self.retry
    }

    /// The brownout controller, when `resilience.brownout` enabled it.
    pub fn brownout(&self) -> Option<&BrownoutController> {
        self.brownout.as_ref()
    }

    /// Route and submit one image under the configured default deadline.
    pub fn submit(&self, image: ImageRgb) -> Result<RequestHandle, SubmitError> {
        self.submit_deadline(image, None)
    }

    /// Route and submit with an explicit deadline override (`None` falls
    /// back to `ServingConfig::deadline_ms`, applied by the shard — the
    /// same contract as `Coordinator::submit_deadline`).
    pub fn submit_deadline(
        &self,
        image: ImageRgb,
        deadline: Option<Instant>,
    ) -> Result<RequestHandle, SubmitError> {
        let mut req = ProposalRequest::new(image);
        if let Some(d) = deadline {
            req = req.deadline(d);
        }
        self.submit_request(req)
    }

    /// Route and submit a typed proposal request (per-request top-k and
    /// deadline ride along to the shard executor). Brownout degradation
    /// applies here; retries do not (the caller owns the raw handle — use
    /// [`Self::serve`] for the resilient path).
    pub fn submit_request(&self, mut req: ProposalRequest) -> Result<RequestHandle, SubmitError> {
        self.apply_brownout_proposal(&mut req);
        let route = RouteRequest {
            image_w: req.image.w,
            image_h: req.image.h,
            session: req.session,
        };
        self.route_submit(route, move |coord| coord.submit_request(req))
    }

    /// Route and submit one image through the full detection cascade with
    /// the configured cascade defaults.
    pub fn detect(&self, image: ImageRgb) -> Result<DetectHandle, SubmitError> {
        self.submit_detect(DetectRequest::new(image))
    }

    /// Route and submit a typed detection request: one request in, one
    /// [`DetectResponse`] out — proposals, stage-II calibration, NMS and
    /// Platt confidence all happen shard-side.
    pub fn submit_detect(&self, mut req: DetectRequest) -> Result<DetectHandle, SubmitError> {
        self.apply_brownout_detect(&mut req);
        let route =
            RouteRequest { image_w: req.image.w, image_h: req.image.h, session: None };
        self.route_submit(route, move |coord| coord.submit_detect(req))
    }

    /// The routing loop shared by every submit flavour (no exclusions, no
    /// resilience — the raw-handle path).
    fn route_submit<H>(
        &self,
        route: RouteRequest,
        submit: impl FnOnce(&Coordinator<B>) -> Result<H, SubmitError>,
    ) -> Result<H, SubmitError> {
        self.route_submit_excluding(route, &[], true, submit).map(|(_, h)| h)
    }

    /// Pick a shard, hold its admission gate across the draining re-check,
    /// hand the request to its coordinator; returns which shard served it.
    /// `pre_excluded[i]` masks shard `i` for this call (the retry path's
    /// "prefer an untried shard"); the supervisor's health mask is folded
    /// in the same way, invisibly to the policy. `count_reject = false`
    /// keeps an exploratory probe (one with a fallback, or a hedge that
    /// leaves the primary in flight) out of the rejection counters.
    fn route_submit_excluding<H>(
        &self,
        req: RouteRequest,
        pre_excluded: &[bool],
        count_reject: bool,
        submit: impl FnOnce(&Coordinator<B>) -> Result<H, SubmitError>,
    ) -> Result<(usize, H), SubmitError> {
        let with_load = self.policy.needs_load();
        let mut excluded: Vec<bool> = (0..self.shards.len())
            .map(|i| pre_excluded.get(i).copied().unwrap_or(false))
            .collect();
        // circuit breaker: quarantined shards are masked exactly like
        // draining ones. Fail open when the mask (with the drains and
        // exclusions) would leave no shard at all — a fully-tripped fleet
        // keeps serving (availability over purity); drains and explicit
        // exclusions still hold.
        let masked: Vec<bool> =
            (0..self.shards.len()).map(|i| !self.supervisor.admits(i)).collect();
        let fail_open = self
            .shards
            .iter()
            .enumerate()
            .all(|(i, s)| masked[i] || excluded[i] || s.is_draining());
        // Re-route loop: an attempt fails only when the picked shard raced
        // with a drain flip; the shard is then excluded from this request's
        // next routing pass (so a deterministic policy like LeastLoaded
        // moves on instead of re-picking it), which bounds the loop at one
        // attempt per shard.
        let mut submit = Some(submit);
        for _ in 0..self.shards.len() {
            let snapshots: Vec<ShardSnapshot> = self
                .shards
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut snap = s.snapshot(with_load);
                    snap.draining |= excluded[i] || (!fail_open && masked[i]);
                    snap
                })
                .collect();
            let idx = match self.policy.route(&req, &snapshots) {
                Some(i) if i < self.shards.len() && !snapshots[i].draining => i,
                // a policy that ignored the draining flag picked a draining
                // shard: exclude it and re-route instead of refusing while
                // healthy shards sit idle
                Some(i) if i < self.shards.len() => {
                    excluded[i] = true;
                    continue;
                }
                // out-of-range pick (misbehaving custom policy) or no shard
                // left: a refusal, not a panic on the serving path
                _ => break,
            };
            let shard = &self.shards[idx];
            // try_read, not read: a blocked acquisition means a drain flip
            // is pending on this shard (its writer queued behind an
            // in-flight admission) — steer away instead of parking a
            // possibly-deadlined submit behind the writer
            let Ok(admit) = shard.gate.try_read() else {
                excluded[idx] = true;
                continue;
            };
            if shard.is_draining() || shard.coordinator.is_closed() {
                // lost the race with a drain flip, or the shard's executor
                // was closed directly — re-route elsewhere. (Direct close()
                // is best-effort: unlike drain_shard it takes no gate, so a
                // submit that loses the exact race still surfaces a
                // retryable ShuttingDown below. Prefer drain_shard for
                // client-invisible maintenance.)
                drop(admit);
                excluded[idx] = true;
                continue;
            }
            let submit = submit.take().expect("one admission per request");
            let result = submit(&shard.coordinator);
            drop(admit);
            // count the image as routed only once the shard actually
            // admitted it — refusals must not inflate the lane totals
            if result.is_ok() {
                if let Some(lane) = self.metrics.shard(idx) {
                    lane.images.inc();
                }
            }
            return result.map(|h| (idx, h));
        }
        if count_reject {
            self.metrics.rejected.inc();
            self.metrics.rejected_unroutable.inc();
        }
        Err(SubmitError::Unroutable)
    }

    // ── the resilient request path ──────────────────────────────────────

    /// Serve one proposal request end to end: brownout degradation,
    /// routing, and — on `WorkerLost`/`Transient` — retries on untried
    /// shards plus optional hedging, all inside the request's deadline
    /// budget. Refused submissions surface as
    /// `Err(ResponseError::Rejected(_))`.
    pub fn serve(&self, req: ProposalRequest) -> Result<ProposalResponse, ResponseError> {
        self.serve_proposal_inner(req, None)
    }

    /// [`Self::serve`] with a cancellation token that stays valid across
    /// retry attempts: a racing `token.cancel()` stops the in-flight
    /// attempt *and* prevents the next one from launching.
    pub fn serve_cancellable(
        &self,
        req: ProposalRequest,
        token: &ResilienceToken,
    ) -> Result<ProposalResponse, ResponseError> {
        self.serve_proposal_inner(req, Some(token))
    }

    /// The shared proposal path: golden-probe sampling happens *before*
    /// submission (so the oracle's image copy is only paid for audited
    /// requests), the audit itself after a successful resolution. Audited
    /// requests that came back downgraded are skipped — a browned-out
    /// response legitimately diverges from the full-fidelity oracle.
    fn serve_proposal_inner(
        &self,
        req: ProposalRequest,
        token: Option<&ResilienceToken>,
    ) -> Result<ProposalResponse, ResponseError> {
        let audit_img = self.auditor.as_ref().and_then(|a| {
            let ordinal = self.audit_ordinal.fetch_add(1, Ordering::Relaxed);
            a.should_audit(ordinal).then(|| req.image.clone())
        });
        let top_k = req.top_k.unwrap_or(self.config.top_k);
        let (image, session, deadline, submit) = self.proposal_parts(req);
        let (served_by, resp) = self.serve_core(image, session, deadline, token, true, submit)?;
        if let (Some(auditor), Some(img)) = (&self.auditor, &audit_img) {
            if !resp.downgrade.any() && !auditor.audit(img, top_k, &resp.items) {
                // the golden probe caught silent corruption that structural
                // validation could not: weight it like a validated Corrupt
                // so the serving shard quarantines just as fast
                self.supervisor.record_weighted(served_by, true, CORRUPT_WEIGHT);
            }
        }
        Ok(resp)
    }

    /// [`Self::serve`] through the full detection cascade.
    pub fn serve_detect(&self, req: DetectRequest) -> Result<DetectResponse, ResponseError> {
        let (image, session, deadline, submit) = self.detect_parts(req);
        self.serve_core(image, session, deadline, None, true, submit).map(|(_, resp)| resp)
    }

    /// [`Self::serve_detect`] with a cross-attempt cancellation token.
    pub fn serve_detect_cancellable(
        &self,
        req: DetectRequest,
        token: &ResilienceToken,
    ) -> Result<DetectResponse, ResponseError> {
        let (image, session, deadline, submit) = self.detect_parts(req);
        self.serve_core(image, session, deadline, Some(token), true, submit).map(|(_, resp)| resp)
    }

    /// Submit a batch and wait for every result, `max_batch` images in
    /// flight together, results in submission order (refusals surface as
    /// `Err(Rejected(_))` in their slot). First attempts are pipelined —
    /// every submission is in flight before any wait; only failed attempts
    /// retry serially. Hedging stays off on the batch path (the batch is
    /// its own parallelism).
    pub fn serve_batch(
        &self,
        images: Vec<ImageRgb>,
    ) -> Vec<Result<ProposalResponse, ResponseError>> {
        self.serve_batch_requests(images.into_iter().map(ProposalRequest::new).collect())
    }

    /// [`Self::serve_batch`] over typed requests.
    pub fn serve_batch_requests(
        &self,
        requests: Vec<ProposalRequest>,
    ) -> Vec<Result<ProposalResponse, ResponseError>> {
        self.batch_core(requests, |req| self.proposal_parts(req))
    }

    /// [`Self::serve_batch`] through the full cascade: every image becomes
    /// a default [`DetectRequest`] and resolves to detections.
    pub fn detect_batch(
        &self,
        images: Vec<ImageRgb>,
    ) -> Vec<Result<DetectResponse, ResponseError>> {
        self.detect_batch_requests(images.into_iter().map(DetectRequest::new).collect())
    }

    /// [`Self::detect_batch`] over typed requests.
    pub fn detect_batch_requests(
        &self,
        requests: Vec<DetectRequest>,
    ) -> Vec<Result<DetectResponse, ResponseError>> {
        self.batch_core(requests, |req| self.detect_parts(req))
    }

    /// Decompose a proposal request into the pieces the resilient core
    /// needs: the image, the session id (for routing), the *resolved*
    /// deadline (config default applied once, so every retry shares one
    /// budget instead of restarting it), and a re-submittable closure
    /// carrying the per-request options. Retries keep the session: a
    /// re-submitted frame re-diffs against the session's canonical frame
    /// (an identical frame dirties nothing), so the retry stays
    /// bit-identical on any shard.
    fn proposal_parts(
        &self,
        mut req: ProposalRequest,
    ) -> (
        ImageRgb,
        Option<u64>,
        Option<Instant>,
        impl Fn(ImageRgb, &Coordinator<B>) -> Result<RequestHandle, SubmitError>,
    ) {
        self.apply_brownout_proposal(&mut req);
        let ProposalRequest { image, top_k, deadline, scale_stride, session, downgrade } = req;
        let deadline = deadline.or_else(|| {
            self.config.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms))
        });
        let submit = move |img: ImageRgb, coord: &Coordinator<B>| {
            let mut r = ProposalRequest::new(img);
            r.top_k = top_k;
            r.deadline = deadline;
            r.scale_stride = scale_stride;
            r.session = session;
            r.downgrade = downgrade;
            coord.submit_request(r)
        };
        (image, session, deadline, submit)
    }

    /// [`Self::proposal_parts`] for detection requests.
    fn detect_parts(
        &self,
        mut req: DetectRequest,
    ) -> (
        ImageRgb,
        Option<u64>,
        Option<Instant>,
        impl Fn(ImageRgb, &Coordinator<B>) -> Result<DetectHandle, SubmitError>,
    ) {
        self.apply_brownout_detect(&mut req);
        let DetectRequest {
            image,
            deadline,
            top_k,
            nms_thresh,
            min_confidence,
            scale_stride,
            downgrade,
        } = req;
        let deadline = deadline.or_else(|| {
            self.config.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms))
        });
        let submit = move |img: ImageRgb, coord: &Coordinator<B>| {
            let mut r = DetectRequest::new(img);
            r.deadline = deadline;
            r.top_k = top_k;
            r.nms_thresh = nms_thresh;
            r.min_confidence = min_confidence;
            r.scale_stride = scale_stride;
            r.downgrade = downgrade;
            coord.submit_detect(r)
        };
        (image, None, deadline, submit)
    }

    /// First attempt + resilient resolution for one request. Returns the
    /// index of the shard that produced the response alongside it, so the
    /// audit path can attribute a late-discovered mismatch to the right
    /// shard's health record.
    fn serve_core<H: ServeHandle>(
        &self,
        image: ImageRgb,
        session: Option<u64>,
        deadline: Option<Instant>,
        token: Option<&ResilienceToken>,
        hedge_allowed: bool,
        submit: impl Fn(ImageRgb, &Coordinator<B>) -> Result<H, SubmitError>,
    ) -> Result<(usize, ServeResponse<H::Item>), ResponseError> {
        if token.is_some_and(|t| t.is_cancelled()) {
            self.metrics.cancellations.inc();
            return Err(ResponseError::Cancelled);
        }
        let route = RouteRequest { image_w: image.w, image_h: image.h, session };
        let hedging = hedge_allowed && self.retry.hedge_after.is_some();
        // zero-copy fast path: the master copy (for re-submission) only
        // exists when the policy can actually need a second attempt
        let master = (self.retry.max_attempts > 1 || hedging).then(|| image.clone());
        let first = self.route_submit_excluding(route, &[], true, |c| submit(image, c));
        self.resolve_resilient(first, master, route, deadline, token, hedge_allowed, &submit)
    }

    /// The shared batch loop: phase 1 pipelines every first attempt into
    /// the shards, phase 2 resolves them in order (retries, when needed,
    /// run serially per slot).
    fn batch_core<P, H, S>(
        &self,
        requests: Vec<P>,
        parts: impl Fn(P) -> (ImageRgb, Option<u64>, Option<Instant>, S),
    ) -> Vec<Result<ServeResponse<H::Item>, ResponseError>>
    where
        H: ServeHandle,
        S: Fn(ImageRgb, &Coordinator<B>) -> Result<H, SubmitError>,
    {
        let max_batch = self.config.max_batch.max(1);
        let retry_possible = self.retry.max_attempts > 1;
        let mut results = Vec::with_capacity(requests.len());
        let mut requests = requests.into_iter();
        loop {
            let chunk: Vec<P> = requests.by_ref().take(max_batch).collect();
            if chunk.is_empty() {
                break;
            }
            let pending: Vec<_> = chunk
                .into_iter()
                .map(|req| {
                    let (image, session, deadline, submit) = parts(req);
                    let route =
                        RouteRequest { image_w: image.w, image_h: image.h, session };
                    let master = retry_possible.then(|| image.clone());
                    let first =
                        self.route_submit_excluding(route, &[], true, |c| submit(image, c));
                    (first, master, route, deadline, submit)
                })
                .collect();
            for (first, master, route, deadline, submit) in pending {
                results.push(
                    self.resolve_resilient(first, master, route, deadline, None, false, &submit)
                        .map(|(_, resp)| resp),
                );
            }
        }
        results
    }

    /// The retry loop: resolve the (already-routed) first attempt, and on
    /// a retryable failure re-submit to an untried shard until the policy,
    /// the deadline budget, or a cancellation says stop.
    #[allow(clippy::too_many_arguments)]
    fn resolve_resilient<H: ServeHandle>(
        &self,
        first: Result<(usize, H), SubmitError>,
        master: Option<ImageRgb>,
        route: RouteRequest,
        deadline: Option<Instant>,
        token: Option<&ResilienceToken>,
        hedge_allowed: bool,
        submit: &dyn Fn(ImageRgb, &Coordinator<B>) -> Result<H, SubmitError>,
    ) -> Result<(usize, ServeResponse<H::Item>), ResponseError> {
        let max_attempts = self.retry.max_attempts.max(1);
        let mut tried = vec![false; self.shards.len()];
        let mut attempt: u32 = 0;
        let mut next = Some(first);
        loop {
            attempt += 1;
            let routed = match next.take() {
                Some(r) => r,
                None => {
                    // a retry: re-check cancellation and the deadline
                    // budget before burning another attempt
                    if token.is_some_and(|t| t.is_cancelled()) {
                        self.metrics.cancellations.inc();
                        return Err(ResponseError::Cancelled);
                    }
                    if deadline.is_some_and(|d| Instant::now() >= d) {
                        self.metrics.deadline_misses.inc();
                        return Err(ResponseError::DeadlineExceeded);
                    }
                    let img = master.clone().expect("retries require a master copy");
                    // prefer a shard this request has not tried yet; when
                    // none exists (or the exclusions alone made the fleet
                    // unroutable) fall back to already-tried shards rather
                    // than giving up
                    let routed = if tried.iter().all(|&t| t) {
                        self.route_submit_excluding(route, &[], true, |c| submit(img, c))
                    } else {
                        match self
                            .route_submit_excluding(route, &tried, false, |c| submit(img, c))
                        {
                            Err(SubmitError::Unroutable) => {
                                let img = master.clone().expect("retries require a master copy");
                                self.route_submit_excluding(route, &[], true, |c| submit(img, c))
                            }
                            r => r,
                        }
                    };
                    if routed.is_ok() {
                        // retries = extra *admitted* submissions, so the
                        // accounting `requests == first admits + retries +
                        // hedges` holds exactly
                        self.metrics.retries.inc();
                    }
                    routed
                }
            };
            let (idx, handle) = match routed {
                Ok(x) => x,
                Err(e) => return Err(ResponseError::Rejected(e)),
            };
            tried[idx] = true;
            if let Some(t) = token {
                // if a cancel already landed, arm() cancels this attempt
                // on the spot; the wait below then resolves it promptly
                t.arm(handle.cancel_token());
            }
            let (served_by, result) = match self.retry.hedge_after {
                Some(after) if hedge_allowed && master.is_some() => self.wait_with_hedge(
                    handle,
                    idx,
                    after,
                    route,
                    deadline,
                    &mut tried,
                    token,
                    submit,
                    master.as_ref().expect("checked above"),
                ),
                _ => (idx, self.wait_bounded(handle, deadline)),
            };
            if let Some(t) = token {
                t.disarm();
            }
            match result {
                Ok(resp) => {
                    self.supervisor.record(served_by, false);
                    if let Some(b) = &self.brownout {
                        b.record(false);
                    }
                    return Ok((served_by, resp));
                }
                Err(err) => {
                    if let Some(b) = &self.brownout {
                        b.record(err == ResponseError::DeadlineExceeded);
                    }
                    if err == ResponseError::Cancelled {
                        // the caller's choice, not the shard's fault:
                        // neutral for shard health
                        return Err(err);
                    }
                    if err == ResponseError::Corrupt {
                        // validated corruption: weighted so a shard emitting
                        // garbage quarantines much faster than one crashing
                        self.supervisor.record_weighted(served_by, true, CORRUPT_WEIGHT);
                    } else {
                        self.supervisor.record(served_by, true);
                    }
                    if !err.retryable() || attempt >= max_attempts || master.is_none() {
                        return Err(err);
                    }
                    if token.is_some_and(|t| t.is_cancelled()) {
                        self.metrics.cancellations.inc();
                        return Err(ResponseError::Cancelled);
                    }
                    // linear backoff, never past the deadline
                    let mut pause = self.retry.backoff * attempt;
                    if let Some(d) = deadline {
                        pause = pause.min(d.saturating_duration_since(Instant::now()));
                    }
                    if !pause.is_zero() {
                        std::thread::sleep(pause);
                    }
                }
            }
        }
    }

    /// Block on one attempt, but never past the request's deadline. A
    /// coordinator normally resolves its own deadline misses — but only on
    /// a live worker thread. A *wedged* worker (injected hang, driver
    /// stall) never finalizes its scale task, so a plain `wait()` would
    /// block the caller indefinitely. Timing out client-side contains the
    /// hang within ~the deadline: the stuck attempt is expired (its late
    /// completion, if any, resolves as a deadline miss into a dropped
    /// channel), wedged workers are reaped and replaced so pool capacity
    /// survives, and the caller gets `DeadlineExceeded` on schedule.
    fn wait_bounded<H: ServeHandle>(
        &self,
        handle: H,
        deadline: Option<Instant>,
    ) -> Result<ServeResponse<H::Item>, ResponseError> {
        let Some(d) = deadline else { return handle.wait() };
        match handle.wait_until(d) {
            Ok(result) => result,
            Err(stuck) => {
                stuck.cancel_token().expire();
                self.contain_hang();
                Err(ResponseError::DeadlineExceeded)
            }
        }
    }

    /// The deadline-miss half of hang containment: count the miss, reap
    /// any worker that has been busy for most of a request budget, and
    /// tally replacements. The coordinator may count the same miss again
    /// if the wedged task eventually finalizes — `deadline_misses` is a
    /// pressure signal, not an exactly-once ledger, and an infinite hang
    /// would otherwise never be counted at all.
    fn contain_hang(&self) {
        self.metrics.deadline_misses.inc();
        let reaped = pool::global().reap_wedged(self.reap_stall());
        if reaped > 0 {
            self.metrics.workers_wedged.add(reaped as u64);
        }
    }

    /// How long a worker must have been busy on one task before a
    /// deadline-missing request treats it as wedged: 3/4 of the configured
    /// request budget (fallback 750ms). Healthy scale tasks finish orders
    /// of magnitude faster, so false positives are rare — and harmless by
    /// design (an abandoned worker still finishes and delivers its task;
    /// only its slot is handed to a replacement).
    fn reap_stall(&self) -> Duration {
        Duration::from_millis((self.config.deadline_ms.unwrap_or(1000) * 3 / 4).max(1))
    }

    /// Wait on `primary`; if it has not resolved by the hedge point, fire
    /// one duplicate on an untried shard and race them — first resolution
    /// wins, the loser is cancelled (it resolves shard-side as a
    /// cancellation into a dropped channel; deliberately not recorded as a
    /// health outcome).
    #[allow(clippy::too_many_arguments)]
    fn wait_with_hedge<H: ServeHandle>(
        &self,
        primary: H,
        primary_idx: usize,
        hedge_after: Duration,
        route: RouteRequest,
        deadline: Option<Instant>,
        tried: &mut [bool],
        token: Option<&ResilienceToken>,
        submit: &dyn Fn(ImageRgb, &Coordinator<B>) -> Result<H, SubmitError>,
        master: &ImageRgb,
    ) -> (usize, Result<ServeResponse<H::Item>, ResponseError>) {
        let mut hedge_at = Instant::now() + hedge_after;
        if let Some(d) = deadline {
            hedge_at = hedge_at.min(d);
        }
        let primary = match primary.wait_until(hedge_at) {
            Ok(result) => return (primary_idx, result),
            Err(h) => h,
        };
        let img = master.clone();
        let (hedge_idx, hedge) =
            match self.route_submit_excluding(route, tried, false, |c| submit(img, c)) {
            Ok(x) => x,
            // nowhere to hedge to: keep waiting on the primary (still
            // bounded, so a wedged primary cannot outlive the deadline)
            Err(_) => return (primary_idx, self.wait_bounded(primary, deadline)),
        };
        self.metrics.hedges_fired.inc();
        tried[hedge_idx] = true;
        if let Some(t) = token {
            t.arm(hedge.cancel_token());
        }
        let slice = Duration::from_micros(500);
        let mut primary = primary;
        let mut hedge = hedge;
        loop {
            if deadline.is_some_and(|d| Instant::now() >= d) {
                // both attempts outlived the budget — expire them (late
                // completions resolve as deadline misses into dropped
                // channels) and contain any wedged workers behind them
                primary.cancel_token().expire();
                hedge.cancel_token().expire();
                self.contain_hang();
                return (primary_idx, Err(ResponseError::DeadlineExceeded));
            }
            primary = match primary.wait_until(Instant::now() + slice) {
                Ok(result) => {
                    hedge.cancel_token().cancel();
                    return (primary_idx, result);
                }
                Err(h) => h,
            };
            hedge = match hedge.wait_until(Instant::now() + slice) {
                Ok(result) => {
                    primary.cancel_token().cancel();
                    return (hedge_idx, result);
                }
                Err(h) => h,
            };
        }
    }

    // ── brownout (load shedding by degradation) ─────────────────────────

    /// Current shedding level from fleet pressure (0 when disabled).
    fn brownout_level(&self) -> u8 {
        match &self.brownout {
            None => 0,
            Some(b) => {
                let fleet_load: usize =
                    self.shards.iter().map(|s| s.coordinator.inflight_tasks()).sum();
                b.level(fleet_load)
            }
        }
    }

    fn apply_brownout_proposal(&self, req: &mut ProposalRequest) {
        let level = self.brownout_level();
        if level == 0 {
            return;
        }
        let r = &self.config.resilience;
        let before = req.downgrade;
        if req.top_k.unwrap_or(self.config.top_k) > r.brownout_top_k {
            req.top_k = Some(r.brownout_top_k);
            req.downgrade.top_k_capped = true;
        }
        if level >= 2 && req.scale_stride < r.brownout_scale_stride {
            req.scale_stride = r.brownout_scale_stride;
            req.downgrade.reduced_scales = true;
        }
        if req.downgrade != before {
            self.metrics.brownout_downgrades.inc();
        }
    }

    fn apply_brownout_detect(&self, req: &mut DetectRequest) {
        let level = self.brownout_level();
        if level == 0 {
            return;
        }
        let r = &self.config.resilience;
        let before = req.downgrade;
        if req.top_k.unwrap_or(self.config.cascade.top_k) > r.brownout_top_k {
            req.top_k = Some(r.brownout_top_k);
            req.downgrade.top_k_capped = true;
        }
        if level >= 2 {
            if req.scale_stride < r.brownout_scale_stride {
                req.scale_stride = r.brownout_scale_stride;
                req.downgrade.reduced_scales = true;
            }
            // cheapest cascade: skip NMS, map proposals straight to
            // calibrated detections
            req.downgrade.proposals_only = true;
        }
        if req.downgrade != before {
            self.metrics.brownout_downgrades.inc();
        }
    }

    // ── lifecycle ───────────────────────────────────────────────────────

    /// Gracefully drain one shard: steer the router away, then block until
    /// the shard's in-flight scale tasks finish. The flag flips under the
    /// shard's admission write-gate, so a submit that snapshotted the shard
    /// as healthy either lands before the flip (and is awaited below) or
    /// re-checks, sees the flag and re-routes — when this returns, the
    /// shard holds no work and can receive none. The shard stays usable —
    /// [`Self::resume_shard`] puts it back in rotation (rolling restarts).
    pub fn drain_shard(&self, idx: usize) {
        let shard = &self.shards[idx];
        {
            let _gate = shard.gate.write().unwrap();
            shard.draining.store(true, Ordering::Release);
        }
        shard.coordinator.wait_idle();
    }

    /// Put a drained shard back in the routing rotation.
    pub fn resume_shard(&self, idx: usize) {
        self.shards[idx].draining.store(false, Ordering::Release);
    }

    /// Block until every shard is idle (no queued or executing scale
    /// tasks). New submissions may still arrive afterwards.
    pub fn wait_idle(&self) {
        for shard in &self.shards {
            shard.coordinator.wait_idle();
        }
    }

    /// Backpressure engagements over all shard admission gates (the shared
    /// metrics counter every shard queue feeds exactly, under its mutex).
    pub fn queue_full_events(&self) -> u64 {
        self.metrics.queue_full_events.get()
    }

    /// One-line fleet summary (the shared metrics sink, including the
    /// per-shard lane rollup and a fresh worker-pool sample).
    pub fn summary(&self) -> String {
        self.metrics.observe_pool(&pool::global().stats());
        self.metrics.summary()
    }

    /// Graceful shutdown: each shard refuses new work and drains (runs on
    /// Drop too; consuming `self` just makes it explicit).
    pub fn shutdown(self) {
        drop(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{ScoringMode, SoftwareBing};
    use crate::bing::{default_stage1, Pyramid};
    use crate::config::ResilienceConfig;
    use crate::data::SyntheticDataset;

    fn sizes() -> Vec<(usize, usize)> {
        vec![(16, 16), (32, 32)]
    }

    fn software() -> Arc<SoftwareBing> {
        Arc::new(SoftwareBing::new(
            Pyramid::new(sizes()),
            default_stage1(),
            Stage2Calibration::identity(sizes()),
            ScoringMode::Exact,
        ))
    }

    fn runtime(shards: usize, policy: RoutePolicyKind) -> ServerRuntime<SoftwareBing> {
        ServerRuntime::new(
            software(),
            Stage2Calibration::identity(sizes()),
            ServingConfig { shards, policy, top_k: 60, workers: 2, ..Default::default() },
        )
    }

    #[test]
    fn make_policy_names_match_config_spellings() {
        // the bench labels rows with RoutePolicyKind::name() while logs use
        // the trait impl's name() — they must never drift apart
        for kind in [
            RoutePolicyKind::RoundRobin,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::ScaleAffinity,
            RoutePolicyKind::SessionAffinity,
        ] {
            assert_eq!(make_policy(kind).name(), kind.name());
        }
    }

    #[test]
    fn every_policy_and_shard_count_matches_the_baseline() {
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let want = software().propose(&img, 60);
        for policy in [
            RoutePolicyKind::RoundRobin,
            RoutePolicyKind::LeastLoaded,
            RoutePolicyKind::ScaleAffinity,
            RoutePolicyKind::SessionAffinity,
        ] {
            for shards in [1usize, 2, 3] {
                let rt = runtime(shards, policy);
                assert_eq!(rt.shards(), shards);
                let resp = rt.submit(img.clone()).unwrap().wait().unwrap();
                assert_eq!(
                    resp.items, want,
                    "policy {policy:?} x {shards} shards diverged from the baseline"
                );
                rt.shutdown();
            }
        }
    }

    #[test]
    fn round_robin_spreads_images_across_lanes() {
        let rt = runtime(3, RoutePolicyKind::RoundRobin);
        let ds = SyntheticDataset::voc_like_val(6);
        let results = rt.serve_batch(ds.iter().map(|s| s.image).collect());
        assert!(results.iter().all(|r| r.is_ok()));
        for i in 0..3 {
            assert_eq!(
                rt.metrics.shard(i).unwrap().images.get(),
                2,
                "rr must balance 6 images over 3 shards"
            );
        }
        // shared id space: ids unique and in submission order
        let ids: Vec<u64> = results.iter().map(|r| r.as_ref().unwrap().id).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5, 6]);
        assert!(rt.summary().contains("shard2["), "{}", rt.summary());
        rt.shutdown();
    }

    #[test]
    fn session_frames_pin_to_one_shard_and_reuse_its_frame_cache() {
        let rt = runtime(2, RoutePolicyKind::SessionAffinity);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let want = software().propose(&img, 60);
        for _ in 0..3 {
            let resp = rt.serve(ProposalRequest::new(img.clone()).session(7)).unwrap();
            assert_eq!(resp.items, want, "session serving must stay bit-identical");
        }
        // session 7 homes on shard 7 % 2 = 1; every frame must land there
        assert_eq!(rt.metrics.shard(1).unwrap().images.get(), 3);
        assert_eq!(rt.metrics.shard(0).unwrap().images.get(), 0);
        assert_eq!(rt.metrics.sessions_active.get(), 1);
        // frame 1 recomputes everything; identical frames 2 and 3 skip
        // every tile — the whole point of the pin
        let per_frame = rt.metrics.tiles_recomputed.get();
        assert!(per_frame > 0, "first frame must recompute its tiles");
        assert_eq!(
            rt.metrics.tiles_skipped.get(),
            2 * per_frame,
            "identical follow-up frames must skip every tile"
        );
        assert_eq!(rt.metrics.cache_invalidations.get(), 0, "no drain, no re-pin");
        rt.shutdown();
    }

    #[test]
    fn draining_shard_receives_no_new_images_and_resumes() {
        let rt = runtime(2, RoutePolicyKind::RoundRobin);
        let ds = SyntheticDataset::voc_like_val(5);
        rt.drain_shard(1);
        assert!(rt.shard(1).is_draining());
        let results = rt.serve_batch(ds.iter().map(|s| s.image).collect());
        assert!(results.iter().all(|r| r.is_ok()), "drain must not drop work");
        assert_eq!(rt.metrics.shard(1).unwrap().images.get(), 0);
        assert_eq!(rt.metrics.shard(0).unwrap().images.get(), 5);

        rt.resume_shard(1);
        let more = rt.serve_batch(ds.iter().map(|s| s.image).collect());
        assert!(more.iter().all(|r| r.is_ok()));
        assert!(
            rt.metrics.shard(1).unwrap().images.get() > 0,
            "resumed shard never came back into rotation"
        );
        rt.shutdown();
    }

    #[test]
    fn all_shards_draining_is_unroutable() {
        let rt = runtime(2, RoutePolicyKind::LeastLoaded);
        rt.drain_shard(0);
        rt.drain_shard(1);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        assert_eq!(rt.submit(img).unwrap_err(), SubmitError::Unroutable);
        assert_eq!(rt.metrics.rejected.get(), 1);
        assert_eq!(
            rt.metrics.rejected_unroutable.get(),
            1,
            "fleet exhaustion must be visible in its own counter"
        );
        rt.shutdown();
    }

    #[test]
    fn served_detections_match_the_direct_cascade() {
        use crate::detect::{CascadeDetector, CascadeParams, DetectionBackend};
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let cfg = ServingConfig { shards: 2, top_k: 60, workers: 2, ..Default::default() };
        let oracle = CascadeDetector::new(
            software(),
            Stage2Calibration::identity(sizes()),
            CascadeParams::from_config(&cfg.cascade),
            cfg.top_k,
        );
        let want = oracle.detect(&img).unwrap();
        let rt = ServerRuntime::new(software(), Stage2Calibration::identity(sizes()), cfg);
        let resp = rt.detect(img).unwrap().wait().unwrap();
        assert_eq!(resp.items, want, "served cascade diverged from the direct path");
        rt.shutdown();
    }

    #[test]
    fn per_request_cascade_overrides_apply() {
        let rt = runtime(1, RoutePolicyKind::RoundRobin);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let full = rt.detect(img.clone()).unwrap().wait().unwrap();
        let capped = rt
            .submit_detect(DetectRequest::new(img).top_k(3))
            .unwrap()
            .wait()
            .unwrap();
        assert!(capped.items.len() <= 3);
        assert!(full.items.len() >= capped.items.len());
        // greedy keeps are decided in score order: the cap is a prefix
        assert_eq!(capped.items[..], full.items[..capped.items.len()]);
        rt.shutdown();
    }

    #[test]
    fn heterogeneous_backends_one_per_shard() {
        // from_backends: distinct replica instances, still one id space
        let rt: ServerRuntime<SoftwareBing> = ServerRuntime::from_backends(
            vec![software(), software()],
            Stage2Calibration::identity(sizes()),
            ServingConfig { top_k: 40, ..Default::default() },
        );
        assert_eq!(rt.shards(), 2);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let a = rt.submit(img.clone()).unwrap().wait().unwrap();
        let b = rt.submit(img).unwrap().wait().unwrap();
        assert_eq!(a.items, b.items);
        assert_ne!(a.id, b.id);
        rt.shutdown();
    }

    // ── resilience ──────────────────────────────────────────────────────

    /// A backend whose first `fail_first` calls per scale return a
    /// transient `Err`, then recovers — the deterministic retry fixture.
    struct FlakyFirst {
        inner: Arc<SoftwareBing>,
        calls: Vec<AtomicU64>,
        fail_first: u64,
    }

    impl FlakyFirst {
        fn new(inner: Arc<SoftwareBing>, fail_first: u64) -> Self {
            let n = inner.pyramid().sizes.len();
            Self { inner, calls: (0..n).map(|_| AtomicU64::new(0)).collect(), fail_first }
        }
    }

    impl ProposalBackend for FlakyFirst {
        fn name(&self) -> &'static str {
            "flaky"
        }
        fn pyramid(&self) -> &Pyramid {
            self.inner.pyramid()
        }
        fn scale_candidates(
            &self,
            img: &ImageRgb,
            scale_idx: usize,
        ) -> anyhow::Result<crate::backend::ScaleCandidates> {
            if self.calls[scale_idx].fetch_add(1, Ordering::Relaxed) < self.fail_first {
                anyhow::bail!("flaky: injected transient failure");
            }
            self.inner.scale_candidates(img, scale_idx)
        }
    }

    fn resilient_config(resilience: ResilienceConfig) -> ServingConfig {
        ServingConfig { top_k: 60, workers: 2, resilience, ..Default::default() }
    }

    #[test]
    fn serve_happy_path_is_bit_identical_with_zero_retries() {
        let rt = runtime(2, RoutePolicyKind::RoundRobin);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let want = software().propose(&img, 60);
        let resp = rt.serve(ProposalRequest::new(img)).unwrap();
        assert_eq!(resp.items, want);
        assert!(!resp.downgrade.any());
        assert_eq!(rt.metrics.retries.get(), 0);
        assert_eq!(rt.metrics.hedges_fired.get(), 0);
        rt.shutdown();
    }

    #[test]
    fn retry_recovers_transient_failures_bit_identically() {
        let inner = software();
        let want = inner.propose(&SyntheticDataset::voc_like_val(1).sample(0).image, 60);
        let rt = ServerRuntime::new(
            Arc::new(FlakyFirst::new(inner, 1)),
            Stage2Calibration::identity(sizes()),
            resilient_config(ResilienceConfig {
                retry_max_attempts: 4,
                retry_backoff_ms: 0,
                ..Default::default()
            }),
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = rt.serve(ProposalRequest::new(img)).unwrap();
        assert_eq!(resp.items, want, "a retried request must stay bit-identical");
        assert!(rt.metrics.retries.get() >= 1, "the transient had to cost a retry");
        assert!(rt.metrics.transient_errors.get() >= 1);
        rt.shutdown();
    }

    #[test]
    fn without_retries_the_transient_surfaces_typed() {
        let rt = ServerRuntime::new(
            Arc::new(FlakyFirst::new(software(), u64::MAX)),
            Stage2Calibration::identity(sizes()),
            resilient_config(ResilienceConfig::default()),
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        assert_eq!(
            rt.serve(ProposalRequest::new(img)).unwrap_err(),
            ResponseError::Transient
        );
        assert_eq!(rt.metrics.retries.get(), 0);
        rt.shutdown();
    }

    #[test]
    fn quarantined_shard_is_routed_around() {
        let rt = runtime(2, RoutePolicyKind::RoundRobin);
        // trip shard 1's breaker directly (the unit-level seam; the soak
        // trips it through real chaos faults)
        for _ in 0..ResilienceConfig::default().quarantine_failures {
            rt.supervisor().record(1, true);
        }
        assert_eq!(rt.shard_health(1), ShardHealth::Quarantined);
        let ds = SyntheticDataset::voc_like_val(4);
        let results = rt.serve_batch(ds.iter().map(|s| s.image).collect());
        assert!(results.iter().all(|r| r.is_ok()));
        assert_eq!(
            rt.metrics.shard(1).unwrap().images.get(),
            0,
            "quarantined shard must receive nothing"
        );
        assert_eq!(rt.metrics.shard(0).unwrap().images.get(), 4);
        assert_eq!(rt.metrics.shards_quarantined.get(), 1);
        rt.shutdown();
    }

    #[test]
    fn fully_quarantined_fleet_fails_open() {
        let rt = runtime(2, RoutePolicyKind::LeastLoaded);
        for idx in 0..2 {
            for _ in 0..ResilienceConfig::default().quarantine_failures {
                rt.supervisor().record(idx, true);
            }
            assert_eq!(rt.shard_health(idx), ShardHealth::Quarantined);
        }
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = rt.serve(ProposalRequest::new(img)).unwrap();
        assert!(!resp.items.is_empty(), "fail-open must keep serving");
        rt.shutdown();
    }

    #[test]
    fn hedge_fires_on_a_slow_primary_and_stays_bit_identical() {
        /// Delays every scale call — the "slow replica" fixture.
        struct Slow {
            inner: Arc<SoftwareBing>,
            delay: Duration,
        }
        impl ProposalBackend for Slow {
            fn name(&self) -> &'static str {
                "slow"
            }
            fn pyramid(&self) -> &Pyramid {
                self.inner.pyramid()
            }
            fn scale_candidates(
                &self,
                img: &ImageRgb,
                scale_idx: usize,
            ) -> anyhow::Result<crate::backend::ScaleCandidates> {
                std::thread::sleep(self.delay);
                self.inner.scale_candidates(img, scale_idx)
            }
        }
        let want = software().propose(&SyntheticDataset::voc_like_val(1).sample(0).image, 60);
        // rr picks shard 0 first: the slow one; the hedge lands on shard 1
        let backends: Vec<Arc<dyn ProposalBackend>> = vec![
            Arc::new(Slow { inner: software(), delay: Duration::from_millis(30) }),
            software(),
        ];
        let rt: ServerRuntime = ServerRuntime::from_backends(
            backends,
            Stage2Calibration::identity(sizes()),
            resilient_config(ResilienceConfig {
                hedge_after_ms: Some(2),
                ..Default::default()
            }),
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = rt.serve(ProposalRequest::new(img)).unwrap();
        assert_eq!(resp.items, want, "whichever attempt wins, the payload is the same");
        assert_eq!(rt.metrics.hedges_fired.get(), 1);
        rt.shutdown();
    }

    #[test]
    fn cancel_during_retry_does_not_leak_an_attempt() {
        // an always-failing backend keeps the retry loop spinning until the
        // token lands; the regression here is a retry submitted *after* the
        // cancel (it would hang accounting and waste a worker)
        let rt = ServerRuntime::new(
            Arc::new(FlakyFirst::new(software(), u64::MAX)),
            Stage2Calibration::identity(sizes()),
            resilient_config(ResilienceConfig {
                retry_max_attempts: 10_000,
                retry_backoff_ms: 1,
                ..Default::default()
            }),
        );
        let token = Arc::new(ResilienceToken::new());
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let canceller = {
            let token = token.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(10));
                token.cancel();
            })
        };
        let err = rt.serve_cancellable(ProposalRequest::new(img), &token).unwrap_err();
        canceller.join().unwrap();
        assert_eq!(err, ResponseError::Cancelled);
        // no attempt may be submitted after the cancel: the admitted-request
        // counter must be frozen once serve_cancellable returned
        rt.wait_idle();
        let frozen = rt.metrics.requests.get();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(rt.metrics.requests.get(), frozen, "a retry leaked past the cancel");
        rt.shutdown();
    }

    #[test]
    fn brownout_downgrades_instead_of_rejecting() {
        let rt = runtime_with_brownout();
        // saturate the miss-rate window: pressure 4x the threshold → level 2
        let b = rt.brownout().expect("brownout enabled");
        for _ in 0..32 {
            b.record(true);
        }
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = rt.serve(ProposalRequest::new(img.clone())).unwrap();
        assert!(resp.downgrade.top_k_capped, "level>=1 caps top_k");
        assert!(resp.downgrade.reduced_scales, "level 2 strides the pyramid");
        assert!(resp.items.len() <= 5);
        let det = rt.serve_detect(DetectRequest::new(img)).unwrap();
        assert!(det.downgrade.proposals_only, "level 2 serves the lite cascade");
        assert!(rt.metrics.brownout_downgrades.get() >= 2);
        rt.shutdown();
    }

    fn runtime_with_brownout() -> ServerRuntime<SoftwareBing> {
        ServerRuntime::new(
            software(),
            Stage2Calibration::identity(sizes()),
            resilient_config(ResilienceConfig {
                brownout: true,
                brownout_miss_rate: 0.25,
                brownout_top_k: 5,
                brownout_scale_stride: 2,
                ..Default::default()
            }),
        )
    }

    // ── integrity: silent-data-corruption defense ───────────────────────

    #[test]
    fn corrupt_soak_zero_escapes_and_survivors_bit_identical() {
        use crate::fault::{ChaosBackend, FaultPlan};
        let inner = software();
        let chaos = Arc::new(ChaosBackend::new(
            inner,
            FaultPlan { corrupt_p: 0.25, ..FaultPlan::zero(7) },
        ));
        let mut cfg = resilient_config(ResilienceConfig {
            retry_max_attempts: 6,
            retry_backoff_ms: 0,
            // keep every shard routable: this test is about the validation
            // seam, not the breaker (covered separately below)
            quarantine_failures: usize::MAX,
            ..Default::default()
        });
        cfg.shards = 2;
        let rt = ServerRuntime::new(chaos.clone(), Stage2Calibration::identity(sizes()), cfg);
        let ds = SyntheticDataset::voc_like_val(24);
        let mut ok = 0usize;
        for sample in ds.iter() {
            let want = software().propose(&sample.image, 60);
            match rt.serve(ProposalRequest::new(sample.image)) {
                // THE acceptance property: a response that reaches the
                // caller is bit-identical to the fault-free baseline —
                // validated corruption never escapes as payload
                Ok(resp) => {
                    assert_eq!(resp.items, want, "corrupted payload escaped to a caller");
                    ok += 1;
                }
                // attempts exhausted against the 25% corruption rate:
                // typed containment, not silent wrongness
                Err(e) => assert_eq!(e, ResponseError::Corrupt),
            }
        }
        assert!(ok >= 1, "soak produced no successful responses at all");
        assert!(chaos.injected_corrupts.get() >= 1, "plan injected nothing");
        assert!(
            rt.metrics.integrity_violations.get() >= chaos.injected_corrupts.get(),
            "every injected corruption must be caught by validation (injected {}, caught {})",
            chaos.injected_corrupts.get(),
            rt.metrics.integrity_violations.get()
        );
        rt.shutdown();
    }

    #[test]
    fn corrupting_shard_quarantines_fast_and_requests_fail_over() {
        use crate::fault::{ChaosBackend, FaultPlan};
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let want = software().propose(&img, 60);
        let poisoned: Arc<dyn ProposalBackend> = Arc::new(ChaosBackend::new(
            software(),
            FaultPlan { corrupt_p: 1.0, ..FaultPlan::zero(3) },
        ));
        let backends: Vec<Arc<dyn ProposalBackend>> = vec![poisoned, software()];
        let rt: ServerRuntime = ServerRuntime::from_backends(
            backends,
            Stage2Calibration::identity(sizes()),
            resilient_config(ResilienceConfig {
                retry_max_attempts: 4,
                retry_backoff_ms: 0,
                supervisor_window: 8,
                quarantine_failures: 4,
                quarantine_cooldown_ms: 60_000,
                ..Default::default()
            }),
        );
        // rr lands the first attempt on shard 0 (always-corrupt): one
        // weighted Corrupt outcome fills the 4-failure window on its own,
        // and the retry fails over to the clean shard bit-identically
        let resp = rt.serve(ProposalRequest::new(img.clone())).unwrap();
        assert_eq!(resp.items, want, "failover response diverged from baseline");
        assert_eq!(
            rt.shard_health(0),
            ShardHealth::Quarantined,
            "a single corrupt outcome (weight {CORRUPT_WEIGHT}) must quarantine"
        );
        assert_eq!(rt.metrics.shards_quarantined.get(), 1);
        assert!(rt.metrics.retries.get() >= 1);
        assert!(rt.metrics.integrity_violations.get() >= 1);
        // follow-up traffic routes around the poisoned shard entirely
        let shard0_before = rt.metrics.shard(0).unwrap().images.get();
        let resp2 = rt.serve(ProposalRequest::new(img)).unwrap();
        assert_eq!(resp2.items, want);
        assert_eq!(rt.metrics.shard(0).unwrap().images.get(), shard0_before);
        rt.shutdown();
    }

    #[test]
    fn audit_mismatch_latches_fleet_wide_kernel_demotion() {
        /// Structurally valid but silently wrong: every candidate score is
        /// bumped by one — inside every validator bound, order preserved,
        /// caught only by the golden probe's bitwise comparison.
        struct Tamper {
            inner: Arc<SoftwareBing>,
        }
        impl ProposalBackend for Tamper {
            fn name(&self) -> &'static str {
                "tamper"
            }
            fn pyramid(&self) -> &Pyramid {
                self.inner.pyramid()
            }
            fn scale_candidates(
                &self,
                img: &ImageRgb,
                scale_idx: usize,
            ) -> anyhow::Result<crate::backend::ScaleCandidates> {
                let mut out = self.inner.scale_candidates(img, scale_idx)?;
                for c in &mut out.candidates {
                    c.score += 1;
                }
                Ok(out)
            }
        }
        let _guard = crate::simd::DEMOTION_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        crate::simd::reset_demotion();
        let mut cfg = resilient_config(ResilienceConfig::default());
        cfg.integrity.audit_rate = 1; // audit every request
        let mut rt = ServerRuntime::new(
            Arc::new(Tamper { inner: software() }),
            Stage2Calibration::identity(sizes()),
            cfg,
        );
        // claim the production path scores with a multi-lane SIMD kernel:
        // a mismatch then implicates it and must latch the SWAR demotion
        rt.install_auditor(software(), ScoreKernel::Avx2);
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let resp = rt.serve(ProposalRequest::new(img.clone())).unwrap();
        assert!(!resp.items.is_empty(), "tampered output is structurally valid");
        assert_eq!(rt.metrics.audits_run.get(), 1);
        assert_eq!(rt.metrics.audit_mismatches.get(), 1);
        assert_eq!(rt.metrics.kernel_demotions.get(), 1);
        assert!(crate::simd::demoted(), "mismatch must latch the fleet-wide demotion");
        // the latch is one-way: a second mismatch is counted but demotes
        // nothing further
        rt.serve(ProposalRequest::new(img)).unwrap();
        assert_eq!(rt.metrics.audits_run.get(), 2);
        assert_eq!(rt.metrics.audit_mismatches.get(), 2);
        assert_eq!(rt.metrics.kernel_demotions.get(), 1, "demotion must count exactly once");
        rt.shutdown();
        crate::simd::reset_demotion();
    }

    #[test]
    fn injected_hang_is_contained_within_the_deadline() {
        /// Wedges the first scale-0 call for far longer than any request
        /// budget; every other call is clean.
        struct HangOnce {
            inner: Arc<SoftwareBing>,
            hung: AtomicBool,
            hang: Duration,
        }
        impl ProposalBackend for HangOnce {
            fn name(&self) -> &'static str {
                "hang-once"
            }
            fn pyramid(&self) -> &Pyramid {
                self.inner.pyramid()
            }
            fn scale_candidates(
                &self,
                img: &ImageRgb,
                scale_idx: usize,
            ) -> anyhow::Result<crate::backend::ScaleCandidates> {
                if scale_idx == 0 && !self.hung.swap(true, Ordering::SeqCst) {
                    std::thread::sleep(self.hang);
                }
                self.inner.scale_candidates(img, scale_idx)
            }
        }
        let mut cfg = resilient_config(ResilienceConfig {
            retry_max_attempts: 1,
            ..Default::default()
        });
        cfg.deadline_ms = Some(80); // reap stall = 60ms, hang = 400ms
        let rt = ServerRuntime::new(
            Arc::new(HangOnce {
                inner: software(),
                hung: AtomicBool::new(false),
                hang: Duration::from_millis(400),
            }),
            Stage2Calibration::identity(sizes()),
            cfg,
        );
        let img = SyntheticDataset::voc_like_val(1).sample(0).image;
        let t0 = Instant::now();
        let err = rt.serve(ProposalRequest::new(img.clone())).unwrap_err();
        let elapsed = t0.elapsed();
        assert_eq!(err, ResponseError::DeadlineExceeded);
        assert!(
            elapsed < Duration::from_millis(300),
            "hang must be contained near the 80ms deadline, took {elapsed:?}"
        );
        assert!(
            rt.metrics.workers_wedged.get() >= 1,
            "the wedged worker must be reaped and tallied"
        );
        // pool capacity survived: the replacement worker serves the next
        // request cleanly (the original sleeper is abandoned, not joined)
        let want = software().propose(&img, 60);
        let resp = rt.serve(ProposalRequest::new(img)).unwrap();
        assert_eq!(resp.items, want, "post-reap serving diverged");
        rt.shutdown();
    }
}
