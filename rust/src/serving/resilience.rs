//! Request-level resilience primitives: the retry/hedge policy, the
//! brownout (load-shedding) controller, and the cancellation token that
//! lets a caller cancel a request *across* retry attempts without leaking
//! an in-flight attempt.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::config::ResilienceConfig;
use crate::coordinator::CancelToken;

/// How the runtime re-attempts retryable failures (`WorkerLost`,
/// `Transient`): up to `max_attempts` total submissions, preferring a
/// shard the request has not tried yet, with linear backoff capped by the
/// remaining deadline budget; optionally a hedged second attempt when the
/// primary is slow.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts (1 = no retries).
    pub max_attempts: u32,
    /// Base backoff: attempt `i` sleeps `i * backoff` before re-submitting.
    pub backoff: Duration,
    /// Fire a hedged duplicate if the primary has not resolved after this
    /// long. `None` disables hedging.
    pub hedge_after: Option<Duration>,
}

impl RetryPolicy {
    pub fn from_config(cfg: &ResilienceConfig) -> Self {
        Self {
            max_attempts: cfg.retry_max_attempts.max(1),
            backoff: Duration::from_millis(cfg.retry_backoff_ms),
            hedge_after: cfg.hedge_after_ms.map(Duration::from_millis),
        }
    }

    /// The neutral policy: one attempt, no hedge (the PR-4/5 behavior).
    pub fn none() -> Self {
        Self { max_attempts: 1, backoff: Duration::ZERO, hedge_after: None }
    }

    /// Whether this policy can ever need a second submission (drives the
    /// zero-copy fast path: no master image clone when it can't).
    pub fn single_shot(&self) -> bool {
        self.max_attempts <= 1 && self.hedge_after.is_none()
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self::none()
    }
}

/// Caller-side cancellation that stays valid across retry attempts. A
/// plain `RequestHandle::cancel` only reaches the attempt it was created
/// from; when the resilient path re-submits, a racing cancel must both
/// stop the *current* attempt and prevent the *next* one — this token is
/// that per-request flag plus the plumbing to the in-flight attempts.
#[derive(Default)]
pub struct ResilienceToken {
    cancelled: AtomicBool,
    /// Cancel tokens of the attempt(s) currently in flight (primary and,
    /// under hedging, the hedge). Guarded by the same lock `cancel` takes,
    /// so an attempt can never be armed after the flag flipped.
    inflight: Mutex<Vec<CancelToken>>,
}

impl ResilienceToken {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cancel the request: stops every in-flight attempt and makes the
    /// retry loop refuse to launch another. Idempotent, thread-safe.
    pub fn cancel(&self) {
        let inflight = self.inflight.lock().unwrap();
        self.cancelled.store(true, Ordering::Release);
        for t in inflight.iter() {
            t.cancel();
        }
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Register an in-flight attempt. Returns `false` (after cancelling
    /// the attempt) when the token was already cancelled — the flag and
    /// the registration are checked under one lock, so a cancel can never
    /// slip between them.
    pub(crate) fn arm(&self, token: CancelToken) -> bool {
        let mut inflight = self.inflight.lock().unwrap();
        if self.is_cancelled() {
            token.cancel();
            false
        } else {
            inflight.push(token);
            true
        }
    }

    /// Drop the registered attempts (called once an attempt resolved).
    pub(crate) fn disarm(&self) {
        self.inflight.lock().unwrap().clear();
    }
}

/// Outcome window length for the deadline-miss-rate signal.
const BROWNOUT_WINDOW: usize = 32;
/// Minimum outcomes before the miss-rate signal engages (early requests
/// should not trip a brownout off one unlucky miss).
const BROWNOUT_MIN_SAMPLES: usize = 8;

/// The load-shedding controller: watches fleet queue depth and the recent
/// deadline-miss rate, and answers "how much should we shed right now?"
/// as a level — 0 (nothing), 1 (cap `top_k`), 2 (also reduce the scale
/// set and downgrade cascades to proposals-only). Levels engage at the
/// configured thresholds and 2× them, so pressure has to double again to
/// escalate.
pub struct BrownoutController {
    queue_depth_threshold: usize,
    miss_rate_threshold: f64,
    outcomes: Mutex<VecDeque<bool>>,
}

impl BrownoutController {
    pub fn new(cfg: &ResilienceConfig) -> Self {
        Self {
            queue_depth_threshold: cfg.brownout_queue_depth.max(1),
            miss_rate_threshold: cfg.brownout_miss_rate.max(f64::MIN_POSITIVE),
            outcomes: Mutex::new(VecDeque::with_capacity(BROWNOUT_WINDOW)),
        }
    }

    /// Record one served-request outcome (`miss` = deadline miss).
    pub fn record(&self, miss: bool) {
        let mut w = self.outcomes.lock().unwrap();
        w.push_back(miss);
        if w.len() > BROWNOUT_WINDOW {
            w.pop_front();
        }
    }

    /// Deadline-miss rate over the recent window (0.0 until enough
    /// samples accumulate).
    pub fn miss_rate(&self) -> f64 {
        let w = self.outcomes.lock().unwrap();
        if w.len() < BROWNOUT_MIN_SAMPLES {
            return 0.0;
        }
        w.iter().filter(|&&m| m).count() as f64 / w.len() as f64
    }

    /// Current shedding level given the fleet's queued scale tasks.
    pub fn level(&self, fleet_queue_depth: usize) -> u8 {
        let queue_pressure = fleet_queue_depth as f64 / self.queue_depth_threshold as f64;
        let miss_pressure = self.miss_rate() / self.miss_rate_threshold;
        let pressure = queue_pressure.max(miss_pressure);
        if pressure >= 2.0 {
            2
        } else if pressure >= 1.0 {
            1
        } else {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ResilienceConfig {
        ResilienceConfig {
            brownout_queue_depth: 10,
            brownout_miss_rate: 0.25,
            ..Default::default()
        }
    }

    #[test]
    fn policy_from_config_and_single_shot() {
        let p = RetryPolicy::from_config(&ResilienceConfig::default());
        assert_eq!(p, RetryPolicy::none());
        assert!(p.single_shot());
        let p = RetryPolicy::from_config(&ResilienceConfig {
            retry_max_attempts: 3,
            retry_backoff_ms: 5,
            hedge_after_ms: Some(20),
            ..Default::default()
        });
        assert_eq!(p.max_attempts, 3);
        assert_eq!(p.backoff, Duration::from_millis(5));
        assert_eq!(p.hedge_after, Some(Duration::from_millis(20)));
        assert!(!p.single_shot());
        // hedging alone also needs the master copy
        assert!(!RetryPolicy { hedge_after: Some(Duration::ZERO), ..RetryPolicy::none() }
            .single_shot());
    }

    #[test]
    fn token_cancel_blocks_future_arms_and_stops_inflight() {
        let t = ResilienceToken::new();
        assert!(!t.is_cancelled());
        t.cancel();
        t.cancel(); // idempotent
        assert!(t.is_cancelled());
    }

    #[test]
    fn queue_pressure_escalates_levels() {
        let b = BrownoutController::new(&cfg());
        assert_eq!(b.level(0), 0);
        assert_eq!(b.level(9), 0);
        assert_eq!(b.level(10), 1, "at threshold: level 1");
        assert_eq!(b.level(19), 1);
        assert_eq!(b.level(20), 2, "at 2x threshold: level 2");
    }

    #[test]
    fn miss_rate_needs_samples_then_escalates() {
        let b = BrownoutController::new(&cfg());
        for _ in 0..BROWNOUT_MIN_SAMPLES - 1 {
            b.record(true);
        }
        assert_eq!(b.miss_rate(), 0.0, "too few samples to judge");
        assert_eq!(b.level(0), 0);
        b.record(true);
        assert_eq!(b.miss_rate(), 1.0);
        assert_eq!(b.level(0), 2, "a fully-missing window is 4x the 0.25 threshold");
        // successes wash the window back down
        for _ in 0..BROWNOUT_WINDOW {
            b.record(false);
        }
        assert_eq!(b.miss_rate(), 0.0);
        assert_eq!(b.level(0), 0);
    }
}
