//! Proposal-quality metrics: IoU, detection rate (DR) and mean average best
//! overlap (MABO), plus the #WIN sweeps that regenerate Fig. 5.

use crate::bing::BBox;
use crate::data::GtBox;

/// Intersection-over-union of two proposal boxes.
pub fn iou(a: &BBox, b: &BBox) -> f32 {
    iou_u32((a.x0, a.y0, a.x1, a.y1), (b.x0, b.y0, b.x1, b.y1))
}

/// IoU on raw inclusive coordinates (shared by GtBox/BBox call sites).
pub fn iou_u32(a: (u32, u32, u32, u32), b: (u32, u32, u32, u32)) -> f32 {
    let ix0 = a.0.max(b.0);
    let iy0 = a.1.max(b.1);
    let ix1 = a.2.min(b.2);
    let iy1 = a.3.min(b.3);
    if ix1 < ix0 || iy1 < iy0 {
        return 0.0;
    }
    let inter = (ix1 - ix0 + 1) as u64 * (iy1 - iy0 + 1) as u64;
    let area_a = (a.2 - a.0 + 1) as u64 * (a.3 - a.1 + 1) as u64;
    let area_b = (b.2 - b.0 + 1) as u64 * (b.3 - b.1 + 1) as u64;
    let union = area_a + area_b - inter;
    inter as f32 / union as f32
}

fn gt_tuple(g: &GtBox) -> (u32, u32, u32, u32) {
    (g.x0, g.y0, g.x1, g.y1)
}

fn bb_tuple(b: &BBox) -> (u32, u32, u32, u32) {
    (b.x0, b.y0, b.x1, b.y1)
}

/// Per-image evaluation input: ranked proposals + ground truth.
pub struct ImageEval<'a> {
    pub proposals: &'a [BBox],
    pub gt: &'a [GtBox],
}

/// Detection rate at `n_win` proposals: fraction of GT boxes matched by at
/// least one of the first `n_win` proposals with IoU ≥ `thresh`
/// (paper's "DR v.s. #WIN", default threshold 0.4 per §4.2).
pub fn detection_rate(images: &[ImageEval<'_>], n_win: usize, thresh: f32) -> f64 {
    let mut total = 0usize;
    let mut hit = 0usize;
    for img in images {
        let head = &img.proposals[..n_win.min(img.proposals.len())];
        for gt in img.gt {
            total += 1;
            if head
                .iter()
                .any(|p| iou_u32(bb_tuple(p), gt_tuple(gt)) >= thresh)
            {
                hit += 1;
            }
        }
    }
    if total == 0 {
        return 0.0;
    }
    hit as f64 / total as f64
}

/// Mean Average Best Overlap at `n_win` proposals: for each GT box take the
/// best IoU among the first `n_win` proposals; average per image, then across
/// images ("MABO v.s. #WIN").
pub fn mabo(images: &[ImageEval<'_>], n_win: usize) -> f64 {
    let mut per_image = Vec::with_capacity(images.len());
    for img in images {
        if img.gt.is_empty() {
            continue;
        }
        let head = &img.proposals[..n_win.min(img.proposals.len())];
        let mut sum = 0f64;
        for gt in img.gt {
            let best = head
                .iter()
                .map(|p| iou_u32(bb_tuple(p), gt_tuple(gt)))
                .fold(0f32, f32::max);
            sum += best as f64;
        }
        per_image.push(sum / img.gt.len() as f64);
    }
    if per_image.is_empty() {
        return 0.0;
    }
    per_image.iter().sum::<f64>() / per_image.len() as f64
}

/// A (#WIN, value) curve — one series of Fig. 5.
#[derive(Debug, Clone, PartialEq)]
pub struct Curve {
    pub n_win: Vec<usize>,
    pub value: Vec<f64>,
}

/// Sweep DR over #WIN (Fig. 5 left panel).
pub fn dr_curve(images: &[ImageEval<'_>], n_wins: &[usize], thresh: f32) -> Curve {
    Curve {
        n_win: n_wins.to_vec(),
        value: n_wins
            .iter()
            .map(|&n| detection_rate(images, n, thresh))
            .collect(),
    }
}

/// Sweep MABO over #WIN (Fig. 5 right panel).
pub fn mabo_curve(images: &[ImageEval<'_>], n_wins: &[usize]) -> Curve {
    Curve {
        n_win: n_wins.to_vec(),
        value: n_wins.iter().map(|&n| mabo(images, n)).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bb(x0: u32, y0: u32, x1: u32, y1: u32) -> BBox {
        BBox { x0, y0, x1, y1 }
    }

    fn gt(x0: u32, y0: u32, x1: u32, y1: u32) -> GtBox {
        GtBox::new(x0, y0, x1, y1)
    }

    #[test]
    fn iou_identical_is_one() {
        assert_eq!(iou(&bb(2, 3, 11, 12), &bb(2, 3, 11, 12)), 1.0);
    }

    #[test]
    fn iou_disjoint_is_zero() {
        assert_eq!(iou(&bb(0, 0, 4, 4), &bb(10, 10, 14, 14)), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        // 10x10 boxes sharing a 5x10 strip: inter 50, union 150
        let v = iou(&bb(0, 0, 9, 9), &bb(5, 0, 14, 9));
        assert!((v - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn iou_single_pixel_cases() {
        assert_eq!(iou(&bb(3, 3, 3, 3), &bb(3, 3, 3, 3)), 1.0);
        assert_eq!(iou(&bb(3, 3, 3, 3), &bb(4, 3, 4, 3)), 0.0);
    }

    #[test]
    fn dr_counts_first_n_only() {
        let proposals = vec![bb(100, 100, 120, 120), bb(0, 0, 9, 9)];
        let gts = vec![gt(0, 0, 9, 9)];
        let images = [ImageEval { proposals: &proposals, gt: &gts }];
        assert_eq!(detection_rate(&images, 1, 0.5), 0.0); // only the miss
        assert_eq!(detection_rate(&images, 2, 0.5), 1.0);
    }

    #[test]
    fn mabo_takes_best_overlap() {
        let proposals = vec![bb(0, 0, 9, 9), bb(0, 0, 19, 19)];
        let gts = vec![gt(0, 0, 19, 19)];
        let images = [ImageEval { proposals: &proposals, gt: &gts }];
        assert!((mabo(&images, 2) - 1.0).abs() < 1e-9);
        // with only the small proposal: IoU = 100/400
        assert!((mabo(&images, 1) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn curves_are_monotone_in_n_win() {
        let proposals = vec![bb(50, 50, 70, 70), bb(0, 0, 9, 9), bb(10, 10, 29, 29)];
        let gts = vec![gt(0, 0, 9, 9), gt(12, 12, 30, 30)];
        let images = [ImageEval { proposals: &proposals, gt: &gts }];
        let dr = dr_curve(&images, &[1, 2, 3], 0.4);
        let mb = mabo_curve(&images, &[1, 2, 3]);
        for i in 1..3 {
            assert!(dr.value[i] >= dr.value[i - 1]);
            assert!(mb.value[i] >= mb.value[i - 1]);
        }
    }

    #[test]
    fn empty_inputs_are_zero() {
        assert_eq!(detection_rate(&[], 10, 0.5), 0.0);
        assert_eq!(mabo(&[], 10), 0.0);
    }
}
