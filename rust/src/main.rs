//! `bingflow` — CLI entrypoint for the coordinator and tools.
//!
//! Subcommands (hand-rolled parser; the environment has no clap):
//!
//! ```text
//! bingflow serve     [--images N] [--backend engine|software|sim]
//!                    [--engine pjrt|mock] [--workers N] [--batch N]
//!                    [--shards N] [--policy rr|least|affinity|session]
//!                    [--deadline-ms D] [--top-k K] [--cascade]
//!                    [--video N] [--sessions S] [--fps F] [--jitter J]
//!                    [--trace-record F] [--trace-replay F]
//!                    [--chaos-seed S] [--corrupt-p P] [--hang-p P]
//!                    [--retry N] [--hedge-ms H] [--brownout]
//!                    [--audit-rate N] [--no-validate]
//!                    [--artifacts DIR] [--config F]
//! bingflow detect    [--input img.ppm | --images N] [--backend ...]
//!                    [--detections K] [--nms T] [--min-confidence C]
//! bingflow propose   --input img.ppm [--top-k K] [--backend ...] [--engine pjrt|mock]
//! bingflow simulate  [--device artix7|kintex] [--pipelines P] [--workload paper|synthetic]
//!                    [--table1] [--summary]
//! bingflow train     [--out FILE] [--train-images N] [--epochs E]
//! bingflow evaluate  [--images N] [--iou T] [--mode exact|binarized|quantized]
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use bingflow::backend::{EngineBackend, ProposalBackend, SimulatedAccelerator};
use bingflow::baseline::{ScoringMode, SoftwareBing};
use bingflow::bing::{Pyramid, Stage1Weights};
use bingflow::config::{Config, Device};
use bingflow::coordinator::{Coordinator, DetectRequest};
use bingflow::serving::ServerRuntime;
use bingflow::data::SyntheticDataset;
use bingflow::dataflow::{power_estimate, resource_estimate, Accelerator, WorkloadGeometry};
use bingflow::fault::{ChaosBackend, FaultPlan};
use bingflow::metrics::{dr_curve, mabo_curve, ImageEval};
#[cfg(feature = "pjrt")]
use bingflow::runtime::PjrtEngine;
use bingflow::runtime::{MockEngine, ScaleExecutor};
use bingflow::simd::{KernelChoice, ScoreKernel};
use bingflow::svm::{train_stage1, train_stage2, CalibSample, Stage2Calibration, WeightBundle};
use bingflow::svm::SvmTrainConfig;
use bingflow::util::rng;

/// Minimal flag parser: `--key value` and `--flag` (boolean) pairs.
struct Args {
    cmd: String,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse() -> Self {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".to_string());
        let mut flags = Vec::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let tok = &rest[i];
            if let Some(name) = tok.strip_prefix("--") {
                let value = if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    i += 1;
                    Some(rest[i].clone())
                } else {
                    None
                };
                flags.push((name.to_string(), value));
            } else {
                eprintln!("warning: ignoring stray argument `{tok}`");
            }
            i += 1;
        }
        Self { cmd, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }
}

fn load_config(args: &Args) -> Config {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(&PathBuf::from(path)).unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        }),
        None => Config::new(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    cfg.serving.workers = args.get_parse("workers", cfg.serving.workers);
    cfg.serving.max_batch = args.get_parse("batch", cfg.serving.max_batch);
    cfg.serving.top_k = args.get_parse("top-k", cfg.serving.top_k);
    cfg.serving.shards = args.get_parse("shards", cfg.serving.shards);
    if let Some(p) = args.get("policy") {
        cfg.serving.policy = p.parse().unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });
    }
    if let Some(ms) = args.get("deadline-ms") {
        let ms: u64 = ms.parse().unwrap_or_else(|_| {
            eprintln!("error: --deadline-ms expects an integer, got `{ms}`");
            std::process::exit(2);
        });
        // 0 disables the deadline, matching `serving.deadline_ms = 0`
        cfg.serving.deadline_ms = (ms > 0).then_some(ms);
    }
    if let Some(n) = args.get("retry") {
        let retries: u32 = n.parse().unwrap_or_else(|_| {
            eprintln!("error: --retry expects an integer retry count, got `{n}`");
            std::process::exit(2);
        });
        // --retry N means N retries on top of the first attempt
        cfg.serving.resilience.retry_max_attempts = retries + 1;
    }
    if let Some(ms) = args.get("hedge-ms") {
        let ms: u64 = ms.parse().unwrap_or_else(|_| {
            eprintln!("error: --hedge-ms expects an integer, got `{ms}`");
            std::process::exit(2);
        });
        cfg.serving.resilience.hedge_after_ms = (ms > 0).then_some(ms);
    }
    if args.has("brownout") {
        cfg.serving.resilience.brownout = true;
    }
    if let Some(seed) = args.get("chaos-seed") {
        let seed: u64 = seed.parse().unwrap_or_else(|_| {
            eprintln!("error: --chaos-seed expects an integer, got `{seed}`");
            std::process::exit(2);
        });
        cfg.serving.resilience.chaos_seed = Some(seed);
    }
    if let Some(p) = args.get("corrupt-p") {
        cfg.serving.resilience.chaos_corrupt_p = p.parse().unwrap_or_else(|_| {
            eprintln!("error: --corrupt-p expects a probability in [0,1], got `{p}`");
            std::process::exit(2);
        });
    }
    if let Some(p) = args.get("hang-p") {
        cfg.serving.resilience.chaos_hang_p = p.parse().unwrap_or_else(|_| {
            eprintln!("error: --hang-p expects a probability in [0,1], got `{p}`");
            std::process::exit(2);
        });
    }
    if let Some(r) = args.get("audit-rate") {
        cfg.serving.integrity.audit_rate = r.parse().unwrap_or_else(|_| {
            eprintln!("error: --audit-rate expects an integer (audit 1-in-N), got `{r}`");
            std::process::exit(2);
        });
    }
    // structural validation defaults on; --no-validate opts out (--validate
    // accepted for explicitness/symmetry)
    if args.has("validate") {
        cfg.serving.integrity.validate = true;
    }
    if args.has("no-validate") {
        cfg.serving.integrity.validate = false;
    }
    if let Some(d) = args.get("device") {
        cfg.accel.device = match d {
            "artix7" => Device::Artix7LowVolt,
            "kintex" => Device::KintexUltraScalePlus,
            other => {
                eprintln!("error: unknown device `{other}`");
                std::process::exit(2);
            }
        };
    }
    cfg.accel.pipelines = args.get_parse("pipelines", cfg.accel.pipelines);
    if let Some(k) = args.get("kernel") {
        cfg.kernel = k.parse().unwrap_or_else(|e| {
            eprintln!("error: {e}");
            std::process::exit(2);
        });
    }
    if args.has("no-pin") {
        cfg.pool_pin = false;
    }
    // Must precede the first pool use: pinning is decided at worker spawn.
    bingflow::util::pool::set_pinning(cfg.pool_pin);
    cfg
}

/// Build the engine selected by `--engine`. The default is the backend the
/// binary was compiled for: `pjrt` with the feature enabled, `mock` (the
/// bit-identical pure-rust twin) otherwise.
fn make_engine(args: &Args, cfg: &Config, weights: &Stage1Weights) -> Arc<dyn ScaleExecutor> {
    let default_engine = if cfg!(feature = "pjrt") { "pjrt" } else { "mock" };
    let choice = args.get("engine").unwrap_or(default_engine);
    match choice {
        "mock" => Arc::new(MockEngine::new(weights.clone(), cfg.sizes.clone())),
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let dir = PathBuf::from(&cfg.artifacts_dir);
            match PjrtEngine::from_dir(&dir, &cfg.sizes) {
                Ok(engine) => {
                    eprintln!("[runtime] PJRT platform: {}", engine.platform());
                    Arc::new(engine)
                }
                Err(e) => {
                    eprintln!(
                        "error: cannot load PJRT artifacts from {}: {e:#}\n\
                         hint: run `make artifacts`, or pass `--engine mock`; if the \
                         error mentions the xla stub, swap `rust/xla-stub` for the \
                         real xla-rs crate in rust/Cargo.toml",
                        dir.display()
                    );
                    std::process::exit(2);
                }
            }
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            eprintln!(
                "error: this binary was built without the `pjrt` feature\n\
                 hint: rebuild with `cargo build --features pjrt` or pass `--engine mock`"
            );
            std::process::exit(2);
        }
        other => {
            eprintln!("error: unknown engine `{other}`");
            std::process::exit(2);
        }
    }
}

fn load_bundle(cfg: &Config) -> WeightBundle {
    let path = PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json");
    WeightBundle::load(&path).unwrap_or_else(|| WeightBundle::default_for(&cfg.sizes))
}

/// Build the `--backend` selected [`ProposalBackend`] (EXPERIMENTS.md
/// §Backends). All three produce bit-identical proposals; they differ in
/// what they measure (wall-clock vs engine latency vs simulated cycles).
fn make_backend(args: &Args, cfg: &Config, bundle: &WeightBundle) -> Arc<dyn ProposalBackend> {
    let pyramid = Pyramid::new(cfg.sizes.clone());
    match args.get("backend").unwrap_or("engine") {
        "engine" => Arc::new(EngineBackend::new(
            make_engine(args, cfg, &bundle.stage1),
            pyramid,
        )),
        "software" => {
            // Exact scoring preserves bit-parity with the engine/sim
            // backends; `--mode binarized` opts into BING's approximate
            // CPU fast path, where the `--kernel` selection takes effect.
            let mode = match args.get("mode").unwrap_or("exact") {
                "binarized" => ScoringMode::Binarized { nw: 3, ng: 6 },
                _ => ScoringMode::Exact,
            };
            let sw = SoftwareBing::new(
                pyramid,
                bundle.stage1.clone(),
                bundle.stage2.clone(),
                mode,
            )
            .with_kernel(cfg.kernel);
            if matches!(mode, ScoringMode::Binarized { .. }) {
                eprintln!("[backend] software binarized scoring, kernel `{}`", sw.kernel);
            }
            Arc::new(sw)
        }
        "sim" => Arc::new(SimulatedAccelerator::new(
            cfg.accel.clone(),
            pyramid,
            bundle.stage1.clone(),
        )),
        other => {
            eprintln!("error: unknown backend `{other}` (expected engine|software|sim)");
            std::process::exit(2);
        }
    }
}

fn main() {
    let args = Args::parse();
    match args.cmd.as_str() {
        "serve" => cmd_serve(&args),
        "detect" => cmd_detect(&args),
        "propose" => cmd_propose(&args),
        "simulate" => cmd_simulate(&args),
        "train" => cmd_train(&args),
        "evaluate" => cmd_evaluate(&args),
        "help" | "--help" | "-h" => print_help(),
        other => {
            eprintln!("error: unknown command `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!(
        "bingflow — pipelined dataflow region-proposal system\n\n\
         USAGE: bingflow <serve|propose|simulate|train|evaluate> [flags]\n\n\
         serve     run the sharded serving runtime over synthetic requests and\n\
                   report latency/throughput   (--images N --shards N\n\
                   --policy rr|least|affinity|session --deadline-ms D\n\
                   --backend engine|software|sim --engine pjrt|mock\n\
                   --workers N --batch N --top-k K --cascade --artifacts DIR\n\
                   --video N --sessions S --fps F --jitter J\n\
                   --trace-record F --trace-replay F\n\
                   --chaos-seed S --corrupt-p P --hang-p P\n\
                   --retry N --hedge-ms H --brownout\n\
                   --audit-rate N --no-validate\n\
                   --kernel auto|swar|avx2|neon --mode exact|binarized --no-pin)\n\
         detect    end-to-end detections (proposals -> stage-II SVM -> NMS ->\n\
                   Platt confidence) through the serving runtime\n\
                   (--input FILE.ppm | --images N; --detections K --nms T\n\
                   --min-confidence C --backend engine|software|sim)\n\
         propose   proposals for one PPM image (--input FILE --top-k K\n\
                   --backend engine|software|sim --mode exact|binarized\n\
                   --kernel auto|swar|avx2|neon)\n\
         simulate  cycle-level accelerator simulation (--device artix7|kintex\n\
                   --pipelines P --workload paper|synthetic --table1 --summary)\n\
         train     train SVM stage-I/II on the synthetic train split\n\
                   (--out FILE --train-images N --epochs E)\n\
         evaluate  DR / MABO curves on the synthetic val split\n\
                   (--images N --iou T --mode exact|binarized)"
    );
}

fn cmd_serve(args: &Args) {
    let cfg = load_config(args);
    let bundle = load_bundle(&cfg);
    let backend = make_backend(args, &cfg, &bundle);
    let backend_name = backend.name();
    // --chaos-seed wraps the backend in the deterministic fault injector;
    // the resilient serve path (--retry/--hedge-ms/--brownout) then has
    // real faults to absorb
    let chaos = cfg.serving.resilience.chaos_seed.map(|seed| {
        Arc::new(ChaosBackend::new(
            backend.clone(),
            FaultPlan::from_config(seed, &cfg.serving.resilience),
        ))
    });
    let mut runtime: ServerRuntime = match &chaos {
        Some(c) => ServerRuntime::new(
            c.clone() as Arc<dyn ProposalBackend>,
            bundle.stage2.clone(),
            cfg.serving.clone(),
        ),
        None => ServerRuntime::new(backend, bundle.stage2.clone(), cfg.serving.clone()),
    };
    // --audit-rate N samples 1-in-N served requests through a fault-free
    // scalar oracle (golden probe); mismatches implicate the production
    // kernel and can latch the fleet-wide SWAR demotion
    if cfg.serving.integrity.audit_rate > 0 {
        let oracle = Arc::new(
            SoftwareBing::new(
                Pyramid::new(cfg.sizes.clone()),
                bundle.stage1.clone(),
                bundle.stage2.clone(),
                ScoringMode::Exact,
            )
            .with_kernel(KernelChoice::Fixed(ScoreKernel::Reference)),
        );
        runtime.install_auditor(oracle, cfg.kernel.resolve());
    }
    let runtime = runtime;

    // --video / --trace-replay switch serve into the open-loop video path:
    // per-session frame streams with temporal coherence, routed (under
    // `--policy session`) so each session's frame cache stays warm.
    if args.has("video") || args.has("trace-replay") {
        cmd_serve_video(args, &runtime);
        println!("metrics           {}", runtime.summary());
        println!("backpressure      {} queue-full events", runtime.queue_full_events());
        runtime.shutdown();
        return;
    }

    let n_images = args.get_parse("images", 16usize);
    let cascade = args.has("cascade");
    let ds = SyntheticDataset::voc_like_val(n_images);
    let images: Vec<_> = ds.iter().map(|s| s.image).collect();
    eprintln!(
        "[serve] {n_images} images, {} shards x {} workers, policy `{}`, backend \
         `{backend_name}`{}{}",
        runtime.shards(),
        cfg.serving.workers,
        runtime.policy_name(),
        if cascade { ", full cascade" } else { "" },
        match cfg.serving.resilience.chaos_seed {
            Some(seed) => format!(
                ", chaos seed {seed} (retry budget {})",
                cfg.serving.resilience.retry_max_attempts - 1
            ),
            None => String::new(),
        },
    );

    let t0 = std::time::Instant::now();
    let (n_ok, n_failed, first_line) = if cascade {
        let results = runtime.detect_batch(images);
        let ok: Vec<_> = results.iter().filter_map(|r| r.as_ref().ok()).collect();
        let line = ok.first().map(|r| {
            let top = r.items.first().map(|d| d.confidence).unwrap_or(0.0);
            format!("detections/image  {} (top confidence {top:.3})", r.items.len())
        });
        (ok.len(), results.len() - ok.len(), line)
    } else {
        let results = runtime.serve_batch(images);
        let ok: Vec<_> = results.iter().filter_map(|r| r.as_ref().ok()).collect();
        let line = ok.first().map(|r| format!("proposals/image   {}", r.items.len()));
        (ok.len(), results.len() - ok.len(), line)
    };
    let wall = t0.elapsed();

    let fps = n_ok as f64 / wall.as_secs_f64();
    println!("images            {n_images} ({n_ok} ok, {n_failed} failed)");
    println!("wall time         {:.3} s", wall.as_secs_f64());
    println!("throughput        {fps:.1} images/s");
    if let Some(line) = first_line {
        println!("{line}");
    }
    println!("metrics           {}", runtime.summary());
    println!("backpressure      {} queue-full events", runtime.queue_full_events());
    if let Some(c) = &chaos {
        println!(
            "chaos             {} faults injected ({} panics, {} transients, {} latencies, \
             {} corrupts, {} hangs)",
            c.injected_total(),
            c.injected_panics.get(),
            c.injected_transients.get(),
            c.injected_latencies.get(),
            c.injected_corrupts.get(),
            c.injected_hangs.get()
        );
    }
    runtime.shutdown();
}

/// The `serve --video` path: replay a frame-arrival trace open-loop
/// through the runtime. Arrivals come from `--trace-replay FILE` when
/// given; otherwise they are synthesized (Poisson arrivals per session)
/// and can be persisted with `--trace-record FILE` for a byte-identical
/// re-run. Open-loop means the wall clock, not the server, paces
/// submissions: a slow server accumulates in-flight frames instead of
/// slowing the arrival process, so tail latencies reflect genuine
/// overload rather than coordinated omission.
fn cmd_serve_video(args: &Args, runtime: &ServerRuntime) {
    use bingflow::coordinator::ProposalRequest;
    use bingflow::data::{SceneConfig, SyntheticVideo};
    use bingflow::temporal::trace::{self, TraceEvent};

    let events: Vec<TraceEvent> = match args.get("trace-replay") {
        Some(path) => trace::load(&PathBuf::from(path)).unwrap_or_else(|e| {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }),
        None => {
            let frames = args.get_parse("video", 32usize).max(1);
            let sessions = args.get_parse("sessions", 2usize).max(1) as u64;
            let fps = args.get_parse("fps", 30.0f64);
            let mut events = Vec::with_capacity(frames * sessions as usize);
            for s in 0..sessions {
                let offsets = trace::arrival_offsets_poisson(frames, fps, 0xC0FF_EE00 ^ s);
                for (f, &at_ms) in offsets.iter().enumerate() {
                    events.push(TraceEvent {
                        at_ms,
                        session: s,
                        seed: 9000 + s,
                        frame: f as u64,
                        width: 192,
                        height: 192,
                    });
                }
            }
            events.sort_by(|a, b| a.at_ms.total_cmp(&b.at_ms));
            events
        }
    };
    if let Some(path) = args.get("trace-record") {
        trace::save(&PathBuf::from(path), &events).unwrap_or_else(|e| {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        });
        eprintln!("[serve] recorded {} trace events to {path}", events.len());
    }
    let jitter = args.get_parse("jitter", 2u32);
    let n_sessions = {
        let mut ids: Vec<u64> = events.iter().map(|e| e.session).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    };
    eprintln!(
        "[serve] open-loop video replay: {} frames across {n_sessions} session(s), \
         jitter {jitter}px",
        events.len(),
    );

    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(events.len());
    for ev in &events {
        let target = t0 + std::time::Duration::from_secs_f64(ev.at_ms.max(0.0) / 1000.0);
        let now = std::time::Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        let clip = SyntheticVideo::new(
            SceneConfig { width: ev.width, height: ev.height, ..Default::default() },
            ev.seed,
            jitter,
        );
        let frame = clip.frame(ev.frame);
        handles.push(runtime.submit_request(ProposalRequest::new(frame).session(ev.session)).ok());
    }
    let mut failed = handles.iter().filter(|h| h.is_none()).count();
    let mut latencies: Vec<f64> = Vec::with_capacity(handles.len());
    for h in handles.into_iter().flatten() {
        match h.wait() {
            Ok(resp) => latencies.push(resp.latency.as_secs_f64() * 1e3),
            Err(_) => failed += 1,
        }
    }
    let wall = t0.elapsed();
    latencies.sort_by(f64::total_cmp);
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        latencies[((latencies.len() - 1) as f64 * p) as usize]
    };
    println!("frames            {} ({} ok, {failed} failed)", events.len(), latencies.len());
    println!("wall time         {:.3} s", wall.as_secs_f64());
    println!("throughput        {:.1} frames/s", latencies.len() as f64 / wall.as_secs_f64());
    println!("latency p50/p99   {:.2} / {:.2} ms", pct(0.50), pct(0.99));
}

/// End-to-end detections through the serving runtime: one request in,
/// calibrated (box, score, confidence) triples out. Reads a PPM when
/// `--input` is given, otherwise serves `--images N` synthetic frames.
fn cmd_detect(args: &Args) {
    let cfg = load_config(args);
    let bundle = load_bundle(&cfg);
    let backend = make_backend(args, &cfg, &bundle);
    let backend_name = backend.name();
    let runtime: ServerRuntime =
        ServerRuntime::new(backend, bundle.stage2, cfg.serving.clone());

    let images: Vec<bingflow::image::ImageRgb> = match args.get("input") {
        Some(input) => {
            let img = bingflow::image::read_ppm(&PathBuf::from(input)).unwrap_or_else(|e| {
                eprintln!("error: {e}");
                std::process::exit(2);
            });
            vec![img]
        }
        None => {
            let n = args.get_parse("images", 4usize);
            SyntheticDataset::voc_like_val(n).iter().map(|s| s.image).collect()
        }
    };
    eprintln!("[detect] {} image(s), backend `{backend_name}`", images.len());

    let make_request = |img: bingflow::image::ImageRgb| {
        let mut req = DetectRequest::new(img);
        if let Some(k) = args.get("detections") {
            req = req.top_k(k.parse().unwrap_or_else(|_| {
                eprintln!("error: --detections expects an integer, got `{k}`");
                std::process::exit(2);
            }));
        }
        if let Some(t) = args.get("nms") {
            req = req.nms_thresh(t.parse().unwrap_or_else(|_| {
                eprintln!("error: --nms expects a float in [0,1], got `{t}`");
                std::process::exit(2);
            }));
        }
        if let Some(c) = args.get("min-confidence") {
            req = req.min_confidence(c.parse().unwrap_or_else(|_| {
                eprintln!("error: --min-confidence expects a float, got `{c}`");
                std::process::exit(2);
            }));
        }
        req
    };

    let top_show = args.get_parse("show", 10usize);
    for (i, img) in images.into_iter().enumerate() {
        let resp = runtime
            .submit_detect(make_request(img))
            .unwrap_or_else(|e| {
                eprintln!("error: submission refused: {e}");
                std::process::exit(2);
            })
            .wait()
            .unwrap_or_else(|e| {
                eprintln!("error: serving failed: {e}");
                std::process::exit(2);
            });
        println!(
            "image {i}: {} detections in {:.2} ms (showing {})",
            resp.items.len(),
            resp.latency.as_secs_f64() * 1e3,
            top_show.min(resp.items.len())
        );
        for d in resp.items.iter().take(top_show) {
            println!(
                "  [{:4},{:4},{:4},{:4}]  score {:>8.1}  confidence {:.3}",
                d.bbox.x0, d.bbox.y0, d.bbox.x1, d.bbox.y1, d.score, d.confidence
            );
        }
    }
    println!("metrics: {}", runtime.summary());
    runtime.shutdown();
}

fn cmd_propose(args: &Args) {
    let cfg = load_config(args);
    let bundle = load_bundle(&cfg);
    let input = args.get("input").unwrap_or_else(|| {
        eprintln!("error: --input FILE.ppm required");
        std::process::exit(2);
    });
    let img = bingflow::image::read_ppm(&PathBuf::from(input)).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let backend = make_backend(args, &cfg, &bundle);
    let coord: Coordinator =
        Coordinator::with_backend(backend, bundle.stage2, cfg.serving.clone());
    let resp = coord
        .submit(img)
        .unwrap_or_else(|e| {
            eprintln!("error: submission refused: {e}");
            std::process::exit(2);
        })
        .wait()
        .unwrap_or_else(|e| {
            eprintln!("error: serving failed: {e}");
            std::process::exit(2);
        });
    let top_show = args.get_parse("show", 10usize);
    println!("proposals: {} (showing {top_show})", resp.items.len());
    for p in resp.items.iter().take(top_show) {
        println!(
            "  [{:4},{:4},{:4},{:4}]  score {:.1}",
            p.bbox.x0, p.bbox.y0, p.bbox.x1, p.bbox.y1, p.score
        );
    }
    println!("latency: {:.2} ms", resp.latency.as_secs_f64() * 1e3);
    coord.shutdown();
}

fn cmd_simulate(args: &Args) {
    let cfg = load_config(args);
    let workload = args.get("workload").unwrap_or("synthetic");
    let (pyramid, geometry, img) = match workload {
        "paper" => {
            // BING's pyramid on a VOC-sized frame
            let ladder = [10usize, 20, 40, 80, 160, 320];
            let sizes: Vec<_> = ladder
                .iter()
                .flat_map(|&h| ladder.iter().map(move |&w| (h, w)))
                .collect();
            let ds = SyntheticDataset::new(
                bingflow::data::SceneConfig { width: 500, height: 375, ..Default::default() },
                2007,
                1,
            );
            (Pyramid::new(sizes), WorkloadGeometry::paper(), ds.sample(0).image)
        }
        _ => (
            Pyramid::new(cfg.sizes.clone()),
            WorkloadGeometry::synthetic(),
            SyntheticDataset::voc_like_val(1).sample(0).image,
        ),
    };

    if args.has("table1") {
        for device in [Device::Artix7LowVolt, Device::KintexUltraScalePlus] {
            let mut acfg = cfg.accel.clone();
            acfg.device = device;
            acfg.heap_capacity = 1000;
            let est = resource_estimate(&acfg, &geometry);
            println!("## {}", device.name());
            println!("  LUT      {:>7}", est.lut);
            println!("  LUT-RAM  {:>7}", est.lutram);
            println!("  FF       {:>7}", est.ff);
            println!("  BRAM     {:>7}", est.bram36);
            println!("  DSP      {:>7}", est.dsp);
            println!("  BUF-G    {:>7}", est.bufg);
        }
        return;
    }

    let bundle = load_bundle(&cfg);
    let accel = Accelerator::new(cfg.accel.clone(), pyramid, bundle.stage1);
    let t0 = std::time::Instant::now();
    let report = accel.run_image(&img);
    let sim_wall = t0.elapsed();
    let device = cfg.accel.device;
    // fps() is None only for an empty run; run_image always steps ≥1 cycle
    let fps = report.fps(device.clock_hz()).expect("simulation ran cycles");
    let power = power_estimate(device, report.activity);

    println!("device            {}", device.name());
    println!("workload          {workload} ({} scales)", report.per_scale.len());
    println!("pipelines         {}", cfg.accel.pipelines);
    println!("total cycles      {}", report.total_cycles);
    println!("fps @ clock       {fps:.1}");
    println!("activity          {:.3}", report.activity);
    println!(
        "power             {:.0} mW total ({:.0} mW dynamic)",
        power.total_mw(),
        power.dynamic_mw
    );
    println!("candidates        {}", report.candidates.len());
    println!(
        "sim speed         {:.1} Mcycles/s",
        report.total_cycles as f64 / sim_wall.as_secs_f64() / 1e6
    );
    if args.has("summary") {
        // paper §4.2 headline claims
        let i7_fps = 300.0;
        let arm_fps = 16.0;
        println!("--- paper §4.2 comparison ---");
        println!("speedup vs i7     {:.2}x (paper: 3.67x on Kintex)", fps / i7_fps);
        println!("speedup vs ARM    {:.1}x (paper: 68x on Kintex)", fps / arm_fps);
        let eff = fps / (power.total_mw() / 1000.0);
        let i7_eff = i7_fps / 55.0;
        println!(
            "energy eff        {:.0} fps/W vs i7 {:.1} fps/W → {:.0}x (paper: >220x)",
            eff,
            i7_eff,
            eff / i7_eff
        );
    }
}

fn cmd_train(args: &Args) {
    let cfg = load_config(args);
    let n_train = args.get_parse("train-images", 48usize);
    let epochs = args.get_parse("epochs", 12usize);
    let ds = SyntheticDataset::voc_like_train(n_train);
    eprintln!("[train] stage-I hinge SGD on {n_train} images, {epochs} epochs");
    let scfg = SvmTrainConfig { epochs, ..Default::default() };
    let model = train_stage1(&ds, &scfg);
    let stage1 = Stage1Weights::quantize(&model.w);

    // stage-II: run the stage-I pipeline on the train split, collect
    // (scale, score, hit) calibration samples
    eprintln!("[train] collecting stage-II calibration samples");
    let pyramid = Pyramid::new(cfg.sizes.clone());
    let sw = SoftwareBing::new(
        pyramid.clone(),
        stage1.clone(),
        Stage2Calibration::identity(cfg.sizes.clone()),
        ScoringMode::Exact,
    );
    let mut samples = Vec::new();
    for sample in ds.iter() {
        for c in sw.candidates(&sample.image) {
            let bbox = bingflow::bing::window_to_box(
                c.x,
                c.y,
                pyramid.sizes[c.scale_idx],
                sample.image.w,
                sample.image.h,
            );
            let hit = sample.boxes.iter().any(|gt| {
                bingflow::metrics::iou_u32(
                    (bbox.x0, bbox.y0, bbox.x1, bbox.y1),
                    (gt.x0, gt.y0, gt.x1, gt.y1),
                ) >= 0.5
            });
            samples.push(CalibSample {
                scale_idx: c.scale_idx,
                raw_score: c.score,
                is_object: hit,
            });
        }
    }
    let stage2 = train_stage2(&cfg.sizes, &samples, 11);
    let bundle = WeightBundle { stage1, stage2 };

    let out = args
        .get("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(&cfg.artifacts_dir).join("svm_weights.json"));
    if let Some(parent) = out.parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    bundle.save(&out).expect("writing weights");
    println!("wrote {}", out.display());
    println!("stage-I template:");
    for row in bundle.stage1.w {
        println!("  {row:>4?}");
    }
    println!("note: re-run `make artifacts` to bake the new weights into the HLOs");
}

fn cmd_evaluate(args: &Args) {
    let cfg = load_config(args);
    let bundle = load_bundle(&cfg);
    let n_images = args.get_parse("images", 32usize);
    let iou_thr: f32 = args.get_parse("iou", 0.4f32);
    let mode = match args.get("mode").unwrap_or("exact") {
        "binarized" => ScoringMode::Binarized { nw: 3, ng: 6 },
        _ => ScoringMode::Exact,
    };
    let ds = SyntheticDataset::voc_like_val(n_images);
    let pyramid = Pyramid::new(cfg.sizes.clone());
    let sw =
        SoftwareBing::new(pyramid, bundle.stage1, bundle.stage2, mode).with_kernel(cfg.kernel);

    let mut all_proposals = Vec::new();
    let mut all_gt = Vec::new();
    for sample in ds.iter() {
        let props: Vec<_> = sw
            .propose(&sample.image, cfg.serving.top_k)
            .into_iter()
            .map(|p| p.bbox)
            .collect();
        all_proposals.push(props);
        all_gt.push(sample.boxes);
    }
    let evals: Vec<ImageEval> = all_proposals
        .iter()
        .zip(&all_gt)
        .map(|(p, g)| ImageEval { proposals: p, gt: g })
        .collect();
    let n_wins = [1, 10, 50, 100, 250, 500, 1000, 2000, 4000];
    let dr = dr_curve(&evals, &n_wins, iou_thr);
    let mb = mabo_curve(&evals, &n_wins);
    println!("# images={n_images} iou={iou_thr} mode={mode:?}");
    println!("{:>6}  {:>8}  {:>8}", "#WIN", "DR", "MABO");
    for i in 0..n_wins.len() {
        println!(
            "{:>6}  {:>8.4}  {:>8.4}",
            dr.n_win[i], dr.value[i], mb.value[i]
        );
    }
    // deterministic sanity anchor for EXPERIMENTS.md
    let mut check = rng(0);
    let _ = check.next_u64();
}
