//! Non-maximum suppression.
//!
//! Two NMS flavours live in the system:
//!
//! * the paper's **5×5 block NMS** over the score map — that one is part of
//!   the kernel-computing module and lives in [`crate::bing::winners_from_scores`]
//!   (rust) and `python/compile/kernels/nms_pool.py` (HLO);
//! * the classical **greedy IoU NMS** over boxes, used as the software
//!   baseline's post-processing and by quality ablations — implemented here.

use crate::bing::BBox;
use crate::metrics::iou;

/// Greedy IoU NMS: sort by score desc, keep a box iff its IoU with every
/// already-kept box is `< thresh`. Ties sort by (score desc, y0, x0) so the
/// result is deterministic.
pub fn greedy_nms(boxes: Vec<(BBox, f32)>, thresh: f32) -> Vec<(BBox, f32)> {
    greedy_nms_topk(boxes, thresh, usize::MAX)
}

/// [`greedy_nms`] with an early exit once `top_k` boxes are kept — the
/// detection cascade's hot variant. Greedy keeps are decided in score order
/// and never revised, so the first `top_k` kept boxes of the unbounded run
/// and of this run are identical; stopping early only skips work.
pub fn greedy_nms_topk(
    mut boxes: Vec<(BBox, f32)>,
    thresh: f32,
    top_k: usize,
) -> Vec<(BBox, f32)> {
    assert!((0.0..=1.0).contains(&thresh));
    boxes.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then_with(|| (a.0.y0, a.0.x0).cmp(&(b.0.y0, b.0.x0)))
    });
    let mut kept: Vec<(BBox, f32)> = Vec::with_capacity(boxes.len().min(top_k.min(1024)));
    'outer: for (b, s) in boxes {
        if kept.len() >= top_k {
            break;
        }
        for (k, _) in &kept {
            if iou(&b, k) >= thresh {
                continue 'outer;
            }
        }
        kept.push((b, s));
    }
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bb(x0: u32, y0: u32, x1: u32, y1: u32) -> BBox {
        BBox { x0, y0, x1, y1 }
    }

    #[test]
    fn suppresses_heavy_overlap() {
        let boxes = vec![
            (bb(0, 0, 9, 9), 1.0),
            (bb(1, 1, 10, 10), 0.9), // IoU with first ≈ 0.68 → suppressed
            (bb(50, 50, 59, 59), 0.8),
        ];
        let kept = greedy_nms(boxes, 0.5);
        assert_eq!(kept.len(), 2);
        assert_eq!(kept[0].0, bb(0, 0, 9, 9));
        assert_eq!(kept[1].0, bb(50, 50, 59, 59));
    }

    #[test]
    fn keeps_light_overlap() {
        let boxes = vec![(bb(0, 0, 9, 9), 1.0), (bb(8, 8, 17, 17), 0.9)];
        let kept = greedy_nms(boxes, 0.5);
        assert_eq!(kept.len(), 2);
    }

    #[test]
    fn highest_score_survives() {
        let boxes = vec![(bb(0, 0, 9, 9), 0.3), (bb(0, 0, 9, 9), 0.7)];
        let kept = greedy_nms(boxes, 0.5);
        assert_eq!(kept, vec![(bb(0, 0, 9, 9), 0.7)]);
    }

    #[test]
    fn empty_input_ok() {
        assert!(greedy_nms(Vec::new(), 0.5).is_empty());
    }

    #[test]
    fn topk_is_a_prefix_of_the_unbounded_run() {
        let boxes: Vec<(BBox, f32)> = (0..20)
            .map(|i| {
                let o = (i as u32 % 5) * 7;
                (bb(o, o, o + 9, o + 9), 1.0 - i as f32 * 0.01)
            })
            .collect();
        let full = greedy_nms(boxes.clone(), 0.4);
        for k in 0..=full.len() + 2 {
            assert_eq!(greedy_nms_topk(boxes.clone(), 0.4, k), full[..k.min(full.len())]);
        }
    }

    #[test]
    fn threshold_one_keeps_all_distinct() {
        let boxes = vec![(bb(0, 0, 9, 9), 0.5), (bb(0, 0, 9, 8), 0.4)];
        let kept = greedy_nms(boxes.clone(), 1.0);
        assert_eq!(kept.len(), 2);
    }
}
