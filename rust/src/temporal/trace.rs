//! Request-trace record/replay and open-loop arrival schedules.
//!
//! A trace is a JSONL log (one `util::json` object per line) of the request
//! stream a serving run saw or should see: which session sent which frame
//! of which synthetic video, and *when*. Replaying a trace open-loop —
//! submitting each request at its recorded offset regardless of whether
//! earlier responses came back — is what exposes queueing collapse:
//! a closed-loop driver slows its own arrival rate exactly when the server
//! degrades, hiding the latency the clients would really see (the
//! coordinated-omission trap). `video_bench` and `bingflow serve
//! --trace-replay` both drive from these schedules.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;
use crate::util::rng;

/// One recorded request: frame `frame` of the synthetic video `seed`
/// (`width`×`height`), submitted by `session` at `at_ms` milliseconds after
/// the trace starts.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at_ms: f64,
    pub session: u64,
    /// Seed of the [`crate::data::SyntheticVideo`] this session plays.
    pub seed: u64,
    /// Frame index within the video.
    pub frame: u64,
    pub width: usize,
    pub height: usize,
}

impl TraceEvent {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("at_ms".to_string(), Json::Num(self.at_ms));
        // u64 ids ride in f64 — exact up to 2^53, plenty for seeds/sessions
        m.insert("session".to_string(), Json::Num(self.session as f64));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert("frame".to_string(), Json::Num(self.frame as f64));
        m.insert("width".to_string(), Json::Num(self.width as f64));
        m.insert("height".to_string(), Json::Num(self.height as f64));
        Json::Obj(m)
    }

    fn from_json(j: &Json) -> Result<Self, String> {
        let num = |key: &str| {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("missing numeric field `{key}`"))
        };
        Ok(Self {
            at_ms: num("at_ms")?,
            session: num("session")? as u64,
            seed: num("seed")? as u64,
            frame: num("frame")? as u64,
            width: num("width")? as usize,
            height: num("height")? as usize,
        })
    }
}

/// Write `events` as JSONL.
pub fn save(path: &Path, events: &[TraceEvent]) -> Result<()> {
    let mut out = String::with_capacity(events.len() * 96);
    for e in events {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    std::fs::write(path, out).with_context(|| format!("writing trace {}", path.display()))
}

/// Read a JSONL trace; blank lines are skipped, anything else must parse.
pub fn load(path: &Path) -> Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow!("trace line {}: {e}", i + 1))?;
        events.push(
            TraceEvent::from_json(&j).map_err(|e| anyhow!("trace line {}: {e}", i + 1))?,
        );
    }
    Ok(events)
}

/// `n` Poisson-process arrival offsets (milliseconds from start) at mean
/// rate `rate_hz`: i.i.d. exponential inter-arrival gaps via inverse-CDF
/// sampling. Deterministic in `seed`.
pub fn arrival_offsets_poisson(n: usize, rate_hz: f64, seed: u64) -> Vec<f64> {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    let mut r = rng(seed);
    let mut t = 0.0f64;
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        // u ∈ [0,1): ln(1-u) is finite
        t += -(1.0 - r.f64()).ln() / rate_hz * 1000.0;
        v.push(t);
    }
    v
}

/// `n` bursty arrival offsets at the same mean rate as the Poisson
/// schedule: arrivals land in back-to-back groups of `burst` (identical
/// offsets), with exponential gaps between groups stretched by `burst` so
/// the long-run rate stays `rate_hz`. This is the worst case for the
/// bounded router queues — each burst must be absorbed at once.
pub fn arrival_offsets_bursty(n: usize, rate_hz: f64, burst: usize, seed: u64) -> Vec<f64> {
    assert!(rate_hz > 0.0, "arrival rate must be positive");
    let burst = burst.max(1);
    let mut r = rng(seed);
    let mut t = 0.0f64;
    let mut v = Vec::with_capacity(n);
    while v.len() < n {
        t += -(1.0 - r.f64()).ln() / (rate_hz / burst as f64) * 1000.0;
        for _ in 0..burst {
            if v.len() < n {
                v.push(t);
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<TraceEvent> {
        (0..5)
            .map(|i| TraceEvent {
                at_ms: i as f64 * 12.5,
                session: i % 2,
                seed: 42,
                frame: i,
                width: 192,
                height: 160,
            })
            .collect()
    }

    #[test]
    fn trace_round_trips_through_jsonl() {
        let path = std::env::temp_dir()
            .join(format!("bingflow_trace_test_{}.jsonl", std::process::id()));
        let events = sample_events();
        save(&path, &events).unwrap();
        let back = load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back, events);
    }

    #[test]
    fn malformed_trace_lines_error_with_line_number() {
        let path = std::env::temp_dir()
            .join(format!("bingflow_trace_bad_{}.jsonl", std::process::id()));
        std::fs::write(&path, "{\"at_ms\": 1}\n").unwrap();
        let err = load(&path).unwrap_err().to_string();
        std::fs::remove_file(&path).ok();
        assert!(err.contains("line 1"), "got: {err}");
        assert!(err.contains("session"), "names the missing field: {err}");
    }

    #[test]
    fn poisson_offsets_are_monotone_at_the_requested_rate() {
        let v = arrival_offsets_poisson(2000, 100.0, 7);
        assert_eq!(v.len(), 2000);
        assert!(v.windows(2).all(|w| w[0] <= w[1]), "offsets must be sorted");
        let mean_gap = v.last().unwrap() / 2000.0;
        assert!((5.0..20.0).contains(&mean_gap), "mean gap {mean_gap} far from 10ms");
        assert_eq!(v, arrival_offsets_poisson(2000, 100.0, 7), "deterministic");
        assert_ne!(v, arrival_offsets_poisson(2000, 100.0, 8), "seed matters");
    }

    #[test]
    fn bursty_offsets_group_and_keep_the_mean_rate() {
        let burst = 8;
        let v = arrival_offsets_bursty(2000, 100.0, burst, 7);
        assert_eq!(v.len(), 2000);
        assert!(v.windows(2).all(|w| w[0] <= w[1]));
        // full groups share one timestamp
        for g in v.chunks(burst).filter(|g| g.len() == burst) {
            assert!(g.iter().all(|&t| t == g[0]), "burst not simultaneous");
        }
        let mean_gap = v.last().unwrap() / 2000.0;
        assert!((5.0..20.0).contains(&mean_gap), "mean gap {mean_gap} far from 10ms");
    }

    #[test]
    fn burst_of_one_is_plain_poisson() {
        assert_eq!(
            arrival_offsets_bursty(64, 50.0, 1, 3),
            arrival_offsets_poisson(64, 50.0, 3)
        );
    }
}
